"""Gradient compression (error feedback) + parallelism-variant smoke tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import CONFIGS, reduced
from repro.optim.compress import GradCompression, _quant_dequant


def test_quant_dequant_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    y = _quant_dequant(x)
    err = jnp.abs(y - x)
    assert float(err.max()) <= float(jnp.abs(x).max()) / 127 + 1e-6


def test_error_feedback_preserves_descent():
    """SGD on a quadratic with int8+EF grads must converge ~like exact SGD;
    naive quantization of tiny late-stage grads alone would stall."""
    A = jnp.diag(jnp.linspace(0.5, 3.0, 64))
    b = jnp.ones((64,))
    loss = lambda w: 0.5 * w @ A @ w - b @ w
    gc = GradCompression()
    params = {"w": jnp.zeros((64,))}
    gc_state = gc.init({"w": jnp.zeros((4096,))})  # force EF on
    gc_state = {"error": {"w": jnp.zeros((64,))}}
    w_exact = w_comp = jnp.zeros((64,))
    for _ in range(300):
        g_exact = jax.grad(loss)(w_exact)
        w_exact = w_exact - 0.1 * g_exact
        g = jax.grad(loss)(w_comp)
        gh, gc_state = gc.apply({"w": g}, gc_state)
        w_comp = w_comp - 0.1 * gh["w"]
    w_star = jnp.linalg.solve(A, b)
    assert float(jnp.linalg.norm(w_comp - w_star)) < 1e-2
    assert float(jnp.linalg.norm(w_comp - w_exact)) < 1e-2


def test_error_feedback_residual_carried():
    gc = GradCompression(min_size=1)
    st = gc.init({"w": jnp.zeros((512,))})
    g = {"w": jnp.full((512,), 1e-3)}
    gh, st = gc.apply(g, st)
    # whatever was rounded away must be in the error buffer
    np.testing.assert_allclose(
        np.asarray(gh["w"] + st["error"]["w"]), np.asarray(g["w"]),
        rtol=1e-6)


def test_wire_bytes_ratio():
    comp, raw = GradCompression.wire_bytes({"w": jnp.zeros((1 << 20,))})
    assert raw / comp > 3.8  # ~4x minus scale overhead


@pytest.mark.parametrize("flag", ["dp_over_model", "seq_shard_resid"])
def test_parallel_variant_flags_run_on_cpu(flag):
    """Hillclimb config flags must not change single-device semantics."""
    from repro.models import Model
    base = reduced(CONFIGS["gemma2-9b"])
    cfg = replace(base, **{flag: True})
    m0, m1 = Model(base), Model(cfg)
    p = m0.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              base.vocab_size)
    l0, _ = jax.jit(m0.loss)(p, {"tokens": toks})
    l1, _ = jax.jit(m1.loss)(p, {"tokens": toks})
    assert abs(float(l0) - float(l1)) < 1e-5
