"""ChunkIOExecutor: ordering, bounded in-flight window, error join
semantics (nothing may still be running when map_ordered raises — the
crash matrix's post-crash fsck depends on it), serial-mode equivalence,
and the pipelined CAS paths built on top of it."""
import threading
import time

import numpy as np
import pytest

from repro.core.cas import ChunkStore, chunk_digest, split_payload
from repro.core.chunk_exec import ChunkIOExecutor
from repro.core.errors import CorruptShardError
from repro.core.storage import Tier, TieredStore


def _store(tmp_path, name="fast"):
    return TieredStore(Tier(name, tmp_path / name))


# ---------------------------------------------------------------------------
# executor semantics
# ---------------------------------------------------------------------------

def test_map_ordered_preserves_item_order():
    with ChunkIOExecutor(4) as ex:
        out = ex.map_ordered(
            lambda i: (time.sleep(0.002 * (i % 3)), i)[1], range(40))
    assert out == list(range(40))


def test_map_ordered_bounds_inflight_window():
    active = 0
    peak = 0
    lock = threading.Lock()

    def fn(i):
        nonlocal active, peak
        with lock:
            active += 1
            peak = max(peak, active)
        time.sleep(0.005)
        with lock:
            active -= 1
        return i

    with ChunkIOExecutor(2) as ex:
        out = ex.map_ordered(fn, range(30), window=3)
    assert out == list(range(30))
    assert peak <= 3


def test_map_ordered_error_joins_all_inflight_work():
    """On failure nothing submitted may still be running after the raise —
    a straggler writing objects while the caller's abort/GC path runs
    would corrupt the crash matrix's invariants."""
    running = 0
    lock = threading.Lock()

    def fn(i):
        nonlocal running
        with lock:
            running += 1
        try:
            time.sleep(0.01)
            if i == 7:
                raise RuntimeError("boom")
            return i
        finally:
            with lock:
                running -= 1

    ex = ChunkIOExecutor(4)
    with pytest.raises(RuntimeError):
        ex.map_ordered(fn, range(50))
    assert running == 0
    ex.shutdown()


def test_on_result_runs_in_order_on_caller_thread():
    seen = []
    caller = threading.get_ident()

    def on_result(r):
        assert threading.get_ident() == caller   # heartbeat thread-affinity
        seen.append(r)

    with ChunkIOExecutor(4) as ex:
        ex.map_ordered(lambda i: i * i, range(10), on_result=on_result)
    assert seen == [i * i for i in range(10)]


def test_serial_mode_runs_inline_without_threads():
    ex = ChunkIOExecutor(1)
    assert ex.serial
    tid = threading.get_ident()
    out = ex.map_ordered(lambda i: (threading.get_ident(), i), range(5))
    assert all(t == tid for t, _ in out)
    assert ex._pool is None                      # no pool was ever created


# ---------------------------------------------------------------------------
# pipelined CAS paths
# ---------------------------------------------------------------------------

def test_pipelined_put_payload_matches_serial(tmp_path, rng):
    payload = rng.bytes(10_000)
    ser = ChunkStore(_store(tmp_path, "ser"), chunk_size=256, io_threads=1)
    par = ChunkStore(_store(tmp_path, "par"), chunk_size=256, io_threads=8)
    dser, nser = ser.put_payload(payload)
    dpar, npar = par.put_payload(payload)
    assert dser == dpar == [chunk_digest(c)
                            for c in split_payload(payload, 256)]
    assert nser == npar == len(payload)
    assert ser.read_payload(dser, len(payload)) == payload
    assert par.read_payload(dpar, len(payload)) == payload


def test_pipelined_put_heartbeats_per_chunk(tmp_path, rng):
    beats = []
    cs = ChunkStore(_store(tmp_path), chunk_size=128, io_threads=4)
    digests, _ = cs.put_payload(rng.bytes(128 * 9),
                                on_chunk=lambda: beats.append(1))
    assert len(beats) == len(digests) == 9


def test_concurrent_same_digest_put_writes_once(tmp_path):
    cs = ChunkStore(_store(tmp_path), chunk_size=128, io_threads=8)
    data = b"q" * 500
    d = chunk_digest(data)
    totals = []

    def put():
        totals.append(cs.put(d, data))

    ts = [threading.Thread(target=put) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # exactly one writer paid the IO; every racer deduped
    assert sorted(totals) == [0] * 7 + [500]
    assert cs.get(d) == data


def test_crc_fast_path_detects_and_recovers_corruption(tmp_path, rng):
    """The pipelined read skips per-chunk digest checks (the payload crc
    is the gate) — a corrupted primary must still be detected AND healed
    through the verified fallback + buddy replica."""
    import zlib
    from repro.core.cas import object_rel
    cs = ChunkStore(_store(tmp_path), chunk_size=256, replicas=2,
                    io_threads=4)
    payload = rng.bytes(1024)
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    digests, _ = cs.put_payload(payload)
    # corrupt one primary object in place (same length)
    victim = cs.store.fast.root / object_rel(digests[1])
    victim.write_bytes(b"\xff" * 256)
    got = cs.read_payload(digests, len(payload), crc32=crc)
    assert got == payload                        # healed via .r1 replica
    # with NO replica, the verified fallback must raise, not return junk
    cs1 = ChunkStore(_store(tmp_path, "nr"), chunk_size=256, io_threads=4)
    digests, _ = cs1.put_payload(payload)
    (cs1.store.fast.root / object_rel(digests[0])).write_bytes(b"\xff" * 256)
    with pytest.raises(CorruptShardError):
        cs1.read_payload(digests, len(payload), crc32=crc)


def test_read_payload_crc_checked_in_serial_mode_too(tmp_path, rng):
    cs = ChunkStore(_store(tmp_path), chunk_size=256, io_threads=1)
    payload = rng.bytes(777)
    digests, _ = cs.put_payload(payload)
    with pytest.raises(CorruptShardError):
        cs.read_payload(digests, len(payload), crc32=0xDEADBEEF)


def test_pipelined_read_prefetch_matches_payload(tmp_path, rng):
    # many small chunks → the bounded prefetch window actually cycles
    cs = ChunkStore(_store(tmp_path), chunk_size=64, io_threads=4)
    payload = rng.bytes(64 * 200 + 13)
    digests, _ = cs.put_payload(payload)
    assert cs.read_payload(digests, len(payload)) == payload


def test_cdc_chunker_through_chunkstore(tmp_path, rng):
    from repro.core.cdc import GearChunker
    ck = GearChunker(512)
    cs = ChunkStore(_store(tmp_path), chunk_size=512, io_threads=4)
    payload = rng.bytes(40_000)
    digests, new = cs.put_payload(payload, chunker=ck.chunk)
    assert digests == [chunk_digest(c) for c in ck.chunk(payload)]
    assert new == len(payload)
    assert cs.read_payload(digests, len(payload)) == payload
    # dedup on re-put
    _, new2 = cs.put_payload(payload, chunker=ck.chunk)
    assert new2 == 0
