"""Checkpoint engine: roundtrips, codecs, 2PC abort, crash consistency,
retention, namespace, registry validation, buddy redundancy."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import atomic, cas
from repro.core import codec as codec_mod
from repro.core.atomic import CrashInjector, CrashPoint
from conftest import make_ckpt_policy
from repro.core.checkpoint import FORMAT_VERSION, CheckpointManager
from repro.core.elastic import ShardRange, assemble, plan_reads
from repro.core.errors import (AbortedError, CodecUnavailableError,
                               CorruptShardError, MissingShardError,
                               NamespaceError, NoCheckpointError,
                               RegistryMismatchError, SpaceError)
from repro.core.namespace import check_leaf_name
from repro.core.registry import validate_against
from repro.core.storage import Tier, TieredStore

KEY = jax.random.PRNGKey(0)

requires_zstd = pytest.mark.skipif(not codec_mod.HAVE_ZSTD,
                                   reason="zstandard not installed "
                                          "(compress extra)")


def _store(tmp_path, **kw):
    return TieredStore(Tier("fast", tmp_path / "fast", **kw))


def _state(dtype=jnp.float32):
    return {
        "params": {
            "w": jax.random.normal(KEY, (16, 8), dtype),
            "stage_0": {"b0": {"wg": jax.random.normal(KEY, (2, 8, 4))}},
        },
        "opt": {"count": jnp.zeros((), jnp.int32)},
        "step": jnp.asarray(5, jnp.int32),
        "rng": jax.random.key_data(KEY),
    }


def _abstract(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


@pytest.mark.parametrize("codec", [
    "raw", pytest.param("zstd", marks=requires_zstd)])
def test_roundtrip_exact(tmp_path, codec):
    mgr = CheckpointManager(_store(tmp_path), codec=codec, n_writers=3)
    state = _state()
    mgr.save(state, 5)
    restored, extra = mgr.restore(_abstract(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_params_codec_bounded_error(tmp_path):
    # codec=None resolves to the best available lossless codec, so this
    # runs with or without the zstandard package (int8 adapts likewise)
    mgr = CheckpointManager(_store(tmp_path), codec=None,
                            params_codec="int8")
    state = _state()
    mgr.save(state, 1)
    restored, _ = mgr.restore(_abstract(state))
    w0 = np.asarray(state["params"]["w"])
    w1 = np.asarray(restored["params"]["w"])
    assert np.max(np.abs(w0 - w1)) <= np.abs(w0).max() / 127 + 1e-6
    # non-params leaves stay exact
    np.testing.assert_array_equal(np.asarray(state["rng"]),
                                  np.asarray(restored["rng"]))


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(_store(tmp_path), retain=2)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(state, s)
    assert mgr.latest_step() == 4
    steps = atomic.list_committed_steps(mgr.store.root)
    assert steps == [3, 4]  # retention GC'd 1, 2


def test_extra_payload_roundtrip(tmp_path):
    mgr = CheckpointManager(_store(tmp_path))
    mgr.save(_state(), 9, extra={"data_state": {"seed": 3, "step": 9,
                                                "source_counts": [1, 2]}})
    _, extra = mgr.restore(_abstract(_state()))
    assert extra["data_state"]["step"] == 9


def test_abort_on_injected_rank_failure_preserves_previous(tmp_path):
    """With retries disabled, a dead writer aborts the round and the
    previous checkpoint stays the valid latest."""
    mgr = CheckpointManager(_store(tmp_path), n_writers=3, max_retries=0)
    state = _state()
    mgr.save(state, 1)
    mgr.coordinator.inject_failure(1)
    with pytest.raises(AbortedError):
        mgr.save(state, 2)
    # previous checkpoint intact, no staging litter
    assert mgr.latest_step() == 1
    assert atomic.list_committed_steps(mgr.store.root) == [1]
    assert not list(mgr.store.root.glob("*.tmp-*"))
    mgr.restore(_abstract(state))  # still restorable


def test_rank_failure_retry_redistributes_and_commits(tmp_path):
    """Node-failure recovery: the dead rank is excluded, its shards are
    redistributed to survivors, and the checkpoint COMMITS (the paper's
    reliability goal, beyond abort-only)."""
    mgr = CheckpointManager(_store(tmp_path), n_writers=4, max_retries=1)
    state = _state()
    mgr.coordinator.inject_failure(2)  # persistent node death
    rep = mgr.save(state, 7)
    assert rep["step"] == 7
    assert mgr.coordinator.metrics["aborts"] == 1
    assert mgr.coordinator.metrics["commits"] == 1
    restored, _ = mgr.restore(_abstract(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_all_ranks_dead_still_aborts(tmp_path):
    mgr = CheckpointManager(_store(tmp_path), n_writers=2, max_retries=3)
    for r in range(2):
        mgr.coordinator.inject_failure(r)
    with pytest.raises(AbortedError):
        mgr.save(_state(), 1)
    assert mgr.latest_step() is None


@pytest.mark.parametrize("point", ["rank0_before_write", "before_manifest",
                                   "before_commit_rename",
                                   "after_commit_rename", "after_tmp_write"])
def test_crash_consistency(tmp_path, point):
    """A crash at any protocol step leaves a valid latest checkpoint."""
    mgr = CheckpointManager(_store(tmp_path), n_writers=2)
    state = _state()
    mgr.save(state, 1)
    try:
        mgr.save(state, 2, crash=CrashInjector(point))
    except (CrashPoint, AbortedError):
        pass
    atomic.gc_staging(mgr.store.root)
    mgr2 = CheckpointManager(_store(tmp_path), n_writers=2)
    latest = mgr2.latest_step()
    assert latest in (1, 2)
    restored, _ = mgr2.restore(_abstract(state), step=latest)
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


def test_blocking_save_failure_still_drains_counters(tmp_path):
    """A blocking save dying AFTER phase 1 (manifest write, rename, LATEST
    — here an injected crash) must still drain the P4 counters exactly
    once on the SAME manager, or every later save()/wait() stalls for
    save_timeout_s in counters.wait()."""
    mgr = CheckpointManager(_store(tmp_path), codec="raw", n_writers=2,
                            save_timeout_s=5.0)
    state = _state()
    with pytest.raises(CrashPoint):
        mgr.save(state, 1, crash=CrashInjector("before_latest_write"))
    assert mgr.counters.drained()
    atomic.gc_staging(mgr.store.root)
    rep = mgr.save(state, 2)            # no timeout stall
    assert rep["step"] == 2


def test_buddy_replica_restores_after_primary_loss(tmp_path):
    mgr = CheckpointManager(_store(tmp_path), replicas=2, n_writers=2)
    state = _state()
    mgr.save(state, 3)
    # destroy one primary shard file
    prim = next(p for p in mgr.store.root.rglob("shard-*.bin")
                if not p.name.endswith(".r1"))
    prim.unlink()
    restored, _ = mgr.restore(_abstract(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corrupt_primary_falls_back_to_replica(tmp_path):
    mgr = CheckpointManager(_store(tmp_path), replicas=2, n_writers=2)
    state = _state()
    mgr.save(state, 3)
    prim = next(p for p in mgr.store.root.rglob("shard-*.bin")
                if not p.name.endswith(".r1"))
    data = bytearray(prim.read_bytes())
    data[-1] ^= 0xFF  # flip payload byte -> crc mismatch
    prim.write_bytes(bytes(data))
    restored, _ = mgr.restore(_abstract(state))
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


def test_missing_shard_without_replica_raises(tmp_path):
    mgr = CheckpointManager(_store(tmp_path), replicas=1, n_writers=2)
    state = _state()
    mgr.save(state, 3)
    next(iter(mgr.store.root.rglob("shard-00000.bin"))).unlink()
    with pytest.raises((MissingShardError, CorruptShardError)):
        mgr.restore(_abstract(state))


def test_no_checkpoint_error(tmp_path):
    mgr = CheckpointManager(_store(tmp_path))
    with pytest.raises(NoCheckpointError):
        mgr.restore(_abstract(_state()))


def test_registry_validation_catches_shape_drift(tmp_path):
    mgr = CheckpointManager(_store(tmp_path))
    state = _state()
    mgr.save(state, 1)
    manifest = mgr.load_manifest(1)
    bad = dict(manifest["leaves"])
    bad["params/w"] = dict(bad["params/w"], shape=[4, 4])
    with pytest.raises(RegistryMismatchError):
        validate_against(state, bad)


def test_namespace_collision_rejected():
    with pytest.raises(NamespaceError):
        check_leaf_name("_META/evil")
    with pytest.raises(NamespaceError):
        check_leaf_name("LATEST")
    assert check_leaf_name("params/stage_0/b0/wg")


def test_space_preflight(tmp_path):
    tier = Tier("tiny", tmp_path / "t", capacity_bytes=100)
    with pytest.raises(SpaceError):
        tier.preflight(1000)


@pytest.mark.skipif(codec_mod.HAVE_ZSTD, reason="zstandard installed")
def test_zstd_codec_unavailable_raises():
    """Without the optional `zstandard` package, asking for the zstd codec
    is a clear coded error, not an ImportError at module import."""
    with pytest.raises(CodecUnavailableError):
        codec_mod.encode(np.zeros(4, np.float32), "zstd")
    assert codec_mod.default_codec() == "raw"
    assert not codec_mod.available("zstd")


def _rewrite_manifest_as_v2(root: Path, step: int):
    """Strip every post-v2 field so the on-disk checkpoint is exactly what
    the v2 writer produced."""
    mpath = root / f"step_{step:08d}" / atomic.MANIFEST
    m = json.loads(mpath.read_text())
    assert m["format"] == FORMAT_VERSION
    m["format"] = 2
    m.pop("mode", None)
    m.pop("chunk_size", None)
    m.pop("chunking", None)
    m.pop("chunk_bounds", None)
    mpath.write_text(json.dumps(m))


def _rewrite_manifest_as_v3(root: Path, step: int):
    """Strip the v4+/v5-only chunking-scheme fields — exactly what the v3
    (PR-1 incremental) writer produced."""
    mpath = root / f"step_{step:08d}" / atomic.MANIFEST
    m = json.loads(mpath.read_text())
    assert m["format"] == FORMAT_VERSION
    m["format"] = 3
    m.pop("chunking", None)
    m.pop("chunk_bounds", None)
    for rec in m["leaves"].values():
        for s in rec["shards"]:
            s.pop("chunking", None)
            s.pop("chunk_lens", None)
    mpath.write_text(json.dumps(m))


def test_v2_manifest_restores_under_v4_reader(tmp_path):
    """Backward compatibility: a checkpoint written by the v2 (full-mode)
    writer — inline shard files, no mode/chunk_size keys — restores under
    the v4 code path."""
    mgr = CheckpointManager(_store(tmp_path), codec="raw", n_writers=3)
    state = _state()
    mgr.save(state, 4)
    _rewrite_manifest_as_v2(mgr.store.root, 4)
    mgr2 = CheckpointManager(_store(tmp_path))
    assert mgr2.load_manifest(4)["format"] == 2
    restored, _ = mgr2.restore(_abstract(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_v3_chunked_manifest_restores_and_gcs_under_v4_reader(tmp_path):
    """A v3 incremental checkpoint (chunked records without a chunking
    scheme field) must stay bit-exact restorable AND keep participating in
    the CAS mark set — mixed-history GC must not sweep its chunks."""
    mgr = CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
        codec="raw", n_writers=2, mode="incremental", chunk_size=512))
    state = _state()
    mgr.save(state, 1)
    _rewrite_manifest_as_v3(mgr.store.root, 1)
    mgr2 = CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
        codec="raw", n_writers=2, mode="incremental", chunk_size=512))
    assert mgr2.load_manifest(1)["format"] == 3
    restored, _ = mgr2.restore(_abstract(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a later v4 save + gc marks the v3 step's chunks as live
    state2 = _state()
    state2["params"]["w"] = state2["params"]["w"] + 2.0
    mgr2.save(state2, 2)
    mgr2.gc()
    assert mgr2.chunks.fsck(mgr2._live_chunk_refs())["ok"]
    restored, _ = mgr2.restore(_abstract(state), step=1)
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


def test_mixed_chunking_history_restores_and_gcs(tmp_path):
    """fixed- and cdc-chunked steps interleaved in one store: both restore
    bit-exact, GC keeps both alive, and a fresh save still commits."""
    def mk(chunking):
        return CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
            codec="raw", n_writers=2, mode="incremental", chunk_size=512,
            chunking=chunking, retain=4))

    s1, s2 = _state(), _state()
    s2["params"]["w"] = s2["params"]["w"] + 1.0
    mk("fixed").save(s1, 1)
    mk("cdc").save(s2, 2)
    mgr = mk("fixed")
    for step, expect in ((1, s1), (2, s2)):
        restored, _ = mgr.restore(_abstract(expect), step=step)
        for a, b in zip(jax.tree.leaves(expect), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.gc()
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
    m1, m2 = mgr.load_manifest(1), mgr.load_manifest(2)
    assert m1["chunking"] == "fixed" and m2["chunking"] == "cdc"
    s3 = _state()
    mgr.save(s3, 3)
    restored, _ = mgr.restore(_abstract(s3), step=3)
    np.testing.assert_array_equal(np.asarray(s3["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


def test_parallel_restore_matches_serial(tmp_path):
    """Leaf fan-out + chunk prefetch must be bit-identical to the serial
    engine, in both save modes."""
    for mode in ("full", "incremental"):
        root = tmp_path / mode
        state = _state()
        CheckpointManager(TieredStore(Tier("fast", root)),
                          policy=make_ckpt_policy(
                              codec="raw", n_writers=3, mode=mode,
                              chunk_size=512)).save(state, 1)
        serial, _ = CheckpointManager(
            TieredStore(Tier("fast", root)), io_threads=1).restore(
            _abstract(state))
        parallel, _ = CheckpointManager(
            TieredStore(Tier("fast", root)), io_threads=8).restore(
            _abstract(state))
        for a, b in zip(jax.tree.leaves(serial), jax.tree.leaves(parallel)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_read_cache_accounting_and_lru(tmp_path):
    """Regression: (1) re-inserting a cached key must not double-count its
    bytes (the leaked total eventually exceeded the limit forever and
    thrashed the cache to one entry); (2) a cache hit must refresh recency
    so eviction is LRU, not FIFO."""
    mgr = CheckpointManager(_store(tmp_path), codec="raw")
    a = np.zeros(100, np.uint8)
    mgr.read_cache_limit = 350          # fits three 100-byte entries
    mgr._cache_put("a", a)
    mgr._cache_put("a", a)              # re-insert: no double count
    assert mgr._read_cache_bytes == 100
    mgr._cache_put("b", np.zeros(100, np.uint8))
    mgr._cache_put("c", np.zeros(100, np.uint8))
    assert mgr._cache_get("a") is not None      # touch: "a" becomes MRU
    mgr._cache_put("d", np.zeros(100, np.uint8))  # 400 > 350 → evict LRU
    assert "b" not in mgr._read_cache           # LRU was "b", not "a"
    assert "a" in mgr._read_cache
    assert mgr._read_cache_bytes == 300
    # steady state under churn: never collapses below the byte budget
    for i in range(20):
        mgr._cache_put(f"k{i}", np.zeros(100, np.uint8))
    assert len(mgr._read_cache) == 3
    assert mgr._read_cache_bytes == 300


def test_unsupported_manifest_format_rejected(tmp_path):
    mgr = CheckpointManager(_store(tmp_path), codec="raw")
    mgr.save(_state(), 1)
    mpath = mgr.store.root / "step_00000001" / atomic.MANIFEST
    m = json.loads(mpath.read_text())
    m["format"] = 99
    mpath.write_text(json.dumps(m))
    from repro.core.errors import CkptError
    with pytest.raises(CkptError):
        CheckpointManager(_store(tmp_path)).load_manifest(1)


def _split_rows(mgr, parts: int):
    """Make the manager snapshot every ≥`parts`-row leaf as `parts` row
    shards — an N-'device' data-parallel topology without N real devices."""
    orig = mgr._snapshot

    def snap(state):
        items = []
        for name, rng, arr in orig(state):
            if arr.ndim and arr.shape[0] >= parts:
                cuts = np.linspace(0, arr.shape[0], parts + 1, dtype=int)
                for a, b in zip(cuts[:-1], cuts[1:]):
                    start = (int(a),) + (0,) * (arr.ndim - 1)
                    stop = (int(b),) + tuple(arr.shape[1:])
                    items.append((name, ShardRange(start, stop),
                                  np.ascontiguousarray(arr[a:b])))
            else:
                items.append((name, rng, arr))
        return items

    mgr._snapshot = snap
    return mgr


def test_incremental_restore_across_topology_change(tmp_path):
    """Save incrementally on 8 'devices' (8 row-shards per large leaf),
    then restore on a 4-'device' topology: plan_reads must cover each new
    quarter-range from the saved chunked eighth-ranges."""
    mgr = _split_rows(CheckpointManager(_store(tmp_path), codec="raw",
                                        n_writers=4, mode="incremental",
                                        chunk_size=256), parts=8)
    state = _state()
    mgr.save(state, 1)
    manifest = mgr.load_manifest(1)
    w_rec = manifest["leaves"]["params/w"]
    assert len(w_rec["shards"]) == 8
    assert all("chunks" in s for s in w_rec["shards"])

    # full single-device restore (8 → 1)
    mgr1 = CheckpointManager(_store(tmp_path))
    restored, _ = mgr1.restore(_abstract(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # 8 → 4: each of the 4 'devices' asks for a quarter row-range and
    # assembles it from the saved chunked eighths via plan_reads
    w = np.asarray(state["params"]["w"])
    available = [(ShardRange(tuple(s["start"]), tuple(s["stop"])), s)
                 for s in w_rec["shards"]]
    rows = w.shape[0]
    cuts = np.linspace(0, rows, 5, dtype=int)
    for a, b in zip(cuts[:-1], cuts[1:]):
        target = ShardRange((int(a), 0), (int(b), w.shape[1]))
        picks = plan_reads(target, available)
        pieces = [(rng, mgr1._read_shard("step_00000001", s))
                  for rng, s in picks]
        got = assemble(target, pieces, w.dtype)
        np.testing.assert_array_equal(got, w[a:b])


@pytest.mark.parametrize("mode", ["full", "incremental"])
def test_buddy_replica_chunk_loss_recovery(tmp_path, mode):
    """replicas=2 survives losing any one primary object/file."""
    mgr = CheckpointManager(_store(tmp_path), codec="raw", replicas=2,
                            n_writers=2, mode=mode, chunk_size=512)
    state = _state()
    mgr.save(state, 3)
    if mode == "incremental":
        prim = next(p for p in mgr.store.root.rglob("*.obj"))
        prim.unlink()
    else:
        prim = next(p for p in mgr.store.root.rglob("shard-*.bin")
                    if not p.name.endswith(".r1"))
        prim.unlink()
    restored, _ = mgr.restore(_abstract(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manifest_is_single_handle(tmp_path):
    """P7 (srun arg-limit lesson): restore needs ONLY the manifest path —
    shard discovery never passes file lists around."""
    mgr = CheckpointManager(_store(tmp_path), n_writers=4)
    state = _state()
    mgr.save(state, 1)
    m = mgr.load_manifest(1)
    files = [s["file"] for rec in m["leaves"].values()
             for s in rec["shards"]]
    assert len(files) == len(jax.tree.leaves(state))
    for f in files:
        assert (mgr.store.root / "step_00000001" / f).exists()
