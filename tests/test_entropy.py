"""Plane entropy coding (byteplane-rle / byteplane-rans) — oracle
round-trip fuzz, the per-block raw-escape framing, three-backend parity
(numpy oracle / jnp / Pallas-interpret byte-identical), and the
chunk-slice identity that lets the save path slice per-chunk encodings
out of ONE whole-payload device encoding.

The encoded stream is the dedup keyspace when a chunk-encoded codec is
active — digests, crcs and chunk_lens all describe ENCODED bytes — so a
backend that drifts by one byte re-writes history. Everything here pins
bit-exactness against the numpy oracle in ``core.codec``.
"""
import numpy as np
import pytest

import jax.numpy as jnp
from repro.core import codec as codec_mod
from repro.core.cdc import GearChunker
from repro.core.codec import ENTROPY_BLOCK
from repro.kernels.ckpt_codec import entropy as ent

CODECS = ["byteplane-rle", "byteplane-rans"]

# empty, odd, unaligned, sub-block, exactly-one-block, ragged multi-block
SIZES = [0, 1, 3, 255, 256, 4095, 4096, 4097, 8193, 65536, 65549, 200_003]
ITEMSIZES = [1, 2, 4, 8]


def _payload(n, kind, seed=0):
    """Payload families spanning the escape decision space."""
    rng = np.random.default_rng(seed)
    if kind == "random":            # incompressible → raw escapes
        return rng.integers(0, 256, n, dtype=np.uint8)
    if kind == "zeros":             # maximal runs → RLE wins
        return np.zeros(n, dtype=np.uint8)
    if kind == "runs":              # mixed run lengths (crosses the 255 cap)
        reps = rng.integers(1, 700, size=max(n // 100, 1))
        vals = rng.integers(0, 256, size=reps.size, dtype=np.uint8)
        return np.repeat(vals, reps)[:n].copy() if n else \
            np.zeros(0, dtype=np.uint8)
    if kind == "skewed":            # few symbols, no long runs → rANS wins
        return rng.choice(
            np.arange(8, dtype=np.uint8), size=n,
            p=np.array([.55, .2, .1, .06, .04, .03, .01, .01]))
    if kind == "planes":            # realistic: byteplane'd small floats
        f = (rng.standard_normal(max(n // 4, 1)) * 0.02).astype(np.float32)
        u8 = codec_mod.contig_u8(f)
        t = codec_mod.byteplane_forward(u8, 4)
        return np.resize(t, n).copy() if n else np.zeros(0, dtype=np.uint8)
    raise AssertionError(kind)


KINDS = ["random", "zeros", "runs", "skewed", "planes"]


# ---------------------------------------------------------------------------
# the numpy oracle — round trip, determinism, framing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n", SIZES)
def test_oracle_stream_round_trip(n, kind, codec):
    u8 = _payload(n, kind, seed=n + len(kind))
    enc, _ = codec_mod.plane_stream_encode(u8, codec)
    back = codec_mod.plane_stream_decode(enc, n, codec)
    np.testing.assert_array_equal(back, u8)
    # determinism: the stream is the dedup keyspace
    enc2, _ = codec_mod.plane_stream_encode(u8.copy(), codec)
    np.testing.assert_array_equal(enc, enc2)


@pytest.mark.parametrize("codec", CODECS)
def test_block_framing_and_escape_semantics(codec):
    # one incompressible block, one all-zero block, one skewed block:
    # the per-block choice must be raw / rle / (rle|rans) respectively,
    # and every block body must be strictly smaller than raw unless raw
    u8 = np.concatenate([_payload(ENTROPY_BLOCK, "random", seed=1),
                         _payload(ENTROPY_BLOCK, "zeros"),
                         _payload(ENTROPY_BLOCK, "skewed", seed=2)])
    enc, _ = codec_mod.plane_stream_encode(u8, codec)
    stats = list(codec_mod.entropy_block_stats(enc, len(u8)))
    assert len(stats) == 3
    flags = [s[2] for s in stats]
    assert flags[0] == 0, "incompressible block must escape to raw"
    assert flags[1] != 0, "all-zero block must compress"
    if codec == "byteplane-rans":
        assert 2 in flags, "skewed block should pick rANS"
    for _off, blen, flag, enc_len in stats:
        if flag == 0:
            assert enc_len == blen
        else:
            assert enc_len < blen       # strictly-smaller-wins rule
    # stream is exactly the sum of header+body framings
    assert len(enc) == sum(3 + s[3] for s in stats)


def test_raw_escape_bounds_expansion():
    # worst case (pure noise): overhead is exactly 3 bytes per block
    u8 = _payload(1 << 20, "random", seed=9)
    for codec in CODECS:
        enc, _ = codec_mod.plane_stream_encode(u8, codec)
        nb = -(-len(u8) // ENTROPY_BLOCK)
        assert len(enc) <= len(u8) + 3 * nb


def test_rle_run_cap_crosses_255():
    # a single 4096-byte run must emit ceil(4096/255) pairs, not overflow
    u8 = np.full(ENTROPY_BLOCK, 7, dtype=np.uint8)
    enc, _ = codec_mod.plane_stream_encode(u8, "byteplane-rle")
    (_, _, flag, enc_len), = codec_mod.entropy_block_stats(enc, len(u8))
    assert flag == 1 and enc_len == 2 * (-(-ENTROPY_BLOCK // 255))
    np.testing.assert_array_equal(
        codec_mod.plane_stream_decode(enc, len(u8), "byteplane-rle"), u8)


@pytest.mark.parametrize("codec", CODECS)
def test_decode_rejects_corrupt_framing(codec):
    u8 = _payload(8192, "skewed", seed=3)
    enc = codec_mod.plane_stream_encode(u8, codec)[0].copy()
    enc[0] = 9                          # invalid flag byte
    with pytest.raises(ValueError):
        codec_mod.plane_stream_decode(enc, len(u8), codec)
    with pytest.raises(ValueError):     # truncated stream
        codec_mod.plane_stream_decode(enc[:-5], len(u8), codec)


# ---------------------------------------------------------------------------
# device backends — byte-identical to the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n", [0, 1, 255, 4095, 4096, 4097, 65549])
def test_jnp_backend_matches_oracle(n, kind, codec):
    u8 = _payload(n, kind, seed=n * 3 + 1)
    ref, _ = codec_mod.plane_stream_encode(u8, codec)
    stream, block_lens = ent.encode_stream(u8, codec, backend="jnp")
    np.testing.assert_array_equal(stream, ref)
    # block_lens must be the framing walk of the stream
    stats = list(codec_mod.entropy_block_stats(ref, n))
    np.testing.assert_array_equal(block_lens,
                                  np.array([3 + s[3] for s in stats],
                                           dtype=np.int64))


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("kind", ["random", "runs", "skewed"])
@pytest.mark.parametrize("n", [0, 4097, 65549])
def test_pallas_backend_matches_oracle(n, kind, codec):
    u8 = _payload(n, kind, seed=n + 5)
    ref, _ = codec_mod.plane_stream_encode(u8, codec)
    stream, _ = ent.encode_stream(u8, codec, backend="pallas",
                                  interpret=True)
    np.testing.assert_array_equal(stream, ref)


@pytest.mark.parametrize("k", ITEMSIZES)
def test_backends_on_byteplaned_itemsizes(k):
    # the production input: transformed streams of every plane width,
    # ragged tails included
    rng = np.random.default_rng(k)
    raw = rng.integers(0, 256, 13 * ENTROPY_BLOCK + 3, dtype=np.uint8)
    raw[: 6 * ENTROPY_BLOCK] = (raw[: 6 * ENTROPY_BLOCK] % 5) * 17
    t = codec_mod.byteplane_forward(raw, k)
    for codec in CODECS:
        ref, _ = codec_mod.plane_stream_encode(t, codec)
        got, _ = ent.encode_stream(t, codec, backend="jnp")
        np.testing.assert_array_equal(got, ref)
        np.testing.assert_array_equal(
            codec_mod.plane_stream_decode(ref, t.size, codec), t)


# ---------------------------------------------------------------------------
# chunk-slice identity — the property the save path is built on
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODECS)
def test_chunk_encodings_are_slices_of_the_stream(codec):
    # cut the transformed stream on ENTROPY_BLOCK-aligned CDC cuts: the
    # concatenation of per-chunk encodings must equal the whole-stream
    # encoding, so the fused device dispatch can encode ONCE and the host
    # can slice per-chunk objects out of it
    ck = GearChunker(16384, scan_backend="numpy")
    t = _payload(300_001, "planes", seed=11)
    cuts = ck.align_cuts(ck.cut_points(t), len(t), ENTROPY_BLOCK)
    assert len(cuts) > 3 and cuts[-1] == len(t)
    whole, _ = codec_mod.plane_stream_encode(t, codec)
    parts, pos = [], 0
    for c in cuts:
        parts.append(codec_mod.plane_encode_chunk(t[pos:c], codec))
        pos = c
    assert b"".join(parts) == whole.tobytes()
    # and the ranged decode reassembles the exact transformed stream
    enc_lens = [len(p) for p in parts]
    raw_lens = np.diff([0] + cuts).tolist()
    back = codec_mod.plane_decode_chunks(whole, enc_lens, raw_lens, codec)
    np.testing.assert_array_equal(back, t)


def test_align_cuts_properties():
    cuts = [1, 4096, 5000, 12289, 20000]
    out = GearChunker.align_cuts(cuts, 20000, ENTROPY_BLOCK)
    assert out == [4096, 8192, 16384, 20000]    # dedup'd, final == n
    assert all(c % ENTROPY_BLOCK == 0 or c == 20000 for c in out)
    assert GearChunker.align_cuts([], 0, ENTROPY_BLOCK) == []


# ---------------------------------------------------------------------------
# codec surface — encode()/decode() entries and policy names
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("dtype", ["float32", "float16", "int8"])
def test_codec_entry_round_trip(dtype, codec):
    rng = np.random.default_rng(4)
    arr = rng.integers(-127, 128, 4099, dtype=np.int8) if dtype == "int8" \
        else (rng.standard_normal(4099) * 0.02).astype(dtype)
    payload, meta = codec_mod.encode(arr, codec)
    assert meta == {"bp": arr.dtype.itemsize}
    back = codec_mod.decode(payload, codec, arr.shape, dtype, meta)
    np.testing.assert_array_equal(back, arr)


@pytest.mark.parametrize("codec", CODECS)
def test_chunk_encoded_availability_and_classes(codec):
    assert codec in codec_mod.CODECS
    assert codec in codec_mod.PRECONDITIONED
    assert codec in codec_mod.CHUNK_ENCODED
    assert codec_mod.available(codec)       # no optional deps
    assert not codec_mod.lossy(codec)


def test_compresses_real_float_payloads():
    # the whole point: byteplane'd small-magnitude floats shrink without
    # zstd — the sign/exponent plane concentrates on a few symbols, which
    # rANS exploits; RLE needs literal runs, so it must only stay within
    # the 3-bytes-per-block escape overhead on this payload
    rng = np.random.default_rng(12)
    arr = (rng.standard_normal(1 << 16) * 0.02).astype(np.float32)
    rans, _ = codec_mod.encode(arr, "byteplane-rans")
    assert len(rans) < arr.nbytes * 0.90
    rle, _ = codec_mod.encode(arr, "byteplane-rle")
    nb = -(-arr.nbytes // ENTROPY_BLOCK)
    assert len(rle) <= arr.nbytes + 3 * nb
