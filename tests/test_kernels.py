"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ckpt_codec import (dequantize_blocks, quantize_blocks,
                                      quantize_reference)
from repro.kernels.flash_attention import attention_reference, flash_attention
from repro.kernels.rmsnorm import rmsnorm_fused, rmsnorm_reference

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("B,Sq,Sk,H,K,D", [
    (1, 64, 64, 4, 4, 32),
    (2, 128, 128, 4, 1, 16),    # MQA
    (1, 96, 96, 8, 2, 64),      # GQA 4:1
    (1, 60, 60, 2, 2, 16),      # non-multiple-of-block seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Sk, H, K, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, D), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    ref = attention_reference(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=True).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("window,softcap,causal", [
    (16, 0.0, True), (0, 30.0, True), (24, 0.0, False), (0, 0.0, False),
])
def test_flash_attention_masks(window, softcap, causal):
    B, S, H, K, D = 1, 80, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=16, block_k=16,
                          interpret=True)
    ref = attention_reference(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=causal,
                              window=window,
                              softcap=softcap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("shape", [(16, 64), (37, 96), (3, 5, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = (jax.random.normal(KEY, shape[-1:]) * 0.1).astype(dtype)
    out = rmsnorm_fused(x, s, block_rows=8, interpret=True)
    ref = rmsnorm_reference(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("n", [256, 1000, 4096, 65537])
def test_codec_kernel_matches_host_codec(n):
    x = np.random.default_rng(n).standard_normal(n).astype(np.float32)
    q, s = quantize_blocks(jnp.asarray(x), interpret=True)
    qr, sr = quantize_reference(x)
    np.testing.assert_array_equal(np.asarray(q)[:qr.size], qr)
    np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-7)
    d = dequantize_blocks(q, s, n=n, interpret=True)
    bound = np.abs(x).reshape(-1, 1)  # per-block bound below
    err = np.abs(np.asarray(d) - x)
    # quantization error bound: scale/2 per block
    scales = np.repeat(sr, 256)[:n]
    assert np.all(err <= scales * 0.5 + 1e-7)
