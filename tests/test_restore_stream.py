"""Streaming restore-behind: first-use ordering, the frontier contract,
the completion gate's bit-exactness, cold-remote restores, and the
ReadCache single-oversized-entry pin."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_ckpt_policy
from repro.core.checkpoint import CheckpointManager
from repro.core.elastic import (FIRST_USE_DEFAULT, FIRST_USE_TAIL,
                                first_use_order, leaf_first_use_class)
from repro.core.restore_path import ReadCache
from repro.core.storage import RemoteTier, Tier, TieredStore, mirror_to_tier

KEY = jax.random.PRNGKey(7)


def _state(layers=4):
    params = {"embed": jax.random.normal(KEY, (32, 8)),
              "lm_head": jax.random.normal(KEY, (8, 32))}
    for k in range(layers):
        params[f"stage_0/b{k}/w"] = jax.random.normal(
            jax.random.fold_in(KEY, k), (16, 8))
    return {"params": params,
            "opt": {"count": jnp.zeros((), jnp.int32)},
            "step": jnp.asarray(3, jnp.int32)}


def _abstract(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# first-use ordering
# ---------------------------------------------------------------------------

def test_leaf_first_use_classes():
    assert leaf_first_use_class("params/embed") == 0
    assert leaf_first_use_class("step") == 0
    assert leaf_first_use_class("opt/count") == 0
    b0 = leaf_first_use_class("params/stage_0/b0/w")
    b1 = leaf_first_use_class("params/stage_0/b1/w")
    assert 0 < b0 < b1 < FIRST_USE_DEFAULT
    assert leaf_first_use_class("opt/f/something/v") == FIRST_USE_DEFAULT
    assert leaf_first_use_class("params/lm_head") == FIRST_USE_TAIL
    assert leaf_first_use_class("params/final_norm/scale") == FIRST_USE_TAIL
    # blocks order before ANY unclassified or tail leaf
    assert b1 < leaf_first_use_class("params/final_norm/scale")


def test_first_use_order_sorts_like_a_forward_pass():
    names = ["params/lm_head", "params/stage_0/b1/w", "params/embed",
             "params/stage_0/b0/w", "opt/f/misc", "step"]
    assert [names[i] for i in first_use_order(names)] == [
        "params/embed", "step", "params/stage_0/b0/w",
        "params/stage_0/b1/w", "opt/f/misc", "params/lm_head"]
    # a model-supplied priority overrides the heuristic entirely
    rev = first_use_order(names, priority=lambda n: -names.index(n))
    assert [names[i] for i in rev] == list(reversed(names))


def test_first_use_schedule_frontier(tmp_path):
    state = _state()
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)),
                            policy=make_ckpt_policy(mode="incremental"))
    mgr.save(state, 3)
    _, _, _, plan, _ = mgr._plan_restore(_abstract(state), None, None)
    schedule, frontier = plan.first_use_schedule(None, 2)
    names = [plan.jobs[i][0] for i in schedule]
    # class 0 (embed + scalars) first, then block 0 — the frontier
    want_frontier = {"params/embed", "opt/count", "step",
                     "params/stage_0/b0/w"}
    assert set(plan.jobs[i][0] for i in frontier) == want_frontier
    assert set(names[:len(frontier)]) == want_frontier
    assert names[-1] == "params/lm_head"
    mgr.close()


# ---------------------------------------------------------------------------
# the stream: frontier, completion gate, bit-exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("io_threads", [1, 4])
def test_streaming_bit_exact_vs_blocking(tmp_path, io_threads):
    state = _state()
    pol = make_ckpt_policy(mode="incremental", io_threads=io_threads,
                           streaming_restore=True)
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)), policy=pol)
    mgr.save(state, 3, extra={"tag": "x"})
    blocking, _ = mgr.restore(_abstract(state))

    stream, extra = mgr.restore_streaming(_abstract(state))
    assert extra == {"tag": "x"}
    assert set(stream.names) == set(
        ["params/embed", "params/lm_head", "opt/count", "step"]
        + [f"params/stage_0/b{k}/w" for k in range(4)])
    stream.wait_frontier()
    for name in stream.frontier_names:
        assert stream.landed(name)
        np.testing.assert_array_equal(
            np.asarray(stream.leaf(name)),
            np.asarray(stream.leaf(name)))      # memoized touch
    got = stream.state()
    assert stream.state() is got                # idempotent gate
    assert stream.landed_count() == len(stream.names)
    _assert_tree_equal(blocking, got)
    _assert_tree_equal(state, got)
    mgr.close()


def test_streaming_restore_cold_remote(tmp_path):
    """The production redeploy: the only copy of the checkpoint lives on
    the object-store tier; a cold store (empty fast tier) streams the
    restore straight off multipart ranged GETs."""
    state = _state()
    writer = CheckpointManager(
        TieredStore(Tier("w", tmp_path / "writer")),
        policy=make_ckpt_policy(mode="incremental", io_threads=4))
    writer.save(state, 3)
    writer.close()
    mirror_to_tier(Tier("w", tmp_path / "writer"),
                   RemoteTier("obj", tmp_path / "remote"))

    cold = CheckpointManager(
        TieredStore(Tier("fast", tmp_path / "cold"),
                    remote=RemoteTier("obj", tmp_path / "remote",
                                      part_bytes=256,
                                      request_latency_s=0.0)),
        policy=make_ckpt_policy(mode="incremental", io_threads=4,
                                streaming_restore=True))
    assert cold.latest_step() == 3
    stream, _ = cold.restore_streaming(_abstract(state))
    got = stream.wait_frontier().state()
    _assert_tree_equal(state, got)
    cold.close()


def test_remote_part_bytes_policy_reaches_the_tier(tmp_path):
    remote = RemoteTier("obj", tmp_path / "remote")
    mgr = CheckpointManager(
        TieredStore(Tier("f", tmp_path / "fast"), remote=remote),
        policy=make_ckpt_policy(remote_part_bytes=1234))
    assert remote.part_bytes == 1234
    mgr.close()


# ---------------------------------------------------------------------------
# ReadCache: the single-oversized-entry pin
# ---------------------------------------------------------------------------

def test_read_cache_single_over_limit_entry_stays_resident():
    """Deliberate (docstring-pinned) behaviour: ONE entry larger than the
    budget stays resident — the leaf that fetched it is about to consume
    it, and evicting it would only force a full re-fetch. The budget
    bounds steady-state growth, not the high-water mark of one shard."""
    cache = ReadCache(limit=100)
    big = np.zeros(150, np.uint8)
    cache.put("big", big)
    assert cache.get("big") is big
    assert cache.nbytes == 150
    # the next insert evicts the oversized one (LRU) down to one entry
    small = np.zeros(60, np.uint8)
    cache.put("small", small)
    assert cache.get("small") is small
    assert len(cache.entries) == 1 and cache.nbytes == 60
    assert cache.get("big") is None
