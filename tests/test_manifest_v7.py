"""Manifest v7: chunk-encoded codec records (byteplane-rle / -rans)
carry per-chunk (raw_len, enc_len) pairs — ``chunk_lens`` stay PHYSICAL
(encoded bytes: offsets, digests and the crc all describe what is read
from disk) and ``chunk_raw_lens`` drive the plane entropy decode after
placement.

Covers: well-formed v7 records with matching length lists; device /
host-entropy / serial writers producing byte-identical manifests; serial
purity (no device entropy stage on the PR-1 engine); the direct-read
restore path with its crc-gated fallback; the crash point between the
fused dispatch and chunk submission; mixed v5/v6/v7 histories restoring
bit-exact with GC leaking nothing; and future-format rejection."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_ckpt_policy
from repro.core import atomic, cas, cdc_scan
from repro.core import codec as codec_mod
from repro.core.atomic import CrashInjector, CrashPoint
from repro.core.cas import ChunkStore
from repro.core.cdc_scan import GearScanner
from repro.core.checkpoint import FORMAT_VERSION, CheckpointManager
from repro.core.errors import AbortedError, CkptError
from repro.core.storage import Tier, TieredStore
from repro.kernels.ckpt_codec import entropy as ent


def _store(tmp_path, name="fast"):
    return TieredStore(Tier(name, tmp_path / name))


def _state(seed=0, n=400_000):
    # small-magnitude floats: the sign/exponent plane concentrates on a
    # few symbols, so the entropy stage actually bites
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(
        (rng.standard_normal(n) * 0.02).astype(np.float32))},
        "opt": {"m": jnp.asarray(rng.integers(0, 50, 30_000,
                                              dtype=np.int32))}}


def _abstract(state):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)


def _manifest_path(root, step):
    return root / f"step_{step:08d}" / atomic.MANIFEST


def _writer(tmp_path, sub="fast", **kw):
    kw.setdefault("codec", "byteplane-rans")
    kw.setdefault("n_writers", 2)
    kw.setdefault("mode", "incremental")
    kw.setdefault("chunking", "cdc")
    kw.setdefault("chunk_size", 65536)
    kw.setdefault("io_threads", 4)
    return CheckpointManager(_store(tmp_path, sub),
                             policy=make_ckpt_policy(**kw))


def _restores(mgr, step, expect):
    restored, _ = mgr.restore(_abstract(expect), step=step)
    for a, b in zip(jax.tree.leaves(expect), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _records(man, *, raw_fields=True):
    out = {}
    for leaf, spec in man["leaves"].items():
        for s in spec["shards"]:
            key = (leaf, tuple(s["start"]))
            out[key] = (tuple(s["chunks"]), s["crc32"], s["payload_bytes"],
                        tuple(s.get("chunk_lens") or ()),
                        tuple(s.get("chunk_raw_lens") or ())
                        if raw_fields else None,
                        s.get("raw_payload_bytes") if raw_fields else None,
                        s["meta"], s["codec"])
    return out


# ---------------------------------------------------------------------------
# the v7 record shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunking", ["cdc", "fixed"])
@pytest.mark.parametrize("codec", ["byteplane-rle", "byteplane-rans"])
def test_v7_records_carry_raw_and_encoded_lengths(tmp_path, codec,
                                                  chunking):
    mgr = _writer(tmp_path, codec=codec, chunking=chunking)
    state = _state()
    mgr.save(state, 1)
    m = json.loads(_manifest_path(mgr.store.root, 1).read_text())
    assert m["format"] == FORMAT_VERSION == 7
    seen = 0
    for spec in m["leaves"].values():
        for s in spec["shards"]:
            if s["codec"] not in codec_mod.CHUNK_ENCODED:
                continue
            seen += 1
            assert len(s["chunk_raw_lens"]) == len(s["chunk_lens"]) \
                == len(s["chunks"])
            # chunk_lens are PHYSICAL: they sum to the stored payload
            assert sum(s["chunk_lens"]) == s["payload_bytes"]
            assert sum(s["chunk_raw_lens"]) == s["raw_payload_bytes"]
            assert all(n > 0 for n in s["chunk_lens"])
            assert all(n > 0 for n in s["chunk_raw_lens"])
            # every interior chunk is plane-block aligned in RAW space
            raw = np.cumsum(s["chunk_raw_lens"])
            assert all(int(c) % codec_mod.ENTROPY_BLOCK == 0
                       for c in raw[:-1])
    assert seen, "no chunk-encoded shard records written"
    # the entropy stage actually shrank the f32 leaf
    w = m["leaves"]["params/w"]["shards"][0]
    if codec == "byteplane-rans":
        assert w["payload_bytes"] < w["raw_payload_bytes"]
    _restores(mgr, 1, state)
    mgr.close()


def test_save_report_counts_encoded_bytes(tmp_path):
    mgr = _writer(tmp_path)
    rep = mgr.save(_state(), 1)
    assert rep["payload_bytes"] < rep["bytes"]     # entropy stage bites
    mgr.close()


# ---------------------------------------------------------------------------
# engine identity and serial purity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ["byteplane-rle", "byteplane-rans"])
def test_device_host_serial_write_identical_v7_manifests(tmp_path, codec):
    st = _state()
    mans = {}
    for name, flat in [
        ("dev", dict(io_threads=4, device_entropy=True)),
        ("host", dict(io_threads=4, device_entropy=False)),
        ("serial", dict(io_threads=1)),
    ]:
        m = _writer(tmp_path, name, codec=codec, **flat)
        m.save(st, 1)
        mans[name] = _records(m.load_manifest(1))
        _restores(m, 1, st)
        m.close()
    assert mans["dev"] == mans["host"], \
        "device entropy coding changed the stored bytes"
    assert mans["dev"] == mans["serial"], \
        "serial engine drifted from the pipelined encoded-chunk grid"


def test_serial_engine_never_touches_device_entropy(tmp_path, monkeypatch):
    # PR-1 purity: io_threads=1 must encode through the host oracle —
    # no fused dispatch, no device entropy kernel
    def boom(*a, **kw):
        raise AssertionError("device entropy stage ran on the serial "
                             "engine")
    monkeypatch.setattr(GearScanner, "scan_transform_encode_async", boom)
    monkeypatch.setattr(cdc_scan, "transform_async", boom)
    monkeypatch.setattr(ent, "encode_stream", boom)
    mgr = _writer(tmp_path, io_threads=1)
    st = _state()
    mgr.save(st, 1)
    _restores(mgr, 1, st)
    mgr.close()


def test_fused_entropy_dispatch_actually_engages(tmp_path, monkeypatch):
    # the pipelined engine with CDC + a chunk-encoded codec must route
    # through the fused scan+transform+entropy dispatch
    calls = []
    orig = GearScanner.scan_transform_encode_async

    def spy(self, payload, itemsize, entropy_codec):
        calls.append(len(payload))
        return orig(self, payload, itemsize, entropy_codec)
    monkeypatch.setattr(GearScanner, "scan_transform_encode_async", spy)
    mgr = _writer(tmp_path, io_threads=4, device_entropy=True)
    rng = np.random.default_rng(0)
    st = {"params": {"w": jnp.asarray(
        (rng.standard_normal(900_000) * 0.02).astype(np.float32))}}
    mgr.save(st, 1)
    mgr.close()
    assert calls and max(calls) >= cdc_scan.MIN_ACCEL_BYTES, \
        "fused scan_transform_encode_async never invoked"


def test_adoption_keeps_readers_device_entropy(tmp_path):
    st = _state()
    w = _writer(tmp_path, "adopt", device_entropy=True)
    w.save(st, 1)
    w.close()
    r = CheckpointManager(
        _store(tmp_path, "adopt"),
        policy=make_ckpt_policy(mode="incremental", chunking="cdc",
                                chunk_size=65536, codec="raw",
                                io_threads=4, device_entropy=False))
    _restores(r, 1, st)
    # codec NAME adopted from the writer; the machine-local perf knob is
    # NOT — the reader explicitly pinned the host entropy path
    assert r.codec == "byteplane-rans"
    assert r.policy.codec.device_entropy is False
    assert r.device_entropy is False
    r.close()


# ---------------------------------------------------------------------------
# restore: direct placement of ENCODED chunks + decode after the read
# ---------------------------------------------------------------------------

def test_v7_restore_uses_direct_placement_of_encoded_chunks(tmp_path,
                                                            monkeypatch):
    mgr = _writer(tmp_path)
    state = _state()
    mgr.save(state, 1)

    calls = {"direct": 0}
    real_direct = ChunkStore.read_payload_direct

    def counting_direct(self, *a, **kw):
        calls["direct"] += 1
        return real_direct(self, *a, **kw)

    def forbidden_join(self, *a, **kw):
        raise AssertionError("join-path read_payload used for a v7 "
                             "record on the pipelined engine")

    monkeypatch.setattr(ChunkStore, "read_payload_direct", counting_direct)
    monkeypatch.setattr(ChunkStore, "read_payload", forbidden_join)
    _restores(mgr, 1, state)
    assert calls["direct"] > 0
    mgr.close()


def test_v7_direct_placement_damage_falls_back_and_heals(tmp_path):
    """A corrupted primary object fails the digest gate; the read drops
    back to the verified path and heals through the buddy replica — then
    the plane decode still reproduces the exact raw bytes."""
    mgr = _writer(tmp_path, replicas=2)
    state = _state()
    mgr.save(state, 1)
    m = json.loads(_manifest_path(mgr.store.root, 1).read_text())
    rec = next(s for spec in m["leaves"].values() for s in spec["shards"]
               if s["codec"] in codec_mod.CHUNK_ENCODED)
    obj = mgr.store.fast.root / cas.object_rel(rec["chunks"][0])
    obj.write_bytes(b"\x00" * obj.stat().st_size)      # torn primary
    _restores(mgr, 1, state)
    mgr.close()


# ---------------------------------------------------------------------------
# crash matrix extension: die between fused dispatch and chunk submission
# ---------------------------------------------------------------------------

def test_crash_between_fused_dispatch_and_chunk_submission(tmp_path):
    states = {1: _state(1), 2: _state(2), 3: _state(3)}
    mk = lambda: _writer(tmp_path, retain=4, max_retries=0)  # noqa: E731
    mk().save(states[1], 1)
    with pytest.raises((CrashPoint, AbortedError)):
        mk().save(states[2], 2,
                  crash=CrashInjector("rank0_after_fused_dispatch"))
    mgr = mk()
    mgr.gc()                      # staging litter + mark-and-sweep
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
    assert mgr.latest_step() == 1
    _restores(mgr, 1, states[1])
    mgr.save(states[3], 3)        # recovered store commits normally
    _restores(mgr, 3, states[3])
    mgr.close()


# ---------------------------------------------------------------------------
# cross-version history
# ---------------------------------------------------------------------------

def _downgrade(root, step, fmt):
    """Rewrite a committed manifest as its older-writer equivalent (only
    valid for steps whose records carry no v7-only fields)."""
    mpath = _manifest_path(root, step)
    m = json.loads(mpath.read_text())
    assert m["format"] == FORMAT_VERSION
    for rec in m["leaves"].values():
        for s in rec["shards"]:
            assert "chunk_raw_lens" not in s, \
                "cannot downgrade a chunk-encoded record"
    m["format"] = fmt
    if fmt < 6:
        m.pop("policy", None)
    if fmt < 5:
        m.pop("chunk_bounds", None)
        for rec in m["leaves"].values():
            for s in rec["shards"]:
                s.pop("chunk_lens", None)
    mpath.write_text(json.dumps(m))


def test_mixed_v5_v6_v7_history_restores_and_gc_leaks_nothing(tmp_path):
    """A v7-rans step written over a v6-byteplane step over a v5-raw
    step: every step restores bit-exact, and mark-and-sweep over the
    mixed history reclaims orphans without touching live chunks."""
    states = {1: _state(1), 2: _state(2), 3: _state(3)}
    w1 = _writer(tmp_path, codec="raw", retain=8)
    w1.save(states[1], 1)
    w1.close()
    _downgrade(_writer(tmp_path, retain=8).store.root, 1, 5)
    w2 = _writer(tmp_path, codec="byteplane", retain=8)
    w2.save(states[2], 2)
    w2.close()
    _downgrade(_writer(tmp_path, retain=8).store.root, 2, 6)
    mgr = _writer(tmp_path, codec="byteplane-rans", retain=8)
    mgr.save(states[3], 3)
    # an unreferenced orphan object for the sweep to prove itself on
    orphan = mgr.store.fast.root / cas.object_rel("ff" * 16)
    orphan.parent.mkdir(parents=True, exist_ok=True)
    orphan.write_bytes(b"junk")
    mgr.gc()
    assert not orphan.exists()
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
    for step, fmt in ((1, 5), (2, 6), (3, 7)):
        assert mgr.load_manifest(step)["format"] == fmt
        _restores(mgr, step, states[step])
    mgr.close()


@pytest.mark.skipif(not codec_mod.HAVE_ZSTD,
                    reason="zstandard not installed")
def test_mixed_zstd_history_restores_bit_exact(tmp_path):
    """The ISSUE's exact ladder where zstd is available: v7 rans over
    v6 byteplane-zstd over v5 zstd."""
    states = {1: _state(1), 2: _state(2), 3: _state(3)}
    w1 = _writer(tmp_path, codec="zstd", retain=8)
    w1.save(states[1], 1)
    w1.close()
    _downgrade(_writer(tmp_path, retain=8).store.root, 1, 5)
    w2 = _writer(tmp_path, codec="byteplane-zstd", retain=8)
    w2.save(states[2], 2)
    w2.close()
    _downgrade(_writer(tmp_path, retain=8).store.root, 2, 6)
    mgr = _writer(tmp_path, codec="byteplane-rans", retain=8)
    mgr.save(states[3], 3)
    mgr.gc()
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
    for step in (1, 2, 3):
        _restores(mgr, step, states[step])
    mgr.close()


def test_inspector_reports_entropy_planes(tmp_path):
    """--verify on a v7 step walks the encoded block framing and reports
    per-plane raw/encoded bytes + escape counts: mantissa planes of f32
    noise escape to raw, the sign/exponent plane codes with rANS."""
    from repro.launch.inspect_ckpt import inspect
    mgr = _writer(tmp_path)
    mgr.save(_state(), 1)
    mgr.close()
    rep = inspect(tmp_path / "fast", verify=True, out=lambda *a: None)
    assert rep["ok"], rep["problems"]
    planes = rep["entropy_planes"]["byteplane-rans"]
    assert set(planes) >= {"0", "1", "2", "3"}
    assert any(p["rans_blocks"] for p in planes.values())
    assert any(p["raw_escape_blocks"] for p in planes.values())
    for p in planes.values():
        assert p["blocks"] == p["raw_escape_blocks"] + p["rle_blocks"] \
            + p["rans_blocks"]
        assert 0 < p["encoded_bytes"] <= p["raw_bytes"] + 3 * p["blocks"]


def test_future_manifest_format_rejected(tmp_path):
    mgr = _writer(tmp_path)
    mgr.save(_state(), 1)
    mpath = _manifest_path(mgr.store.root, 1)
    m = json.loads(mpath.read_text())
    m["format"] = FORMAT_VERSION + 1
    mpath.write_text(json.dumps(m))
    with pytest.raises(CkptError):
        _writer(tmp_path).load_manifest(1)
    mgr.close()
