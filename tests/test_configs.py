"""Config registry: assigned hyperparameters, param counts vs published
figures, stage machinery, applicability matrix."""
import pytest

from repro.configs import (ARCH_IDS, CONFIGS, SHAPES, applicable,
                           build_stages, cells, get_config, param_counts,
                           reduced)

# published parameter counts (billions): total, active
PUBLISHED = {
    "kimi-k2-1t-a32b": (1040, 32.6),
    "llama4-scout-17b-a16e": (109, 17),
    "gemma3-1b": (1.0, 1.0),
    "stablelm-1.6b": (1.6, 1.6),
    "starcoder2-3b": (3.0, 3.0),
    "gemma2-9b": (9.2, 9.2),
    "hubert-xlarge": (1.0, 1.0),
    "recurrentgemma-9b": (9.0, 9.0),
    "mamba2-780m": (0.78, 0.78),
    "chameleon-34b": (34, 34),
}


def test_all_archs_registered():
    assert len(ARCH_IDS) == 10


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_match_published(arch):
    pc = param_counts(CONFIGS[arch])
    tot, act = PUBLISHED[arch]
    assert pc["n_total"] / 1e9 == pytest.approx(tot, rel=0.15), pc
    assert pc["n_active"] / 1e9 == pytest.approx(act, rel=0.15), pc


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_stages_cover_all_layers(arch):
    cfg = CONFIGS[arch]
    stages = build_stages(cfg)
    assert sum(len(s.kinds) * s.repeat for s in stages) == cfg.n_layers
    # per-layer kinds reconstructed from stages must equal cfg.layer_kinds
    kinds = []
    for s in stages:
        for _ in range(s.repeat):
            kinds.extend(s.kinds)
    assert tuple(kinds) == cfg.layer_kinds


def test_cell_matrix():
    cs = cells(CONFIGS)
    assert len(cs) == 32
    # encoder: no decode
    assert ("hubert-xlarge", "decode_32k") not in cs
    assert ("hubert-xlarge", "long_500k") not in cs
    # long_500k only for sub-quadratic archs
    long = {a for a, s in cs if s == "long_500k"}
    assert long == {"gemma3-1b", "recurrentgemma-9b", "mamba2-780m"}


def test_applicability_reasons():
    ok, reason = applicable(CONFIGS["chameleon-34b"], SHAPES["long_500k"])
    assert not ok and "full-attention" in reason
    ok, reason = applicable(CONFIGS["hubert-xlarge"], SHAPES["decode_32k"])
    assert not ok and "encoder" in reason


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_preserves_family(arch):
    cfg = CONFIGS[arch]
    r = reduced(cfg)
    assert r.family == cfg.family
    assert (r.moe is None) == (cfg.moe is None)
    assert (r.ssm is None) == (cfg.ssm is None)
    assert r.pattern == cfg.pattern
    assert r.d_model <= 64 and r.vocab_size <= 128


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("nope-7b")


def test_presets_cover_all_archs_and_apply():
    from dataclasses import replace
    from repro.configs.presets import PRESETS, preset_overrides
    assert set(PRESETS) == set(ARCH_IDS)
    for arch in ARCH_IDS:
        ov = preset_overrides(arch)
        cfg = replace(CONFIGS[arch], **ov)   # every preset key is a real field
        assert cfg.arch_id == arch
