"""Chunked/parallel sequence forms vs step-by-step recurrence (the decode
path IS the mathematical definition — equivalence is the correctness proof
for SSD and RG-LRU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import CONFIGS, reduced
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod

KEY = jax.random.PRNGKey(7)


def test_ssd_chunked_equals_recurrent():
    cfg = reduced(CONFIGS["mamba2-780m"])
    params = ssm_mod.init_ssm(KEY, cfg)
    B, S = 2, 64
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.5
    y_seq, final_state = ssm_mod.ssd_forward(params, x, cfg,
                                             return_state=True)
    state = ssm_mod.init_ssm_state(cfg, B)
    outs = []
    for t in range(S):
        y, state = ssm_mod.ssd_decode_step(params, x[:, t:t + 1], cfg, state)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(y_seq - y_step)) < 1e-3
    assert jnp.max(jnp.abs(final_state["h"] - state["h"])) < 1e-3


def test_ssd_state_carry_across_segments():
    """prefill(x[:32]) then prefill(x[32:], state) == prefill(x) — segmented
    prefill for long-context serving."""
    cfg = reduced(CONFIGS["mamba2-780m"])
    params = ssm_mod.init_ssm(KEY, cfg)
    x = jax.random.normal(KEY, (1, 64, cfg.d_model)) * 0.5
    y_full, st_full = ssm_mod.ssd_forward(params, x, cfg, return_state=True)
    y1, st1 = ssm_mod.ssd_forward(params, x[:, :32], cfg, return_state=True)
    y2, st2 = ssm_mod.ssd_forward(params, x[:, 32:], cfg, state=st1,
                                  return_state=True)
    assert jnp.max(jnp.abs(jnp.concatenate([y1, y2], 1) - y_full)) < 1e-3
    assert jnp.max(jnp.abs(st2["h"] - st_full["h"])) < 1e-3


def test_rglru_scan_equals_recurrent():
    cfg = reduced(CONFIGS["recurrentgemma-9b"])
    params = rglru_mod.init_rglru(KEY, cfg)
    B, S = 2, 48
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.5
    y_seq, final = rglru_mod.rglru_forward(params, x, cfg, return_state=True)
    state = rglru_mod.init_rglru_state(cfg, B)
    outs = []
    for t in range(S):
        y, state = rglru_mod.rglru_decode_step(params, x[:, t:t + 1], cfg,
                                               state)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(y_seq - y_step)) < 1e-4
    assert jnp.max(jnp.abs(final["h"] - state["h"])) < 1e-4


def test_rglru_decay_bounded():
    """RG-LRU recurrence weight a ∈ (0,1) — stability invariant."""
    cfg = reduced(CONFIGS["recurrentgemma-9b"])
    params = rglru_mod.init_rglru(KEY, cfg)
    u = jax.random.normal(KEY, (4, 16, cfg.rglru.lru_width or cfg.d_model))
    a, b = rglru_mod._gates(params, u)
    assert bool(jnp.all(a > 0)) and bool(jnp.all(a < 1))


def test_moe_dispatch_positions():
    """positions-in-expert are unique per expert and arrival-ordered."""
    import numpy as np
    from repro.models.moe import _positions_in_expert
    idx = jax.random.randint(KEY, (512,), 0, 8)
    pos, counts = _positions_in_expert(idx, 8, block=64)
    pos, idx, counts = map(np.asarray, (pos, idx, counts))
    for e in range(8):
        mine = pos[idx == e]
        assert sorted(mine.tolist()) == list(range(len(mine)))
        assert counts[e] == len(mine)
