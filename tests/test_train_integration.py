"""End-to-end behaviour tests for the paper's system claims:

  * bit-exact resume (Gromacs claim);
  * preemption → checkpoint → restart (preempt-queue use case);
  * async checkpointing overlap + drain;
  * data-pipeline state restores exactly.
"""
import jax
import numpy as np
import pytest

from repro.configs import CONFIGS, reduced
from repro.core.preempt import PreemptionGuard
from repro.data.pipeline import DataState, SyntheticPipeline
from repro.train.loop import Trainer, TrainerConfig

CFG = reduced(CONFIGS["gemma3-1b"])


def _tcfg(tmp_path, **kw):
    kw.setdefault("batch", 4)
    kw.setdefault("seq_len", 32)
    kw.setdefault("ckpt_every", 4)
    kw.setdefault("log_every", 100)
    return TrainerConfig(workdir=str(tmp_path / "run"), **kw)


@pytest.mark.slow
def test_bit_exact_resume(tmp_path):
    """train N straight == train N/2 + ckpt + kill + restore + N/2."""
    tA = Trainer(CFG, _tcfg(tmp_path / "a", ckpt_every=100, seed=5))
    tA.init_or_restore()
    tA.fit(8)
    dA = tA.params_digest()

    tB = Trainer(CFG, _tcfg(tmp_path / "b", ckpt_every=4, async_ckpt=True,
                            seed=5))
    tB.init_or_restore()
    tB.fit(8, stop_after=4)
    del tB  # "node failure"
    tB2 = Trainer(CFG, _tcfg(tmp_path / "b", ckpt_every=4, seed=5))
    tB2.init_or_restore()
    assert tB2.restored_from == 4
    tB2.fit(8)
    assert tB2.params_digest() == dA


@pytest.mark.slow
def test_preemption_checkpoint_and_resume(tmp_path):
    t = Trainer(CFG, _tcfg(tmp_path, ckpt_every=100, seed=1))
    t.init_or_restore()
    with PreemptionGuard() as guard:
        t.fit(6, guard=guard, stop_after=2)
        guard.request()                    # SIGTERM analogue
        rep = t.fit(6, guard=guard)
    assert rep["status"] == "preempted"
    assert t.manager.latest_step() == rep["step"]
    t2 = Trainer(CFG, _tcfg(tmp_path, ckpt_every=100, seed=1))
    t2.init_or_restore()
    assert t2.restored_from == rep["step"]
    out = t2.fit(6)
    assert out["status"] == "completed" and out["step"] == 6


@pytest.mark.slow
def test_async_checkpoint_drains_and_is_valid(tmp_path):
    t = Trainer(CFG, _tcfg(tmp_path, ckpt_every=2, async_ckpt=True, seed=2))
    t.init_or_restore()
    t.fit(6)
    assert t.manager.counters.drained()    # sent == received (P4)
    assert t.manager.latest_step() == 6
    t2 = Trainer(CFG, _tcfg(tmp_path, seed=2))
    t2.init_or_restore()
    assert t2.params_digest() == t.params_digest()


@pytest.mark.slow
def test_streaming_restore_bit_exact_resume(tmp_path):
    """Restore-behind through the Trainer: step 0 begins at the first-use
    frontier, the tail streams in behind the completion gate, and the
    resumed run is bit-exact with a straight-through run."""
    tA = Trainer(CFG, _tcfg(tmp_path / "a", ckpt_every=100, seed=5))
    tA.init_or_restore()
    tA.fit(8)
    dA = tA.params_digest()

    tB = Trainer(CFG, _tcfg(tmp_path / "b", ckpt_every=4, seed=5))
    tB.init_or_restore()
    tB.fit(8, stop_after=4)
    del tB  # "node failure"
    tB2 = Trainer(CFG, _tcfg(tmp_path / "b", ckpt_every=4, seed=5,
                             streaming_restore=True))
    tB2.init_or_restore()
    assert tB2.restored_from == 4
    assert tB2._restore_stream is not None     # tail still streaming
    assert tB2.state is None                   # fit() crosses the gate
    out = tB2.fit(8)
    assert out["status"] == "completed" and out["step"] == 8
    assert tB2.params_digest() == dA


def test_pipeline_state_restores_exactly():
    pipe = SyntheticPipeline(CFG, batch=4, seq_len=16)
    s0 = pipe.init_state(seed=9)
    batches = []
    s = s0
    for _ in range(5):
        b, s = pipe.next(s)
        batches.append(b)
    # checkpoint the state after 3 batches (JSON roundtrip = manifest path),
    # then replay: batch 3 must be identical
    mid = _advance(pipe, s0, 3)
    mid = DataState.from_json(mid.to_json())
    b3, _ = pipe.next(mid)
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    # counters monotone and conserved
    assert sum(s.source_counts) == 5 * 4 * 16


def _advance(pipe, state, n):
    for _ in range(n):
        _, state = pipe.next(state)
    return state


def test_trainer_restores_data_state(tmp_path):
    t = Trainer(CFG, _tcfg(tmp_path, ckpt_every=3, seed=4))
    t.init_or_restore()
    t.fit(3)
    counts = t.data_state.source_counts
    t2 = Trainer(CFG, _tcfg(tmp_path, seed=4))
    t2.init_or_restore()
    assert t2.data_state.step == 3
    assert t2.data_state.source_counts == counts
