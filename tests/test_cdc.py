"""Content-defined chunking: structural invariants (roundtrip, size
bounds, determinism), the shift-tolerance property that motivates CDC, and
the acceptance bound — strictly better dedup than fixed-size chunking at
equal average chunk size under a shifted-payload churn model."""
import hashlib

import numpy as np
import pytest

from repro.core import cdc
from repro.core.cas import split_payload
from repro.core.cdc import GearChunker


def _dig(c: bytes) -> str:
    return hashlib.blake2b(c, digest_size=16).hexdigest()


def _new_bytes(before: list, after: list) -> int:
    """Bytes of `after` whose chunk digest never appeared in `before` —
    what a content-addressed store would physically re-write."""
    seen = set(map(_dig, before))
    return sum(len(c) for c in after if _dig(c) not in seen)


# ---------------------------------------------------------------------------
# structural invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("size", [0, 1, 63, 64, 65, 255, 256, 1000,
                                  4096, 100_000])
def test_roundtrip_and_bounds(size, rng):
    ck = GearChunker(1024)
    payload = rng.bytes(size)
    chunks = ck.chunk(payload)
    assert b"".join(chunks) == payload
    assert all(len(c) <= ck.max_size for c in chunks)
    assert all(len(c) >= ck.min_size for c in chunks[:-1])
    if size == 0:
        assert chunks == []


def test_cut_points_deterministic_across_instances(rng):
    payload = rng.bytes(50_000)
    assert GearChunker(512).cut_points(payload) == \
        GearChunker(512).cut_points(payload)


def test_gear_table_is_stable():
    # boundaries ARE the dedup keyspace: the table must never drift
    # between processes/versions or every historical chunk re-writes
    assert cdc.GEAR.dtype == np.uint32
    assert len(cdc.GEAR) == 256
    assert int(cdc.GEAR[0]) == int.from_bytes(
        hashlib.blake2b(bytes([0]), digest_size=4,
                        person=b"repro-cdc-v1").digest(), "little")


def test_low_entropy_payload_force_cuts_at_max():
    # constant bytes have one window hash everywhere: either it matches the
    # mask (boundary every min) or it never does (boundary every max) —
    # both must respect the bounds
    ck = GearChunker(512)
    chunks = ck.chunk(b"\x00" * 50_000)
    assert all(ck.min_size <= len(c) <= ck.max_size for c in chunks[:-1])
    assert b"".join(chunks) == b"\x00" * 50_000


def test_avg_size_tracks_target(rng):
    for avg in (512, 2048):
        chunks = GearChunker(avg).chunk(rng.bytes(1 << 20))
        mean = np.mean([len(c) for c in chunks])
        # normalized chunking keeps the realized average near the target
        assert avg / 2 < mean < avg * 2


def test_validation():
    with pytest.raises(ValueError):
        GearChunker(100)                      # below the hash window floor
    with pytest.raises(ValueError):
        GearChunker(1 << 29)                  # beyond 32-bit masks
    with pytest.raises(ValueError):
        GearChunker(1024, min_size=8)         # min below window


# ---------------------------------------------------------------------------
# shift tolerance — the reason CDC exists
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("edit_pos_frac", [0.1, 0.5, 0.9])
def test_insert_rewrites_only_overlapping_chunks(edit_pos_frac, rng):
    """Acceptance: a single inserted region dedups to near-fixed-point —
    only chunks overlapping the edit (± boundary resync) are rewritten."""
    ck = GearChunker(1024)
    p0 = rng.bytes(256 * 1024)
    pos = int(len(p0) * edit_pos_frac)
    insert = rng.bytes(16)
    p1 = p0[:pos] + insert + p0[pos:]
    c0, c1 = ck.chunk(p0), ck.chunk(p1)
    assert b"".join(c1) == p1
    new = _new_bytes(c0, c1)
    # the edit can dirty the chunk it lands in plus a couple of resync
    # chunks — never an O(payload) rewrite
    assert new <= len(insert) + 4 * ck.max_size
    assert new < len(p1) // 8


def test_delete_region_rewrites_only_overlapping_chunks(rng):
    ck = GearChunker(1024)
    p0 = rng.bytes(256 * 1024)
    p1 = p0[:100_000] + p0[100_200:]          # drop 200 bytes mid-payload
    new = _new_bytes(ck.chunk(p0), ck.chunk(p1))
    assert new <= 4 * ck.max_size


def test_cdc_strictly_beats_fixed_on_shifted_payload(rng):
    """The headline property: at EQUAL average chunk size, a byte-shifted
    payload re-writes almost everything under fixed-size chunking and
    almost nothing under CDC."""
    avg = 1024
    p0 = rng.bytes(256 * 1024)
    p1 = p0[:1000] + rng.bytes(32) + p0[1000:]     # shift by 32 near front
    fixed_new = _new_bytes(split_payload(p0, avg), split_payload(p1, avg))
    ck = GearChunker(avg)
    cdc_new = _new_bytes(ck.chunk(p0), ck.chunk(p1))
    assert cdc_new < fixed_new                      # strictly better
    assert fixed_new > len(p1) // 2                 # fixed lost ~everything
    assert cdc_new <= 32 + 4 * ck.max_size          # cdc lost ~nothing


def test_unshifted_churn_equivalent_for_both_schemes(rng):
    """In-place edits (same offsets) dedup fine under BOTH schemes — CDC
    must not regress the aligned-churn case fixed chunking already won."""
    avg = 1024
    p0 = rng.bytes(128 * 1024)
    edited = bytearray(p0)
    edited[50_000:50_016] = rng.bytes(16)           # in-place, no shift
    p1 = bytes(edited)
    ck = GearChunker(avg)
    cdc_new = _new_bytes(ck.chunk(p0), ck.chunk(p1))
    fixed_new = _new_bytes(split_payload(p0, avg), split_payload(p1, avg))
    assert cdc_new <= 4 * ck.max_size
    assert fixed_new <= 2 * avg
