"""Edge cases of the elastic restore planner (`plan_reads`/`assemble`):
zero-size shards, targets with no covering source, single-element
overlaps, scalars, and dtype preservation through assemble."""
import numpy as np
import pytest

from repro.core.elastic import (ShardRange, assemble, normalize_index,
                                overlap, plan_reads)


def _rng(start, stop):
    return ShardRange(tuple(start), tuple(stop))


# ---------------------------------------------------------------------------
# zero-size shards
# ---------------------------------------------------------------------------

def test_zero_size_shard_never_overlaps():
    empty = _rng((3, 0), (3, 4))          # zero rows
    target = _rng((0, 0), (8, 4))
    assert overlap(empty, target) is None
    assert empty.size() == 0


def test_zero_size_target_assembles_empty():
    """A (0,)-shaped target is trivially covered: nothing to read, empty
    result, correct dtype."""
    target = _rng((5,), (5,))
    picks = plan_reads(target, [(_rng((0,), (10,)), "h")])
    out = assemble(target, [(r, np.arange(10, dtype=np.int16)[r.start[0]:
                                                              r.stop[0]])
                            for r, _ in picks], np.int16)
    assert out.shape == (0,)
    assert out.dtype == np.int16


def test_zero_size_available_shard_is_harmless():
    """Zero-size shards in the available list must not break planning or
    coverage for a real target."""
    data = np.arange(12, dtype=np.float32).reshape(3, 4)
    available = [
        (_rng((0, 0), (0, 4)), "empty"),          # zero-size
        (_rng((0, 0), (3, 4)), "full"),
    ]
    target = _rng((0, 0), (3, 4))
    picks = plan_reads(target, available)
    assert ("full" in [h for _, h in picks])
    pieces = [(r, data[r.start[0]:r.stop[0], r.start[1]:r.stop[1]])
              for r, h in picks if h == "full"]
    np.testing.assert_array_equal(assemble(target, pieces, np.float32), data)


# ---------------------------------------------------------------------------
# no covering source
# ---------------------------------------------------------------------------

def test_target_with_no_covering_source_raises_lookup():
    target = _rng((0,), (8,))
    available = [(_rng((0,), (4,)), "half")]      # covers only [0, 4)
    picks = plan_reads(target, available)
    pieces = [(r, np.zeros(r.shape, np.float32)) for r, _ in picks]
    with pytest.raises(LookupError, match="uncovered"):
        assemble(target, pieces, np.float32)


def test_fully_disjoint_source_raises_lookup():
    target = _rng((0, 0), (2, 2))
    pieces = [(_rng((4, 4), (6, 6)), np.ones((2, 2), np.float32))]
    with pytest.raises(LookupError):
        assemble(target, pieces, np.float32)


def test_partial_hole_in_middle_detected():
    """Coverage accounting is per element, not per shard count: two shards
    covering the edges must not mask a hole in the middle."""
    target = _rng((0,), (9,))
    pieces = [(_rng((0,), (3,)), np.zeros(3, np.float32)),
              (_rng((6,), (9,)), np.zeros(3, np.float32))]
    with pytest.raises(LookupError, match="3 elements"):
        assemble(target, pieces, np.float32)


# ---------------------------------------------------------------------------
# single-element overlaps
# ---------------------------------------------------------------------------

def test_single_element_overlap_assembles_exact():
    base = np.arange(25, dtype=np.int64).reshape(5, 5)
    # four quadrants overlapping on single rows/cols + one 1×1 center shard
    available = [
        (_rng((0, 0), (3, 3)), base[0:3, 0:3]),
        (_rng((2, 2), (5, 5)), base[2:5, 2:5]),
        (_rng((0, 2), (3, 5)), base[0:3, 2:5]),
        (_rng((2, 0), (5, 3)), base[2:5, 0:3]),
        (_rng((2, 2), (3, 3)), base[2:3, 2:3]),   # single element
    ]
    target = _rng((0, 0), (5, 5))
    picks = plan_reads(target, [(r, a) for r, a in available])
    got = assemble(target, [(r, a) for r, a in picks], np.int64)
    np.testing.assert_array_equal(got, base)


def test_single_element_target():
    base = np.arange(16, dtype=np.float64).reshape(4, 4)
    target = _rng((2, 3), (3, 4))
    picks = plan_reads(target, [(_rng((0, 0), (4, 4)), base)])
    got = assemble(target, [(r, a) for r, a in picks], np.float64)
    assert got.shape == (1, 1)
    assert got[0, 0] == base[2, 3]


def test_scalar_target_roundtrip():
    target = _rng((), ())
    val = np.asarray(7, np.int32)
    got = assemble(target, [(_rng((), ()), val)], np.int32)
    assert got.shape == ()
    assert int(got) == 7


# ---------------------------------------------------------------------------
# dtype preservation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float16, np.float32, np.int8,
                                   np.uint8, np.int64, np.bool_])
def test_dtype_preserved_through_assemble(dtype):
    base = (np.arange(12) % 2).astype(dtype).reshape(3, 4)
    pieces = [(_rng((0, 0), (3, 2)), base[:, 0:2]),
              (_rng((0, 2), (3, 4)), base[:, 2:4])]
    got = assemble(_rng((0, 0), (3, 4)), pieces, dtype)
    assert got.dtype == np.dtype(dtype)
    np.testing.assert_array_equal(got, base)


def test_assemble_casts_to_requested_dtype():
    """The restore path resolves the TARGET dtype on the main thread;
    assemble must honour it even when pieces arrive in another dtype."""
    pieces = [(_rng((0,), (4,)), np.arange(4, dtype=np.float64))]
    got = assemble(_rng((0,), (4,)), pieces, np.float32)
    assert got.dtype == np.float32
    np.testing.assert_array_equal(got, np.arange(4, dtype=np.float32))


def test_normalize_index_open_slices():
    rng = normalize_index((slice(None), slice(2, None)), (4, 8))
    assert rng == _rng((0, 2), (4, 8))
    assert rng.shape == (4, 6)
