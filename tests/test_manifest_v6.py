"""Manifest v6: the writer's effective CheckpointPolicy rides the
manifest, so a zero-config restart adopts the writer's chunking/scan/
codec settings — a config-drifted caller restores byte-identically AND
keeps deduplicating future saves against the restored history. A
corrupted policy block degrades to a warning (shard records are
self-describing); v≤5 manifests simply predate the block."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_ckpt_policy
from repro.core import atomic
from repro.core.checkpoint import FORMAT_VERSION, CheckpointManager
from repro.core.policy import CheckpointPolicy
from repro.core.storage import Tier, TieredStore


def _store(tmp_path):
    return TieredStore(Tier("fast", tmp_path / "fast"))


def _state(seed=0, n=40_000):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(
        rng.standard_normal((n,), dtype=np.float32))}}


def _abstract(state):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)


def _manifest_path(root, step):
    return root / f"step_{step:08d}" / atomic.MANIFEST


def _writer(tmp_path, **kw):
    kw.setdefault("codec", "raw")
    kw.setdefault("n_writers", 2)
    kw.setdefault("mode", "incremental")
    kw.setdefault("chunking", "cdc")
    kw.setdefault("chunk_size", 1024)
    kw.setdefault("io_threads", 4)
    return CheckpointManager(_store(tmp_path),
                             policy=make_ckpt_policy(**kw))


def test_v6_manifest_round_trips_the_writing_policy(tmp_path):
    mgr = _writer(tmp_path)
    mgr.save(_state(), 1)
    m = json.loads(_manifest_path(mgr.store.root, 1).read_text())
    assert m["format"] == FORMAT_VERSION >= 6
    embedded = CheckpointPolicy.from_dict(m["policy"])
    assert embedded.chunking == mgr.policy.chunking
    assert embedded.mode == "incremental"
    # the embedded codec is the RESOLVED one, not the writer's "auto"
    assert embedded.codec.codec == mgr.codec == "raw"
    assert embedded.codec.params_codec == mgr.params_codec
    # and the block is a faithful to_dict of the effective policy
    assert m["policy"] == mgr._effective_policy_dict()


def test_mismatched_caller_adopts_writer_policy_and_keeps_dedup(tmp_path):
    """The regression this redesign exists for: history written cdc@1K,
    restarted with a fixed@4K caller config. Restore must be
    byte-identical, the manager must adopt the writer's chunking/codec
    (logged reconciliation), and the NEXT save of unchanged state must
    dedup to zero new object bytes — without adoption the drifted chunk
    grid re-writes the entire model."""
    state = _state(7)
    _writer(tmp_path).save(state, 1)

    caller = CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
        codec="raw", n_writers=2, mode="incremental",
        chunking="fixed", chunk_size=4096, io_threads=4))
    restored, _ = caller.restore(_abstract(state))
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert caller.policy.chunking.scheme == "cdc"
    assert caller.policy.chunking.chunk_size == 1024
    assert caller.chunks.chunk_size == 1024
    assert caller._chunker is not None and \
        caller._chunker.avg_size == 1024
    rep = caller.save(state, 2)
    assert rep["new_object_bytes"] == 0         # full dedup vs step 1
    # the adopted policy is what step 2's manifest records
    m2 = CheckpointPolicy.from_dict(caller.load_manifest(2)["policy"])
    assert m2.chunking == caller.policy.chunking


def test_matched_caller_adopts_nothing(tmp_path):
    mgr = _writer(tmp_path)
    mgr.save(_state(), 1)
    before = mgr.policy
    mgr.restore(_abstract(_state()))
    assert mgr.policy is before                 # no rebind, no churn


def test_corrupted_policy_block_degrades_to_warning(tmp_path):
    """Garbage in the policy block must not take restore down — the shard
    records are self-describing; the caller keeps its own policy."""
    state = _state(3)
    mgr = _writer(tmp_path)
    mgr.save(state, 1)
    mpath = _manifest_path(mgr.store.root, 1)
    for garbage in ({"mode": "bogus"}, "not-a-mapping",
                    {"chunking": {"scheme": 999}},
                    # parses as a valid-looking policy but can't BUILD a
                    # write engine (cdc average below the scan window) —
                    # must degrade exactly like unparseable garbage
                    {"mode": "incremental",
                     "chunking": {"scheme": "cdc", "chunk_size": 100}}):
        m = json.loads(mpath.read_text())
        m["policy"] = garbage
        mpath.write_text(json.dumps(m))
        caller = CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
            codec="raw", n_writers=2, mode="incremental",
            chunking="fixed", chunk_size=4096))
        restored, _ = caller.restore(_abstract(state))
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(state["params"]["w"]))
        assert caller.policy.chunking.scheme == "fixed"  # nothing adopted


def test_unavailable_writer_codec_is_not_adopted(tmp_path):
    """A manifest recording a codec this environment can't decode-encode
    with (e.g. zstd without the package) must not poison the caller's
    write path — chunking still adopts, codec stays the caller's."""
    from repro.core import codec as codec_mod
    state = _state(5)
    mgr = _writer(tmp_path)
    mgr.save(state, 1)
    mpath = _manifest_path(mgr.store.root, 1)
    m = json.loads(mpath.read_text())
    m["policy"]["codec"] = {"codec": "zstd", "params_codec": "zstd"}
    mpath.write_text(json.dumps(m))
    caller = CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
        codec="raw", n_writers=2, mode="incremental",
        chunking="fixed", chunk_size=4096))
    caller.restore(_abstract(state))            # records still say raw
    assert caller.policy.chunking.scheme == "cdc"      # adopted
    if codec_mod.HAVE_ZSTD:
        assert caller.codec == "zstd"           # available → adopted too
    else:
        assert caller.codec == "raw"            # unavailable → kept


def test_restore_plan_carries_the_written_policy(tmp_path):
    from repro.core.restore_path import RestorePlan
    mgr = _writer(tmp_path)
    state = _state()
    mgr.save(state, 1)
    manifest = mgr.load_manifest(1)
    flat, _ = jax.tree_util.tree_flatten(_abstract(state))
    plan = RestorePlan.build(manifest, "step_00000001",
                             ["params/w"], flat, [None], 1)
    assert plan.written_policy == manifest["policy"]
    # a v5 manifest (no block) yields None, not a crash
    manifest.pop("policy")
    plan = RestorePlan.build(manifest, "step_00000001",
                             ["params/w"], flat, [None], 1)
    assert plan.written_policy is None


def test_v6_step_in_mixed_history_gc_leaks_nothing(tmp_path):
    """A v6 step alongside a policy-less (v5-style) step: the mark set
    spans both, the sweep reclaims an injected orphan, and both steps
    restore."""
    from repro.core import cas
    mgr = _writer(tmp_path, retain=8)
    s1, s2 = _state(1), _state(2)
    mgr.save(s1, 1)
    # strip step 1 down to a v5 manifest (older-writer history)
    mpath = _manifest_path(mgr.store.root, 1)
    m = json.loads(mpath.read_text())
    m["format"] = 5
    m.pop("policy")
    mpath.write_text(json.dumps(m))
    mgr2 = _writer(tmp_path, retain=8)
    mgr2.save(s2, 2)
    orphan = mgr2.store.fast.root / cas.object_rel("ee" * 16)
    orphan.parent.mkdir(parents=True, exist_ok=True)
    orphan.write_bytes(b"junk")
    mgr2.gc()
    assert not orphan.exists()
    assert mgr2.chunks.fsck(mgr2._live_chunk_refs())["ok"]
    for step, st in ((1, s1), (2, s2)):
        r, _ = mgr2.restore(_abstract(st), step=step)
        np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                      np.asarray(st["params"]["w"]))


def test_serial_engine_still_writes_v6_with_numpy_scan_pinned(tmp_path):
    """The PR-1 baseline purity rule survives the redesign: io_threads=1
    pins the numpy scan and queue depth 1 whatever the policy asks."""
    mgr = CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
        codec="raw", n_writers=1, mode="incremental", chunking="cdc",
        chunk_size=1024, scan_backend="auto", io_threads=1,
        persist_queue_depth=4))
    assert mgr._chunker.scan_backend == "numpy"
    assert mgr._persist.depth == 1
    state = _state()
    mgr.save(state, 1)
    assert mgr.load_manifest(1)["format"] == FORMAT_VERSION
    r, _ = mgr.restore(_abstract(state))
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
