"""PreemptionGuard signal discipline: deferral, re-delivery to the
restored handler, full signal history, callbacks, and the manager
fast-flush hook."""
import os
import signal
import threading

import pytest

from repro.core.preempt import PreemptionGuard, PreemptQueue


def test_os_signal_deferred_and_redelivered_to_outer_handler():
    """A real SIGUSR1 caught inside the guard must (a) set the flag and
    (b) reach the OUTER handler once the guard exits — before this fix the
    signal simply vanished and the process out-lived its eviction."""
    outer: list = []
    old = signal.signal(signal.SIGUSR1, lambda s, f: outer.append(s))
    try:
        with PreemptionGuard(signals=(signal.SIGUSR1,)) as guard:
            os.kill(os.getpid(), signal.SIGUSR1)
            assert guard.should_preempt
            assert outer == []          # deferred, not forwarded mid-guard
        assert outer == [signal.SIGUSR1]
    finally:
        signal.signal(signal.SIGUSR1, old)


def test_every_signum_recorded_not_just_last():
    outer: list = []
    old = signal.signal(signal.SIGUSR1, lambda s, f: outer.append(s))
    old2 = signal.signal(signal.SIGUSR2, lambda s, f: outer.append(s))
    try:
        with PreemptionGuard(signals=(signal.SIGUSR1,
                                      signal.SIGUSR2)) as guard:
            os.kill(os.getpid(), signal.SIGUSR1)
            os.kill(os.getpid(), signal.SIGUSR2)
            os.kill(os.getpid(), signal.SIGUSR1)
            assert guard.signums == [signal.SIGUSR1, signal.SIGUSR2,
                                     signal.SIGUSR1]
            assert guard.signum == signal.SIGUSR1    # most recent
        # each distinct signal re-delivered exactly once
        assert sorted(outer) == sorted([signal.SIGUSR1, signal.SIGUSR2])
    finally:
        signal.signal(signal.SIGUSR1, old)
        signal.signal(signal.SIGUSR2, old2)


def test_programmatic_request_does_not_redeliver():
    """request() has no OS signal behind it — __exit__ must not manufacture
    one (a re-raised SIGUSR1 under the default handler would KILL the
    process)."""
    outer: list = []
    old = signal.signal(signal.SIGUSR1, lambda s, f: outer.append(s))
    try:
        with PreemptionGuard(signals=(signal.SIGUSR1,)) as guard:
            guard.request()
            assert guard.should_preempt
            assert guard.signums == [signal.SIGUSR1]
        assert outer == []
    finally:
        signal.signal(signal.SIGUSR1, old)


def test_callbacks_run_on_signal_and_failures_are_contained():
    fired = threading.Event()
    guard = PreemptionGuard(signals=(signal.SIGUSR1,))

    def bad():
        raise RuntimeError("broken hook")

    guard.add_callback(bad)
    guard.add_callback(fired.set)
    guard.add_callback(fired.set)        # duplicate: must not stack
    assert len(guard._callbacks) == 2
    guard.request()                      # must not raise despite bad()
    assert fired.is_set() and guard.should_preempt


def test_preempt_queue_triggers_guard():
    guard = PreemptionGuard()
    q = PreemptQueue()
    q.submit_high_priority(guard, "high-pri-job")
    assert guard.should_preempt
    assert q.events[0][0] == "preempt"


def test_exit_restores_previous_handlers():
    old = signal.getsignal(signal.SIGUSR1)
    with PreemptionGuard(signals=(signal.SIGUSR1,)):
        assert signal.getsignal(signal.SIGUSR1) != old
    assert signal.getsignal(signal.SIGUSR1) == old


def test_manager_fast_flush_callback(tmp_path):
    """The trainer wires guard → manager.request_fast_flush; a signal must
    flip the persist stage's fast-flush flag."""
    from conftest import make_ckpt_policy
    from repro.core.checkpoint import CheckpointManager
    from repro.core.storage import Tier, TieredStore
    mgr = CheckpointManager(TieredStore(Tier("fast", tmp_path / "f")),
                            policy=make_ckpt_policy(codec="raw",
                                                    n_writers=1))
    guard = PreemptionGuard()
    guard.add_callback(mgr.request_fast_flush)
    assert not mgr._persist.fast_flush_requested
    guard.request()
    assert mgr._persist.fast_flush_requested
    mgr.close()
