"""Elastic (M×N) restore across device counts and mesh shapes.

Runs in a SUBPROCESS with --xla_force_host_platform_device_count=8 so the
main test process keeps its single-device view (mirrors the dry-run rule).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys, json, tempfile
    sys.path.insert(0, {src!r})
    import logging; logging.disable(logging.INFO)
    from repro.configs import CONFIGS, reduced
    from repro.train.loop import Trainer, TrainerConfig
    from repro.launch.mesh import make_host_mesh

    wd = tempfile.mkdtemp()
    cfg = reduced(CONFIGS[{arch!r}])
    def tc(**kw):
        return TrainerConfig(workdir=wd, batch=4, seq_len=32, ckpt_every=2,
                             log_every=100, seed=11, **kw)
    meshA = make_host_mesh((2, 4), ("data", "model"))
    tA = Trainer(cfg, tc(), mesh=meshA).init_or_restore()
    tA.fit(2)
    dA = tA.params_digest()
    results = {{"saved": dA, "restores": {{}}}}
    for shape in [(4, 2), (8, 1), (1, 1)]:
        meshB = make_host_mesh(shape, ("data", "model"))
        tB = Trainer(cfg, tc(), mesh=meshB).init_or_restore()
        ok = tB.params_digest() == dA and tB.restored_from == 2
        tB.fit(3, stop_after=1)   # restored state must be trainable
        results["restores"][str(shape)] = ok
    print("RESULT::" + json.dumps(results))
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma3-1b", "kimi-k2-1t-a32b"])
def test_cross_mesh_restore(arch):
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=SRC, arch=arch)],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("RESULT::"))
    res = json.loads(line[len("RESULT::"):])
    assert all(res["restores"].values()), res
