"""Public-API snapshot: the exported names and the signatures of the
policy objects and ``CheckpointManager`` are pinned here so future kwarg
creep (the 14-kwarg soup this redesign replaced) fails loudly in CI
instead of accreting silently. Changing the public surface is allowed —
but it must be a deliberate edit to THIS file, reviewed as such."""
import inspect

import repro.core as core
from repro.core.chunk_exec import DEFAULT_IO_THREADS
from repro.core.policy import (CheckpointPolicy, ChunkingPolicy,
                               CodecPolicy, DurabilityPolicy,
                               LEGACY_KWARGS, PipelinePolicy, RestorePolicy)
from repro.core.storage import DEFAULT_REMOTE_PART_BYTES

EXPORTED = [
    "AbortedError", "CASError", "CheckpointCoordinator", "CheckpointManager",
    "CheckpointPolicy", "ChunkIOExecutor", "ChunkStore", "ChunkingPolicy",
    "CircuitBreaker", "CkptError", "CodecPolicy", "CodecUnavailableError",
    "CorruptShardError", "CrashInjector", "CrashPoint", "Deadline",
    "DrainCounters", "DurabilityPolicy", "FaultPlane", "FaultSpec",
    "FaultyTier", "GearChunker", "GearScanner",
    "MissingShardError", "NamespaceError",
    "NoCheckpointError", "PeerTier", "PersistStage", "PipelinePolicy",
    "PreemptQueue", "PreemptionGuard",
    "ReadCache", "RegistryMismatchError", "RemoteInconsistencyError",
    "RemoteTier", "RestorePlan",
    "RestorePolicy", "RestoreSession", "RestoreStream", "RetryPolicy",
    "SavePlan", "SaveSession", "SpaceError", "Tier", "TierHealth",
    "TieredStore", "WeightPublisher", "WeightSubscriber",
    "abstract_train_state", "build_fleet", "config_digest", "default_store",
    "init_train_state", "is_tier_full", "is_transient", "leaf_paths",
    "lower_half_descriptor",
    "quiesce_device_state", "retry_io", "state_shardings", "wrap_store",
]


def test_core_exports_are_pinned():
    assert sorted(core.__all__) == sorted(EXPORTED)
    for name in EXPORTED:
        assert hasattr(core, name), name


def test_checkpoint_manager_signature_is_policy_first():
    """The canonical constructor is (store, policy=None, **legacy) — a new
    flat kwarg can only arrive via the legacy shim, which this test and
    the LEGACY_KWARGS freeze below make a deliberate act."""
    params = list(inspect.signature(
        core.CheckpointManager.__init__).parameters.values())
    names = [p.name for p in params]
    assert names == ["self", "store", "policy", "legacy"]
    assert params[2].default is None
    assert params[3].kind is inspect.Parameter.VAR_KEYWORD


def test_legacy_kwargs_are_frozen():
    assert LEGACY_KWARGS == (
        "n_writers", "codec", "params_codec", "replicas", "retain",
        "keepalive_s", "save_timeout_s", "max_retries",
        "async_drain_to_slow", "mode", "chunk_size", "chunking",
        "scan_backend", "io_threads")


def _fields(cls):
    return {p.name: p.default
            for p in inspect.signature(cls).parameters.values()}


def test_policy_fields_and_defaults_are_pinned():
    assert _fields(ChunkingPolicy) == {
        "scheme": "fixed", "chunk_size": 1 << 20, "min_size": None,
        "max_size": None, "scan_backend": "auto"}
    assert _fields(PipelinePolicy) == {
        "io_threads": DEFAULT_IO_THREADS, "persist_queue_depth": 1,
        "host_bytes_budget": None, "read_cache_bytes": 1 << 30,
        "async_drain": None}
    assert _fields(DurabilityPolicy) == {
        "replicas": 1, "retain": 3, "keepalive_s": 10.0,
        "save_timeout_s": 600.0, "max_retries": 1,
        "io_retries": 2, "io_backoff_ms": 5.0, "io_deadline_s": 30.0}
    assert _fields(CodecPolicy) == {"codec": None, "params_codec": None,
                                    "device_precondition": None,
                                    "device_entropy": None}
    assert _fields(RestorePolicy) == {
        "streaming": False, "frontier_classes": 2,
        "remote_part_bytes": DEFAULT_REMOTE_PART_BYTES}
    top = _fields(CheckpointPolicy)
    assert list(top) == ["mode", "n_writers", "chunking", "pipeline",
                         "durability", "codec", "restore"]
    assert top["mode"] == "full" and top["n_writers"] == 4


def test_manager_config_surface_reads_from_policy(tmp_path):
    """The pre-policy attribute surface (mode/chunking/replicas/…) stays
    readable but is a VIEW of the policy — not independently assignable
    state that could drift from it."""
    from repro.core.storage import Tier, TieredStore
    mgr = core.CheckpointManager(
        TieredStore(Tier("f", tmp_path)),
        policy=CheckpointPolicy(mode="incremental",
                                durability=DurabilityPolicy(
                                    keepalive_s=60.0, replicas=2)))
    assert (mgr.mode, mgr.chunking, mgr.replicas) == \
        ("incremental", "fixed", 2)
    assert mgr.n_writers == 4 and mgr.max_retries == 1
    assert mgr.save_timeout_s == 600.0
    mgr.close()
