"""Unit tests for the staged pipeline engines (`core.save_path` /
`core.restore_path`): the rank-wide SaveSession submission queue
(cross-payload pipelining), the direct-placement fixed-chunking restore,
the persist stage, and the plan builders."""
import threading
import zlib

import numpy as np
import pytest

from repro.core import cas
from repro.core.atomic import CrashInjector, CrashPoint
from repro.core.save_path import (PayloadTicket, PersistStage, SavePlan,
                                  SaveSession)
from repro.core.storage import Tier, TieredStore


def _chunks(tmp_path, io_threads=4, chunk_size=128, replicas=1):
    store = TieredStore(Tier("fast", tmp_path / f"cs{io_threads}"))
    return cas.ChunkStore(store, chunk_size=chunk_size, replicas=replicas,
                          io_threads=io_threads)


# ---------------------------------------------------------------------------
# SaveSession: rank-wide cross-payload submission queue
# ---------------------------------------------------------------------------

def test_session_matches_put_payload_reference(tmp_path, rng):
    """Digests, byte accounting and crc of the streaming session must be
    identical to the one-payload-at-a-time reference engine."""
    payloads = [rng.bytes(500), rng.bytes(128), b"", rng.bytes(1000)]
    ref = _chunks(tmp_path / "ref", io_threads=1)
    want = []
    for p in payloads:
        digests, new = ref.put_payload(p)
        want.append((digests, new, zlib.crc32(p) & 0xFFFFFFFF))

    cs = _chunks(tmp_path / "ses", io_threads=4)
    session = SaveSession(cs)
    tickets = [session.submit_payload(p) for p in payloads]  # NO flush between
    session.barrier()
    got = [session.result(t) for t in tickets]
    assert got == want
    for p, (digests, _, _) in zip(payloads, got):
        assert bytes(cs.read_payload(digests, len(p))) == p
    cs.close()
    ref.close()


def test_session_pipelines_across_payload_boundaries(tmp_path, rng):
    """The drain-bubble regression probe: after submitting payload A the
    session must accept payload B's chunks without waiting for A to
    finish (a per-shard drain would force ticket A complete first)."""
    cs = _chunks(tmp_path, io_threads=4, chunk_size=64)
    gate = threading.Event()
    orig = cs.store_chunk
    stalled = []

    def slow_store(digest, data, crash=None, dirs=None, dirs_lock=None):
        if not stalled:
            stalled.append(digest)
            gate.wait(timeout=10)        # first chunk parks a pool worker
        return orig(digest, data, crash or cas.NO_CRASH, dirs, dirs_lock)

    cs.store_chunk = slow_store
    session = SaveSession(cs, window=8)
    a = session.submit_payload(rng.bytes(64 * 2))    # 2 chunks, first stalls
    b = session.submit_payload(rng.bytes(64 * 2))    # must submit immediately
    assert not a.done and not b.done                 # neither forced a drain
    gate.set()
    session.barrier()
    da, _, _ = session.result(a)
    db, _, _ = session.result(b)
    assert len(da) == 2 and len(db) == 2
    cs.close()


def test_session_scan_ahead_defers_and_matches_serial(tmp_path, rng):
    """With an accelerated CDC scanner, a payload's scan dispatches at
    submit time but its chunks only feed the pool when the next payload
    arrives (or at flush) — and the digests/lens/crc are identical to the
    serial engine's."""
    from repro.core.cdc import GearChunker
    from repro.core.cdc_scan import MIN_ACCEL_BYTES
    payloads = [rng.bytes(MIN_ACCEL_BYTES + 13), rng.bytes(MIN_ACCEL_BYTES)]
    ck = GearChunker(1 << 18, scan_backend="jnp")

    ref = _chunks(tmp_path / "ref", io_threads=1, chunk_size=1 << 18)
    want = []
    for p in payloads:
        lens: list = []
        digests, new = ref.put_payload(p, chunker=ck, lens_out=lens)
        want.append((digests, lens, zlib.crc32(p) & 0xFFFFFFFF))

    cs = _chunks(tmp_path / "ses", io_threads=4, chunk_size=1 << 18)
    session = SaveSession(cs, chunker=ck)
    t1 = session.submit_payload(payloads[0])
    assert not t1.submitted                # queued behind its async scan
    t2 = session.submit_payload(payloads[1])
    assert t1.submitted                    # depth-1 scan-ahead kicked it in
    session.barrier()
    for t, (digests, lens, crc) in zip((t1, t2), want):
        d, _, c = session.result(t)
        assert (d, t.lens, c) == (digests, lens, crc)
    assert sum(t1.lens) == len(payloads[0])
    cs.close()
    ref.close()


def test_session_serial_engine_is_put_payload(tmp_path, rng):
    """io_threads=1 must stay byte-for-byte the PR-1 engine: the session
    degrades to inline put_payload calls, tickets resolve immediately."""
    cs = _chunks(tmp_path, io_threads=1)
    session = SaveSession(cs)
    payload = rng.bytes(300)
    ticket = session.submit_payload(payload)
    assert ticket.done                              # resolved inline
    digests, new, crc = session.result(ticket)
    assert crc == (zlib.crc32(payload) & 0xFFFFFFFF)
    assert bytes(cs.read_payload(digests, len(payload))) == payload
    cs.close()


def test_session_error_joins_all_in_flight(tmp_path, rng):
    """A CrashPoint mid-batch must cancel the queue and join every
    in-flight chunk before re-raising — no stray worker may still be
    writing while the caller's abort path runs."""
    cs = _chunks(tmp_path, io_threads=4, chunk_size=64)
    session = SaveSession(cs, crash=CrashInjector("cas_mid_batch"))
    with pytest.raises(CrashPoint):
        session.submit_payload(rng.bytes(64 * 40))
        session.barrier()
    assert not session._pending                     # queue fully drained
    cs.close()


def test_session_caller_abort_joins_in_flight(tmp_path, rng):
    """A caller whose error occurs BETWEEN session calls must be able to
    abort(): it blocks until every in-flight chunk worker has finished —
    no stray worker may write objects after abort() returns."""
    cs = _chunks(tmp_path, io_threads=4, chunk_size=64)
    gate = threading.Event()
    started = threading.Event()
    orig = cs.store_chunk

    def slow(digest, data, crash=None, dirs=None, dirs_lock=None):
        started.set()
        gate.wait(timeout=10)
        return orig(digest, data, crash or cas.NO_CRASH, dirs, dirs_lock)

    cs.store_chunk = slow
    session = SaveSession(cs, window=8)
    session.submit_payload(rng.bytes(64 * 4))
    assert started.wait(5)
    done = []
    t = threading.Thread(
        target=lambda: (session.abort(), done.append(1)), daemon=True)
    t.start()
    t.join(0.3)
    assert not done                 # abort still joining the stalled worker
    gate.set()
    t.join(10)
    assert done and not session._pending
    cs.close()


def test_session_dedup_accounting(tmp_path, rng):
    """Identical payloads across the session dedup: second submission
    writes zero new bytes but reports the same digests."""
    cs = _chunks(tmp_path, io_threads=4, chunk_size=64)
    payload = rng.bytes(64 * 3)
    session = SaveSession(cs)
    t1 = session.submit_payload(payload)
    t2 = session.submit_payload(payload)
    session.barrier()
    d1, n1, c1 = session.result(t1)
    d2, n2, c2 = session.result(t2)
    assert d1 == d2 and c1 == c2
    assert n1 == 64 * 3 and n2 == 0
    cs.close()


def test_session_batched_dirs_fsynced_once(tmp_path, rng):
    """The session records fan-out dirs for ONE rank-level fsync barrier;
    barrier() clears them."""
    cs = _chunks(tmp_path, io_threads=4, chunk_size=64)
    session = SaveSession(cs)
    session.submit_payload(rng.bytes(64 * 8))
    session.flush()
    assert session.dirs                             # recorded, not yet synced
    session.barrier()
    assert not session.dirs
    cs.close()


# ---------------------------------------------------------------------------
# SavePlan
# ---------------------------------------------------------------------------

def _items(n):
    from repro.core.elastic import ShardRange
    return [(f"params/w{i}", ShardRange((0,), (4,)),
             np.arange(4, dtype=np.float32)) for i in range(n)]


def test_save_plan_round_robin_and_replicas():
    plan = SavePlan.build(_items(4), alive=[0, 1], incremental=False,
                          replicas=2, leaf_codec=lambda n: "raw")
    # each rank gets 2 primaries + 2 buddy replicas
    for r in (0, 1):
        work = plan.per_rank[r]
        assert sum(1 for w in work if not w[5]) == 2
        assert sum(1 for w in work if w[5]) == 2
    recs = [s for recs in plan.manifest_shards.values() for s in recs]
    assert all(len(s["replicas"]) == 2 for s in recs)


def test_save_plan_incremental_skips_file_records():
    plan = SavePlan.build(_items(3), alive=[0], incremental=True,
                          replicas=2, leaf_codec=lambda n: "raw")
    assert plan.manifest_shards == {}
    assert plan.shard_order == {f"params/w{i}": [i] for i in range(3)}


# ---------------------------------------------------------------------------
# direct-placement restore (fixed chunking)
# ---------------------------------------------------------------------------

def test_read_payload_fixed_matches_join_path(tmp_path, rng):
    cs = _chunks(tmp_path, io_threads=4, chunk_size=128)
    for size in (0, 1, 127, 128, 129, 128 * 7 + 3):
        payload = rng.bytes(size)
        digests, _ = cs.put_payload(payload)
        crc = zlib.crc32(payload) & 0xFFFFFFFF
        direct = cs.read_payload_fixed(digests, size, 128, crc)
        assert bytes(direct) == payload
    cs.close()


def test_read_payload_fixed_heals_corrupt_primary(tmp_path, rng):
    """A corrupted fast-tier object must fail the crc gate and recover
    through the fully-verified path (buddy replica)."""
    cs = _chunks(tmp_path, io_threads=4, chunk_size=128, replicas=2)
    payload = rng.bytes(128 * 4)
    digests, _ = cs.put_payload(payload)
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    victim = tmp_path / "cs4" / cas.object_rel(digests[1])
    data = bytearray(victim.read_bytes())
    data[0] ^= 0xFF
    victim.write_bytes(bytes(data))                 # same length, bad bytes
    got = cs.read_payload_fixed(digests, len(payload), 128, crc)
    assert bytes(got) == payload
    cs.close()


def test_read_payload_fixed_short_object_falls_back(tmp_path, rng):
    """A truncated primary (length mismatch on readinto) falls back to the
    verified per-chunk path without corrupting the buffer."""
    cs = _chunks(tmp_path, io_threads=4, chunk_size=128, replicas=2)
    payload = rng.bytes(128 * 3)
    digests, _ = cs.put_payload(payload)
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    victim = tmp_path / "cs4" / cas.object_rel(digests[0])
    victim.write_bytes(victim.read_bytes()[:50])    # truncate
    got = cs.read_payload_fixed(digests, len(payload), 128, crc)
    assert bytes(got) == payload
    cs.close()


def test_read_payload_fixed_serial_engine_uses_join_path(tmp_path, rng):
    """The serial engine must not take the direct-placement path (it is
    the byte-for-byte PR-1 baseline)."""
    cs = _chunks(tmp_path, io_threads=1, chunk_size=128)
    payload = rng.bytes(128 * 2 + 5)
    digests, _ = cs.put_payload(payload)
    got = cs.read_payload_fixed(digests, len(payload), 128,
                                zlib.crc32(payload) & 0xFFFFFFFF)
    assert isinstance(got, bytes)                   # join path returns bytes
    assert got == payload
    cs.close()


# ---------------------------------------------------------------------------
# PersistStage
# ---------------------------------------------------------------------------

def test_persist_stage_propagates_error_once():
    stage = PersistStage()
    handled = []

    def boom():
        raise RuntimeError("persist died")

    stage.submit(boom, on_error=handled.append)
    with pytest.raises(RuntimeError, match="persist died"):
        stage.wait()
    stage.wait()                                    # second wait: clean
    assert len(handled) == 1


def test_persist_stage_fast_flush_flag():
    stage = PersistStage()
    assert not stage.fast_flush_requested
    stage.request_fast_flush()
    assert stage.fast_flush_requested


def test_payload_ticket_empty_payload():
    t = PayloadTicket(0, 0)
    assert t.done and t.digests == [] and t.crc == 0


# ---------------------------------------------------------------------------
# PersistStage: bounded multi-round queue + byte-budget admission
# ---------------------------------------------------------------------------

def test_persist_stage_runs_queued_rounds_in_order():
    import time
    stage = PersistStage(depth=3)
    order = []
    gate = threading.Event()
    stage.submit(lambda: (gate.wait(10), order.append(1)), on_error=print)
    stage.submit(lambda: order.append(2), on_error=print)
    stage.submit(lambda: order.append(3), on_error=print)
    assert stage.inflight == 3 and stage.active
    time.sleep(0.05)
    assert order == []                  # all parked behind round 1
    gate.set()
    stage.wait()
    assert order == [1, 2, 3]           # FIFO: commits stay ordered
    assert stage.inflight == 0 and not stage.active


def test_persist_stage_depth_bounds_admission():
    stage = PersistStage(depth=2)
    gate = threading.Event()
    stage.admit()
    stage.submit(lambda: gate.wait(10), on_error=print, reserved=True)
    stage.admit()
    stage.submit(lambda: None, on_error=print, reserved=True)
    blocked = []
    t = threading.Thread(target=lambda: (stage.admit(),
                                         blocked.append(True)),
                         daemon=True)
    t.start()
    t.join(0.2)
    assert t.is_alive() and not blocked         # third admit parked
    gate.set()
    t.join(5)
    assert blocked
    stage.release()                             # the probe's reservation
    stage.wait()


def test_persist_stage_byte_budget_blocks_third_round():
    """Two rounds fill the budget; the third's admit() must park until a
    round lands — and a lone over-budget round still admits (an empty
    stage never deadlocks)."""
    stage = PersistStage(depth=8, host_bytes_budget=200)
    gate = threading.Event()
    for _ in range(2):
        stage.admit(100)
        stage.submit(lambda: gate.wait(10), on_error=print, nbytes=100,
                     reserved=True)
    assert stage.inflight_bytes == 200
    blocked = []
    t = threading.Thread(target=lambda: (stage.admit(100),
                                         blocked.append(True),
                                         stage.release(100)),
                         daemon=True)
    t.start()
    t.join(0.2)
    assert t.is_alive() and not blocked         # budget full → parked
    gate.set()
    t.join(5)
    assert blocked
    stage.wait()
    # empty stage: a round bigger than the whole budget still admits
    assert stage.admit(10_000) == pytest.approx(0.0, abs=0.2)
    stage.release(10_000)


def test_persist_stage_release_on_failed_snapshot_frees_the_slot():
    stage = PersistStage(depth=1)
    stage.admit(50)
    stage.release(50)                   # snapshot died before submit
    assert stage.admit(50) < 0.1        # slot is free again, no deadlock
    stage.release(50)


def _queue_mgr(tmp_path, **kw):
    from conftest import make_ckpt_policy
    from repro.core.checkpoint import CheckpointManager
    kw.setdefault("codec", "raw")
    kw.setdefault("n_writers", 1)
    kw.setdefault("mode", "incremental")
    kw.setdefault("chunk_size", 4096)
    kw.setdefault("io_threads", 4)
    return CheckpointManager(TieredStore(Tier("fast", tmp_path / "q")),
                             policy=make_ckpt_policy(**kw))


def _np_state(seed, kib=64):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(
        rng.standard_normal((kib * 256,), dtype=np.float32))}}


def test_manager_queue_depth2_admits_round_while_prior_persists(tmp_path):
    """The ROADMAP's multi-round persist queue: with depth 2 the second
    async save must be ADMITTED (snapshot taken, control returned) while
    round 1 is still persisting — and both rounds must commit and restore
    bit-exact."""
    import time

    import jax

    from repro.core import cas as cas_mod
    mgr = _queue_mgr(tmp_path, persist_queue_depth=2)
    gate = threading.Event()
    entered = threading.Event()
    orig = mgr.chunks.store_chunk

    def slow(digest, data, crash=None, dirs=None, dirs_lock=None):
        entered.set()
        gate.wait(10)                   # round 1 parks inside its persist
        return orig(digest, data, crash or cas_mod.NO_CRASH, dirs,
                    dirs_lock)

    mgr.chunks.store_chunk = slow
    s1, s2 = _np_state(1), _np_state(2)
    mgr.save(s1, 1, blocking=False)
    assert entered.wait(5)
    t0 = time.monotonic()
    mgr.save(s2, 2, blocking=False)     # must NOT wait for round 1
    assert time.monotonic() - t0 < 5.0
    assert mgr._persist.inflight == 2   # genuinely overlapped
    gate.set()
    mgr.wait()
    for step, st in ((1, s1), (2, s2)):
        restored, _ = mgr.restore(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         st), step=step)
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(st["params"]["w"]))
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
    mgr.close()


def test_manager_byte_budget_blocks_third_snapshot(tmp_path):
    """Crash-matrix-style budget probe: depth 3 but a budget sized for two
    rounds — the third save must park in admission BEFORE its snapshot is
    taken (two full snapshots may pin host memory, a third may not), then
    proceed once a round lands. Everything still commits and fscks."""
    import time

    from repro.core import cas as cas_mod
    from repro.core.save_path import estimate_snapshot_bytes
    s = {n: _np_state(n) for n in (1, 2, 3)}
    per_round = estimate_snapshot_bytes(s[1])
    mgr = _queue_mgr(tmp_path, persist_queue_depth=3,
                     host_bytes_budget=2 * per_round)
    snapshots = []
    orig_snap = mgr._snapshot
    mgr._snapshot = lambda state: (snapshots.append(1),
                                   orig_snap(state))[1]
    gate = threading.Event()
    orig = mgr.chunks.store_chunk

    def slow(digest, data, crash=None, dirs=None, dirs_lock=None):
        gate.wait(10)
        return orig(digest, data, crash or cas_mod.NO_CRASH, dirs,
                    dirs_lock)

    mgr.chunks.store_chunk = slow
    mgr.save(s[1], 1, blocking=False)
    mgr.save(s[2], 2, blocking=False)
    assert len(snapshots) == 2
    third_done = threading.Event()
    t = threading.Thread(
        target=lambda: (mgr.save(s[3], 3, blocking=False),
                        third_done.set()), daemon=True)
    t.start()
    t.join(0.3)
    assert t.is_alive() and len(snapshots) == 2     # snapshot 3 blocked
    gate.set()
    assert third_done.wait(30)
    assert len(snapshots) == 3
    mgr.wait()
    assert sorted(s_ for s_ in (1, 2, 3)
                  if (mgr.store.root / f"step_{s_:08d}").exists()) == \
        [1, 2, 3]
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
    mgr.close()


def test_serial_engine_policy_pins_queue_depth_to_one(tmp_path):
    mgr = _queue_mgr(tmp_path, io_threads=1, persist_queue_depth=4)
    assert mgr._persist.depth == 1
    mgr.close()
