"""WeightSync: checkpoint-as-transport weight distribution.

The contract under test, per the subsystem's invariants:

  1. a subscriber's flipped set is BIT-EXACT with a fresh ``restore()``
     of the announced step, leaf by leaf — structural, because the
     subscriber assembles through the restore path's own fetch engine;
  2. a second sync moves ONLY the delta: chunks already cache-resident
     are never re-pulled;
  3. peer fan-out spares the source: downstream replicas pull from peer
     caches, and the source tiers see O(tree root) chunk reads;
  4. a subscriber killed mid-pull or around the flip resumes to a
     bit-exact swap, never serves a torn buffer set, and never re-pulls
     what already landed (every cache write is atomic);
  5. injected storage faults degrade a sync to hold-last-good — the
     active set stays the previous step's, bit-exact — and a clean
     retry recovers;
  6. the publisher is best-effort: an announce failure never aborts the
     committed save.

Plus the satellite units: ``truncated_get``/``stale_head`` fault kinds
with classification, and breaker-aware (deprioritize-never-skip) drain
scheduling.
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_ckpt_policy
from repro.core import resilience
from repro.core.atomic import CrashInjector, CrashPoint
from repro.core.checkpoint import CheckpointManager
from repro.core.faults import FaultPlane, FaultyTier, wrap_store
from repro.core.storage import RemoteTier, Tier, TieredStore
from repro.core.weightsync import (ANNOUNCE_REL, SUBSCRIBERS_DIR,
                                   WeightPublisher, WeightSubscriber,
                                   assert_bitexact, build_fleet)

KEY = jax.random.PRNGKey(11)


def _state(step: int):
    k = jax.random.PRNGKey(step)
    return {
        "params": {"emb": jax.random.normal(k, (48, 16)),
                   "w0": jnp.arange(4096, dtype=jnp.float32) + step,
                   "frozen": jax.random.normal(KEY, (64, 8))},
        "opt": {"m": jnp.full((256,), float(step), jnp.float32)},
        "step": jnp.asarray(step, jnp.int32),
    }


def _abstract(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


def _policy(io_threads=2):
    return make_ckpt_policy(mode="incremental", chunk_size=2048,
                            io_threads=io_threads, io_retries=2,
                            io_backoff_ms=1.0, io_deadline_s=10.0)


def _store(tmp_path):
    return TieredStore(Tier("fast", tmp_path / "fast"),
                       Tier("slow", tmp_path / "slow"))


def _mgr(store, io_threads=2):
    return CheckpointManager(store, policy=_policy(io_threads))


def _params_filter(n):
    return n.startswith("params/")


# ---------------------------------------------------------------------------
# publisher
# ---------------------------------------------------------------------------

def test_publisher_announces_at_commit(tmp_path):
    store = _store(tmp_path)
    mgr = _mgr(store)
    pub = WeightPublisher(mgr)
    mgr.save(_state(0), 0, blocking=True)
    mgr.wait()
    assert pub.last_announced_step == 0
    ann = json.loads(store.fast.read_file(ANNOUNCE_REL).decode())
    assert ann["step"] == 0 and ann["manifest"]["step"] == 0
    assert ann["step_dir"] == "step_00000000"
    # the announcement also reaches the slow tier for cold subscribers
    assert (store.slow.root / ANNOUNCE_REL).exists()
    mgr.save(_state(1), 1, blocking=True)
    mgr.wait()
    ann = json.loads(store.fast.read_file(ANNOUNCE_REL).decode())
    assert ann["step"] == 1 and ann["seq"] == 2
    mgr.close()


def test_publisher_failure_never_aborts_save(tmp_path):
    store = _store(tmp_path)
    mgr = _mgr(store)
    pub = WeightPublisher(mgr)

    def boom(step, manifest):
        raise RuntimeError("announcement plane on fire")

    mgr.on_commit.insert(0, boom)
    mgr.save(_state(0), 0, blocking=True)       # must not raise
    mgr.wait()
    assert mgr.latest_step() == 0
    assert pub.last_announced_step == 0         # later hooks still ran
    mgr.close()


# ---------------------------------------------------------------------------
# subscriber: correctness + delta + fan-out
# ---------------------------------------------------------------------------

def test_sync_is_bitexact_with_restore(tmp_path):
    store = _store(tmp_path)
    mgr = _mgr(store)
    WeightPublisher(mgr)
    state = _state(0)
    mgr.save(state, 0, blocking=True)
    mgr.wait()
    sub = WeightSubscriber(store, tmp_path / "cache0", name="r0",
                           policy=_policy())
    st = sub.sync()
    assert st["state"] == "live" and st["last_flipped_step"] == 0
    step, arrays = sub.current()
    restored, _ = mgr.restore(_abstract(state), step=0)
    assert_bitexact(arrays, restored)
    # and against the source state too (restore is itself bit-exact)
    assert_bitexact(arrays, state)
    sub.close()
    mgr.close()


def test_second_sync_pulls_only_the_delta(tmp_path):
    store = _store(tmp_path)
    mgr = _mgr(store)
    WeightPublisher(mgr)
    s0 = _state(0)
    mgr.save(s0, 0, blocking=True)
    mgr.wait()
    sub = WeightSubscriber(store, tmp_path / "cache0", name="r0",
                           policy=_policy(), leaf_filter=_params_filter)
    sub.sync()
    full_wire = sub.counters["wire_bytes"]
    assert full_wire > 0
    # step 1 churns ONLY emb (~15% of params bytes); w0 and frozen dedup
    # to already-resident chunks, so the wire carries just emb's chunks
    s1 = {"params": {"emb": s0["params"]["emb"] + 1.0,
                     "w0": s0["params"]["w0"],
                     "frozen": s0["params"]["frozen"]},
          "opt": {"m": s0["opt"]["m"]},
          "step": jnp.asarray(1, jnp.int32)}
    mgr.save(s1, 1, blocking=True)
    mgr.wait()
    sub.sync()
    delta_wire = sub.counters["wire_bytes"] - full_wire
    assert 0 < delta_wire < full_wire / 2
    step, arrays = sub.current()
    assert step == 1
    assert_bitexact(arrays, s1, leaf_filter=_params_filter)
    # idempotent: re-sync of the same announcement moves nothing
    before = sub.counters["wire_bytes"]
    sub.sync()
    assert sub.counters["wire_bytes"] == before
    sub.close()
    mgr.close()


def test_peer_fanout_spares_the_source(tmp_path):
    store = _store(tmp_path)
    mgr = _mgr(store)
    WeightPublisher(mgr)
    state = _state(0)
    mgr.save(state, 0, blocking=True)
    mgr.wait()
    fleet = build_fleet(store, tmp_path / "fleet", 4, fanout=3,
                        policy=_policy(), leaf_filter=_params_filter)
    for sub in fleet:
        sub.sync()
    # the tree root pulled from the source; every downstream replica was
    # served entirely by peer caches
    assert fleet[0].counters["source_bytes"] > 0
    assert fleet[0].counters["peer_bytes"] == 0
    for sub in fleet[1:]:
        assert sub.counters["source_bytes"] == 0
        assert sub.counters["peer_bytes"] > 0
        _, arrays = sub.current()
        assert_bitexact(arrays, state, leaf_filter=_params_filter)
    # a peer cache is read-only: the pull path can never mutate it
    peer = fleet[0].as_peer_tier()
    with pytest.raises(OSError):
        peer.write_file("x", b"nope")
    for sub in fleet:
        sub.close()
    mgr.close()


def test_non_incremental_announcement_degrades(tmp_path):
    store = _store(tmp_path)
    mgr = CheckpointManager(store, policy=make_ckpt_policy(mode="full"))
    WeightPublisher(mgr)
    mgr.save(_state(0), 0, blocking=True)
    mgr.wait()
    sub = WeightSubscriber(store, tmp_path / "c", name="r0",
                           policy=_policy())
    st = sub.sync()
    assert st["state"] == "init"        # nothing ever flipped
    assert "incremental" in (st["last_error"] or "")
    assert sub.counters["sync_failures"] == 1
    sub.close()
    mgr.close()


# ---------------------------------------------------------------------------
# subscriber: crash points (satellite 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("point", ["ws_mid_pull", "ws_before_flip",
                                   "ws_after_flip"])
def test_subscriber_killed_then_resumes_bitexact(tmp_path, point):
    store = _store(tmp_path)
    mgr = _mgr(store)
    WeightPublisher(mgr)
    state = _state(0)
    mgr.save(state, 0, blocking=True)
    mgr.wait()
    cache = tmp_path / "cache0"
    # serial pull (io_threads=1) makes the mid-pull kill deterministic
    sub = WeightSubscriber(store, cache, name="r0", policy=_policy(1),
                           crash=CrashInjector(point))
    with pytest.raises(CrashPoint):
        sub.sync()
    if point == "ws_mid_pull":
        # killed before the flip: never flipped, never torn
        assert sub.current() == (None, {})
    pulled_before = sub.cache_residency()["chunks"]
    # "restart" the replica over the SAME cache dir
    sub2 = WeightSubscriber(store, cache, name="r0", policy=_policy(1))
    st = sub2.sync()
    assert st["state"] == "live" and st["last_flipped_step"] == 0
    step, arrays = sub2.current()
    assert_bitexact(arrays, state)
    # resume never re-pulls what already landed (atomic cache writes)
    assert sub2.counters["chunks_pulled"] + pulled_before == \
        sub2.cache_residency()["chunks"]
    if point in ("ws_before_flip", "ws_after_flip"):
        # everything was already resident at the kill: zero wire on resume
        assert sub2.counters["wire_bytes"] == 0
    sub.close()
    sub2.close()
    mgr.close()


def test_readers_never_see_a_torn_set_across_flips(tmp_path):
    """Concurrent readers snapshot (step, arrays) while syncs flip
    underneath them: every snapshot must be internally consistent —
    all leaves from ONE step."""
    store = _store(tmp_path)
    mgr = _mgr(store)
    WeightPublisher(mgr)
    states = {s: {"params": {"w": jnp.full((2048,), float(s), jnp.float32)},
                  "step": jnp.asarray(s, jnp.int32)}
              for s in range(4)}
    mgr.save(states[0], 0, blocking=True)
    mgr.wait()
    sub = WeightSubscriber(store, tmp_path / "c", name="r0",
                           policy=_policy())
    sub.sync()
    stop = threading.Event()
    torn: list = []

    def reader():
        while not stop.is_set():
            step, arrays = sub.current()
            if step is None:
                continue
            w = arrays["params/w"]
            s = arrays["step"]
            if not (np.all(w == float(step)) and int(s) == step):
                torn.append((step, float(w[0]), int(s)))

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        for s in range(1, 4):
            mgr.save(states[s], s, blocking=True)
            mgr.wait()
            sub.sync()
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not torn
    assert sub.flipped_step == 3
    sub.close()
    mgr.close()


# ---------------------------------------------------------------------------
# subscriber: fault plane → degraded hold-last-good
# ---------------------------------------------------------------------------

def test_faulted_pull_holds_last_good_then_recovers(tmp_path):
    store = _store(tmp_path)
    mgr = _mgr(store)
    WeightPublisher(mgr)
    s0 = _state(0)
    mgr.save(s0, 0, blocking=True)
    mgr.wait()
    cache = tmp_path / "c"
    sub = WeightSubscriber(store, cache, name="r0", policy=_policy(1),
                           leaf_filter=_params_filter)
    sub.sync()
    assert sub.state == "live"
    s1 = _state(1)
    mgr.save(s1, 1, blocking=True)
    mgr.wait()
    # every source read of chunk objects now dies with EIO, exhausting
    # the bounded retries — the sync must degrade, not throw
    plane = FaultPlane(seed=7)
    plane.add("read", "eio", tier="*", match=".obj", count=-1)
    wrap_store(sub.store, plane)
    st = sub.sync()
    assert st["state"] == "degraded"
    assert sub.counters["sync_failures"] == 1
    step, arrays = sub.current()
    assert step == 0                    # held the last good set
    assert_bitexact(arrays, s0, leaf_filter=_params_filter)
    # storage heals: the next sync converges to step 1, bit-exact
    plane.clear()
    st = sub.sync()
    assert st["state"] == "live" and st["last_flipped_step"] == 1
    _, arrays = sub.current()
    assert_bitexact(arrays, s1, leaf_filter=_params_filter)
    sub.close()
    mgr.close()


def test_bitrot_on_peer_falls_through_to_source(tmp_path):
    store = _store(tmp_path)
    mgr = _mgr(store)
    WeightPublisher(mgr)
    state = _state(0)
    mgr.save(state, 0, blocking=True)
    mgr.wait()
    fleet = build_fleet(store, tmp_path / "fleet", 2, policy=_policy(1),
                        leaf_filter=_params_filter)
    fleet[0].sync()
    # every peer-served byte is rotten: the digest gate must reject the
    # peer copy and the pull must fall through to the source, bit-exact
    plane = FaultPlane(seed=3)
    plane.add("read", "bitrot", tier=f"ws-peer-{fleet[0].name}",
              match=".obj", count=-1)
    wrap_store(fleet[1].store, plane)
    st = fleet[1].sync()
    assert st["state"] == "live"
    assert fleet[1].counters["pull_corrupt"] > 0
    assert fleet[1].counters["source_bytes"] > 0
    _, arrays = fleet[1].current()
    assert_bitexact(arrays, state, leaf_filter=_params_filter)
    for sub in fleet:
        sub.close()
    mgr.close()


# ---------------------------------------------------------------------------
# satellite: remote fault kinds + classification
# ---------------------------------------------------------------------------

def _remote(tmp_path, **kw):
    return RemoteTier("object-store", tmp_path / "remote", **kw)


def test_truncated_get_faults_multipart_read(tmp_path):
    remote = _remote(tmp_path, part_bytes=1024)
    payload = bytes(range(256)) * 16            # 4 KiB → 4 parts
    remote.write_file("obj", payload)
    plane = FaultPlane(seed=1)
    plane.add("read_range", "truncated_get", tier="object-store", nth=2)
    ft = FaultyTier(remote, plane)
    buf = bytearray(len(payload))
    assert ft.read_into("obj", memoryview(buf)) is False
    assert remote.io_counters.get("truncated_get", 0) == 1
    # the fault window closed: the re-issued GET succeeds and is exact
    buf = bytearray(len(payload))
    assert ft.read_into("obj", memoryview(buf)) is True
    assert bytes(buf) == payload
    assert [f[3] for f in plane.fired()] == ["truncated_get"]


def test_stale_head_faults_and_classification(tmp_path):
    remote = _remote(tmp_path, part_bytes=1024)
    remote.write_file("obj", b"x" * 2048)
    plane = FaultPlane(seed=1)
    plane.add("read_into", "stale_head", tier="object-store", nth=1)
    plane.add("read_file", "stale_head", tier="object-store", nth=1)
    ft = FaultyTier(remote, plane)
    buf = bytearray(2048)
    assert ft.read_into("obj", memoryview(buf)) is False
    assert remote.io_counters.get("stale_head", 0) == 1
    with pytest.raises(resilience.RemoteInconsistencyError) as ei:
        ft.read_file("obj")
    # classified transient (EIO family): retry_io will re-issue it
    assert resilience.is_transient(ei.value)
    assert not resilience.is_tier_full(ei.value)
    assert ei.value.kind == "stale_head"
    # a bounded retry absorbs it end to end
    plane.add("read_file", "stale_head", tier="object-store", nth=1)
    out = resilience.retry_io(
        lambda: ft.read_file("obj"),
        resilience.RetryPolicy(retries=2, backoff_ms=0.1))
    assert out == b"x" * 2048


def test_remote_read_file_mismatch_is_typed_transient(tmp_path):
    """RemoteTier.read_file's own HEAD/GET disagreement (no fault plane)
    now raises the typed, retryable error."""
    remote = _remote(tmp_path, part_bytes=64)

    class Shrinking(RemoteTier):
        def read_range(self, rel, dest, offset):
            ok = super().read_range(rel, dest, offset)
            return False                # every part "short"

    t = Shrinking("object-store", tmp_path / "r2", part_bytes=64)
    t.write_file("obj", b"y" * 256)
    with pytest.raises(resilience.RemoteInconsistencyError) as ei:
        t.read_file("obj")
    assert resilience.is_transient(ei.value)


# ---------------------------------------------------------------------------
# satellite: breaker-aware drain scheduling
# ---------------------------------------------------------------------------

def test_drain_defers_while_breaker_open_then_flushes(tmp_path):
    fast = Tier("fast", tmp_path / "fast")
    slow = Tier("slow", tmp_path / "slow")
    store = TieredStore(fast, slow, drain_async=True)
    (fast.root / "step_00000001").mkdir(parents=True)
    (fast.root / "step_00000001" / "f").write_bytes(b"a" * 128)
    (fast.root / "step_00000002").mkdir(parents=True)
    (fast.root / "step_00000002" / "f").write_bytes(b"b" * 128)
    health = store.health_for(slow)
    for _ in range(health.breaker.threshold):
        health.record_error("drain_write")
    assert not health.allow()
    store.drain_step("step_00000001")
    # deprioritized, NOT copied yet — and NOT skipped
    assert not (slow.root / "step_00000001" / "f").exists()
    assert store._drain_pending
    assert health.counters.get("drain_deferred") == 1
    # next drain with the breaker closed flushes the backlog in order
    health.record_ok("drain_write")
    assert health.allow()
    store.drain_step("step_00000002")
    store.wait_drained()
    assert (slow.root / "step_00000001" / "f").read_bytes() == b"a" * 128
    assert (slow.root / "step_00000002" / "f").read_bytes() == b"b" * 128
    assert not store._drain_pending


def test_wait_drained_forces_deferred_copies(tmp_path):
    fast = Tier("fast", tmp_path / "fast")
    slow = Tier("slow", tmp_path / "slow")
    store = TieredStore(fast, slow, drain_async=True)
    (fast.root / "step_00000001").mkdir(parents=True)
    (fast.root / "step_00000001" / "f").write_bytes(b"z" * 64)
    health = store.health_for(slow)
    for _ in range(health.breaker.threshold):
        health.record_error("drain_write")
    store.drain_step("step_00000001")
    assert not (slow.root / "step_00000001" / "f").exists()
    # the barrier every eviction takes must push the copy through even
    # with the breaker still open — deprioritize, never skip
    assert not health.allow()
    store.wait_drained()
    assert (slow.root / "step_00000001" / "f").read_bytes() == b"z" * 64


# ---------------------------------------------------------------------------
# inspector surface
# ---------------------------------------------------------------------------

def test_subscriber_status_published_for_inspector(tmp_path):
    store = _store(tmp_path)
    mgr = _mgr(store)
    WeightPublisher(mgr)
    mgr.save(_state(0), 0, blocking=True)
    mgr.wait()
    sub = WeightSubscriber(store, tmp_path / "c", name="edge-7",
                           policy=_policy())
    sub.sync()
    rel = f"{SUBSCRIBERS_DIR}/edge-7.json"
    doc = json.loads(store.fast.read_file(rel).decode())
    assert doc["name"] == "edge-7"
    assert doc["last_flipped_step"] == 0
    assert doc["cache_chunks"] > 0

    # inspector view: caught up → ok; a newer announcement → lagging
    from repro.launch.inspect_ckpt import run_subscribers
    rep = run_subscribers(store.fast.root, out=lambda *_: None)
    assert rep["ok"] and rep["announce"]["step"] == 0
    assert [s["name"] for s in rep["subscribers"]] == ["edge-7"]
    mgr.save(_state(1), 1, blocking=True)
    mgr.wait()
    rep = run_subscribers(store.fast.root, out=lambda *_: None)
    assert not rep["ok"] and rep["announce"]["step"] == 1
    sub.sync()
    rep = run_subscribers(store.fast.root, out=lambda *_: None)
    assert rep["ok"]
    sub.close()
    mgr.close()
