"""CheckpointPolicy config objects: legacy-kwarg parity (every historical
flat ``CheckpointManager`` kwarg maps onto the identical policy field,
with the same validation errors and the same resolved defaults, behind
exactly one ``DeprecationWarning``), dict round-tripping, and CLI/env
override merging."""
import warnings

import pytest

from conftest import make_ckpt_policy
from repro.core.checkpoint import CheckpointManager
from repro.core.policy import (CheckpointPolicy, ChunkingPolicy,
                               CodecPolicy, DurabilityPolicy, FLAT_FIELDS,
                               LEGACY_KWARGS, PipelinePolicy)
from repro.core.storage import Tier, TieredStore


def _store(tmp_path):
    return TieredStore(Tier("fast", tmp_path / "fast"))


def _get(policy, path):
    obj = policy
    for part in path:
        obj = getattr(obj, part)
    return obj


# one (kwarg, non-default value) probe per legacy kwarg — the value must
# differ from the field's default so the mapping is actually observable
LEGACY_PROBES = {
    "n_writers": 7,
    "codec": "raw",
    "params_codec": "int8",
    "replicas": 2,
    "retain": 5,
    "keepalive_s": 33.0,
    "save_timeout_s": 12.0,
    "max_retries": 0,
    "async_drain_to_slow": False,
    "mode": "incremental",
    "chunk_size": 2048,
    "chunking": "cdc",
    "scan_backend": "numpy",
    "io_threads": 2,
}


def test_every_legacy_kwarg_has_a_probe_and_a_field():
    assert sorted(LEGACY_PROBES) == sorted(LEGACY_KWARGS)
    assert set(LEGACY_KWARGS) <= set(FLAT_FIELDS)


@pytest.mark.parametrize("kwarg", sorted(LEGACY_PROBES))
def test_legacy_kwarg_maps_to_identical_policy_field(kwarg, tmp_path):
    value = LEGACY_PROBES[kwarg]
    with pytest.warns(DeprecationWarning) as rec:
        policy = CheckpointPolicy.from_legacy_kwargs(**{kwarg: value})
    assert len(rec) == 1                       # exactly one, per call
    path = FLAT_FIELDS[kwarg]
    assert _get(policy, path) == value
    # every OTHER field keeps its resolved default
    default = CheckpointPolicy()
    rebuilt = policy.to_dict()
    expect = default.to_dict()
    node = expect
    for part in path[:-1]:
        node = node[part]
    node[path[-1]] = value
    assert rebuilt == expect

    # the manager's legacy constructor takes the same path: one warning,
    # and the composed policy is what policy= would have received
    with pytest.warns(DeprecationWarning) as rec:
        mgr = CheckpointManager(_store(tmp_path), **{kwarg: value})
    assert len(rec) == 1
    assert _get(mgr.policy, path) == value
    mgr.close()


def test_legacy_defaults_equal_policy_defaults():
    with pytest.warns(DeprecationWarning):
        assert CheckpointPolicy.from_legacy_kwargs() == CheckpointPolicy()


@pytest.mark.parametrize("bad_kwargs,match", [
    ({"mode": "bogus"}, r"mode must be one of"),
    ({"chunking": "bogus"}, r"chunking must be one of"),
    ({"scan_backend": "bogus"}, r"scan_backend must be one of"),
    ({"codec": "bogus"}, r"unknown codec"),
    ({"chunk_size": 0}, r"chunk_size must be positive"),
])
def test_validation_error_parity(bad_kwargs, match, tmp_path):
    """The legacy path and the policy constructor reject bad values with
    the SAME ValueError."""
    with pytest.raises(ValueError, match=match), \
            warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        CheckpointManager(_store(tmp_path), **bad_kwargs)
    with pytest.raises(ValueError, match=match):
        CheckpointPolicy().with_overrides(**bad_kwargs)


def test_unknown_legacy_kwarg_rejected(tmp_path):
    with pytest.raises(TypeError, match="nonsense"):
        CheckpointManager(_store(tmp_path), nonsense=1)


def test_policy_and_legacy_kwargs_are_mutually_exclusive(tmp_path):
    with pytest.raises(TypeError, match="not both"):
        CheckpointManager(_store(tmp_path), policy=CheckpointPolicy(),
                          retain=2)


def test_policy_constructor_emits_no_deprecation(tmp_path):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        mgr = CheckpointManager(_store(tmp_path),
                                policy=make_ckpt_policy(codec="raw"))
    mgr.close()


# ---------------------------------------------------------------------------
# section validation and composition
# ---------------------------------------------------------------------------

def test_new_pipeline_knob_validation():
    with pytest.raises(ValueError, match="persist_queue_depth"):
        PipelinePolicy(persist_queue_depth=0)
    with pytest.raises(ValueError, match="host_bytes_budget"):
        PipelinePolicy(host_bytes_budget=-1)
    assert PipelinePolicy(io_threads=1,
                          persist_queue_depth=4).effective_queue_depth == 1
    assert PipelinePolicy(io_threads=8,
                          persist_queue_depth=4).effective_queue_depth == 4


def test_sections_accept_plain_dicts():
    p = CheckpointPolicy(mode="incremental",
                         chunking={"scheme": "cdc", "chunk_size": 4096},
                         pipeline={"io_threads": 2})
    assert isinstance(p.chunking, ChunkingPolicy)
    assert p.chunking.scheme == "cdc" and p.pipeline.io_threads == 2
    assert isinstance(p.durability, DurabilityPolicy)
    assert isinstance(p.codec, CodecPolicy)
    with pytest.raises(TypeError, match="chunking"):
        CheckpointPolicy(chunking=42)


# ---------------------------------------------------------------------------
# dict round trip (the manifest-v6 embedding contract)
# ---------------------------------------------------------------------------

def test_to_dict_from_dict_round_trip():
    p = make_ckpt_policy(mode="incremental", chunking="cdc",
                         chunk_size=4096, io_threads=2,
                         persist_queue_depth=3, host_bytes_budget=1 << 20,
                         replicas=2, codec="raw", params_codec="int8")
    assert CheckpointPolicy.from_dict(p.to_dict()) == p


def test_from_dict_ignores_unknown_keys():
    d = CheckpointPolicy().to_dict()
    d["future_field"] = {"x": 1}
    d["chunking"]["future_knob"] = 99
    assert CheckpointPolicy.from_dict(d) == CheckpointPolicy()


def test_from_dict_rejects_garbage():
    with pytest.raises((TypeError, ValueError)):
        CheckpointPolicy.from_dict("not a mapping")
    with pytest.raises((TypeError, ValueError)):
        CheckpointPolicy.from_dict({"mode": "bogus"})
    with pytest.raises((TypeError, ValueError)):
        CheckpointPolicy.from_dict({"chunking": "not a mapping"})


# ---------------------------------------------------------------------------
# override merging (CLI flags, env vars)
# ---------------------------------------------------------------------------

def test_with_overrides_skips_none_and_rejects_unknown():
    base = make_ckpt_policy(io_threads=2)
    merged = base.with_overrides(codec=None, retain=9)
    assert merged.codec.codec is None           # None never clobbers
    assert merged.durability.retain == 9
    assert merged.pipeline.io_threads == 2      # base preserved
    with pytest.raises(TypeError, match="unknown checkpoint policy"):
        base.with_overrides(frobnicate=1)


def test_from_env_merges_typed_overrides():
    env = {"REPRO_CKPT_IO_THREADS": "6",
           "REPRO_CKPT_PERSIST_QUEUE_DEPTH": "2",
           "REPRO_CKPT_HOST_BYTES_BUDGET": str(64 << 20),
           "REPRO_CKPT_KEEPALIVE_S": "45.5",
           "REPRO_CKPT_ASYNC_DRAIN_TO_SLOW": "false",
           "REPRO_CKPT_CHUNKING": "cdc",
           "REPRO_CKPT_MODE": "",               # empty = unset
           "UNRELATED": "zzz"}
    p = CheckpointPolicy.from_env(env, base=make_ckpt_policy(retain=7))
    assert p.pipeline.io_threads == 6
    assert p.pipeline.persist_queue_depth == 2
    assert p.pipeline.host_bytes_budget == 64 << 20
    assert p.pipeline.async_drain is False
    assert p.durability.keepalive_s == 45.5
    assert p.chunking.scheme == "cdc"
    assert p.mode == "full"                     # empty var ignored
    assert p.durability.retain == 7             # base preserved


def test_async_drain_policy_controls_store_drain_mode(tmp_path):
    """async_drain=None leaves the store as constructed; an explicit
    value overrides it (the legacy ``async_drain_to_slow`` kwarg was a
    dead parameter before the policy redesign — now it is real)."""
    store = TieredStore(Tier("fast", tmp_path / "f"),
                        Tier("slow", tmp_path / "s"), drain_async=False)
    mgr = CheckpointManager(store, policy=make_ckpt_policy(codec="raw"))
    assert store.drain_async is False           # None = hands off
    mgr.close()
    store2 = TieredStore(Tier("fast", tmp_path / "f2"),
                         Tier("slow", tmp_path / "s2"), drain_async=False)
    mgr2 = CheckpointManager(store2, policy=make_ckpt_policy(
        codec="raw", async_drain_to_slow=True))
    assert store2.drain_async is True
    mgr2.close()
