"""Storage tiers: throttling, capacity, tiered drain/evict/locate."""
import time

import pytest

from repro.core.storage import Tier, TieredStore


def test_throttle_enforces_bandwidth(tmp_path):
    bw = 20e6  # 20 MB/s
    tier = Tier("slow", tmp_path, bw_bytes_per_s=bw)
    data = b"x" * int(10e6)  # 10 MB
    t0 = time.monotonic()
    tier.write_file("f.bin", data)
    dt = time.monotonic() - t0
    assert dt >= 0.25  # ≥ (10MB - 1s bucket) / 20MB/s × safety margin


def test_unthrottled_is_fast(tmp_path):
    tier = Tier("fast", tmp_path)
    t0 = time.monotonic()
    tier.write_file("f.bin", b"x" * int(10e6))
    assert time.monotonic() - t0 < 1.0


def test_tiered_drain_and_evict(tmp_path):
    fast = Tier("fast", tmp_path / "fast")
    slow = Tier("slow", tmp_path / "slow")
    store = TieredStore(fast, slow, drain_async=True)
    (fast.root / "step_1").mkdir()
    (fast.root / "step_1" / "a.bin").write_bytes(b"hello")
    store.drain_step("step_1")
    store.wait_drained()
    assert (slow.root / "step_1" / "a.bin").read_bytes() == b"hello"
    assert store.locate("step_1/a.bin").name == "fast"
    store.evict_fast("step_1")
    assert store.locate("step_1/a.bin").name == "slow"
    assert store.locate("step_1/nope.bin") is None


def test_capacity_accounting(tmp_path):
    tier = Tier("t", tmp_path, capacity_bytes=1000)
    tier.write_file("a", b"x" * 600)
    assert tier.free_bytes() == 400
