"""Storage tiers: throttling, capacity, tiered drain/evict/locate, the
overwrite accounting regression, remote-tier ranged reads, tmp sweep."""
import os
import time

import pytest

from repro.core.storage import RemoteTier, Tier, TieredStore


def test_throttle_enforces_bandwidth(tmp_path):
    bw = 20e6  # 20 MB/s
    tier = Tier("slow", tmp_path, bw_bytes_per_s=bw)
    data = b"x" * int(10e6)  # 10 MB
    t0 = time.monotonic()
    tier.write_file("f.bin", data)
    dt = time.monotonic() - t0
    assert dt >= 0.25  # ≥ (10MB - 1s bucket) / 20MB/s × safety margin


def test_unthrottled_is_fast(tmp_path):
    tier = Tier("fast", tmp_path)
    t0 = time.monotonic()
    tier.write_file("f.bin", b"x" * int(10e6))
    assert time.monotonic() - t0 < 1.0


def test_tiered_drain_and_evict(tmp_path):
    fast = Tier("fast", tmp_path / "fast")
    slow = Tier("slow", tmp_path / "slow")
    store = TieredStore(fast, slow, drain_async=True)
    (fast.root / "step_1").mkdir()
    (fast.root / "step_1" / "a.bin").write_bytes(b"hello")
    store.drain_step("step_1")
    store.wait_drained()
    assert (slow.root / "step_1" / "a.bin").read_bytes() == b"hello"
    assert store.locate("step_1/a.bin").name == "fast"
    store.evict_fast("step_1")
    assert store.locate("step_1/a.bin").name == "slow"
    assert store.locate("step_1/nope.bin") is None


def test_capacity_accounting(tmp_path):
    tier = Tier("t", tmp_path, capacity_bytes=1000)
    tier.write_file("a", b"x" * 600)
    assert tier.free_bytes() == 400


def test_overwrite_does_not_double_count_used(tmp_path):
    """The regression this PR fixes: rewriting the same file (LATEST,
    _CAS/refs.json — every save) must NOT keep charging `_used`, or a
    capacity-capped tier drifts into false SpaceError preflights."""
    tier = Tier("t", tmp_path, capacity_bytes=10_000)
    for _ in range(20):
        tier.write_file("LATEST", b"x" * 100)
    assert tier._used == 100
    assert tier.free_bytes() == 9_900
    # shrinking and growing overwrites both settle on the current size
    tier.write_file("LATEST", b"x" * 40)
    assert tier._used == 40
    tier.write_file("LATEST", b"x" * 250, atomic=True)
    assert tier._used == 250
    tier.delete_file("LATEST")
    assert tier._used == 0


def test_read_into_missing_file_returns_false(tmp_path):
    """A vanished object must send the caller to the verified-fallback
    path, not crash a restore pool worker."""
    tier = Tier("t", tmp_path)
    assert tier.read_into("nope.bin", memoryview(bytearray(8))) is False
    remote = RemoteTier("r", tmp_path / "r")
    assert remote.read_into("nope.bin", memoryview(bytearray(8))) is False
    assert remote.read_range("nope.bin", memoryview(bytearray(8)), 0) is False


def test_read_into_pays_the_token_bucket(tmp_path):
    """Bytes read via direct placement pay bandwidth BEFORE the return,
    same as read_file — short-circuiting would corrupt the io-sweep A/B."""
    bw = 20e6
    payload = b"x" * int(10e6)
    (tmp_path / "f.bin").write_bytes(payload)
    # construct AFTER the setup write: the token bucket starts accruing
    # at construction, and a slow 9p write would otherwise pre-fill it
    tier = Tier("slow", tmp_path, bw_bytes_per_s=bw)
    buf = bytearray(len(payload))
    t0 = time.monotonic()
    assert tier.read_into("f.bin", memoryview(buf)) is True
    assert time.monotonic() - t0 >= 0.25  # ≥ (10MB - 1s bucket) / 20MB/s
    assert bytes(buf) == payload


def test_read_into_length_mismatch(tmp_path):
    tier = Tier("t", tmp_path)
    tier.write_file("f.bin", b"abcdef")
    assert tier.read_into("f.bin", memoryview(bytearray(4))) is False
    assert tier.read_into("f.bin", memoryview(bytearray(8))) is False
    assert tier.read_into("f.bin", memoryview(bytearray(6))) is True


def test_remote_tier_multipart_ranged_reads(tmp_path):
    """A read larger than part_bytes is issued as multipart ranged GETs,
    each paying the per-request latency; PUTs are always atomic."""
    payload = os.urandom(10_000)
    remote = RemoteTier("obj", tmp_path, part_bytes=4096,
                        request_latency_s=0.01)
    remote.write_file("o.bin", payload, atomic=False)  # forced atomic anyway
    assert not list(tmp_path.rglob("*.tmp-*"))
    buf = bytearray(len(payload))
    t0 = time.monotonic()
    assert remote.read_into("o.bin", memoryview(buf)) is True
    # ceil(10000/4096) = 3 ranged GETs at 10ms each
    assert time.monotonic() - t0 >= 0.03
    assert bytes(buf) == payload
    assert remote.read_file("o.bin") == payload
    with pytest.raises(ValueError):
        RemoteTier("bad", tmp_path / "bad", part_bytes=0)


def test_tiered_store_reads_fall_through_to_remote(tmp_path):
    fast = Tier("fast", tmp_path / "fast")
    remote = RemoteTier("obj", tmp_path / "remote")
    store = TieredStore(fast, remote=remote)
    remote.write_file("step_1/a.bin", b"cold")
    assert store.locate("step_1/a.bin").name == "obj"
    assert [t.name for t in store.tiers()] == ["fast", "obj"]


def test_sweep_tmp_litter_after_crash_in_write(tmp_path, monkeypatch):
    """Kill inside write_file(atomic=True) → orphan .tmp-* litter that no
    commit path revisits; sweep_tmp_litter removes exactly those FILES
    while leaving staging DIRS (gc_staging territory) alone."""
    tier = Tier("fast", tmp_path)
    tier.write_file("LATEST", b"ok")

    def boom(src, dst):
        raise OSError("killed before rename")
    with monkeypatch.context() as m:
        m.setattr(os, "rename", boom)
        with pytest.raises(OSError):
            tier.write_file("LATEST", b"torn", atomic=True)
    litter = list(tmp_path.rglob("*.tmp-*"))
    assert len(litter) == 1 and litter[0].is_file()
    # a staging DIR and its contents are not this sweep's to remove
    staging = tmp_path / "step_9.tmp-deadbeef"
    staging.mkdir()
    (staging / "shard_0.bin").write_bytes(b"in-flight")
    (staging / "inner.tmp-1234").write_bytes(b"nested litter")
    assert tier.sweep_tmp_litter() == 1
    assert staging.exists()
    assert (staging / "shard_0.bin").exists()
    assert (staging / "inner.tmp-1234").exists()
    assert (tmp_path / "LATEST").read_bytes() == b"ok"
    assert tier.sweep_tmp_litter() == 0


def test_maintenance_sweeps_fast_tier_tmp_litter(tmp_path):
    """The crash-matrix point: after a kill inside an atomic fast-tier
    write, the next maintenance round leaves zero orphan tmp files."""
    import jax.numpy as jnp

    from conftest import make_ckpt_policy
    from repro.core.checkpoint import CheckpointManager

    fast = Tier("fast", tmp_path / "fast")
    mgr = CheckpointManager(TieredStore(fast),
                            policy=make_ckpt_policy(mode="incremental"))
    mgr.save({"step": jnp.asarray(1, jnp.int32)}, 1)
    from repro.core.atomic import committed_dir
    (fast.root / "LATEST.tmp-feed").write_bytes(b"orphan")
    (committed_dir(fast.root, 1) / "extra.json.tmp-beef").write_bytes(
        b"orphan")
    report = mgr.gc()
    assert report["fast_tmp_removed"] == 2
    assert not list(fast.root.rglob("*.tmp-*"))
    mgr.close()
