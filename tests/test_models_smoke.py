"""Per-arch reduced-config smoke tests (assignment requirement): one
forward/train step on CPU, asserting output shapes and no NaNs; plus
prefill↔decode consistency."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, CONFIGS, reduced
from repro.models import Model
from repro.optim import make_optimizer
from repro.train.steps import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    if cfg.family == "encoder":
        return {
            "features": jax.random.normal(KEY, (B, S, cfg.d_model)),
            "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
            "mask": jnp.ones((B, S), bool),
        }
    return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = reduced(CONFIGS[arch])
    model = Model(cfg)
    params = model.init(KEY)
    loss, metrics = jax.jit(model.loss)(params, _batch(cfg))
    assert jnp.isfinite(loss), metrics
    assert 1.0 < float(loss) < 20.0
    # one full optimizer step
    opt = make_optimizer(cfg)
    step = make_train_step(model, opt)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32),
             "rng": jax.random.key_data(KEY)}
    new_state, m = jax.jit(step)(state, _batch(cfg))
    assert int(new_state["step"]) == 1
    assert jnp.isfinite(m["loss"]) and jnp.isfinite(m["grad_norm"])
    assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
               for x in jax.tree.leaves(new_state["params"]))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if CONFIGS[a].family != "encoder"])
def test_prefill_matches_decode(arch):
    cfg = reduced(CONFIGS[arch])
    if cfg.moe is not None:
        # no-drop capacity so token dropping can't cause divergence
        cfg = replace(cfg, moe=replace(cfg.moe,
                                       capacity_factor=float(cfg.moe.n_experts)))
    model = Model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_pf, _ = jax.jit(model.prefill)(params, toks)
    cache = model.init_cache(B, S)
    dec = jax.jit(model.decode_step)
    for t in range(S):
        logits_dec, cache = dec(params, cache, toks[:, t])
    assert jnp.max(jnp.abs(logits_pf - logits_dec)) < 2e-3, arch


@pytest.mark.parametrize("arch", ["gemma3-1b", "recurrentgemma-9b",
                                  "mamba2-780m"])
def test_long_context_decode_state_is_bounded(arch):
    """long_500k archs: decode state must not grow with absolute position."""
    cfg = reduced(CONFIGS[arch])
    model = Model(cfg)
    c64 = model.init_cache(1, 64)
    c128 = model.init_cache(1, 128)
    n64 = sum(x.size for x in jax.tree.leaves(c64))
    n128 = sum(x.size for x in jax.tree.leaves(c128))
    if cfg.family in ("ssm",):
        assert n64 == n128  # pure-SSM state is O(1)
    g = cfg.global_attn_fraction
    # state growth only from global-attention layers (≤ fraction of layers)
    assert n128 <= n64 * 2.2


def test_encoder_shapes():
    cfg = reduced(CONFIGS["hubert-xlarge"])
    model = Model(cfg)
    params = model.init(KEY)
    feats = jax.random.normal(KEY, (2, 24, cfg.d_model))
    logits = jax.jit(model.encode)(params, feats)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
