"""Manifest v5: CDC shard records carry their chunk length list, so the
restore-side direct-placement path (readinto at prefix-sum offsets, no
assemble/join copy) extends to content-defined chunking.

Covers: the v5 writer emits well-formed length lists; v5 CDC restores take
the fixed-offset path (join-copy reassembly is asserted NOT to run);
damage still falls back to the verified join path and heals; v4/v3
history written by older writers restores under the v5 reader and
mixed-version GC leaks nothing."""
import json
import zlib
from pathlib import Path

import jax
import numpy as np
import pytest

from conftest import make_ckpt_policy
from repro.core import atomic, cas
from repro.core.cas import ChunkStore
from repro.core.checkpoint import FORMAT_VERSION, CheckpointManager
from repro.core.storage import Tier, TieredStore


def _store(tmp_path: Path) -> TieredStore:
    return TieredStore(Tier("fast", tmp_path / "fast"))


def _state(seed=0, n=40_000):
    rng = np.random.default_rng(seed)
    import jax.numpy as jnp
    return {"params": {"w": jnp.asarray(
        rng.standard_normal((n,), dtype=np.float32))}}


def _abstract(state):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)


def _mgr(tmp_path, chunking="cdc", io_threads=4, **kw):
    return CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
        codec="raw", n_writers=2, mode="incremental", chunk_size=512,
        chunking=chunking, io_threads=io_threads, **kw))


def _manifest_path(root: Path, step: int) -> Path:
    return root / f"step_{step:08d}" / atomic.MANIFEST


def _cdc_records(manifest):
    return [s for rec in manifest["leaves"].values()
            for s in rec["shards"]
            if s.get("chunking") == "cdc"]


# ---------------------------------------------------------------------------
# v5 writer output
# ---------------------------------------------------------------------------

def test_v5_writer_emits_chunk_len_lists(tmp_path):
    mgr = _mgr(tmp_path)
    state = _state()
    mgr.save(state, 1)
    m = json.loads(_manifest_path(mgr.store.root, 1).read_text())
    assert m["format"] == FORMAT_VERSION
    assert m["chunk_bounds"] == [mgr._chunker.min_size,
                                 mgr._chunker.avg_size,
                                 mgr._chunker.max_size]
    recs = _cdc_records(m)
    assert recs
    for s in recs:
        assert len(s["chunk_lens"]) == len(s["chunks"])
        assert sum(s["chunk_lens"]) == s["payload_bytes"]
        assert all(n > 0 for n in s["chunk_lens"])


def test_v5_serial_writer_also_emits_chunk_lens(tmp_path):
    """The serial engine records the same metadata (its IO behaviour is
    unchanged — lengths fall out of the chunk loop it already runs)."""
    mgr = _mgr(tmp_path, io_threads=1)
    mgr.save(_state(), 1)
    m = json.loads(_manifest_path(mgr.store.root, 1).read_text())
    for s in _cdc_records(m):
        assert sum(s["chunk_lens"]) == s["payload_bytes"]


# ---------------------------------------------------------------------------
# direct placement on restore
# ---------------------------------------------------------------------------

def test_v5_cdc_restore_uses_direct_placement(tmp_path, monkeypatch):
    """Acceptance: same-topology CDC restores must take the fixed-offset
    read path — the join-copy reassembly is asserted unreachable."""
    mgr = _mgr(tmp_path)
    state = _state()
    mgr.save(state, 1)

    calls = {"direct": 0}
    real_direct = ChunkStore.read_payload_direct

    def counting_direct(self, *a, **kw):
        calls["direct"] += 1
        return real_direct(self, *a, **kw)

    def forbidden_join(self, *a, **kw):
        raise AssertionError("join-path read_payload used for a v5 CDC "
                             "record on the pipelined engine")

    monkeypatch.setattr(ChunkStore, "read_payload_direct", counting_direct)
    monkeypatch.setattr(ChunkStore, "read_payload", forbidden_join)
    restored, _ = mgr.restore(_abstract(state))
    assert calls["direct"] > 0
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


def test_v5_direct_placement_damage_falls_back_and_heals(tmp_path):
    """A corrupted primary object fails the crc gate; the read drops back
    to the verified join path and heals through the buddy replica."""
    mgr = _mgr(tmp_path, replicas=2)
    state = _state()
    mgr.save(state, 1)
    m = json.loads(_manifest_path(mgr.store.root, 1).read_text())
    digest = _cdc_records(m)[0]["chunks"][0]
    obj = mgr.store.fast.root / cas.object_rel(digest)
    obj.write_bytes(b"\x00" * obj.stat().st_size)      # torn primary
    restored, _ = mgr.restore(_abstract(state))
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


def test_v5_direct_placement_rejects_inconsistent_lens(tmp_path, rng):
    """A length list that disagrees with the digest list (or payload size)
    must not be trusted for placement — the verified path arbitrates."""
    store = _store(tmp_path)
    cs = ChunkStore(store, chunk_size=128, io_threads=4)
    payload = rng.bytes(1000)
    digests, _ = cs.put_payload(payload)
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    good_lens = [128] * 7 + [104]
    got = cs.read_payload_direct(digests, len(payload), crc, good_lens)
    assert bytes(got) == payload
    for bad in ([128] * 8,                 # sum > payload
                good_lens[:-1],            # count mismatch
                [1000] + [0] * 7):         # zero-length entries
        got = cs.read_payload_direct(digests, len(payload), crc, bad)
        assert bytes(got) == payload       # verified join path served it


# ---------------------------------------------------------------------------
# cross-version history
# ---------------------------------------------------------------------------

def _downgrade(root: Path, step: int, fmt: int):
    """Rewrite a committed v5 manifest as its older-writer equivalent."""
    mpath = _manifest_path(root, step)
    m = json.loads(mpath.read_text())
    assert m["format"] == FORMAT_VERSION
    m["format"] = fmt
    if fmt < 6:
        m.pop("policy", None)
    if fmt < 5:
        m.pop("chunk_bounds", None)
    for rec in m["leaves"].values():
        for s in rec["shards"]:
            if fmt < 5:
                s.pop("chunk_lens", None)
            if fmt < 4:
                s.pop("chunking", None)
    if fmt < 4:
        m.pop("chunking", None)
    mpath.write_text(json.dumps(m))


def test_v5_reader_restores_v4_history(tmp_path):
    """v5↔v4 round trip: a v4-written CDC step (no length lists) restores
    bit-exact under the v5 reader — through the join path, since offsets
    are unknowable — and a v5 step written on top restores too."""
    mgr = _mgr(tmp_path, retain=4)
    s1, s2 = _state(1), _state(2)
    mgr.save(s1, 1)
    _downgrade(mgr.store.root, 1, 4)
    mgr2 = _mgr(tmp_path, retain=4)
    assert mgr2.load_manifest(1)["format"] == 4
    r1, _ = mgr2.restore(_abstract(s1), step=1)
    np.testing.assert_array_equal(np.asarray(s1["params"]["w"]),
                                  np.asarray(r1["params"]["w"]))
    mgr2.save(s2, 2)
    assert mgr2.load_manifest(2)["format"] == FORMAT_VERSION
    for step, expect in ((1, s1), (2, s2)):
        r, _ = mgr2.restore(_abstract(expect), step=step)
        np.testing.assert_array_equal(np.asarray(expect["params"]["w"]),
                                      np.asarray(r["params"]["w"]))


def test_gc_over_mixed_v3_v4_v5_history_leaks_nothing(tmp_path):
    """Mark-and-sweep over a store holding v3 + v4 + v5 steps: every
    version's chunks stay live (no sweep of referenced objects), orphans
    are reclaimed, and every step still restores."""
    mgr = _mgr(tmp_path, retain=8)
    states = {s: _state(s) for s in (1, 2, 3)}
    for step, st in states.items():
        mgr.save(st, step)
    _downgrade(mgr.store.root, 1, 3)
    _downgrade(mgr.store.root, 2, 4)
    _downgrade(mgr.store.root, 3, 5)
    mgr2 = _mgr(tmp_path, retain=8)
    # an unreferenced orphan object for the sweep to prove itself on
    orphan = mgr2.store.fast.root / cas.object_rel("ff" * 16)
    orphan.parent.mkdir(parents=True, exist_ok=True)
    orphan.write_bytes(b"junk")
    mgr2.gc()
    assert not orphan.exists()
    assert mgr2.chunks.fsck(mgr2._live_chunk_refs())["ok"]
    for step, st in states.items():
        assert mgr2.load_manifest(step)["format"] == {1: 3, 2: 4, 3: 5}[step]
        assert "policy" not in mgr2.load_manifest(step)
        r, _ = mgr2.restore(_abstract(st), step=step)
        np.testing.assert_array_equal(np.asarray(st["params"]["w"]),
                                      np.asarray(r["params"]["w"]))


def test_future_manifest_format_rejected(tmp_path):
    mgr = _mgr(tmp_path)
    mgr.save(_state(), 1)
    mpath = _manifest_path(mgr.store.root, 1)
    m = json.loads(mpath.read_text())
    m["format"] = FORMAT_VERSION + 1
    mpath.write_text(json.dumps(m))
    from repro.core.errors import CkptError
    with pytest.raises(CkptError):
        _mgr(tmp_path).load_manifest(1)
