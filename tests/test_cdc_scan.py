"""Accelerated gear-scan backends: cut-point parity against the numpy
oracle (boundaries ARE the dedup keyspace — a one-byte drift re-writes
history), async scan tickets, auto backend resolution, and the zero-copy
chunker contract."""
import numpy as np
import pytest

from repro.core import cdc_scan
from repro.core.cdc import GearChunker
from repro.core.cdc_scan import (GearScanner, ScanTicket, WINDOW,
                                 scan_candidates_numpy)


def _masks(avg=1024):
    ck = GearChunker(avg)
    return int(ck.mask_strict), int(ck.mask_loose)


def _assert_scan_parity(scanner, ref_scanner, payload):
    s, l = scanner.scan(payload)
    rs, rl = ref_scanner.scan(payload)
    np.testing.assert_array_equal(s, rs)
    np.testing.assert_array_equal(l, rl)


# ---------------------------------------------------------------------------
# kernel-vs-numpy parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("size", [
    0, 1, WINDOW - 1, WINDOW, WINDOW + 1,          # below/at the window
    256, 1024,                                     # == min_size territory
    65_536, 300_000,                               # multi-block
    cdc_scan.SEGMENT_BYTES + 12_345,               # crosses a segment cut
    # > MAX_INFLIGHT_SEGMENTS segments: exercises the windowed deferred
    # re-dispatch inside ScanTicket.result()
    cdc_scan.SEGMENT_BYTES * (cdc_scan.MAX_INFLIGHT_SEGMENTS + 1) + 54_321,
])
def test_jnp_candidate_parity(size, rng):
    ms, ml = _masks()
    jnp_s = GearScanner(ms, ml, backend="jnp")
    ref = GearScanner(ms, ml, backend="numpy")
    _assert_scan_parity(jnp_s, ref, rng.bytes(size))


def test_jnp_parity_fuzz(rng):
    """Property fuzz: random sizes × random mask pairs, byte-identical
    candidate sets. Sizes deliberately straddle block and bucket edges."""
    for avg in (512, 4096):
        ms, ml = _masks(avg)
        jnp_s = GearScanner(ms, ml, backend="jnp")
        ref = GearScanner(ms, ml, backend="numpy")
        for _ in range(10):
            size = int(rng.integers(0, 200_000))
            _assert_scan_parity(jnp_s, ref, rng.bytes(size))
    # block/bucket edge sizes (BLOCK columns × _MIN_COLS bucket)
    ms, ml = _masks()
    jnp_s = GearScanner(ms, ml, backend="jnp")
    ref = GearScanner(ms, ml, backend="numpy")
    B = cdc_scan.BLOCK
    for size in (B - 1, B, B + 1, 64 * B - 1, 64 * B, 64 * B + 1):
        _assert_scan_parity(jnp_s, ref, rng.bytes(size))


def test_low_entropy_payload_parity():
    """Constant bytes: either a boundary everywhere or nowhere — the
    force-cut-at-max regime must agree exactly."""
    ms, ml = _masks()
    jnp_s = GearScanner(ms, ml, backend="jnp")
    ref = GearScanner(ms, ml, backend="numpy")
    for fill in (b"\x00", b"\xa7"):
        _assert_scan_parity(jnp_s, ref, fill * 100_000)


@pytest.mark.parametrize("size", [1000, 70_000, 200_001])
def test_pallas_interpret_parity(size, rng):
    """The Pallas kernel, run through the interpreter (this box has no
    accelerator), produces byte-identical candidates."""
    ms, ml = _masks()
    pal = GearScanner(ms, ml, backend="pallas", pallas_interpret=True)
    ref = GearScanner(ms, ml, backend="numpy")
    _assert_scan_parity(pal, ref, rng.bytes(size))


def test_cut_point_parity_through_chunker(rng):
    """End-to-end: GearChunker cut points (min/avg/max discipline applied
    over the candidate sets) are identical across backends, including the
    <WINDOW, ==min_size and force-cut-at-max-tail shapes."""
    for payload in (b"", rng.bytes(WINDOW - 1), rng.bytes(256),
                    rng.bytes(100_000), b"\x00" * 50_000,
                    rng.bytes(1 << 20)):
        ref = GearChunker(1024).cut_points(payload)
        assert GearChunker(1024, scan_backend="jnp") \
            .cut_points(payload) == ref
        assert b"".join(GearChunker(1024, scan_backend="jnp")
                        .chunk(payload)) == payload


# ---------------------------------------------------------------------------
# scanner API
# ---------------------------------------------------------------------------

def test_scan_async_matches_sync(rng):
    ms, ml = _masks()
    sc = GearScanner(ms, ml, backend="jnp")
    payloads = [rng.bytes(n) for n in (50_000, 120_000, 80_000)]
    tickets = [sc.scan_async(p) for p in payloads]
    assert all(isinstance(t, ScanTicket) for t in tickets)
    for t, p in zip(tickets, payloads):
        s, l = t.result()
        rs, rl = sc.scan(p)          # ticket result is memoized + stable
        np.testing.assert_array_equal(s, rs)
        np.testing.assert_array_equal(l, rl)
        s2, l2 = t.result()
        assert s2 is s and l2 is l


def test_auto_backend_size_gate(rng):
    ms, ml = _masks()
    sc = GearScanner(ms, ml, backend="auto")
    assert sc.resolve(1000) == "numpy"
    # large payloads pick an accelerated backend (jnp on a CPU-only host,
    # pallas when an accelerator is attached)
    assert sc.resolve(cdc_scan.MIN_ACCEL_BYTES) in ("jnp", "pallas")


def test_pallas_without_accelerator_falls_back(rng):
    import jax
    if jax.default_backend() != "cpu":
        pytest.skip("accelerator attached — fallback not exercised")
    ms, ml = _masks()
    sc = GearScanner(ms, ml, backend="pallas")
    assert sc.resolve(1 << 20) == "jnp"
    _assert_scan_parity(sc, GearScanner(ms, ml, backend="numpy"),
                        rng.bytes(50_000))


def test_invalid_backend_rejected():
    ms, ml = _masks()
    with pytest.raises(ValueError):
        GearScanner(ms, ml, backend="cuda")
    with pytest.raises(ValueError):
        GearChunker(1024, scan_backend="nope")
    with pytest.raises(ValueError):
        # loose mask must nest inside the strict mask
        GearScanner(0x0F, 0xF0)


def test_oracle_matches_legacy_semantics(rng):
    """The extracted oracle is literally the PR-2 scan: empty below the
    window, end offsets in (WINDOW, n]."""
    ms, ml = _masks()
    s, l = scan_candidates_numpy(np.frombuffer(rng.bytes(WINDOW), np.uint8),
                                 ms, ml)
    assert len(s) == 0 and len(l) == 0
    data = np.frombuffer(rng.bytes(100_000), np.uint8)
    s, l = scan_candidates_numpy(data, ms, ml)
    assert set(s) <= set(l)
    if len(l):
        assert l.min() >= WINDOW and l.max() <= len(data)


# ---------------------------------------------------------------------------
# zero-copy chunking
# ---------------------------------------------------------------------------

def test_chunk_returns_zero_copy_views(rng):
    payload = rng.bytes(100_000)
    chunks = GearChunker(1024).chunk(payload)
    assert all(isinstance(c, memoryview) for c in chunks)
    # views alias the payload, not copies of it
    assert all(c.obj is payload for c in chunks)
    assert b"".join(chunks) == payload


def test_chunk_accepts_ndarray_views(rng):
    arr = np.frombuffer(rng.bytes(64_000), np.uint8)
    chunks = GearChunker(1024).chunk(arr)
    assert b"".join(chunks) == arr.tobytes()
    # slices share the array's memory
    assert all(np.shares_memory(np.frombuffer(c, np.uint8), arr)
               for c in chunks)
