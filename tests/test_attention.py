"""XLA chunked-attention paths vs the naive oracle, values AND gradients."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.layers import (attention_decode, attention_full,
                                 attention_local, attention_reference)

KEY = jax.random.PRNGKey(42)


def _qkv(B, Sq, Sk, H, K, D, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, Sq, H, D), dtype),
            jax.random.normal(ks[1], (B, Sk, K, D), dtype),
            jax.random.normal(ks[2], (B, Sk, K, D), dtype))


@pytest.mark.parametrize("B,S,H,K,D,chunk,causal,cap", [
    (2, 64, 4, 4, 16, 16, True, 0.0),
    (1, 96, 4, 2, 32, 32, True, 0.0),     # GQA, non-divisible pad
    (2, 64, 8, 1, 16, 64, True, 50.0),    # MQA + softcap
    (1, 50, 2, 2, 16, 16, False, 0.0),    # non-causal, padding
])
def test_full_matches_reference(B, S, H, K, D, chunk, causal, cap):
    q, k, v = _qkv(B, S, S, H, K, D)
    out = attention_full(q, k, v, causal=causal, softcap=cap, chunk=chunk,
                         chunk_q=chunk)
    ref = attention_reference(q, k, v, causal=causal, softcap=cap)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("B,S,H,K,D,W,chunk", [
    (2, 64, 4, 2, 16, 16, 16),
    (1, 80, 4, 1, 16, 24, 32),   # window not multiple of chunk
    (2, 48, 2, 2, 16, 48, 16),   # window == S
])
def test_local_matches_reference(B, S, H, K, D, W, chunk):
    q, k, v = _qkv(B, S, S, H, K, D)
    out = attention_local(q, k, v, window=W, chunk=chunk)
    ref = attention_reference(q, k, v, causal=True, window=W)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_gradients_match_reference():
    q, k, v = _qkv(1, 32, 32, 4, 2, 16)

    def f_chunked(q, k, v):
        return attention_full(q, k, v, causal=True, chunk=8, chunk_q=8).sum()

    def f_ref(q, k, v):
        return attention_reference(q, k, v, causal=True).sum()

    g1 = jax.grad(f_chunked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert jnp.max(jnp.abs(a - b)) < 5e-5


def test_decode_matches_reference_tail():
    """Decoding the last position over a cache equals full attention's last
    row, including ring-buffer local caches."""
    B, S, H, K, D = 2, 33, 4, 2, 16
    q, k, v = _qkv(B, S, S, H, K, D)
    ref = attention_reference(q, k, v, causal=True)
    out = attention_decode(q[:, -1:], k, v, kv_len=S)
    assert jnp.max(jnp.abs(out - ref[:, -1:])) < 2e-5


def test_bf16_path_close():
    q, k, v = _qkv(2, 64, 64, 4, 2, 32, jnp.bfloat16)
    out = attention_full(q, k, v, causal=True, chunk=16)
    ref = attention_reference(q, k, v, causal=True)
    assert jnp.max(jnp.abs(out.astype(jnp.float32)
                           - ref.astype(jnp.float32))) < 3e-2
