"""Content-addressed chunk store: chunking/digest properties, object-store
semantics (dedup, replicas, corruption), refcount invariants across
save/save/gc, and the headline dedup guarantee — re-saving identical state
writes ~0 new object bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cas
from repro.core import codec as codec_mod
from repro.core.cas import ChunkStore, chunk_digest, object_rel, split_payload
from conftest import make_ckpt_policy
from repro.core.checkpoint import CheckpointManager
from repro.core.errors import CorruptShardError, MissingShardError
from repro.core.storage import Tier, TieredStore

KEY = jax.random.PRNGKey(0)

CODECS = ["raw", "int8"] + (["zstd"] if codec_mod.HAVE_ZSTD else [])


def _store(tmp_path, name="fast"):
    return TieredStore(Tier(name, tmp_path / name))


def _mgr(tmp_path, **kw):
    kw.setdefault("codec", "raw")
    kw.setdefault("n_writers", 3)
    kw.setdefault("chunk_size", 512)
    kw.setdefault("mode", "incremental")
    # shared test policy: keepalive_s=60 (CI fsync stalls ≠ dead ranks)
    return CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(**kw))


def _state(dtype=jnp.float32):
    return {
        "params": {"w": jax.random.normal(KEY, (32, 16), dtype),
                   "frozen": jax.random.normal(jax.random.PRNGKey(9),
                                               (64, 8), dtype)},
        # distinct values per chunk — all-zero leaves would dedup WITHIN one
        # save (correct, but it breaks the exact per-digest refcount asserts)
        "opt": {"m": jnp.arange(32 * 16, dtype=jnp.float32).reshape(32, 16)},
        "step": jnp.asarray(0, jnp.int32),
    }


def _abstract(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


# ---------------------------------------------------------------------------
# chunking properties (hand-rolled — hypothesis is optional in this env)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("size", [0, 1, 255, 256, 257, 1000, 3 * 256 + 7])
def test_split_roundtrip_at_boundaries(size):
    rng = np.random.default_rng(size)
    payload = rng.bytes(size)
    chunks = split_payload(payload, 256)
    assert b"".join(chunks) == payload
    assert all(len(c) == 256 for c in chunks[:-1])
    if size:
        assert 1 <= len(chunks[-1]) <= 256
    else:
        assert chunks == []


def test_digest_stability_and_sensitivity():
    data = b"x" * 1000
    assert chunk_digest(data) == chunk_digest(b"x" * 1000)
    assert chunk_digest(data) != chunk_digest(b"x" * 999 + b"y")
    assert len(chunk_digest(data)) == 2 * cas.DIGEST_BYTES
    # object paths are fan-out sharded by digest prefix
    rel = object_rel(chunk_digest(data))
    assert rel.startswith(f"{cas.OBJECTS_DIR}/{chunk_digest(data)[:2]}/")


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("dtype", ["float32", "int32"])
def test_chunked_payload_roundtrip_across_codecs(tmp_path, codec, dtype,
                                                 rng):
    if codec == "int8" and dtype == "int32":
        pytest.skip("int leaves never use the lossy codec")
    arr = (rng.standard_normal((37, 13)).astype(dtype)
           if dtype == "float32"
           else rng.integers(-9, 9, (37, 13)).astype(dtype))
    payload, meta = codec_mod.encode(arr, codec)
    cs = ChunkStore(_store(tmp_path), chunk_size=100)
    digests, new = cs.put_payload(payload)
    assert new == len(payload)
    assert digests == [chunk_digest(c) for c in split_payload(payload, 100)]
    back = cs.read_payload(digests, len(payload))
    out = codec_mod.decode(back, codec, arr.shape, arr.dtype, meta)
    if codec == "int8":
        assert np.max(np.abs(out - arr)) <= np.abs(arr).max() / 127 + 1e-6
    else:
        np.testing.assert_array_equal(out, arr)


def test_put_dedups_and_get_verifies(tmp_path):
    cs = ChunkStore(_store(tmp_path), chunk_size=128)
    data = b"a" * 300
    d = chunk_digest(data)
    assert cs.put(d, data) == 300
    assert cs.put(d, data) == 0          # dedup hit
    assert cs.get(d) == data
    # corrupt the object in place → digest verification catches it
    p = cs.store.fast.root / object_rel(d)
    p.write_bytes(b"b" * 300)
    with pytest.raises(CorruptShardError):
        cs.get(d)
    with pytest.raises(MissingShardError):
        cs.get(chunk_digest(b"never stored"))


def test_replicated_objects_survive_primary_corruption(tmp_path):
    cs = ChunkStore(_store(tmp_path), chunk_size=128, replicas=2)
    data = b"c" * 200
    d = chunk_digest(data)
    assert cs.put(d, data) == 400        # primary + buddy copy
    (cs.store.fast.root / object_rel(d)).write_bytes(b"z" * 200)
    assert cs.get(d) == data             # served from .r1


def test_slow_tier_fallback(tmp_path):
    store = TieredStore(Tier("fast", tmp_path / "fast"),
                        Tier("slow", tmp_path / "slow"))
    cs = ChunkStore(store, chunk_size=128)
    data = b"d" * 64
    d = chunk_digest(data)
    cs.put(d, data)
    # simulate burst-buffer eviction: object only on the slow tier
    store.slow.write_file(object_rel(d), data)
    (store.fast.root / object_rel(d)).unlink()
    assert cs.get(d) == data


# ---------------------------------------------------------------------------
# dedup through the full checkpoint path
# ---------------------------------------------------------------------------

def test_identical_resave_writes_no_new_object_bytes(tmp_path):
    mgr = _mgr(tmp_path)
    state = _state()
    r1 = mgr.save(state, 1)
    assert r1["new_object_bytes"] > 0
    r2 = mgr.save(state, 2)
    assert r2["new_object_bytes"] == 0           # every chunk deduped
    assert r2["chunks"] == r1["chunks"]
    restored, _ = mgr.restore(_abstract(state), step=2)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partial_change_writes_only_changed_chunks(tmp_path):
    mgr = _mgr(tmp_path)
    state = _state()
    r1 = mgr.save(state, 1)
    # touch 1 of 4 leaves — steady-state cadence
    state["params"]["w"] = state["params"]["w"] + 1.0
    r2 = mgr.save(state, 2)
    assert 0 < r2["new_object_bytes"] < r1["new_object_bytes"]
    assert r2["dedup_ratio"] > 2.0
    restored, _ = mgr.restore(_abstract(state))
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


@pytest.mark.parametrize("codec", CODECS)
def test_incremental_roundtrip_across_codecs(tmp_path, codec):
    mgr = _mgr(tmp_path, codec=codec)
    state = _state()
    mgr.save(state, 1)
    restored, _ = mgr.restore(_abstract(state))
    if codec_mod.lossy(codec):
        w0 = np.asarray(state["params"]["w"])
        w1 = np.asarray(restored["params"]["w"])
        assert np.max(np.abs(w0 - w1)) <= np.abs(w0).max() / 127 + 1e-6
    else:
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cdc_mode_dedups_byte_shifted_leaf_fixed_does_not(tmp_path):
    """End-to-end acceptance property: a leaf whose bytes SHIFT between
    steps (insert-at-front churn) dedups to near-zero under
    chunking="cdc" and re-writes nearly everything under fixed-size
    chunking, at equal average chunk size."""
    rng = np.random.default_rng(7)
    base = rng.bytes(96 * 1024)

    def state_of(buf: bytes):
        return {"blob": jnp.asarray(np.frombuffer(buf, np.uint8))}

    shifted = (rng.bytes(16) + base)[:len(base)]   # 16-byte front insert
    results = {}
    for chunking in ("fixed", "cdc"):
        mgr = CheckpointManager(
            _store(tmp_path, chunking),
            policy=make_ckpt_policy(mode="incremental", codec="raw",
                                    n_writers=2, chunk_size=1024,
                                    chunking=chunking))
        mgr.save(state_of(base), 1)
        rep = mgr.save(state_of(shifted), 2)
        results[chunking] = rep["new_object_bytes"]
        restored, _ = mgr.restore(_abstract(state_of(shifted)))
        np.testing.assert_array_equal(
            np.asarray(restored["blob"]),
            np.frombuffer(shifted, np.uint8))
    # fixed-size: every boundary moved → ~everything re-written
    assert results["fixed"] > len(base) // 2
    # cdc: only chunks overlapping the edit (+ resync) re-written
    assert results["cdc"] < len(base) // 8
    assert results["cdc"] < results["fixed"]


# ---------------------------------------------------------------------------
# refcount invariants
# ---------------------------------------------------------------------------

def test_refcounts_published_and_consistent_after_saves_and_gc(tmp_path):
    mgr = _mgr(tmp_path, retain=3)
    state = _state()
    mgr.save(state, 1)
    mgr.save(state, 2)                  # identical → same digests, refs += 1
    refs = mgr.chunks.load_refs()
    assert refs and all(v == 2 for v in refs.values())
    live = mgr._live_chunk_refs()
    assert dict(live) == refs
    fsck = mgr.chunks.fsck(live)
    assert fsck["ok"], fsck

    # retention drop (retain=1) must decrement via mark-and-sweep, not leak
    mgr.retain = 1
    state["params"]["w"] = state["params"]["w"] * 2.0
    mgr.save(state, 3)                  # gc retires steps 1 and 2
    refs = mgr.chunks.load_refs()
    live = mgr._live_chunk_refs()
    assert dict(live) == refs
    assert all(v == 1 for v in refs.values())
    fsck = mgr.chunks.fsck(live)
    assert fsck["ok"], fsck
    # sweep actually reclaimed the dropped step-specific objects
    assert mgr.last_gc_report["cas"]["swept"] >= 0
    restored, _ = mgr.restore(_abstract(state))
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


def test_aborted_round_publishes_no_refs_and_gc_reclaims_orphans(tmp_path):
    """An abort must leak nothing: no refcounts published, and any chunk
    objects the dead round managed to write are swept as orphans."""
    mgr = _mgr(tmp_path, n_writers=2, max_retries=0)
    state = _state()
    mgr.save(state, 1)
    refs_before = mgr.chunks.load_refs()
    state["params"]["w"] = state["params"]["w"] + 7.0
    from repro.core.atomic import CrashInjector, CrashPoint
    from repro.core.errors import AbortedError
    try:
        mgr.save(state, 2, crash=CrashInjector("rank0_after_chunk_write"))
    except (AbortedError, CrashPoint):
        pass
    mgr2 = _mgr(tmp_path, n_writers=2)
    assert mgr2.chunks.load_refs() == refs_before
    rep = mgr2.gc()
    live = mgr2._live_chunk_refs()
    fsck = mgr2.chunks.fsck(live)
    assert fsck["ok"], fsck             # zero orphans / missing after sweep
    assert mgr2.latest_step() == 1


def test_fast_tier_eviction_bounds_burst_buffer_growth(tmp_path):
    """Two-tier store, retain=1: the slow tier keeps full history, but the
    fast tier must only pin chunks referenced by ITS OWN retained
    manifests — slow-only-referenced objects are evicted (never deleting
    the last copy). Without eviction the burst buffer grows O(history)."""
    store = TieredStore(Tier("fast", tmp_path / "fast"),
                        Tier("slow", tmp_path / "slow"), drain_async=False)
    mgr = CheckpointManager(store, policy=make_ckpt_policy(
        mode="incremental", codec="raw", n_writers=2, chunk_size=512,
        retain=1))
    state = _state()
    fast_counts = []
    for s in (1, 2, 3, 4, 5):
        state["params"]["w"] = state["params"]["w"] + float(s)
        mgr.save(state, s)
        fast_counts.append(len(
            list((store.fast.root / cas.OBJECTS_DIR).rglob("*.obj"))))
    # bounded, not linear: the last two rounds hold the same object count
    assert fast_counts[-1] == fast_counts[-2]
    assert mgr.last_gc_report["cas"]["evicted"] > 0
    # global fsck stays clean and every copy evicted from fast still has a
    # slow-tier copy: old steps restore from the slow tier alone
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
    import shutil as _sh
    _sh.rmtree(store.fast.root)
    store.fast.root.mkdir(parents=True)
    mgr2 = CheckpointManager(store, n_writers=2)
    restored, _ = mgr2.restore(_abstract(state), step=5)
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


def test_gc_fails_safe_on_unreadable_manifest(tmp_path):
    """A destructive sweep with an incomplete mark set would delete chunks
    a committed checkpoint still references — an unreadable manifest must
    skip the sweep, not contribute zero refs."""
    mgr = _mgr(tmp_path, retain=1)
    state = _state()
    mgr.save(state, 1)
    state["params"]["w"] = state["params"]["w"] + 1.0
    mgr.save(state, 2)
    mpath = mgr.store.root / "step_00000002" / "_META" / "manifest.json"
    good = mpath.read_bytes()
    mpath.write_text("{corrupt json")
    mgr2 = CheckpointManager(_store(tmp_path), mode="incremental",
                             codec="raw", chunk_size=512)
    rep = mgr2.gc()
    assert rep["cas"].get("skipped") and rep["cas"]["swept"] == 0
    # repair the manifest: every chunk must still be there
    mpath.write_bytes(good)
    restored, _ = CheckpointManager(_store(tmp_path)).restore(
        _abstract(state), step=2)
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


def test_gc_never_deletes_live_chunks(tmp_path):
    mgr = _mgr(tmp_path, retain=2)
    states = []
    state = _state()
    for s in (1, 2, 3, 4):
        state = jax.tree.map(lambda x: x, state)
        state["params"]["w"] = state["params"]["w"] + float(s)
        states.append(jax.tree.map(np.asarray, state))
        mgr.save(state, s)
    # steps 1, 2 retired; 3, 4 restorable bit-exact after all sweeps
    for s in (3, 4):
        restored, _ = mgr.restore(_abstract(state), step=s)
        np.testing.assert_array_equal(states[s - 1]["params"]["w"],
                                      np.asarray(restored["params"]["w"]))
