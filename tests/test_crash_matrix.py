"""Fault-injection crash matrix for the commit/GC protocol — every
injection point × {full, incremental} mode.

Invariants asserted after EVERY simulated crash (the paper's
missing-locks / partial-write failure class):

  1. every committed step restores bit-exact to the state saved at it;
  2. the LATEST pointer names a committed, restorable step;
  3. after one recovery GC, the content-addressed store passes fsck —
     zero orphaned objects, zero missing (live) objects, refcounts equal
     to what the committed manifests imply;
  4. a subsequent save on the recovered store commits normally.

Injection points that a mode never reaches (e.g. chunk-write points in
full mode) simply let the save commit — the invariants must hold there
too, so the matrix stays uniform: 16 points × {full, incremental-fixed,
incremental-cdc}. The three newest points live INSIDE the pipelined chunk
executor: a crash mid-batch (other chunks still in flight on pool
threads), a crash after every rename but before the batched directory
fsync, and a crash on the concurrent-dedup path where a racer returns
while another thread owns the digest.

Overlapped rounds (``save(blocking=False)``) get their own axis: the same
injection points fired while the persist runs on the background stage —
plus preempt-during-persist (fast-flush) and abort-of-an-overlapped-round
scenarios. The matrix honours ``CRASH_MATRIX_IO_THREADS`` so CI can sweep
the serial (=1) and wide (=8) engines explicitly.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_ckpt_policy
from repro.core import atomic
from repro.core.atomic import CrashInjector, CrashPoint
from repro.core.checkpoint import CheckpointManager
from repro.core.errors import AbortedError
from repro.core.storage import Tier, TieredStore

KEY = jax.random.PRNGKey(3)

# CI sweeps the executor axis explicitly (1 = serial reference engine,
# 8 = wide pipelined engine); the default matches the manager default
IO_THREADS = int(os.environ.get("CRASH_MATRIX_IO_THREADS", "4"))

# ≥ 8 injection points per mode (acceptance criterion): writer phase,
# chunk-object writes (serial AND pipelined executor), manifest write,
# commit rename, LATEST move, refcount publication, and every GC phase
# (mark, sweep, refs republish)
POINTS = [
    "rank0_before_write",        # writer dies before its first write
    "cas_after_obj_tmp",         # torn chunk-object write (tmp litter)
    "cas_mid_batch",             # executor: crash with chunks in flight
    "cas_before_batch_fsync",    # executor: renamed, batch fsync lost
    "cas_dedup_race",            # executor: crash on concurrent dedup hit
    "rank0_after_chunk_write",   # writer dies with orphan chunks on disk
    "rank0_after_fused_dispatch",  # chunk-encoded codecs: dispatch landed,
    # chunks never submitted (fires only with a chunk-encoded codec on the
    # device path — test_manifest_v7 exercises the firing case; here the
    # save simply commits and the invariants must hold regardless)
    "before_manifest",           # all shards durable, no commit record
    "after_tmp_write",           # manifest tmp written, not yet renamed
    "after_rename",              # manifest renamed, parent dir not fsynced
    "before_commit_rename",      # staging dir fully written, not promoted
    "after_commit_rename",       # committed, LATEST still points back
    "before_latest_write",       # committed, LATEST update never started
    "before_refs_publish",       # committed, refcount publication lost
    "after_gc_mark",             # GC died between mark and sweep
    "mid_gc_sweep",              # GC died mid-sweep (partial deletion)
    "before_gc_refs_publish",    # swept, refs.json republish lost
]

# every (save-mode, chunking-scheme) combination the engine supports; the
# pipelined executor (io_threads default > 1) runs in all of them
MODE_AXES = [("full", "fixed"), ("incremental", "fixed"),
             ("incremental", "cdc")]


def _store(tmp_path):
    return TieredStore(Tier("fast", tmp_path / "fast"))


def _state(seed: int):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (32, 16)),
                   "frozen": jax.random.normal(KEY, (64, 8))},
        "opt": {"m": jnp.arange(512, dtype=jnp.float32).reshape(32, 16)},
        "step": jnp.asarray(seed, jnp.int32),
    }


def _abstract(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


def _assert_restores(mgr, step, expect):
    restored, _ = mgr.restore(_abstract(expect), step=step)
    for a, b in zip(jax.tree.leaves(expect), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mode,chunking", MODE_AXES)
@pytest.mark.parametrize("point", POINTS)
def test_crash_matrix(tmp_path, mode, chunking, point):
    def mk(**kw):
        # generous keepalive: CI boxes stall on fsync under suite-wide IO
        # pressure, and a spurious keepalive abort is not what this matrix
        # is probing. retain=1 so the second save actually drops a step —
        # the per-save path only runs the destructive sweep on retirement,
        # and the GC injection points must fire inside a real sweep.
        return CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
            n_writers=2, codec="raw", mode=mode, chunk_size=512,
            chunking=chunking, retain=1, max_retries=0,
            io_threads=IO_THREADS, **kw))

    states = {1: _state(1), 2: _state(2)}
    mk().save(states[1], 1)
    try:
        mk().save(states[2], 2, crash=CrashInjector(point))
        crashed = False
    except (CrashPoint, AbortedError):
        crashed = True

    # --- recovery: fresh manager = fresh process after the crash ---
    mgr = mk()
    gc_report = mgr.gc()                 # staging litter + mark-and-sweep
    committed = atomic.list_committed_steps(mgr.store.root)
    assert committed, "no committed checkpoint survived the crash"
    assert committed[0] >= 1 and committed[-1] <= 2

    # invariant 2: latest_step() names the NEWEST committed step even when
    # the crash landed between the commit rename and the LATEST write — a
    # trainer trusting a stale pointer would re-save the committed step and
    # crash-loop on FileExistsError forever
    latest = mgr.latest_step()
    assert latest == committed[-1]

    # invariant 1: every committed step restores bit-exact
    for s in committed:
        _assert_restores(mgr, s, states[s])

    # invariant 3: zero leaked/missing CAS objects after GC
    live = mgr._live_chunk_refs()
    fsck = mgr.chunks.fsck(live)
    assert fsck["ok"], (point, mode, fsck)
    if mode == "full" and not crashed:
        # full-mode commits keep the CAS empty — nothing to leak
        assert fsck["objects"] == 0

    # invariant 4: the recovered store accepts the next checkpoint — the
    # step a restarted trainer would actually reach (latest + 1)
    nxt = latest + 1
    states[nxt] = _state(nxt)
    rep = mgr.save(states[nxt], nxt)
    assert rep["step"] == nxt
    _assert_restores(mgr, nxt, states[nxt])
    live = mgr._live_chunk_refs()
    assert mgr.chunks.fsck(live)["ok"]


@pytest.mark.parametrize("mode,chunking", MODE_AXES)
def test_repeated_crashes_then_recovery(tmp_path, mode, chunking):
    """Crash at a DIFFERENT point on every consecutive round — the store
    must stay consistent through an arbitrary crash history, not just one
    isolated fault."""
    def mk():
        return CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
            n_writers=2, codec="raw", mode=mode, chunk_size=512,
            chunking=chunking, retain=2, max_retries=0,
            io_threads=IO_THREADS))

    state = _state(0)
    mk().save(state, 1)
    good = {1: state}
    step = 2
    for point in ["cas_mid_batch", "cas_before_batch_fsync",
                  "rank0_after_chunk_write", "before_manifest",
                  "before_latest_write", "mid_gc_sweep"]:
        nxt = _state(step)
        try:
            mk().save(nxt, step, crash=CrashInjector(point))
            good[step] = nxt
        except (CrashPoint, AbortedError):
            pass
        mgr = mk()
        committed = atomic.list_committed_steps(mgr.store.root)
        # a crash may or may not have committed; either way the newest
        # committed step must restore and fsck must come back clean
        assert committed
        newest = committed[-1]
        if newest in good:
            _assert_restores(mgr, newest, good[newest])
        mgr.gc()
        assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
        step += 1
    # final full recovery round
    mgr = mk()
    final = _state(99)
    mgr.save(final, step)
    _assert_restores(mgr, step, final)
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]


# ---------------------------------------------------------------------------
# overlapped (async-persist) rounds
# ---------------------------------------------------------------------------

OVERLAP_POINTS = [
    "rank0_before_write",        # persist dies before any IO
    "cas_mid_batch",             # chunks in flight on pool threads
    "cas_before_batch_fsync",    # renamed, rank durability barrier lost
    "before_manifest",           # shards durable, no commit record
    "after_commit_rename",       # committed, LATEST points back
    "before_latest_write",       # committed, LATEST update never started
    "before_refs_publish",       # committed, refcount publication lost
    "mid_gc_sweep",              # stage-3 maintenance died mid-sweep
]


def _mk_overlap(tmp_path, **kw):
    kw.setdefault("retain", 1)
    kw.setdefault("io_threads", IO_THREADS)
    return CheckpointManager(_store(tmp_path), policy=make_ckpt_policy(
        n_writers=2, codec="raw", mode="incremental", chunk_size=512,
        max_retries=0, **kw))


@pytest.mark.parametrize("point", OVERLAP_POINTS)
def test_crash_matrix_overlapped_persist(tmp_path, point):
    """The same commit/GC invariants with the crash fired INSIDE the
    background persist stage of save(blocking=False): wait() must surface
    the error, the drain counters must still drain exactly once (a double
    commit would skew the P4 equality forever), and recovery must find
    zero leaked CAS objects."""
    states = {1: _state(1), 2: _state(2)}
    _mk_overlap(tmp_path).save(states[1], 1)
    mgr = _mk_overlap(tmp_path)
    rep = mgr.save(states[2], 2, blocking=False,
                   crash=CrashInjector(point))
    assert rep["async"] and rep["step"] == 2
    try:
        mgr.wait()
        crashed = False     # point unreached on this engine: a clean commit
    except (CrashPoint, AbortedError):
        crashed = True
    if point not in ("cas_mid_batch", "cas_before_batch_fsync"):
        # executor-internal points exist only on the pipelined engine; all
        # others must fire on the persist stage in every configuration
        assert crashed, f"{point} never fired on the persist stage"
    # exactly-once counter drain even though the round died mid-persist
    assert mgr.counters.drained()

    rec = _mk_overlap(tmp_path)              # fresh manager = restart
    rec.gc()
    committed = atomic.list_committed_steps(rec.store.root)
    assert committed, "no committed checkpoint survived the crash"
    latest = rec.latest_step()
    assert latest == committed[-1]
    for s in committed:
        _assert_restores(rec, s, states[s])
    fsck = rec.chunks.fsck(rec._live_chunk_refs())
    assert fsck["ok"], (point, fsck)
    nxt = latest + 1
    states[nxt] = _state(nxt)
    assert rec.save(states[nxt], nxt)["step"] == nxt
    _assert_restores(rec, nxt, states[nxt])
    assert rec.chunks.fsck(rec._live_chunk_refs())["ok"]


def test_crash_in_queued_round_leaks_nothing_and_later_round_lands(
        tmp_path):
    """Multi-round persist queue axis: round 2 crashes on the persist
    worker while round 3 is already admitted behind it. The crash must
    surface on wait() (first error wins), round 3 must still commit
    (rounds are independent), counters must drain exactly once per round,
    and recovery GC must find zero leaked CAS objects.

    Queue-specific: the serial engine pins depth to 1 (covered by
    test_serial_engine_policy_pins_queue_depth_to_one), so this point
    always runs the pipelined engine even on the CI serial axis."""
    import threading

    from repro.core import cas as cas_mod
    mgr = _mk_overlap(tmp_path, persist_queue_depth=2, retain=8,
                      io_threads=max(IO_THREADS, 2))
    states = {1: _state(1), 2: _state(2), 3: _state(3)}
    mgr.save(states[1], 1)
    # park round 2 inside its persist until round 3 is admitted — the
    # crash must deterministically fire with a round QUEUED behind it
    gate = threading.Event()
    orig = mgr.chunks.store_chunk

    def slow(digest, data, crash=None, dirs=None, dirs_lock=None):
        gate.wait(10)
        return orig(digest, data, crash or cas_mod.NO_CRASH, dirs,
                    dirs_lock)

    mgr.chunks.store_chunk = slow
    mgr.save(states[2], 2, blocking=False,
             crash=CrashInjector("before_manifest"))
    mgr.save(states[3], 3, blocking=False)      # queued behind the crash
    gate.set()
    with pytest.raises(CrashPoint):
        mgr.wait()
    mgr.wait()                                  # second wait: clean
    assert mgr.counters.drained()
    # depth-1 parity: the NEXT queued save surfaces a failed round's
    # error instead of letting checkpoints silently fail forever
    mgr2 = _mk_overlap(tmp_path / "p", persist_queue_depth=2,
                       io_threads=max(IO_THREADS, 2))
    mgr2.save(states[1], 1)
    mgr2.save(states[2], 2, blocking=False,
              crash=CrashInjector("before_manifest"))
    import time as _time
    deadline = _time.monotonic() + 10           # let round 2 die quietly
    while mgr2._persist.active and _time.monotonic() < deadline:
        _time.sleep(0.01)
    with pytest.raises(CrashPoint):             # the next save raises
        mgr2.save(states[3], 3, blocking=False)

    rec = _mk_overlap(tmp_path, persist_queue_depth=2, retain=8)
    rec.gc()                                    # staging litter + sweep
    committed = atomic.list_committed_steps(rec.store.root)
    assert committed == [1, 3]                  # 2 died, 3 landed anyway
    assert rec.latest_step() == 3
    for s in committed:
        _assert_restores(rec, s, states[s])
    assert rec.chunks.fsck(rec._live_chunk_refs())["ok"]


def test_preempt_during_persist_fast_flush(tmp_path):
    """SIGTERM while an overlapped round persists: the fast-flush hook
    makes the round skip stage-3 maintenance but NEVER the commit — the
    checkpoint lands, restores bit-exact, and the next explicit gc()
    repairs the deferred maintenance."""
    from repro.core.preempt import PreemptionGuard
    mgr = _mk_overlap(tmp_path)
    states = {1: _state(1), 2: _state(2)}
    mgr.save(states[1], 1)
    guard = PreemptionGuard()
    guard.add_callback(mgr.request_fast_flush)
    guard.request()                      # signal lands BEFORE/DURING persist
    rep = mgr.save(states[2], 2, blocking=False)
    assert rep["async"]
    mgr.wait()                           # the fast-flushed round drains
    assert mgr.last_report["step"] == 2
    assert mgr.last_gc_report == {"skipped": True, "reason": "fast-flush"}
    _assert_restores(mgr, 2, states[2])
    assert mgr.latest_step() == 2
    # fast-flush is per-request, not a latch: the flag clears once the
    # flushed round lands, so the NEXT overlapped round runs maintenance
    assert not mgr._persist.fast_flush_requested
    states[3] = _state(3)
    mgr.save(states[3], 3, blocking=False)
    mgr.wait()
    assert mgr.last_gc_report.get("reason") != "fast-flush"
    assert mgr.last_gc_report["steps_dropped"]      # retention ran again
    # deferred maintenance self-heals: explicit gc() leaves fsck clean
    mgr.gc()
    assert atomic.list_committed_steps(mgr.store.root) == [3]
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
    _assert_restores(mgr, 3, states[3])


def test_abort_of_overlapped_round_leaks_nothing(tmp_path):
    """A writer-rank death inside an overlapped round: wait() surfaces
    AbortedError, counters drain exactly once, no staging litter survives,
    and after GC the CAS holds exactly the committed steps' objects."""
    mgr = _mk_overlap(tmp_path)
    states = {1: _state(1)}
    mgr.save(states[1], 1)
    baseline = mgr.chunks.fsck(mgr._live_chunk_refs())["objects"]
    for r in range(2):
        mgr.coordinator.inject_failure(r)
    with pytest.raises(AbortedError):
        mgr.save(_state(2), 2, blocking=False)
        mgr.wait()
    assert mgr.counters.drained()
    assert not list(mgr.store.root.glob("*.tmp-*"))
    assert mgr.latest_step() == 1
    mgr.gc()                             # reclaims any orphaned objects
    fsck = mgr.chunks.fsck(mgr._live_chunk_refs())
    assert fsck["ok"]
    assert fsck["objects"] == baseline   # zero leaked CAS objects
    _assert_restores(mgr, 1, states[1])
