"""Fault-injection crash matrix for the commit/GC protocol — every
injection point × {full, incremental} mode.

Invariants asserted after EVERY simulated crash (the paper's
missing-locks / partial-write failure class):

  1. every committed step restores bit-exact to the state saved at it;
  2. the LATEST pointer names a committed, restorable step;
  3. after one recovery GC, the content-addressed store passes fsck —
     zero orphaned objects, zero missing (live) objects, refcounts equal
     to what the committed manifests imply;
  4. a subsequent save on the recovered store commits normally.

Injection points that a mode never reaches (e.g. chunk-write points in
full mode) simply let the save commit — the invariants must hold there
too, so the matrix stays uniform: 16 points × {full, incremental-fixed,
incremental-cdc}. The three newest points live INSIDE the pipelined chunk
executor: a crash mid-batch (other chunks still in flight on pool
threads), a crash after every rename but before the batched directory
fsync, and a crash on the concurrent-dedup path where a racer returns
while another thread owns the digest.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import atomic
from repro.core.atomic import CrashInjector, CrashPoint
from repro.core.checkpoint import CheckpointManager
from repro.core.errors import AbortedError
from repro.core.storage import Tier, TieredStore

KEY = jax.random.PRNGKey(3)

# ≥ 8 injection points per mode (acceptance criterion): writer phase,
# chunk-object writes (serial AND pipelined executor), manifest write,
# commit rename, LATEST move, refcount publication, and every GC phase
# (mark, sweep, refs republish)
POINTS = [
    "rank0_before_write",        # writer dies before its first write
    "cas_after_obj_tmp",         # torn chunk-object write (tmp litter)
    "cas_mid_batch",             # executor: crash with chunks in flight
    "cas_before_batch_fsync",    # executor: renamed, batch fsync lost
    "cas_dedup_race",            # executor: crash on concurrent dedup hit
    "rank0_after_chunk_write",   # writer dies with orphan chunks on disk
    "before_manifest",           # all shards durable, no commit record
    "after_tmp_write",           # manifest tmp written, not yet renamed
    "after_rename",              # manifest renamed, parent dir not fsynced
    "before_commit_rename",      # staging dir fully written, not promoted
    "after_commit_rename",       # committed, LATEST still points back
    "before_latest_write",       # committed, LATEST update never started
    "before_refs_publish",       # committed, refcount publication lost
    "after_gc_mark",             # GC died between mark and sweep
    "mid_gc_sweep",              # GC died mid-sweep (partial deletion)
    "before_gc_refs_publish",    # swept, refs.json republish lost
]

# every (save-mode, chunking-scheme) combination the engine supports; the
# pipelined executor (io_threads default > 1) runs in all of them
MODE_AXES = [("full", "fixed"), ("incremental", "fixed"),
             ("incremental", "cdc")]


def _store(tmp_path):
    return TieredStore(Tier("fast", tmp_path / "fast"))


def _state(seed: int):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (32, 16)),
                   "frozen": jax.random.normal(KEY, (64, 8))},
        "opt": {"m": jnp.arange(512, dtype=jnp.float32).reshape(32, 16)},
        "step": jnp.asarray(seed, jnp.int32),
    }


def _abstract(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


def _assert_restores(mgr, step, expect):
    restored, _ = mgr.restore(_abstract(expect), step=step)
    for a, b in zip(jax.tree.leaves(expect), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mode,chunking", MODE_AXES)
@pytest.mark.parametrize("point", POINTS)
def test_crash_matrix(tmp_path, mode, chunking, point):
    def mk(**kw):
        # generous keepalive: CI boxes stall on fsync under suite-wide IO
        # pressure, and a spurious keepalive abort is not what this matrix
        # is probing. retain=1 so the second save actually drops a step —
        # the per-save path only runs the destructive sweep on retirement,
        # and the GC injection points must fire inside a real sweep.
        return CheckpointManager(_store(tmp_path), n_writers=2, codec="raw",
                                 mode=mode, chunk_size=512,
                                 chunking=chunking, retain=1,
                                 max_retries=0, keepalive_s=60.0, **kw)

    states = {1: _state(1), 2: _state(2)}
    mk().save(states[1], 1)
    try:
        mk().save(states[2], 2, crash=CrashInjector(point))
        crashed = False
    except (CrashPoint, AbortedError):
        crashed = True

    # --- recovery: fresh manager = fresh process after the crash ---
    mgr = mk()
    gc_report = mgr.gc()                 # staging litter + mark-and-sweep
    committed = atomic.list_committed_steps(mgr.store.root)
    assert committed, "no committed checkpoint survived the crash"
    assert committed[0] >= 1 and committed[-1] <= 2

    # invariant 2: latest_step() names the NEWEST committed step even when
    # the crash landed between the commit rename and the LATEST write — a
    # trainer trusting a stale pointer would re-save the committed step and
    # crash-loop on FileExistsError forever
    latest = mgr.latest_step()
    assert latest == committed[-1]

    # invariant 1: every committed step restores bit-exact
    for s in committed:
        _assert_restores(mgr, s, states[s])

    # invariant 3: zero leaked/missing CAS objects after GC
    live = mgr._live_chunk_refs()
    fsck = mgr.chunks.fsck(live)
    assert fsck["ok"], (point, mode, fsck)
    if mode == "full" and not crashed:
        # full-mode commits keep the CAS empty — nothing to leak
        assert fsck["objects"] == 0

    # invariant 4: the recovered store accepts the next checkpoint — the
    # step a restarted trainer would actually reach (latest + 1)
    nxt = latest + 1
    states[nxt] = _state(nxt)
    rep = mgr.save(states[nxt], nxt)
    assert rep["step"] == nxt
    _assert_restores(mgr, nxt, states[nxt])
    live = mgr._live_chunk_refs()
    assert mgr.chunks.fsck(live)["ok"]


@pytest.mark.parametrize("mode,chunking", MODE_AXES)
def test_repeated_crashes_then_recovery(tmp_path, mode, chunking):
    """Crash at a DIFFERENT point on every consecutive round — the store
    must stay consistent through an arbitrary crash history, not just one
    isolated fault."""
    def mk():
        return CheckpointManager(_store(tmp_path), n_writers=2, codec="raw",
                                 mode=mode, chunk_size=512,
                                 chunking=chunking, retain=2,
                                 max_retries=0, keepalive_s=60.0)

    state = _state(0)
    mk().save(state, 1)
    good = {1: state}
    step = 2
    for point in ["cas_mid_batch", "cas_before_batch_fsync",
                  "rank0_after_chunk_write", "before_manifest",
                  "before_latest_write", "mid_gc_sweep"]:
        nxt = _state(step)
        try:
            mk().save(nxt, step, crash=CrashInjector(point))
            good[step] = nxt
        except (CrashPoint, AbortedError):
            pass
        mgr = mk()
        committed = atomic.list_committed_steps(mgr.store.root)
        # a crash may or may not have committed; either way the newest
        # committed step must restore and fsck must come back clean
        assert committed
        newest = committed[-1]
        if newest in good:
            _assert_restores(mgr, newest, good[newest])
        mgr.gc()
        assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
        step += 1
    # final full recovery round
    mgr = mk()
    final = _state(99)
    mgr.save(final, step)
    _assert_restores(mgr, step, final)
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
