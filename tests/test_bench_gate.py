"""The bench regression gate (scripts/check_bench_regression.py).

Pins the contract the benches rely on: floors are opt-in per section,
tiny runs gate against baseline_tiny, and a run that DECLARES a metric
unavailable (``unavailable_metrics`` — e.g. the zstd-comparison arms of
bench_codec without the optional zstandard package) is skipped, while a
silently-missing floored metric still fails."""
from __future__ import annotations

import importlib.util
import sys
from pathlib import Path

_SPEC = importlib.util.spec_from_file_location(
    "check_bench_regression",
    Path(__file__).resolve().parents[1] / "scripts"
    / "check_bench_regression.py")
_MOD = importlib.util.module_from_spec(_SPEC)
sys.modules.setdefault("check_bench_regression", _MOD)
_SPEC.loader.exec_module(_MOD)
check = _MOD.check


def _doc(**live):
    return {
        "baseline": {"codec": {"rans_vs_zstd_speedup": 1.5,
                               "rans_ratio_frac": 0.90}},
        "baseline_tiny": {"codec": {"rans_vs_zstd_speedup": 1.2}},
        **live,
    }


def test_passing_metrics_pass():
    doc = _doc(codec={"rans_vs_zstd_speedup": 2.0, "rans_ratio_frac": 0.95})
    assert check(doc, 0.2, out=lambda *a: None) == []


def test_regression_below_threshold_fails():
    # floor 1.5 − 20% → limit 1.2; 1.0 is a real regression
    doc = _doc(codec={"rans_vs_zstd_speedup": 1.0, "rans_ratio_frac": 0.95})
    fails = check(doc, 0.2, out=lambda *a: None)
    assert len(fails) == 1 and "rans_vs_zstd_speedup" in fails[0]


def test_tiny_runs_gate_against_tiny_floors():
    # 1.1 would fail the full floor (1.5) but passes tiny (1.2 − 20%)
    doc = _doc(codec={"tiny": True, "rans_vs_zstd_speedup": 1.1})
    assert check(doc, 0.2, out=lambda *a: None) == []


def test_declared_unavailable_metric_is_skipped():
    # a zstd-less run still has OTHER floored sections; the declared-
    # unavailable codec floors skip with a visible line, not a failure
    doc = _doc(codec={"zstd_absent": True,
                      "unavailable_metrics": ["rans_vs_zstd_speedup",
                                              "rans_ratio_frac"],
                      "rans_enc_gbps": 0.04})
    doc["baseline"]["chunk_scan"] = {"scan_speedup": 4.5}
    doc["chunk_scan"] = {"scan_speedup": 4.4}
    lines = []
    assert check(doc, 0.2, out=lines.append) == []
    assert sum("skipped" in ln for ln in lines) == 2


def test_all_floors_unavailable_still_fails_gate():
    # ...but if NOTHING was checked at all, the gate refuses to pass
    doc = _doc(codec={"zstd_absent": True,
                      "unavailable_metrics": ["rans_vs_zstd_speedup",
                                              "rans_ratio_frac"]})
    fails = check(doc, 0.2, out=lambda *a: None)
    assert fails and "no floored metrics" in fails[0]


def test_silently_missing_floored_metric_fails():
    doc = _doc(codec={"rans_vs_zstd_speedup": 2.0})   # ratio_frac gone
    fails = check(doc, 0.2, out=lambda *a: None)
    assert len(fails) == 1 and "rans_ratio_frac" in fails[0]
    assert "missing" in fails[0]


def test_unfloored_sections_and_metrics_are_ignored():
    doc = _doc(codec={"rans_vs_zstd_speedup": 2.0, "rans_ratio_frac": 0.95,
                      "novel_metric": 0.001},
               other_section={"whatever": 0.0})
    assert check(doc, 0.2, out=lambda *a: None) == []


def test_empty_doc_flags_nothing_checked():
    fails = check({"baseline": {}}, 0.2, out=lambda *a: None)
    assert fails and "no floored metrics" in fails[0]
