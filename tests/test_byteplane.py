"""Byteplane pre-conditioning codec — oracle fuzz, three-backend parity,
the fused transform+scan dispatch, the staging arena, host-encoder
equivalence, serial-engine purity, and full save→restore integration.

The transformed stream is the dedup keyspace when a byteplane codec is
active: a backend that drifts by ONE byte re-writes history. Everything
here pins bit-exactness against the numpy oracle in ``core.codec``."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from conftest import make_ckpt_policy
from repro.core import cdc_scan
from repro.core import codec as codec_mod
from repro.core.cdc_scan import GearScanner, scan_candidates_numpy
from repro.core.checkpoint import CheckpointManager
from repro.core.policy import CheckpointPolicy, CodecPolicy
from repro.core.storage import Tier, TieredStore
from repro.kernels.ckpt_codec import byteplane as bp

MS, ML = (1 << 13) - 1, (1 << 11) - 1      # strict/loose gear masks

# odd, unaligned, empty, sub-BLOCK and multi-block sizes (in BYTES)
SIZES = [0, 1, 3, 5, 63, 64, 65, 1000, 4097, 65549, 300_001]
ITEMSIZES = [1, 2, 4, 8]


def _rand(n, seed=0):
    return np.random.default_rng(seed).integers(0, 256, n, dtype=np.uint8)


# ---------------------------------------------------------------------------
# the numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", ITEMSIZES)
@pytest.mark.parametrize("n", SIZES)
def test_oracle_round_trip(n, k):
    u8 = _rand(n, seed=n + k)
    t = codec_mod.byteplane_forward(u8, k)
    assert t.dtype == np.uint8 and t.size == n          # size-preserving
    back = codec_mod.byteplane_inverse(t, k)
    np.testing.assert_array_equal(back, u8)


@pytest.mark.parametrize("dtype", ["float32", "float16", "int8", "uint32",
                                   "bfloat16"])
def test_oracle_round_trip_real_dtypes(dtype):
    # real param/optimizer payloads: f32/bf16 params, int8 q-payloads
    rng = np.random.default_rng(7)
    if dtype == "int8":
        arr = rng.integers(-127, 128, 5003, dtype=np.int8)
    elif dtype == "uint32":
        arr = rng.integers(0, 1 << 32, 2049, dtype=np.uint32)
    else:
        arr = (rng.standard_normal(4097) * 0.02).astype(np.float32)
        if dtype != "float32":
            arr = np.asarray(jnp.asarray(arr).astype(dtype))
    u8 = codec_mod.contig_u8(arr)
    k = arr.dtype.itemsize
    back = codec_mod.byteplane_inverse(codec_mod.byteplane_forward(u8, k), k)
    np.testing.assert_array_equal(back, u8)


def test_oracle_rejects_bad_itemsize():
    with pytest.raises(ValueError):
        codec_mod.byteplane_forward(_rand(16), 0)
    with pytest.raises(ValueError):
        codec_mod.byteplane_inverse(_rand(16), -2)


def test_ragged_tail_passes_through():
    u8 = _rand(4 * 10 + 3, seed=1)
    t = codec_mod.byteplane_forward(u8, 4)
    np.testing.assert_array_equal(t[-3:], u8[-3:])


# ---------------------------------------------------------------------------
# device backends — byte-identical to the oracle (pallas via interpret)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", ITEMSIZES)
@pytest.mark.parametrize("n", [0, 5, 63, 1000, 4097, 65549])
def test_jnp_backend_matches_oracle(n, k):
    u8 = _rand(n, seed=n * 7 + k)
    t_ref = codec_mod.byteplane_forward(u8, k)
    t = np.asarray(bp.forward_jnp(jnp.asarray(u8), itemsize=k))
    np.testing.assert_array_equal(t, t_ref)
    back = np.asarray(bp.inverse_jnp(jnp.asarray(t_ref), itemsize=k))
    np.testing.assert_array_equal(back, u8)


@pytest.mark.parametrize("k", ITEMSIZES)
@pytest.mark.parametrize("n", [0, 5, 1000, 65549])
def test_pallas_backend_matches_oracle(n, k):
    u8 = _rand(n, seed=n * 3 + k)
    t_ref = codec_mod.byteplane_forward(u8, k)
    t = np.asarray(bp.forward_pallas(jnp.asarray(u8), itemsize=k,
                                     interpret=True))
    np.testing.assert_array_equal(t, t_ref)
    back = np.asarray(bp.inverse_pallas(jnp.asarray(t_ref), itemsize=k,
                                        interpret=True))
    np.testing.assert_array_equal(back, u8)


# ---------------------------------------------------------------------------
# the fused transform+scan dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["numpy", "jnp", "pallas"])
def test_fused_scan_matches_oracle_of_transformed(backend):
    # candidates must equal the oracle scan OF the oracle-transformed
    # stream — the fused dispatch feeds chunking/dedup directly
    n, k = 3_000_000, 4
    u8 = _rand(n, seed=11)
    u8[n // 4:n // 4 + 50_000] = 3          # compressible run → candidates
    t_ref = codec_mod.byteplane_forward(u8, k)
    cand_ref = scan_candidates_numpy(t_ref, MS, ML)
    sc = GearScanner(MS, ML, backend=backend,
                     pallas_interpret=(backend == "pallas"))
    (strict, loose), t = sc.scan_transform_async(u8, k).result()
    np.testing.assert_array_equal(np.asarray(t), t_ref)
    np.testing.assert_array_equal(strict, cand_ref[0])
    np.testing.assert_array_equal(loose, cand_ref[1])
    assert len(cand_ref[1]) > 0             # the fixture actually scans


@pytest.mark.parametrize("n", [0, 5, 64, 1000])
def test_fused_scan_tiny_payloads(n):
    # at/below the window no candidates exist; the transform still runs
    u8 = _rand(n, seed=n)
    sc = GearScanner(MS, ML, backend="jnp")
    (strict, loose), t = sc.scan_transform_async(u8, 2).result()
    np.testing.assert_array_equal(np.asarray(t),
                                  codec_mod.byteplane_forward(u8, 2))
    ref = scan_candidates_numpy(codec_mod.byteplane_forward(u8, 2), MS, ML)
    np.testing.assert_array_equal(strict, ref[0])
    np.testing.assert_array_equal(loose, ref[1])


def test_transform_async_matches_oracle():
    for n in (1000, 3_000_000):             # host inline + device dispatch
        u8 = _rand(n, seed=n)
        t = cdc_scan.transform_async(u8, 4).result()
        np.testing.assert_array_equal(
            t, codec_mod.byteplane_forward(u8, 4))


# ---------------------------------------------------------------------------
# staging arena (small-payload dispatch overhead)
# ---------------------------------------------------------------------------

def test_staging_arena_recycles_after_extraction():
    sc = GearScanner(MS, ML, backend="jnp")
    data = _rand(3_000_000, seed=2)
    sc.scan_async(data).result()
    sizes = [s for s, bufs in cdc_scan._ARENA._free.items() if bufs]
    assert sizes, "no staging buffer returned to the arena"
    s = sizes[0]
    before = len(cdc_scan._ARENA._free[s])
    buf = cdc_scan._ARENA.acquire(s)
    assert buf.nbytes == s
    assert len(cdc_scan._ARENA._free[s]) == before - 1   # recycled, not fresh
    cdc_scan._ARENA.release(buf)


def test_staging_arena_bounds_pool():
    arena = cdc_scan._StagingArena()
    bufs = [arena.acquire(1024) for _ in range(arena.MAX_PER_SIZE + 3)]
    for b in bufs:
        arena.release(b)
    assert len(arena._free[1024]) == arena.MAX_PER_SIZE


# ---------------------------------------------------------------------------
# codec entries — host encoder equivalence and self-describing decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "int8", "float16"])
def test_byteplane_codec_round_trip(dtype):
    rng = np.random.default_rng(5)
    arr = (rng.standard_normal(4099) * 0.1).astype(dtype) \
        if dtype != "int8" else rng.integers(-127, 128, 4099, dtype=np.int8)
    payload, meta = codec_mod.encode(arr, "byteplane")
    assert meta == {"bp": arr.dtype.itemsize}
    assert len(payload) == arr.nbytes                    # size-preserving
    back = codec_mod.decode(payload, "byteplane", arr.shape, str(arr.dtype),
                            meta)
    np.testing.assert_array_equal(back, arr)


def test_encode_preconditioned_matches_host_encoder():
    arr = (np.random.default_rng(6).standard_normal(8192) * 0.02) \
        .astype(np.float32)
    t = codec_mod.byteplane_forward(codec_mod.contig_u8(arr),
                                    arr.dtype.itemsize)
    host, _ = codec_mod.encode(arr, "byteplane")
    assert bytes(codec_mod.encode_preconditioned(t, "byteplane")) == host


@pytest.mark.skipif(not codec_mod.HAVE_ZSTD, reason="zstandard not installed")
def test_byteplane_zstd_round_trip_and_equivalence():
    arr = (np.random.default_rng(8).standard_normal(16384) * 0.02) \
        .astype(np.float32)
    payload, meta = codec_mod.encode(arr, "byteplane-zstd")
    back = codec_mod.decode(payload, "byteplane-zstd", arr.shape,
                            "float32", meta)
    np.testing.assert_array_equal(back, arr)
    t = codec_mod.byteplane_forward(codec_mod.contig_u8(arr), 4)
    assert codec_mod.encode_preconditioned(t, "byteplane-zstd") == payload


def test_byteplane_availability():
    assert codec_mod.available("byteplane")
    assert codec_mod.available("byteplane-zstd") == codec_mod.HAVE_ZSTD
    assert not codec_mod.lossy("byteplane")
    assert not codec_mod.lossy("byteplane-zstd")


def test_decode_falls_back_to_dtype_itemsize_without_meta():
    arr = np.arange(512, dtype=np.float32)
    payload, _ = codec_mod.encode(arr, "byteplane")
    back = codec_mod.decode(payload, "byteplane", arr.shape, "float32", {})
    np.testing.assert_array_equal(back, arr)


@pytest.mark.skipif(not codec_mod.HAVE_ZSTD, reason="zstandard not installed")
def test_zstd_encode_has_no_double_copy():
    # the old encoder did ascontiguousarray(arr).tobytes() — a full extra
    # copy of every payload before the compressor saw it. Compressing an
    # incompressible payload must not allocate another payload-sized block
    # beyond the compressed output itself.
    import tracemalloc
    arr = np.random.default_rng(9).integers(
        0, 256, 8 << 20, dtype=np.uint8).view(np.float32)
    codec_mod.encode(arr, "zstd")           # warm thread-local compressor
    tracemalloc.start()
    payload, _ = codec_mod.encode(arr, "zstd")
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    # incompressible input → output ≈ nbytes; a tobytes() copy would push
    # the peak to ≈ 2× nbytes
    assert peak < int(arr.nbytes * 1.5), (peak, arr.nbytes)


# ---------------------------------------------------------------------------
# policy surface
# ---------------------------------------------------------------------------

def test_codec_policy_accepts_byteplane_names():
    CodecPolicy(codec="byteplane")
    CodecPolicy(codec="byteplane-zstd", params_codec="byteplane")
    with pytest.raises(ValueError):
        CodecPolicy(codec="byteplanes")


def test_device_precondition_resolution():
    auto = CodecPolicy(codec="byteplane")
    assert auto.precondition_enabled(serial=False) is True
    assert auto.precondition_enabled(serial=True) is False   # PR-1 purity
    off = CodecPolicy(codec="byteplane", device_precondition=False)
    assert off.precondition_enabled(serial=False) is False
    on = CodecPolicy(codec="byteplane", device_precondition=True)
    assert on.precondition_enabled(serial=True) is False     # serial pins


def test_device_precondition_flat_and_env_overrides():
    p = CheckpointPolicy().with_overrides(codec="byteplane",
                                          device_precondition=False)
    assert p.codec.codec == "byteplane"
    assert p.codec.device_precondition is False
    p = CheckpointPolicy.from_env(
        {"REPRO_CKPT_DEVICE_PRECONDITION": "true",
         "REPRO_CKPT_CODEC": "byteplane"})
    assert p.codec.device_precondition is True
    assert p.codec.codec == "byteplane"


# ---------------------------------------------------------------------------
# engine integration: identical bytes on every path, serial purity,
# save→restore through the standard store fixture
# ---------------------------------------------------------------------------

def _store(tmp_path, name="fast"):
    return TieredStore(Tier(name, tmp_path / name))


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray((rng.standard_normal(400_000) * 0.02)
                                    .astype(np.float32)),
                   "b": jnp.asarray(rng.standard_normal(300)
                                    .astype(np.float32))},
        "opt": {"m": jnp.asarray(rng.integers(0, 100, 5_000,
                                              dtype=np.int32))},
    }


def _abstract(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


def _mk(tmp_path, sub, **flat):
    flat.setdefault("mode", "incremental")
    flat.setdefault("chunking", "cdc")
    flat.setdefault("chunk_size", 65536)
    return CheckpointManager(_store(tmp_path, sub),
                             policy=make_ckpt_policy(**flat))


def _records(man):
    out = {}
    for leaf, spec in man["leaves"].items():
        for s in spec["shards"]:
            out[(leaf, tuple(s["start"]))] = (
                tuple(s["chunks"]), s["crc32"], s["payload_bytes"],
                tuple(s.get("chunk_lens") or ()), s["meta"], s["codec"])
    return out


def test_device_host_serial_paths_write_identical_manifests(tmp_path):
    st = _state()
    mans = {}
    for name, flat in [
        ("dev", dict(io_threads=4, device_precondition=True)),
        ("host", dict(io_threads=4, device_precondition=False)),
        ("serial", dict(io_threads=1)),
    ]:
        m = _mk(tmp_path, name, codec="byteplane", **flat)
        m.save(st, 1)
        mans[name] = _records(m.load_manifest(1))
        restored, _ = m.restore(_abstract(st), step=1)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        m.close()
    assert mans["dev"] == mans["host"], \
        "device pre-conditioning changed the stored bytes"
    assert mans["dev"] == mans["serial"], \
        "serial engine drifted from the pipelined chunk grid"


def test_serial_engine_never_touches_device_path(tmp_path, monkeypatch):
    # PR-1 purity: the serial engine must encode on the host oracle —
    # no fused dispatch, no standalone device transform
    import repro.core.save_path as sp

    def boom(*a, **kw):
        raise AssertionError("device pre-conditioning ran on the serial "
                             "engine")
    monkeypatch.setattr(sp.SaveSession, "submit_preconditioned", boom)
    monkeypatch.setattr(cdc_scan, "transform_async", boom)
    m = _mk(tmp_path, "serial", codec="byteplane", io_threads=1)
    st = _state()
    m.save(st, 1)
    restored, _ = m.restore(_abstract(st), step=1)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    m.close()


def test_fused_path_actually_engages(tmp_path, monkeypatch):
    # the pipelined engine with CDC + byteplane must route through the
    # fused dispatch (not silently fall back to host encode)
    calls = []
    orig = GearScanner.scan_transform_async

    def spy(self, payload, itemsize):
        calls.append(len(payload))
        return orig(self, payload, itemsize)
    monkeypatch.setattr(GearScanner, "scan_transform_async", spy)
    m = _mk(tmp_path, "dev", codec="byteplane", io_threads=4,
            device_precondition=True)
    # the shard must clear MIN_ACCEL_BYTES or the session correctly picks
    # the standalone transform path instead of the fused dispatch
    rng = np.random.default_rng(0)
    st = {"params": {"w": jnp.asarray(
        (rng.standard_normal(900_000) * 0.02).astype(np.float32))}}
    m.save(st, 1)
    m.close()
    assert calls and max(calls) >= cdc_scan.MIN_ACCEL_BYTES, \
        "fused scan_transform_async never invoked"


def test_save_restore_byteplane_with_replicas_and_second_save(tmp_path):
    # the crash-matrix shaped fixture: two saves, retention, gc, restore
    m = _mk(tmp_path, "bb", codec="byteplane", io_threads=4,
            n_writers=2, replicas=2, retain=2)
    s1, s2 = _state(1), _state(2)
    m.save(s1, 1)
    m.save(s2, 2)
    m.gc()
    for step, st in [(1, s1), (2, s2)]:
        restored, _ = m.restore(_abstract(st), step=step)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    m.close()


@pytest.mark.skipif(not codec_mod.HAVE_ZSTD, reason="zstandard not installed")
def test_save_restore_byteplane_zstd_end_to_end(tmp_path):
    m = _mk(tmp_path, "bbz", codec="byteplane-zstd", io_threads=4)
    st = _state(3)
    rep = m.save(st, 1)
    assert rep["payload_bytes"] < rep["bytes"]       # entropy stage bites
    restored, _ = m.restore(_abstract(st), step=1)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    m.close()


def test_manifest_adoption_keeps_readers_device_precondition(tmp_path):
    st = _state()
    w = _mk(tmp_path, "adopt", codec="byteplane", io_threads=4,
            device_precondition=True)
    w.save(st, 1)
    w.close()
    r = CheckpointManager(
        _store(tmp_path, "adopt"),
        policy=make_ckpt_policy(mode="incremental", chunking="cdc",
                                chunk_size=65536, codec="raw",
                                io_threads=4, device_precondition=False))
    restored, _ = r.restore(_abstract(st), step=1)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # codec NAME adopted from the writer; the machine-local perf knob is
    # NOT — the reader explicitly pinned the host path
    assert r.codec == "byteplane"
    assert r.policy.codec.device_precondition is False
    assert r.device_precondition is False
    r.close()
