import os
import sys
from pathlib import Path

# smoke tests and benches must see the real (single) CPU device — the
# 512-device override belongs ONLY to repro.launch.dryrun.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def make_ckpt_policy(**flat):
    """The tests' shared CheckpointPolicy factory: keepalive_s=60 by
    default — suite-wide fsync stalls on this box's bimodal-latency 9p
    filesystem can exceed the production 10 s keepalive, and a spurious
    keepalive abort is not what any of these tests probe. Flat overrides
    use the legacy kwarg names (plus the newer pipeline knobs), so direct
    construction sites migrate one-for-one:
    ``CheckpointManager(store, policy=make_ckpt_policy(mode=...))``."""
    from repro.core.policy import CheckpointPolicy
    flat.setdefault("keepalive_s", 60.0)
    return CheckpointPolicy().with_overrides(**flat)


@pytest.fixture()
def ckpt_policy():
    """Fixture form of ``make_ckpt_policy`` for test-function sites."""
    return make_ckpt_policy


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def workdir(tmp_path):
    return tmp_path / "ckpt"


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
