import os
import sys
from pathlib import Path

# smoke tests and benches must see the real (single) CPU device — the
# 512-device override belongs ONLY to repro.launch.dryrun.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def workdir(tmp_path):
    return tmp_path / "ckpt"


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
