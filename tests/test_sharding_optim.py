"""Sharding resolver (divisibility fallbacks) + optimizer units +
HLO analyzer units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import CONFIGS, reduced
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamW, Adafactor, lr_schedule
from repro.sharding.partition import MeshAxes, spec_for_param


AX = MeshAxes(batch=("data",), fsdp="data", model="model",
              batch_size=16, fsdp_size=16, tp=16)


class _K:
    def __init__(self, key):
        self.key = key


def _spec(path_names, shape):
    return spec_for_param(tuple(_K(n) for n in path_names), shape, AX)


def test_divisible_dims_shard():
    assert _spec(("params", "embed"), (163840, 7168)) == P("model", None)
    assert _spec(("params", "stage_0", "b0", "q"), (60, 7168, 64, 128)) == \
        P(None, "data", "model", None)
    # MoE experts: E over model, d over fsdp
    assert _spec(("moe", "wg"), (60, 384, 7168, 2048)) == \
        P(None, "model", "data", None)


def test_indivisible_dims_fall_back_to_replication():
    # kv heads = 8 < tp 16 -> replicated head dim
    assert _spec(("b0", "k"), (60, 7168, 8, 128)) == \
        P(None, "data", None, None)
    # hubert vocab 504 % 16 != 0 -> no vocab sharding
    assert _spec(("params", "embed"), (504, 1280)) == P(None, None)
    # gemma3 q heads = 4 -> replicated
    assert _spec(("b0", "q"), (26, 1152, 4, 256)) == \
        P(None, "data", None, None)


def test_norms_replicated():
    assert _spec(("norm_in", "scale"), (1152,)) == P()


def test_adamw_matches_manual_sgd_like_reference():
    opt = AdamW(b1=0.0, b2=0.0, eps=1.0, weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    grads = {"w": jnp.full((4,), 2.0)}
    new_p, _ = opt.update(grads, state, params, lr=0.1)
    # b1=b2=0, eps=1: step = g / (|g| + 1) = 2/3
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               1.0 - 0.1 * (2.0 / 3.0), rtol=1e-5)


def test_adafactor_factored_state_shapes():
    opt = Adafactor(min_dim_size_to_factor=4)
    params = {"w": jnp.ones((8, 16)), "b": jnp.ones((8,))}
    st = opt.init(params)
    assert st["f"]["w"]["v_row"].shape == (8,)
    assert st["f"]["w"]["v_col"].shape == (16,)
    assert st["f"]["b"]["v"].shape == (8,)
    grads = {"w": jnp.ones((8, 16)), "b": jnp.ones((8,))}
    new_p, st2 = opt.update(grads, st, params, lr=0.01)
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(new_p))


def test_lr_schedule_shape():
    assert float(lr_schedule(0)) < float(lr_schedule(99))
    assert float(lr_schedule(100)) >= float(lr_schedule(9000))


def test_hlo_analyzer_trip_counts():
    """A scanned dot must count length× the single-body flops."""
    def f(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jnp.ones((32, 32))
    compiled = jax.jit(f).lower(x).compile()
    res = analyze(compiled.as_text(), total_devices=1)
    one_dot = 2 * 32 * 32 * 32
    assert res["flops"] == pytest.approx(7 * one_dot, rel=0.01), res["flops"]


def test_hlo_analyzer_collectives_counted():
    mesh = make_host_mesh()
    n = mesh.devices.size
    if n < 2:
        pytest.skip("single device: no collectives generated")
