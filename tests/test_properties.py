"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.codec import (HAVE_ZSTD, decode, dequantize_int8,  # noqa: E402
                              encode, quantize_int8)
from repro.core.elastic import (ShardRange, assemble,  # noqa: E402
                                normalize_index, overlap)

CODECS = ["raw", "int8"] + (["zstd"] if HAVE_ZSTD else [])


# ---------------------------------------------------------------------------
# codec invariants
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32),
                min_size=1, max_size=2048))
@settings(max_examples=60, deadline=None)
def test_int8_roundtrip_error_bound(xs):
    x = np.asarray(xs, np.float32)
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, x.size)
    scales = np.repeat(s, 256)[:x.size]
    assert np.all(np.abs(y - x) <= scales * 0.5 + 1e-6)


@given(st.sampled_from(CODECS),
       st.integers(1, 500), st.sampled_from(["float32", "int32"]))
@settings(max_examples=40, deadline=None)
def test_codec_roundtrip(codec, n, dtype):
    rng = np.random.default_rng(n)
    if dtype == "int32":
        if codec == "int8":
            return  # int leaves never use the lossy codec
        arr = rng.integers(-1000, 1000, n).astype(np.int32)
    else:
        arr = rng.standard_normal(n).astype(np.float32)
    payload, meta = encode(arr, codec)
    out = decode(payload, codec, arr.shape, arr.dtype, meta)
    if codec == "int8":
        assert np.max(np.abs(out - arr)) <= np.abs(arr).max() / 127 + 1e-6
    else:
        np.testing.assert_array_equal(out, arr)


# ---------------------------------------------------------------------------
# elastic re-sharding invariants: any partition of an array into ranges
# reassembles exactly, for any requested target range
# ---------------------------------------------------------------------------

@st.composite
def _splits(draw, n):
    cuts = sorted(draw(st.sets(st.integers(1, n - 1), max_size=4))) \
        if n > 1 else []
    bounds = [0] + list(cuts) + [n]
    return list(zip(bounds[:-1], bounds[1:]))


@given(st.integers(1, 40), st.integers(1, 12), st.data())
@settings(max_examples=60, deadline=None)
def test_assemble_from_arbitrary_2d_partitions(rows, cols, data):
    arr = np.arange(rows * cols, dtype=np.float32).reshape(rows, cols)
    row_splits = data.draw(_splits(rows))
    col_splits = data.draw(_splits(cols))
    pieces = []
    for r0, r1 in row_splits:
        for c0, c1 in col_splits:
            rng = ShardRange((r0, c0), (r1, c1))
            pieces.append((rng, arr[r0:r1, c0:c1]))
    # target: random sub-range
    tr0 = data.draw(st.integers(0, rows - 1))
    tr1 = data.draw(st.integers(tr0 + 1, rows))
    tc0 = data.draw(st.integers(0, cols - 1))
    tc1 = data.draw(st.integers(tc0 + 1, cols))
    target = ShardRange((tr0, tc0), (tr1, tc1))
    out = assemble(target, pieces, np.float32)
    np.testing.assert_array_equal(out, arr[tr0:tr1, tc0:tc1])


@given(st.integers(1, 30), st.integers(1, 30), st.integers(1, 30),
       st.integers(1, 30))
@settings(max_examples=50, deadline=None)
def test_overlap_commutative_and_contained(a0, a1, b0, b1):
    ra = ShardRange((min(a0, a1) - 1,), (max(a0, a1) + 1,))
    rb = ShardRange((min(b0, b1) - 1,), (max(b0, b1) + 1,))
    ov1, ov2 = overlap(ra, rb), overlap(rb, ra)
    assert ov1 == ov2
    if ov1 is not None:
        assert ov1.start[0] >= max(ra.start[0], rb.start[0])
        assert ov1.stop[0] <= min(ra.stop[0], rb.stop[0])


def test_normalize_index_handles_nones():
    r = normalize_index((slice(None), slice(2, 5)), (10, 8))
    assert r == ShardRange((0, 2), (10, 5))


# ---------------------------------------------------------------------------
# MoE dispatch: capacity bound respected for any routing
# ---------------------------------------------------------------------------

@given(st.integers(2, 16), st.integers(1, 4), st.integers(16, 128))
@settings(max_examples=20, deadline=None)
def test_moe_positions_capacity_property(n_experts, k, tokens):
    import jax
    from repro.models.moe import _positions_in_expert
    idx = jax.random.randint(jax.random.PRNGKey(tokens),
                             (tokens * k,), 0, n_experts)
    pos, counts = _positions_in_expert(idx, n_experts, block=32)
    pos, idx, counts = map(np.asarray, (pos, idx, counts))
    assert counts.sum() == tokens * k
    for e in range(n_experts):
        mine = np.sort(pos[idx == e])
        np.testing.assert_array_equal(mine, np.arange(len(mine)))
