"""End-to-end behaviour of the paper's system: serve-with-C/R and the
AOT restart cache (startup-time lesson)."""
import jax
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.launch import serve as serve_mod


@pytest.mark.slow
def test_serving_preempt_and_resume_token_exact(tmp_path):
    """Preempt a serving job mid-generation; restored job must produce the
    exact same remaining tokens (paper's preempt-queue use case applied to
    inference)."""
    wd = str(tmp_path / "serve")
    full = serve_mod.run("gemma3-1b", n_requests=3, prompt_len=8, gen_len=12,
                         workdir=str(tmp_path / "full"), ckpt_every=0,
                         seed=13)
    assert full["status"] == "completed"
    pre = serve_mod.run("gemma3-1b", n_requests=3, prompt_len=8, gen_len=12,
                        workdir=wd, ckpt_every=0, preempt_at=5, seed=13)
    assert pre["status"] == "preempted" and pre["cursor"] == 5
    resumed = serve_mod.run("gemma3-1b", n_requests=3, prompt_len=8,
                            gen_len=12, workdir=wd, ckpt_every=0, seed=13)
    assert resumed["status"] == "completed"
    np.testing.assert_array_equal(resumed["tokens"], full["tokens"])


@pytest.mark.slow
def test_serve_hot_swaps_published_weights(tmp_path):
    """A trainer-side WeightPublisher commits params; a serving run with
    --weight-sync pulls and hot-swaps them before decoding, so generation
    diverges from the no-sync baseline and reports the flipped step."""
    from repro.configs import get_config, reduced
    from repro.core import (CheckpointManager, CheckpointPolicy, Tier,
                            TieredStore, WeightPublisher)
    from repro.models import Model

    base = serve_mod.run("gemma3-1b", n_requests=3, prompt_len=8,
                         gen_len=12, workdir=str(tmp_path / "base"),
                         ckpt_every=0, seed=13)
    assert base["status"] == "completed"

    # trainer: publish DIFFERENT params (another init seed) for the same
    # arch — leaf names land under params/ exactly as serve expects
    cfg = reduced(get_config("gemma3-1b"))
    published = Model(cfg).init(jax.random.PRNGKey(99))
    trainer = tmp_path / "trainer"
    mgr = CheckpointManager(
        TieredStore(Tier("fast", trainer)),
        policy=CheckpointPolicy(mode="incremental"))
    WeightPublisher(mgr)
    mgr.save({"params": published}, 0, blocking=True)
    mgr.wait()
    mgr.close()

    swapped = serve_mod.run("gemma3-1b", n_requests=3, prompt_len=8,
                            gen_len=12, workdir=str(tmp_path / "swap"),
                            ckpt_every=0, seed=13, weight_sync=trainer)
    assert swapped["status"] == "completed"
    assert swapped["weight_sync_step"] == 0
    assert not np.array_equal(swapped["tokens"], base["tokens"])


def test_aot_cache_roundtrip(tmp_path):
    """Static-linking analogue: second bring-up loads the serialized
    executable instead of recompiling (falls back gracefully if the backend
    can't serialize)."""
    from repro.core.aot_cache import AotCache
    cache = AotCache(tmp_path / "aot")
    fn = jax.jit(lambda x: x * 2 + 1)
    import jax.numpy as jnp
    args = (jnp.ones((8, 8)),)
    c1, src1 = cache.load_or_compile(fn, args, tag="t")
    assert src1 == "compile"
    if cache.stats["stores"]:
        c2, src2 = cache.load_or_compile(fn, args, tag="t")
        assert src2 == "cache"
        np.testing.assert_array_equal(np.asarray(c2(*args)),
                                      np.asarray(c1(*args)))
