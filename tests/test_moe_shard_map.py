"""shard_map EP MoE vs the GSPMD baseline: loss/grad equivalence on a real
multi-device mesh (subprocess with 8 fake devices), incl. the Megatron-SP
composition."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys, json
    sys.path.insert(0, {src!r})
    from dataclasses import replace
    import jax, jax.numpy as jnp
    from repro.configs import CONFIGS, reduced
    from repro.models import Model
    from repro.models.model import set_constrainer, set_exec_mesh
    from repro.sharding.partition import (act_constrainer, batch_spec,
                                          param_specs)
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((2, 4), ("data", "model"))
    base = reduced(CONFIGS[{arch!r}])
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (8, 16), 0, base.vocab_size)
    outs = {{}}
    variants = [("gspmd", dict(moe_impl="gspmd")),
                ("smap", dict(moe_impl="shard_map")),
                ("smap_sp", dict(moe_impl="shard_map", seq_shard_resid=True))]
    for name, kw in variants:
        cfg = replace(base, moe=replace(base.moe,
                      capacity_factor=float(base.moe.n_experts)), **kw)
        set_constrainer(act_constrainer(cfg, mesh)); set_exec_mesh(mesh)
        model = Model(cfg)
        params = jax.device_put(model.init(key), param_specs(
            jax.eval_shape(model.init, key), mesh))
        batch = jax.device_put({{"tokens": toks}},
                               batch_spec({{"tokens": toks}}, mesh, cfg))
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p, b: model.loss(p, b)[0]))(params, batch)
        outs[name] = (float(loss), grads)
        set_constrainer(None); set_exec_mesh(None)
    l0, g0 = outs["gspmd"]
    res = {{}}
    for name in ("smap", "smap_sp"):
        l, g = outs[name]
        derr = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))), g0, g)
        res[name] = {{"loss_diff": abs(l - l0),
                      "grad_diff": max(jax.tree.leaves(derr))}}
    print("RESULT::" + json.dumps(res))
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "llama4-scout-17b-a16e"])
def test_shard_map_matches_gspmd(arch):
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=SRC, arch=arch)],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("RESULT::"))
    res = json.loads(line[len("RESULT::"):])
    for name, d in res.items():
        assert d["loss_diff"] < 1e-5, (name, d)
        assert d["grad_diff"] < 5e-5, (name, d)
