"""Coordinator protocol (keepalive, stragglers, 2PC) and drain counters.

Timing tests use an INJECTED monotonic clock: the keepalive/straggler
decisions read fake time the test advances explicitly, so a slow or
IO-stalled CI host can never turn a liveness threshold into a flake. Real
wall-clock only bounds how long we poll for the (now deterministic)
outcome."""
import threading
import time

import pytest

from repro.core.coordinator import CheckpointCoordinator, RankState
from repro.core.drain import DrainCounters


class FakeClock:
    """Thread-safe manually-advanced monotonic clock."""

    def __init__(self):
        self._t = 0.0
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float):
        with self._lock:
            self._t += dt


def _poll(predicate, timeout=10.0):
    """Wait (real time) for a condition the fake clock already made
    inevitable; generous deadline, tiny poll interval."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(0.01)
    return predicate()


def _run_ranks(coord, n, work=lambda r: None):
    def rank(r):
        try:
            coord.rank_begin(r)
            work(r)
            coord.rank_prepared(r, nbytes=100, files=[f"f{r}"])
        except Exception as e:  # noqa
            coord.rank_failed(r, str(e))
    ts = [threading.Thread(target=rank, args=(r,)) for r in range(n)]
    for t in ts:
        t.start()
    return ts


def test_commit_happy_path():
    c = CheckpointCoordinator(4)
    c.begin_round(1)
    ts = _run_ranks(c, 4)
    assert c.wait_all_prepared(timeout=5)
    for t in ts:
        t.join()
    c.finish_round(True)
    assert c.metrics["commits"] == 1 and c.metrics["aborts"] == 0


def test_injected_failure_aborts():
    c = CheckpointCoordinator(3)
    c.inject_failure(2)
    c.begin_round(1)
    ts = _run_ranks(c, 3)
    assert not c.wait_all_prepared(timeout=5)
    for t in ts:
        t.join()
    assert "rank 2" in c.abort_reason()
    c.finish_round(False)
    assert c.metrics["aborts"] == 1


def test_keepalive_timeout_detects_dead_rank():
    clk = FakeClock()
    c = CheckpointCoordinator(2, keepalive_s=5.0, clock=clk)
    c.begin_round(1)
    c.rank_begin(0)
    c.rank_prepared(0, nbytes=1, files=[])
    c.rank_begin(1)              # never heartbeats, never acks — silent death
    clk.advance(5.1)             # past the keepalive with zero real sleeping
    assert not c.wait_all_prepared(timeout=30)
    assert "keepalive" in c.abort_reason()
    assert c.metrics["keepalive_timeouts"] == 1


def test_heartbeats_keep_slow_rank_alive_past_keepalive():
    """The inverse guarantee: a rank that takes many keepalive periods but
    keeps heartbeating must NOT be declared dead."""
    clk = FakeClock()
    c = CheckpointCoordinator(1, keepalive_s=5.0, clock=clk)
    c.begin_round(1)
    c.rank_begin(0)
    for _ in range(10):          # 40 fake seconds of slow-but-alive work
        clk.advance(4.0)
        c.heartbeat(0)
        time.sleep(0.02)         # let the monitor observe each interval
    c.rank_prepared(0, nbytes=1, files=[])
    assert c.wait_all_prepared(timeout=30)
    assert c.metrics["keepalive_timeouts"] == 0
    c.finish_round(True)


def test_straggler_flagged_but_commits():
    clk = FakeClock()
    c = CheckpointCoordinator(2, keepalive_s=10.0, straggler_factor=2.0,
                              clock=clk)
    c.begin_round(1)
    c.rank_begin(0)
    c.rank_begin(1)
    c.rank_prepared(0, nbytes=1, files=[])
    # rank 1 lags far past the straggler threshold (factor × keepalive/10
    # = 2 fake seconds) while staying comfortably inside the keepalive
    flagged = False
    for _ in range(200):
        clk.advance(3.0)
        c.heartbeat(1)           # alive, just slow
        if _poll(lambda: c.metrics["stragglers_flagged"] >= 1, timeout=0.05):
            flagged = True
            break
    assert flagged
    c.rank_prepared(1, nbytes=1, files=[])
    assert c.wait_all_prepared(timeout=30)
    assert c.metrics["stragglers_flagged"] >= 1
    assert c.metrics["keepalive_timeouts"] == 0


def test_rank_node_mapping_present():
    c = CheckpointCoordinator(3)
    assert c.ranks[2].node == "nid00002"  # paper's rank-to-node debug aid


def test_drain_counters_equality():
    d = DrainCounters()
    assert d.drained()
    d.enqueue(100)
    assert not d.drained()
    assert not d.wait(timeout=0.05)
    d.commit(100)
    assert d.drained() and d.wait(timeout=0.05)
    s = d.snapshot()
    assert s["enqueued_bytes"] == s["committed_bytes"] == 100


def test_drain_cross_thread():
    d = DrainCounters()
    d.enqueue(1000)

    def worker():
        time.sleep(0.1)
        d.commit(1000)
    threading.Thread(target=worker).start()
    assert d.wait(timeout=5)
