"""Coordinator protocol (keepalive, stragglers, 2PC) and drain counters."""
import threading
import time

import pytest

from repro.core.coordinator import CheckpointCoordinator, RankState
from repro.core.drain import DrainCounters


def _run_ranks(coord, n, work=lambda r: None):
    def rank(r):
        try:
            coord.rank_begin(r)
            work(r)
            coord.rank_prepared(r, nbytes=100, files=[f"f{r}"])
        except Exception as e:  # noqa
            coord.rank_failed(r, str(e))
    ts = [threading.Thread(target=rank, args=(r,)) for r in range(n)]
    for t in ts:
        t.start()
    return ts


def test_commit_happy_path():
    c = CheckpointCoordinator(4)
    c.begin_round(1)
    ts = _run_ranks(c, 4)
    assert c.wait_all_prepared(timeout=5)
    for t in ts:
        t.join()
    c.finish_round(True)
    assert c.metrics["commits"] == 1 and c.metrics["aborts"] == 0


def test_injected_failure_aborts():
    c = CheckpointCoordinator(3)
    c.inject_failure(2)
    c.begin_round(1)
    ts = _run_ranks(c, 3)
    assert not c.wait_all_prepared(timeout=5)
    for t in ts:
        t.join()
    assert "rank 2" in c.abort_reason()
    c.finish_round(False)
    assert c.metrics["aborts"] == 1


def test_keepalive_timeout_detects_dead_rank():
    c = CheckpointCoordinator(2, keepalive_s=0.2)
    c.begin_round(1)

    def rank0():
        c.rank_begin(0)
        c.rank_prepared(0, nbytes=1, files=[])

    def rank1_dies():
        c.rank_begin(1)
        # never heartbeats, never acks — silent death
    threading.Thread(target=rank0).start()
    threading.Thread(target=rank1_dies).start()
    assert not c.wait_all_prepared(timeout=5)
    assert "keepalive" in c.abort_reason()
    assert c.metrics["keepalive_timeouts"] == 1


def test_straggler_flagged_but_commits():
    c = CheckpointCoordinator(2, keepalive_s=1.0, straggler_factor=0.5)

    def slow(r):
        if r == 1:
            for _ in range(8):
                time.sleep(0.05)
                c.heartbeat(1)   # alive, just slow
    c.begin_round(1)
    ts = _run_ranks(c, 2, work=slow)
    assert c.wait_all_prepared(timeout=10)
    for t in ts:
        t.join()
    assert c.metrics["stragglers_flagged"] >= 1


def test_rank_node_mapping_present():
    c = CheckpointCoordinator(3)
    assert c.ranks[2].node == "nid00002"  # paper's rank-to-node debug aid


def test_drain_counters_equality():
    d = DrainCounters()
    assert d.drained()
    d.enqueue(100)
    assert not d.drained()
    assert not d.wait(timeout=0.05)
    d.commit(100)
    assert d.drained() and d.wait(timeout=0.05)
    s = d.snapshot()
    assert s["enqueued_bytes"] == s["committed_bytes"] == 100


def test_drain_cross_thread():
    d = DrainCounters()
    d.enqueue(1000)

    def worker():
        time.sleep(0.1)
        d.commit(1000)
    threading.Thread(target=worker).start()
    assert d.wait(timeout=5)
