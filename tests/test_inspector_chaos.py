"""Checkpoint inspector (fsck) + chaos drill: random fault injection while
training, asserting the system's invariants hold throughout — the paper's
production-hardening story as a single test."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, reduced
from repro.core import atomic
from repro.core.checkpoint import CheckpointManager
from repro.core.errors import AbortedError
from repro.core.storage import Tier, TieredStore
from repro.launch.inspect_ckpt import inspect
from repro.train.loop import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


def _state():
    return {"params": {"w": jax.random.normal(KEY, (32, 16))},
            "step": jnp.asarray(1, jnp.int32)}


def test_inspector_reports_healthy_checkpoint(tmp_path):
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)), n_writers=2,
                            replicas=2)
    mgr.save(_state(), 3, extra={"arch": "x", "config_digest": "abc"})
    rep = inspect(mgr.store.root, verify=True, out=lambda *a: None)
    assert rep["ok"] and rep["shards_bad"] == 0
    assert rep["latest"] == 3 and rep["steps"] == [3]


def test_inspector_shows_in_flight_round_with_age_and_step(tmp_path):
    """An overlapped save keeps a pending-stage dir alive; the inspector
    must show its owning step and age instead of calling it crash litter
    (and a marker-less staging dir is still flagged)."""
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)), n_writers=2)
    mgr.save(_state(), 1)
    stage = atomic.staging_dir(mgr.store.root, 2)
    stage.mkdir(parents=True)
    atomic.mark_pending(stage, {"step": 2, "t": __import__("time").time()})
    lines = []
    rep = inspect(mgr.store.root, out=lambda *a: lines.append(" ".join(
        str(x) for x in a)))
    assert rep["pending_rounds"][0]["step"] == 2
    assert rep["pending_rounds"][0]["age_s"] is not None
    assert rep["pending_rounds"][0]["age_s"] < 60
    assert any("in-flight round: step 2" in ln for ln in lines)
    # a bare staging dir (no marker) is reported as possible litter
    bare = mgr.store.root / "step_00000003.tmp-deadbeef"
    bare.mkdir()
    rep2 = inspect(mgr.store.root, out=lambda *a: None)
    kinds = {(r["step"], r["age_s"] is None) for r in rep2["pending_rounds"]}
    assert (2, False) in kinds and (None, True) in kinds


def test_inspector_detects_corruption_and_replica_recovery(tmp_path):
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)), n_writers=2,
                            replicas=2)
    mgr.save(_state(), 3)
    prim = next(p for p in mgr.store.root.rglob("shard-*.bin")
                if not p.name.endswith(".r1"))
    data = bytearray(prim.read_bytes())
    data[-1] ^= 0xFF
    prim.write_bytes(bytes(data))
    rep = inspect(mgr.store.root, verify=True, out=lambda *a: None)
    # damaged primary but buddy replica covers it: still fully restorable
    # (no dead shards), degradation flagged in problems
    assert rep["shards_bad"] == 0
    assert any("Corrupt" in p or "crc" in p.lower() for p in rep["problems"])
    # without replicas the damage must be flagged
    mgr2 = CheckpointManager(TieredStore(Tier("f", tmp_path / "n")),
                             n_writers=2, replicas=1)
    mgr2.save(_state(), 3)
    prim = next(iter(mgr2.store.root.rglob("shard-00000.bin")))
    prim.write_bytes(b"garbage")
    rep2 = inspect(mgr2.store.root, verify=True, out=lambda *a: None)
    assert not rep2["ok"] and rep2["shards_bad"] >= 1


def test_inspector_reports_chunked_checkpoint_and_dedup(tmp_path):
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)), n_writers=2,
                            mode="incremental", codec="raw", chunk_size=512)
    state = _state()
    mgr.save(state, 1)
    mgr.save(state, 2)          # identical — dedups against step 1 entirely
    rep = inspect(mgr.store.root, verify=True, out=lambda *a: None)
    assert rep["ok"] and rep["shards_bad"] == 0
    assert rep["mode"] == "incremental"
    assert rep["dedup"]["chunks"] > 0
    assert rep["cas"]["orphans"] == 0 and rep["cas"]["missing"] == 0
    assert rep["cas"]["ref_drift"] == 0
    # two steps share every chunk → step-level dedup ratio ~1, but the
    # store holds one copy for two steps' references
    assert rep["cas"]["references"] == 2 * rep["cas"]["objects"]
    # fixed-scheme chunk-size histogram derives from chunk_size alone
    hist = rep["chunk_hist"]["fixed"]
    assert hist["p50"] <= 512 and hist["chunks"] > 0
    assert hist["configured"] == {"size": 512}


def test_inspector_chunk_histogram_vs_cdc_bounds(tmp_path):
    """CDC steps report their realized chunk-size distribution against the
    configured min/avg/max — the fsck surface for misconfigured bounds."""
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)), n_writers=2,
                            mode="incremental", codec="raw",
                            chunking="cdc", chunk_size=512)
    mgr.save({"params": {"w": jax.random.normal(KEY, (128, 128))}}, 1)
    lines = []
    rep = inspect(mgr.store.root,
                  out=lambda *a: lines.append(" ".join(str(x) for x in a)))
    hist = rep["chunk_hist"]["cdc"]
    assert hist["configured"] == {"min": mgr._chunker.min_size,
                                  "avg": mgr._chunker.avg_size,
                                  "max": mgr._chunker.max_size}
    assert mgr._chunker.min_size <= hist["p50"] <= mgr._chunker.max_size
    assert hist["p10"] <= hist["p50"] <= hist["p90"]
    assert any("cdc chunk sizes:" in ln for ln in lines)


def test_inspector_prints_v6_policy_block(tmp_path):
    from conftest import make_ckpt_policy
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)),
                            policy=make_ckpt_policy(
                                n_writers=2, mode="incremental",
                                codec="raw", chunking="cdc",
                                chunk_size=1024, io_threads=4,
                                persist_queue_depth=2))
    mgr.save(_state(), 1)
    lines = []
    rep = inspect(mgr.store.root,
                  out=lambda *a: lines.append(" ".join(str(x) for x in a)))
    assert rep["ok"]
    assert rep["policy"]["chunking"]["scheme"] == "cdc"
    assert rep["policy"]["pipeline"]["persist_queue_depth"] == 2
    assert any("policy: mode=incremental" in ln for ln in lines)
    assert any("chunking=cdc@1K" in ln and "persist_queue=2" in ln
               for ln in lines)


def test_inspector_policy_not_recorded_for_old_manifests(tmp_path):
    """A pre-v6 manifest has no policy block — the inspector says so
    instead of implying damage."""
    import json
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)), n_writers=2,
                            mode="incremental", codec="raw", chunk_size=512)
    mgr.save(_state(), 1)
    mpath = mgr.store.root / "step_00000001" / atomic.MANIFEST
    m = json.loads(mpath.read_text())
    m["format"] = 5
    m.pop("policy")
    mpath.write_text(json.dumps(m))
    lines = []
    rep = inspect(mgr.store.root,
                  out=lambda *a: lines.append(" ".join(str(x) for x in a)))
    assert rep["ok"]
    assert "policy" not in rep
    assert any("policy: not recorded (v≤5)" in ln for ln in lines)


def test_inspector_corrupted_policy_block_warns_not_crashes(tmp_path):
    """Chaos: garbage policy blocks of several shapes. The inspector must
    finish (report, exit-0 semantics unchanged — restore does not depend
    on the block), surface a warning line, and still verify shards."""
    import json
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)), n_writers=2,
                            mode="incremental", codec="raw", chunk_size=512)
    mgr.save(_state(), 1)
    mpath = mgr.store.root / "step_00000001" / atomic.MANIFEST
    for garbage in ({"mode": "bogus"}, [1, 2, 3], "zzz",
                    {"chunking": {"chunk_size": -5}}, None):
        m = json.loads(mpath.read_text())
        m["policy"] = garbage
        mpath.write_text(json.dumps(m))
        lines = []
        rep = inspect(mgr.store.root, verify=True,
                      out=lambda *a: lines.append(" ".join(
                          str(x) for x in a)))
        assert rep["ok"] and rep["shards_bad"] == 0
        assert "policy_error" in rep
        assert any("policy block unreadable" in ln for ln in lines)


def test_verify_deep_pass_skips_step_covered_digests(tmp_path):
    """--verify used to read every chunk the inspected step references
    TWICE (deep CAS pass + per-shard crc/decode pass). The deep pass must
    now only read digests the inspected step does NOT cover — for a
    single-step store that is zero deep reads; with history it is exactly
    the other steps' private digests."""
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)), n_writers=2,
                            mode="incremental", codec="raw", chunk_size=512)
    state = _state()
    mgr.save(state, 1)
    rep = inspect(mgr.store.root, verify=True, out=lambda *a: None)
    assert rep["ok"]
    assert rep["cas"]["deep_reads"] == 0        # per-shard pass covers all
    # second step with different content: inspecting step 2 deep-reads
    # only step 1's now-unshared digests
    state2 = _state()
    state2["params"]["w"] = state2["params"]["w"] + 1.0
    mgr.save(state2, 2)
    rep = inspect(mgr.store.root, step=2, verify=True, out=lambda *a: None)
    assert rep["ok"]
    assert 0 < rep["cas"]["deep_reads"] < rep["cas"]["objects"]
    # a corrupt chunk of the INSPECTED step is still caught (per-shard pass)
    m = mgr.load_manifest(2)
    from repro.core import cas as cas_mod
    digests = {d for rec in m["leaves"].values() for s in rec["shards"]
               for d in s.get("chunks", [])}
    victim = mgr.store.root / cas_mod.object_rel(sorted(digests)[0])
    victim.write_bytes(b"\xff" * victim.stat().st_size)
    rep = inspect(mgr.store.root, step=2, verify=True, out=lambda *a: None)
    assert not rep["ok"] and rep["shards_bad"] >= 1


def test_inspector_flags_missing_chunk_and_orphans(tmp_path):
    mgr = CheckpointManager(TieredStore(Tier("f", tmp_path)), n_writers=2,
                            mode="incremental", codec="raw", chunk_size=512)
    mgr.save(_state(), 1)
    # delete one live object → missing; drop an unreferenced one → orphan
    objs = sorted(mgr.store.root.glob("_CAS/objects/*/*.obj"))
    objs[0].unlink()
    orphan = mgr.store.root / "_CAS/objects/zz" / ("f" * 32 + ".obj")
    orphan.parent.mkdir(parents=True, exist_ok=True)
    orphan.write_bytes(b"junk")
    rep = inspect(mgr.store.root, verify=True, out=lambda *a: None)
    assert not rep["ok"]
    assert rep["cas"]["missing"] == 1
    assert rep["cas"]["orphans"] == 1
    assert rep["shards_bad"] >= 1


@pytest.mark.slow
def test_chaos_drill(tmp_path):
    """Random faults every round; invariants after every event:
      (1) a valid committed checkpoint always exists once one was written;
      (2) restore of the latest step always succeeds;
      (3) training always continues from the restored state."""
    cfg = reduced(CONFIGS["stablelm-1.6b"])
    rng = random.Random(1234)
    tcfg = TrainerConfig(workdir=str(tmp_path), batch=4, seq_len=32,
                         ckpt_every=2, log_every=1000, seed=3,
                         replicas=2, n_writers=3)
    t = Trainer(cfg, tcfg).init_or_restore()
    t.fit(2)
    target = 2
    for round_ in range(5):
        event = rng.choice(["rank_failure", "corrupt_primary",
                            "staging_litter", "none"])
        if event == "rank_failure":
            victim = rng.randrange(3)
            t.manager.coordinator.inject_failure(victim)
        elif event == "corrupt_primary":
            prims = [p for p in t.manager.store.root.rglob("shard-*.bin")
                     if not p.name.endswith(".r1")]
            if prims:
                rng.choice(prims).write_bytes(b"\x00" * 16)
        elif event == "staging_litter":
            d = t.manager.store.root / "step_99999999.tmp-dead"
            (d / "_META").mkdir(parents=True, exist_ok=True)
            (d / "_META" / "PENDING").write_text("{}")
        target += 2
        try:
            t.fit(target)
        except AbortedError:
            pass  # permitted outcome for unrecoverable rounds
        finally:
            t.manager.coordinator._inject_fail.clear()
        # invariant 1+2: latest committed checkpoint is restorable
        steps = atomic.list_committed_steps(t.manager.store.root)
        assert steps, "no committed checkpoint survived"
        t2 = Trainer(cfg, tcfg).init_or_restore()
        assert t2.restored_from == steps[-1]
        # invariant 3: restored state trains
        t2.fit(t2.py_step + 1, stop_after=1)
        t = Trainer(cfg, tcfg).init_or_restore()
        t.py_step = t.py_step  # continue from restore
        target = t.py_step
    rep = inspect(t.manager.store.root, verify=True, out=lambda *a: None)
    assert rep["steps"], rep
