"""Fault-injection matrix for the self-healing tiered store — every
schedulable fault point × {serial, pipelined} engine × {fixed, cdc}
chunking.

Where the crash matrix kills the process at protocol boundaries, this
matrix keeps the process ALIVE and makes the storage layer lie: EIO on
read and write, short/torn writes, bit-rot, vanished files, latency
spikes, and a tier running out of space mid-round. Invariants asserted
under EVERY schedule:

  1. the pipelined engine (io_retries > 0) absorbs transient faults and
     fails over fast→slow for persistent tier-full conditions — the
     round COMMITS (with a ``degraded`` manifest marker on failover)
     instead of aborting;
  2. the serial engine (``io_threads=1``, the PR-1 purity baseline)
     stays fail-FAST: the same schedules abort the round or raise, and a
     clean retry afterwards lands normally — fail-fast, not fail-forever;
  3. every committed step restores bit-exact regardless of which tier
     ended up holding the bytes;
  4. after one GC the content-addressed store passes fsck — zero leaked
     objects, zero silently-lost objects.

Every fault site is addressable by ``(op, tier, match, nth)`` and the
plane is seeded, so any failure in this file is replayable from the
test id alone. ``FAULT_MATRIX_SEED`` feeds the randomized-schedule test
(CI's chaos-smoke echoes the seed it used so a red run can be replayed).
"""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_ckpt_policy
from repro.core import atomic, cas
from repro.core.atomic import CrashInjector, CrashPoint
from repro.core.checkpoint import CheckpointManager
from repro.core.errors import AbortedError, CkptError, SpaceError
from repro.core.faults import FaultPlane, wrap_store
from repro.core.preempt import PreemptionGuard
from repro.core import resilience
from repro.core.storage import Tier, TieredStore

KEY = jax.random.PRNGKey(3)
SEED = int(os.environ.get("FAULT_MATRIX_SEED", "7"))

IO_AXES = [1, 4]                 # 1 = serial fail-fast reference engine
CHUNKINGS = ["fixed", "cdc"]


def _state(seed: int):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (32, 16)),
                   "frozen": jax.random.normal(KEY, (64, 8))},
        "opt": {"m": jnp.arange(512, dtype=jnp.float32).reshape(32, 16)},
        "step": jnp.asarray(seed, jnp.int32),
    }


def _abstract(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


def _assert_restores(mgr, step, expect):
    restored, _ = mgr.restore(_abstract(expect), step=step)
    for a, b in zip(jax.tree.leaves(expect), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _plane_store(tmp_path, plane=None):
    plane = plane if plane is not None else FaultPlane(seed=SEED)
    store = TieredStore(Tier("fast", tmp_path / "fast"),
                        Tier("slow", tmp_path / "slow"))
    return wrap_store(store, plane), plane


def _mgr(store, io_threads, chunking, mode="incremental", replicas=1):
    return CheckpointManager(store, policy=make_ckpt_policy(
        n_writers=2, codec="raw", mode=mode, chunk_size=512,
        chunking=chunking, retain=2, max_retries=0, replicas=replicas,
        io_threads=io_threads, io_retries=2, io_backoff_ms=1.0,
        io_deadline_s=10.0))


# Each point: the fault schedule (save-phase and/or restore-phase specs)
# plus what the serial engine is expected to do about it. Pipelined
# behaviour is uniform — absorb or degrade, never abort — except where
# `degraded` pins the failover marker explicitly.
#
#   serial_save: "ok" | "abort" (writer fails → AbortedError; a clean
#                re-save must land) | "space" (preflight SpaceError)
#   drain_err_serial: the serial engine surfaces the background drain
#                error as OSError (at save-time maintenance or at
#                wait_drained) while the pipelined engine retries it away
#   serial_restore_raises: first restore raises; the retry (fault
#                exhausted) must succeed
#   degraded:   pipelined round must commit with manifest["degraded"]
#   scrub:      run a scrub after the save phase and require it to
#                quarantine + heal (write-side corruption points)
#   cold:       wipe the fast tier before restoring (burst buffer lost)
POINTS = [
    # -- write-side: transient fast-tier failures the retry budget absorbs
    dict(name="w-eio-1", serial_save="abort",
         save=[dict(op="write", kind="eio", tier="fast", match=".obj")]),
    dict(name="w-eio-mid", serial_save="abort",
         save=[dict(op="write", kind="eio", tier="fast", match=".obj",
                    nth=3)]),
    dict(name="w-eio-replica", replicas=2, serial_save="abort",
         save=[dict(op="write", kind="eio", tier="fast", match=".obj",
                    nth=2)]),
    dict(name="w-enospc-1", serial_save="abort",
         save=[dict(op="write", kind="enospc", tier="fast",
                    match=".obj")]),
    dict(name="w-enospc-burst", serial_save="abort",
         save=[dict(op="write", kind="enospc", tier="fast", match=".obj",
                    count=2)]),
    dict(name="w-short-write", serial_save="abort",
         save=[dict(op="write", kind="short_write", tier="fast",
                    match=".obj")]),
    dict(name="w-latency",
         save=[dict(op="write", kind="latency", tier="fast", match=".obj",
                    count=3, latency_s=0.02)]),
    # -- write-side: persistent tier-full → degraded failover to slow
    dict(name="w-enospc-persistent", serial_save="abort", degraded=True,
         save=[dict(op="write", kind="enospc", tier="fast", match=".obj",
                    count=-1)]),
    dict(name="w-erofs-persistent", serial_save="abort", degraded=True,
         save=[dict(op="write", kind="erofs", tier="fast", match=".obj",
                    count=-1)]),
    dict(name="preflight-fast-full", serial_save="space", degraded=True,
         save=[dict(op="free", kind="full", tier="fast", count=-1)]),
    # -- write-side: silent corruption (no errno) — replica + scrub heal
    dict(name="w-bitrot-replica", replicas=2, scrub=True,
         save=[dict(op="write", kind="bitrot", tier="fast",
                    match=".obj")]),
    dict(name="w-torn-replica", replicas=2, scrub=True,
         save=[dict(op="write", kind="torn_write", tier="fast",
                    match=".obj")]),
    # -- full-mode shard writes get the same retry budget
    dict(name="w-eio-fullmode", mode="full", serial_save="abort",
         save=[dict(op="write", kind="eio", tier="fast")]),
    # -- drain protocol: slow-tier faults during the background copy
    dict(name="drain-eio-slow", drain_err_serial=True,
         save=[dict(op="write", kind="eio", tier="slow", match=".obj")]),
    dict(name="drain-latency-slow",
         save=[dict(op="write", kind="latency", tier="slow", match=".obj",
                    count=2, latency_s=0.02)]),
    # -- read-side: both engines fall through fast→slow per copy
    dict(name="r-eio-transient",
         restore=[dict(op="read", kind="eio", tier="fast",
                       match=".obj")]),
    dict(name="r-eio-persistent-fast",
         restore=[dict(op="read", kind="eio", tier="fast", match=".obj",
                       count=-1)]),
    dict(name="r-short-read",
         restore=[dict(op="read", kind="short_write", tier="fast",
                       match=".obj")]),
    dict(name="r-vanish",
         restore=[dict(op="read", kind="vanish", tier="fast",
                       match=".obj")]),
    dict(name="r-latency",
         restore=[dict(op="read", kind="latency", tier="fast",
                       match=".obj", count=4, latency_s=0.02)]),
    dict(name="r-bitrot-transient",
         restore=[dict(op="read", kind="bitrot", tier="fast",
                       match=".obj")]),
    # -- read-side: metadata (manifest / refs cache) faults
    dict(name="r-eio-manifest", serial_restore_raises=True,
         restore=[dict(op="read_file", kind="eio", tier="fast",
                       match="manifest")]),
    dict(name="r-manifest-latency",
         restore=[dict(op="read_file", kind="latency", tier="fast",
                       match="manifest", latency_s=0.02)]),
    dict(name="refs-eio",
         restore=[dict(op="read_file", kind="eio", tier="fast",
                       match="refs.json", count=2)]),
    # -- cold restart: burst buffer gone, slow tier faults on first read
    dict(name="r-eio-slow-cold", cold=True, serial_restore_raises=True,
         restore=[dict(op="read", kind="eio", tier="slow",
                       match=".obj")]),
]


def _wipe_fast(store):
    """Simulate a lost burst buffer: committed steps + CAS vanish from
    the fast tier; LATEST survives (it is tiny and rewritten last)."""
    root = store.fast.root
    for s in atomic.list_committed_steps(root):
        shutil.rmtree(atomic.committed_dir(root, s), ignore_errors=True)
    shutil.rmtree(root / cas.CAS_DIR, ignore_errors=True)


@pytest.mark.parametrize("chunking", CHUNKINGS)
@pytest.mark.parametrize("io_threads", IO_AXES)
@pytest.mark.parametrize("point", POINTS, ids=lambda p: p["name"])
def test_fault_matrix(tmp_path, point, io_threads, chunking):
    serial = io_threads == 1
    store, plane = _plane_store(tmp_path)
    mgr = _mgr(store, io_threads, chunking,
               mode=point.get("mode", "incremental"),
               replicas=point.get("replicas", 1))
    states = {1: _state(1), 2: _state(2)}
    mgr.save(states[1], 1)
    store.wait_drained()

    for kw in point.get("save", []):
        plane.add(**kw)
    expect_serial = point.get("serial_save", "ok")
    drain_err = False
    if serial and expect_serial != "ok":
        exc = {"abort": AbortedError, "space": SpaceError}[expect_serial]
        with pytest.raises(exc):
            mgr.save(states[2], 2)
        assert plane.fired(), "serial round aborted without a fired fault"
        plane.clear()
        mgr.save(states[2], 2)        # fail-fast, not fail-forever
    else:
        try:
            rep = mgr.save(states[2], 2)
        except OSError:
            # serial drain error can surface inside save-time maintenance
            assert serial and point.get("drain_err_serial"), point["name"]
            drain_err, rep = True, None
        if rep is not None and not serial:
            if point.get("degraded"):
                assert rep["degraded"] is True
                assert mgr.load_manifest(2).get("degraded") is True
                assert plane.fired()
            else:
                assert not rep.get("degraded"), point["name"]

    # settle the background drain; the serial engine must SURFACE a
    # drain fault (exactly once), the pipelined engine must retry it away
    try:
        store.wait_drained()
    except OSError:
        drain_err = True
    assert drain_err == bool(serial and point.get("drain_err_serial")), \
        point["name"]
    assert mgr.latest_step() == 2
    plane.clear()

    if point.get("scrub"):
        srep = mgr.scrub()["scrub"]
        assert srep["quarantined"] >= 1, srep
        assert srep["healed"] >= 1, srep
        assert srep["unrecoverable"] == 0, srep
        assert mgr.chunks.quarantine_entries()

    if point.get("cold"):
        _wipe_fast(store)

    for kw in point.get("restore", []):
        plane.add(**kw)
    if serial and point.get("serial_restore_raises"):
        with pytest.raises((OSError, CkptError)):
            _assert_restores(mgr, 2, states[2])
        plane.clear()
    _assert_restores(mgr, 2, states[2])
    _assert_restores(mgr, 1, states[1])
    plane.clear()

    mgr.gc()
    fsck = mgr.chunks.fsck(mgr._live_chunk_refs())
    assert fsck["ok"], (point["name"], fsck)
    mgr.close()


# ---------------------------------------------------------------------------
# randomized schedule — replayable chaos (CI echoes the seed it used)
# ---------------------------------------------------------------------------

def test_randomized_schedule_replayable(tmp_path):
    """A seeded random schedule drawn from the RECOVERABLE catalog must
    never cost a committed round or a byte: every save COMMITS (a
    degraded commit is acceptable when overlapping random bursts outlast
    the retry budget — an abort is not), restores stay bit-exact, and
    fsck stays clean. Replay a red CI run with
    FAULT_MATRIX_SEED=<echoed seed>."""
    plane = FaultPlane.random_schedule(SEED, n=6)
    store, _ = _plane_store(tmp_path, plane)
    mgr = _mgr(store, io_threads=4, chunking="cdc")
    states = {1: _state(1), 2: _state(2), 3: _state(3)}
    for s in (1, 2, 3):
        assert mgr.save(states[s], s)["step"] == s
    store.wait_drained()
    for s in (1, 2, 3):
        _assert_restores(mgr, s, states[s])
    mgr.gc()
    fsck = mgr.chunks.fsck(mgr._live_chunk_refs())
    assert fsck["ok"], (SEED, [s.key for s in plane.specs], fsck)
    mgr.close()


# ---------------------------------------------------------------------------
# scrubber: heal, refuse-last-copy, preemption, crash convergence
# ---------------------------------------------------------------------------

def _corrupt(path, offset=0):
    raw = bytearray(path.read_bytes())
    raw[offset] ^= 0x01
    path.write_bytes(bytes(raw))


def _live_primaries(mgr, tier):
    """(digest, path) for every live primary object on `tier`."""
    live = mgr._live_chunk_refs()
    out = []
    for digest, n in sorted(live.items()):
        if n <= 0:
            continue
        p = tier.root / cas.object_rel(digest)
        if p.is_file():
            out.append((digest, p))
    return out


def test_scrub_heals_bitrot_from_replica(tmp_path):
    """Acceptance: injected bit-rot on a primary is healed from the
    buddy replica, with the corrupt copy quarantined — and the pass is
    idempotent (a second scrub reports everything clean)."""
    store, _ = _plane_store(tmp_path)
    mgr = _mgr(store, io_threads=4, chunking="fixed", replicas=2)
    state = _state(1)
    mgr.save(state, 1)
    store.wait_drained()
    digest, p = _live_primaries(mgr, store.fast)[0]
    _corrupt(p)
    rep = mgr.scrub()["scrub"]
    assert rep["quarantined"] == 1 and rep["unrecoverable"] == 0
    assert rep["healed"] >= 1
    entries = mgr.chunks.quarantine_entries()
    assert [e[2] for e in entries] == [digest]
    # the healed slot holds good bytes again; the quarantined copy holds
    # the damage (kept for forensics, never re-marked by GC)
    assert cas.chunk_digest(p.read_bytes()) == digest
    qpath = store.fast.root / entries[0][1]
    assert cas.chunk_digest(qpath.read_bytes()) != digest
    _assert_restores(mgr, 1, state)
    again = mgr.scrub()["scrub"]
    assert again["quarantined"] == 0 and again["healed"] == 0
    assert again["clean"] == again["scanned"]
    mgr.gc()
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
    mgr.close()


def test_scrub_never_quarantines_last_copy(tmp_path):
    """A corrupt object with NO good copy anywhere is left in place and
    reported unrecoverable — quarantining it would destroy the only
    evidence (and a replica may yet surface from an unmounted tier)."""
    store = TieredStore(Tier("fast", tmp_path / "fast"))
    mgr = _mgr(store, io_threads=4, chunking="fixed")
    mgr.save(_state(1), 1)
    digest, p = _live_primaries(mgr, store.fast)[0]
    _corrupt(p)
    rep = mgr.scrub()["scrub"]
    assert rep["unrecoverable"] == 1 and rep["quarantined"] == 0
    assert p.is_file(), "last surviving copy must stay in place"
    assert not mgr.chunks.quarantine_entries()
    mgr.close()


def test_scrub_heals_from_slow_tier(tmp_path):
    """With replicas=1 the drained slow-tier copy is the healing source."""
    store, _ = _plane_store(tmp_path)
    mgr = _mgr(store, io_threads=4, chunking="fixed")
    state = _state(1)
    mgr.save(state, 1)
    store.wait_drained()
    digest, p = _live_primaries(mgr, store.fast)[0]
    _corrupt(p)
    rep = mgr.scrub()["scrub"]
    assert rep["quarantined"] == 1 and rep["healed"] >= 1
    assert rep["unrecoverable"] == 0
    assert cas.chunk_digest(p.read_bytes()) == digest
    _assert_restores(mgr, 1, state)
    mgr.close()


def test_scrub_preemption_defers_and_converges(tmp_path):
    """Satellite: SIGTERM mid-scrub. The guard's flag defers the
    remainder BETWEEN objects, so no quarantine entry is ever
    half-moved; the re-run after requeue converges to clean."""
    store, _ = _plane_store(tmp_path)
    mgr = _mgr(store, io_threads=4, chunking="fixed", replicas=2)
    state = _state(1)
    mgr.save(state, 1)
    store.wait_drained()
    primaries = _live_primaries(mgr, store.fast)
    assert len(primaries) >= 6
    for _d, p in primaries[:3]:
        _corrupt(p)

    with PreemptionGuard() as guard:
        polls = [0]

        def stop():
            polls[0] += 1
            if polls[0] == 3:
                guard.request()     # the test stand-in for SIGTERM
            return guard.should_preempt

        rep = mgr.scrub(should_stop=stop)["scrub"]
    assert rep["deferred"] > 0
    assert rep["scanned"] < len(primaries)
    # invariant: nothing half-moved — every quarantined digest's origin
    # slot is populated again (quarantine+heal is atomic per object)
    for tier_name, _qrel, digest, replica, _size in \
            mgr.chunks.quarantine_entries():
        tier = next(t for t in store.tiers() if t.name == tier_name)
        assert (tier.root / cas.object_rel(digest, replica)).is_file()

    healed_total = rep["healed"]
    rep2 = mgr.scrub()["scrub"]     # requeued run: no preemption
    healed_total += rep2["healed"]
    assert rep2["deferred"] == 0
    assert healed_total == 3
    rep3 = mgr.scrub()["scrub"]
    assert rep3["quarantined"] == 0 and rep3["healed"] == 0
    _assert_restores(mgr, 1, state)
    mgr.close()


def test_scrub_converges_after_crash_mid_heal(tmp_path):
    """Kill the scrubber in the window between the quarantine rename and
    the heal write: the slot is empty but the quarantine filename holds
    the provenance, so the NEXT scrub's pass-0 re-replicates it."""
    store, _ = _plane_store(tmp_path)
    mgr = _mgr(store, io_threads=4, chunking="fixed", replicas=2)
    state = _state(1)
    mgr.save(state, 1)
    store.wait_drained()
    digest, p = _live_primaries(mgr, store.fast)[0]
    _corrupt(p)
    with pytest.raises(CrashPoint):
        mgr.scrub(crash=CrashInjector("scrub_after_quarantine"))
    assert not p.is_file(), "crash window: quarantined but not healed"
    entries = mgr.chunks.quarantine_entries()
    assert [e[2] for e in entries] == [digest]
    # reads still work through the buddy replica in the meantime
    _assert_restores(mgr, 1, state)
    rep = mgr.scrub()["scrub"]      # fresh process: pass-0 converges
    assert rep["healed"] >= 1 and rep["unrecoverable"] == 0
    assert cas.chunk_digest(p.read_bytes()) == digest
    rep2 = mgr.scrub()["scrub"]
    assert rep2["quarantined"] == 0 and rep2["healed"] == 0
    mgr.gc()
    assert mgr.chunks.fsck(mgr._live_chunk_refs())["ok"]
    mgr.close()


# ---------------------------------------------------------------------------
# degraded-mode commit + health surfaces
# ---------------------------------------------------------------------------

def test_degraded_save_commits_and_is_inspectable(tmp_path):
    """Fast tier goes read-only mid-round: the pipelined engine fails
    the writers over to the slow tier and COMMITS, marking the manifest;
    health counters record the failover for the offline inspector."""
    store, plane = _plane_store(tmp_path)
    mgr = _mgr(store, io_threads=4, chunking="fixed")
    state = _state(2)
    plane.add(op="write", kind="erofs", tier="fast", match=".obj",
              count=-1)
    rep = mgr.save(state, 2)
    assert rep["degraded"] is True
    assert mgr.load_manifest(2).get("degraded") is True
    assert mgr.chunks.degraded_writes > 0
    plane.clear()
    _assert_restores(mgr, 2, state)
    health = store.health_report()
    assert health["slow"]["counters"].get("degraded_writes", 0) > 0
    assert health["fast"]["breaker"]["state"] in ("closed", "open")
    # maintenance persists the snapshot for the out-of-process inspector
    mgr.gc()
    assert (store.fast.root / cas.HEALTH_FILE).is_file()
    mgr.close()


def test_serial_engine_stays_failfast_on_tier_full(tmp_path):
    """PR-1 purity: the serial engine must NOT fail over — a full fast
    tier aborts the round exactly as the baseline engine did."""
    store, plane = _plane_store(tmp_path)
    mgr = _mgr(store, io_threads=1, chunking="fixed")
    plane.add(op="write", kind="enospc", tier="fast", match=".obj",
              count=-1)
    with pytest.raises(AbortedError):
        mgr.save(_state(2), 2)
    assert mgr.chunks.degraded_writes == 0
    plane.clear()
    mgr.save(_state(2), 2)          # clean retry lands normally
    assert mgr.load_manifest(2).get("degraded") is None
    mgr.close()


# ---------------------------------------------------------------------------
# satellite regressions: read_into accounting, replica probe economy
# ---------------------------------------------------------------------------

def test_read_into_distinguishes_missing_from_damage(tmp_path):
    """A missing object is an expected cache miss (silent counter); a
    short read or EIO is DAMAGE and must be counted + warned — once per
    (kind, rel), not once per chunk access."""
    tier = Tier("t", tmp_path)
    buf = bytearray(8)
    assert tier.read_into("absent.obj", buf) is False
    assert tier.io_counters.get("read_missing") == 1
    assert not tier._warned_reads

    tier.write_file("short.obj", b"1234")
    assert tier.read_into("short.obj", buf) is False
    assert tier.read_into("short.obj", buf) is False
    assert tier.io_counters.get("short_read") == 2
    assert len(tier._warned_reads) == 1   # rate-limited: one warn per site

    from repro.core.faults import FaultyTier
    plane = FaultPlane()
    plane.add(op="read_into", kind="eio", tier="t", match="short.obj")
    wrapped = FaultyTier(tier, plane)
    tier.write_file("short.obj", bytes(8))
    assert wrapped.read_into("short.obj", buf) is False
    assert tier.io_counters.get("read_error") == 1
    assert wrapped.read_into("short.obj", buf) is True


def test_single_replica_skips_dead_replica_probe(tmp_path):
    """With replicas=1 the hot path must not probe the dead ``.r1``
    slot; a legacy ``.r1`` copy from an old 2-replica config is still
    honoured — but only as a last resort after the primary fails."""
    store = TieredStore(Tier("fast", tmp_path / "fast"))
    mgr = _mgr(store, io_threads=4, chunking="fixed")
    data = b"x" * 600
    digest = cas.chunk_digest(data)
    assert mgr.chunks.put(digest, data) > 0
    primary = store.fast.root / cas.object_rel(digest)
    legacy = store.fast.root / cas.object_rel(digest, 1)
    assert primary.is_file() and not legacy.exists()
    # plant a legacy replica, then damage the primary: get() must fall
    # back to the .r1 copy even though exists() only probes slot 0
    legacy.parent.mkdir(parents=True, exist_ok=True)
    legacy.write_bytes(data)
    _corrupt(primary)
    assert mgr.chunks.exists(digest) is True
    assert mgr.chunks.get(digest) == data
    primary.unlink()
    assert mgr.chunks.exists(digest) is False   # configured slot only
    assert mgr.chunks.get(digest) == data       # last-ditch still serves
    mgr.close()


# ---------------------------------------------------------------------------
# resilience primitives (no IO): retry, deadline, breaker
# ---------------------------------------------------------------------------

def test_retry_absorbs_transient_and_respects_budget():
    calls = [0]

    def flaky():
        calls[0] += 1
        if calls[0] < 3:
            raise OSError(5, "Input/output error")
        return "ok"

    sleeps = []
    pol = resilience.RetryPolicy(retries=2, backoff_ms=1.0, deadline_s=None)
    assert resilience.retry_io(flaky, pol, sleep=sleeps.append) == "ok"
    assert calls[0] == 3 and len(sleeps) == 2
    # decorrelated jitter: bounded below by base, above by the cap
    assert all(0.001 <= s <= 0.1 for s in sleeps)

    calls[0] = 0
    with pytest.raises(OSError):
        resilience.retry_io(
            flaky, resilience.RetryPolicy(retries=1, backoff_ms=1.0,
                                          deadline_s=None),
            sleep=lambda _s: None)
    assert calls[0] == 2            # budget exhausted → error propagates


def test_retry_fails_fast_on_permanent_and_without_policy():
    def eperm():
        raise PermissionError(1, "Operation not permitted")
    with pytest.raises(PermissionError):
        resilience.retry_io(
            eperm, resilience.RetryPolicy(retries=5, backoff_ms=1.0),
            sleep=lambda _s: None)
    calls = [0]

    def once():
        calls[0] += 1
        raise OSError(5, "io")
    with pytest.raises(OSError):
        resilience.retry_io(once, None)
    assert calls[0] == 1            # retry=None == the serial engine


def test_deadline_cuts_retries_short():
    now = [0.0]
    dl = resilience.Deadline(1.0, clock=lambda: now[0])

    def always():
        now[0] += 0.6
        raise OSError(5, "io")
    with pytest.raises(OSError):
        resilience.retry_io(
            always, resilience.RetryPolicy(retries=99, backoff_ms=1.0),
            deadline=dl, sleep=lambda _s: None)
    assert now[0] <= 1.3            # 2 attempts, not 100


def test_circuit_breaker_lifecycle():
    now = [0.0]
    br = resilience.CircuitBreaker(threshold=3, cooldown_s=30.0,
                                   clock=lambda: now[0])
    assert br.state == "closed" and br.allow()
    for _ in range(2):
        br.record_error()
    assert br.state == "closed"     # below threshold
    br.record_ok()
    br.record_error(); br.record_error()
    assert br.state == "closed"     # success resets the streak
    br.record_error()
    assert br.state == "open" and not br.allow() and br.trips == 1
    now[0] += 31.0
    assert br.state == "half-open" and br.allow()
    br.record_error()               # probe failed: re-arm
    assert br.state == "open"
    now[0] += 31.0
    br.record_ok()                  # probe succeeded: close
    assert br.state == "closed" and br.allow()


def test_fault_classification():
    assert resilience.is_transient(OSError(5, "io"))        # EIO
    assert resilience.is_transient(OSError(28, "nospc"))    # ENOSPC
    assert resilience.is_tier_full(OSError(28, "nospc"))
    assert resilience.is_tier_full(OSError(30, "rofs"))     # EROFS
    assert not resilience.is_tier_full(OSError(5, "io"))
    assert not resilience.is_transient(PermissionError(1, "eperm"))
    assert not resilience.is_transient(ValueError("not IO at all"))
