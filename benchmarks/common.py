"""Shared benchmark helpers: tiered stores mirroring the paper's Cori setup
(Burst Buffer = /dev/shm, CSCRATCH/Lustre = throttled disk), synthetic
states of controlled aggregate size, and the machine-readable perf record
(``BENCH_ckpt.json``) that tracks the checkpoint-path trajectory per PR."""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.storage import Tier, TieredStore  # noqa: E402

LUSTRE_BW = 200e6  # simulated shared-filesystem aggregate bandwidth


def bench_policy(**flat):
    """Shared benchmark ``CheckpointPolicy``: a generous coordinator
    keepalive (this box's bimodal fsync stalls must not read as dead
    writer ranks) plus flat overrides — the benches' one construction
    idiom, mirroring the tests' shared fixture."""
    from repro.core.policy import CheckpointPolicy
    flat.setdefault("keepalive_s", 120.0)
    return CheckpointPolicy().with_overrides(**flat)


def bb_store(tag: str) -> TieredStore:
    root = Path("/dev/shm") if os.access("/dev/shm", os.W_OK) \
        else Path(tempfile.gettempdir())
    return TieredStore(Tier("burst-buffer", root / f"repro-bench-{tag}"))


def scratch_store(tag: str, tmp: Path) -> TieredStore:
    return TieredStore(Tier("cscratch-sim", tmp / tag,
                            bw_bytes_per_s=LUSTRE_BW))


def synth_state(total_bytes: int, *, shards: int = 8, seed: int = 0) -> dict:
    """Params-like f32 state of ~total_bytes aggregate size."""
    per = max(total_bytes // (4 * shards), 1)
    side = max(int(per ** 0.5), 1)
    rng = np.random.default_rng(seed)
    return {
        "params": {f"w{i}": jnp.asarray(
            rng.standard_normal((side, side), dtype=np.float32))
            for i in range(shards)},
        "step": jnp.asarray(1, jnp.int32),
    }


def abstract(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


def cleanup(store: TieredStore):
    for t in store.tiers():
        shutil.rmtree(t.root, ignore_errors=True)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_ckpt.json"


def bench_record(section: str, data: dict):
    """Merge one benchmark section into ``BENCH_ckpt.json`` at the repo
    root — the machine-readable perf trajectory (save/restore wall-clock,
    blocking vs overlapped time, dedup ratios) CI uploads as an artifact
    so per-PR regressions are diffable, not anecdotal."""
    try:
        doc = json.loads(BENCH_JSON.read_text())
    except (OSError, ValueError):
        doc = {}
    doc[section] = dict(data, recorded_at=time.strftime(
        "%Y-%m-%dT%H:%M:%S", time.gmtime()))
    BENCH_JSON.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")


def io_sweep_compare(prefix: str, *, agg: int, shards: int, seed: int,
                     io_threads: int = 8, chunking: str = "fixed",
                     tiny: bool = False, reps: int = 5, retain: int = 2,
                     chunk_size: int = 1 << 20,
                     primary: str = "save") -> dict:
    """Serial chunk-IO baseline (``io_threads=1``, the pre-pipeline
    engine) vs the pipelined engine, on a REAL unthrottled disk store so
    fsync costs are physical, with a single writer rank so the sweep
    isolates the per-rank chunk pipeline.

    Protocol: an untimed warmup pair, then ``reps`` interleaved
    serial/pipelined rep pairs; the headline speedup is the MEDIAN OF
    PER-REP PAIRED RATIOS — serial and pipelined run seconds apart within
    a rep, so each ratio is consistent w.r.t. the backing filesystem's
    latency phase, where a ratio of unpaired medians is not."""
    import statistics
    import time

    from repro.core.checkpoint import CheckpointManager
    from repro.core.storage import Tier, TieredStore

    if io_threads <= 1:
        raise SystemExit("io-sweep compares the pipelined engine against "
                         "the io_threads=1 serial baseline; pass "
                         "--io-threads > 1")
    agg = agg // (16 if tiny else 1)
    reps = 1 if tiny else reps
    state = synth_state(agg, shards=shards, seed=seed)
    samples: dict = {1: [], io_threads: []}
    for rep in range(-1 if not tiny else 0, reps):
        for threads in (1, io_threads):
            tmp = Path(tempfile.mkdtemp())
            store = TieredStore(Tier("disk", tmp / f"io{threads}"))
            mgr = CheckpointManager(store, policy=bench_policy(
                n_writers=1, codec="raw", retain=retain,
                mode="incremental", chunk_size=chunk_size,
                chunking=chunking, io_threads=threads))
            t0 = time.monotonic()
            mgr.save(state, 1)
            save_s = time.monotonic() - t0
            t0 = time.monotonic()
            restored, _ = mgr.restore(abstract(state))
            restore_s = time.monotonic() - t0
            np.testing.assert_array_equal(
                np.asarray(state["params"]["w0"]),
                np.asarray(restored["params"]["w0"]))
            if rep >= 0:                    # rep -1 = untimed warmup
                samples[threads].append((save_s, restore_s))
            mgr.close()
            shutil.rmtree(tmp, ignore_errors=True)
    for threads, ss in samples.items():
        med = {"save": statistics.median(s for s, _ in ss),
               "restore": statistics.median(r for _, r in ss)}
        emit(f"{prefix}_threads{threads}", med[primary] * 1e6,
             f"agg_mib={agg/2**20:.0f};chunking={chunking};reps={reps};"
             f"save_s={med['save']:.3f};restore_s={med['restore']:.3f}")
    save_speedup = statistics.median(
        s1 / max(s8, 1e-9) for (s1, _), (s8, _)
        in zip(samples[1], samples[io_threads]))
    restore_speedup = statistics.median(
        r1 / max(r8, 1e-9) for (_, r1), (_, r8)
        in zip(samples[1], samples[io_threads]))
    emit(f"{prefix}_speedup", 0,
         f"io_threads={io_threads};chunking={chunking};"
         f"save_speedup={save_speedup:.2f}x;"
         f"restore_speedup={restore_speedup:.2f}x")
    bench_record(f"{prefix}_{chunking}", {
        "agg_mib": agg / 2**20, "io_threads": io_threads, "reps": reps,
        "tiny": tiny,
        "serial_save_s": statistics.median(s for s, _ in samples[1]),
        "serial_restore_s": statistics.median(r for _, r in samples[1]),
        "pipelined_save_s": statistics.median(
            s for s, _ in samples[io_threads]),
        "pipelined_restore_s": statistics.median(
            r for _, r in samples[io_threads]),
        "save_speedup": round(save_speedup, 3),
        "restore_speedup": round(restore_speedup, 3),
    })
    return {"save_speedup": save_speedup,
            "restore_speedup": restore_speedup}
