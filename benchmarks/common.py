"""Shared benchmark helpers: tiered stores mirroring the paper's Cori setup
(Burst Buffer = /dev/shm, CSCRATCH/Lustre = throttled disk) and synthetic
states of controlled aggregate size."""
from __future__ import annotations

import os
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.storage import Tier, TieredStore  # noqa: E402

LUSTRE_BW = 200e6  # simulated shared-filesystem aggregate bandwidth


def bb_store(tag: str) -> TieredStore:
    root = Path("/dev/shm") if os.access("/dev/shm", os.W_OK) \
        else Path(tempfile.gettempdir())
    return TieredStore(Tier("burst-buffer", root / f"repro-bench-{tag}"))


def scratch_store(tag: str, tmp: Path) -> TieredStore:
    return TieredStore(Tier("cscratch-sim", tmp / tag,
                            bw_bytes_per_s=LUSTRE_BW))


def synth_state(total_bytes: int, *, shards: int = 8, seed: int = 0) -> dict:
    """Params-like f32 state of ~total_bytes aggregate size."""
    per = max(total_bytes // (4 * shards), 1)
    side = max(int(per ** 0.5), 1)
    rng = np.random.default_rng(seed)
    return {
        "params": {f"w{i}": jnp.asarray(
            rng.standard_normal((side, side), dtype=np.float32))
            for i in range(shards)},
        "step": jnp.asarray(1, jnp.int32),
    }


def abstract(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)


def cleanup(store: TieredStore):
    for t in store.tiers():
        shutil.rmtree(t.root, ignore_errors=True)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
