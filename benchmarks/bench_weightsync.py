"""WeightSync distribution-plane benchmark: checkpoint-as-transport.

Three questions, one synthetic serving fleet:

  1. **Bytes on wire per update** — after a warm full sync, churn X% of
     the weight leaves (default 10%), publish, and measure what a
     replica actually pulls. The CAS diff must keep the delta near the
     churn fraction: ``delta_bytes_frac ≤ 0.25`` at 10% churn is the
     acceptance floor (recorded inverted as ``delta_reduction`` so the
     min-floor gate can hold it).
  2. **Swap latency under load** — the flip a serving loop feels is ONE
     reference assignment; the bench holds it against a full blocking
     ``restore()`` of the same step (the cold-redeploy alternative) and
     records the ratio as ``swap_speedup``.
  3. **Replicas-per-store scaling** — a pull tree of N replicas must
     leave the source store serving O(tree root) bytes;
     ``peer_served_frac`` is the fleet's wire traffic served rack-local
     by peer caches.

Every rep, every replica: the flipped set is asserted bit-exact against
a fresh blocking ``restore()`` leaf-by-leaf before any number is
recorded — a fast wrong answer is not a result.
"""
from __future__ import annotations

import argparse
import shutil
import statistics
import tempfile
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core import cas
from repro.core.checkpoint import CheckpointManager
from repro.core.storage import Tier, TieredStore
from repro.core.weightsync import (WeightPublisher, assert_bitexact,
                                   build_fleet)

from .common import (abstract, bench_policy, bench_record, emit,
                     synth_state)

AGG = 64 << 20
SHARDS = 20
FLEET = 4
CHURN = 0.10
REPS = 3


def _params_filter(name: str) -> bool:
    return name.startswith("params/")


def _churn(state: dict, frac: float, rep: int) -> dict:
    """Mutate ceil(frac · leaves) parameter leaves (rotating which, so
    successive reps churn different chunks), leave the rest untouched."""
    names = sorted(state["params"])
    k = max(int(np.ceil(frac * len(names))), 1)
    hot = {names[(rep * k + i) % len(names)] for i in range(k)}
    return {
        "params": {n: (v + 1.0 if n in hot else v)
                   for n, v in state["params"].items()},
        "step": jnp.asarray(rep + 1, jnp.int32),
    }


def run(tiny: bool = False, *, fleet_n: int = FLEET, churn: float = CHURN,
        io_threads: int = 4, reps: int = REPS, fanout: int = 2) -> dict:
    tmp = Path(tempfile.mkdtemp(prefix="ws-bench-"))
    agg = AGG // (16 if tiny else 1)
    reps = 1 if tiny else reps
    pol = bench_policy(mode="incremental", chunk_size=256 << 10,
                       io_threads=io_threads, retain=reps + 2)
    store = TieredStore(Tier("fast", tmp / "src"))
    mgr = CheckpointManager(store, policy=pol)
    WeightPublisher(mgr)
    state = synth_state(agg, shards=SHARDS, seed=7)

    try:
        mgr.save(state, 0, blocking=True)
        mgr.wait()
        fleet = build_fleet(store, tmp / "fleet", fleet_n, fanout=fanout,
                            policy=pol, leaf_filter=_params_filter)
        for sub in fleet:
            st = sub.sync()
            assert st["state"] == "live", st["last_error"]
        wire_mark = [s.counters["wire_bytes"] for s in fleet]

        delta_fracs, swap_ms, restore_ms = [], [], []
        for rep in range(reps):
            state = _churn(state, churn, rep)
            step = rep + 1
            mgr.save(state, step, blocking=True)
            mgr.wait()
            # full weight bytes = the encoded size of every params chunk
            # this step references — the denominator the ISSUE floors
            manifest = mgr.load_manifest(step)
            index = cas.manifest_chunk_index(manifest, _params_filter)
            full_bytes = sum(n or 0 for n in index.values())
            if not full_bytes:
                # raw-codec manifests carry no per-chunk encoded lens;
                # payload_bytes is the same number for codec="raw"
                full_bytes = sum(
                    s.get("payload_bytes", 0)
                    for nm, rec in manifest["leaves"].items()
                    if _params_filter(nm) for s in rec.get("shards", []))
            for i, sub in enumerate(fleet):
                st = sub.sync()
                assert st["state"] == "live" and \
                    st["last_flipped_step"] == step, st["last_error"]
                pulled = sub.counters["wire_bytes"] - wire_mark[i]
                wire_mark[i] = sub.counters["wire_bytes"]
                delta_fracs.append(pulled / max(full_bytes, 1))
                swap_ms.append(
                    sub.counters["last_flip_blocking_s"] * 1e3)
            # the cold alternative: a full blocking restore of this step
            t0 = time.monotonic()
            restored, _ = mgr.restore(abstract(state), step=step)
            restore_ms.append((time.monotonic() - t0) * 1e3)
            # acceptance gate: every replica bit-exact vs restore(),
            # leaf by leaf, BEFORE any number is recorded
            for sub in fleet:
                _, arrays = sub.current()
                assert_bitexact(arrays, restored,
                                leaf_filter=_params_filter)

        delta_frac = statistics.median(delta_fracs)
        swap = statistics.median(swap_ms)
        restore = statistics.median(restore_ms)
        peer = sum(s.counters["peer_bytes"] for s in fleet)
        source = sum(s.counters["source_bytes"] for s in fleet)
        out = {
            "tiny": tiny,
            "agg_mib": agg / 2**20,
            "fleet": fleet_n,
            "churn_frac": churn,
            "reps": reps,
            "delta_bytes_frac": delta_frac,
            "delta_reduction": (1.0 / delta_frac) if delta_frac else
            float(len(index)),
            "swap_blocking_ms": swap,
            "restore_blocking_ms": restore,
            "swap_speedup": restore / max(swap, 1e-6),
            "peer_served_frac": peer / max(peer + source, 1),
            "bitexact_reps": reps,
        }
        emit("weightsync", swap * 1e3,
             f"fleet={fleet_n};churn={churn:.2f};"
             f"delta_frac={delta_frac:.3f};"
             f"swap_ms={swap:.3f};restore_ms={restore:.1f};"
             f"peer_frac={out['peer_served_frac']:.2f}")
        bench_record("weightsync", out)
        return out
    finally:
        for sub in locals().get("fleet", []):
            sub.close()
        mgr.close()
        shutil.rmtree(tmp, ignore_errors=True)
        for t in store.tiers():
            shutil.rmtree(t.root, ignore_errors=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke scale (1/16 state, 1 rep)")
    ap.add_argument("--fleet", type=int, default=FLEET)
    ap.add_argument("--fanout", type=int, default=2)
    ap.add_argument("--churn", type=float, default=CHURN)
    ap.add_argument("--io-threads", type=int, default=4)
    ap.add_argument("--reps", type=int, default=REPS)
    args = ap.parse_args()
    run(tiny=args.tiny, fleet_n=args.fleet, churn=args.churn,
        io_threads=args.io_threads, reps=args.reps, fanout=args.fanout)


if __name__ == "__main__":
    main()
