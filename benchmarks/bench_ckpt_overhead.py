"""Paper Fig. 2 analogue: checkpoint time vs writer-rank count on the Burst
Buffer vs the (bandwidth-throttled) Lustre/CSCRATCH tier.

Gromacs/ADH in the paper scaled 4→64 ranks with growing aggregate memory;
here aggregate state grows with rank count the same way. Expected shape
(paper's finding): BB time stays low and flat-ish; Lustre time grows with
aggregate size — "performance on the Burst Buffers is superior … and also
scales better."
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core.checkpoint import CheckpointManager

from .common import (abstract, bb_store, cleanup, emit, scratch_store,
                     synth_state)

RANKS = (4, 8, 16, 32, 64)
BYTES_PER_RANK = 12 << 20  # aggregate grows with ranks (ADH-style)


def run():
    rows = []
    tmp = Path(tempfile.mkdtemp())
    for ranks in RANKS:
        agg = ranks * BYTES_PER_RANK
        state = synth_state(agg, shards=ranks)
        times = {}
        for tier_name, store in (("bb", bb_store(f"fig2-{ranks}")),
                                 ("scratch",
                                  scratch_store(f"fig2-{ranks}", tmp))):
            mgr = CheckpointManager(store, n_writers=min(ranks, 16),
                                    codec="raw", retain=1)
            t0 = time.monotonic()
            rep = mgr.save(state, 1)
            times[tier_name] = time.monotonic() - t0
            cleanup(store)
        rows.append((ranks, agg / 2**30, times["bb"], times["scratch"]))
        emit(f"fig2_ckpt_ranks{ranks}", times["bb"] * 1e6,
             f"agg_gib={agg/2**30:.2f};bb_s={times['bb']:.3f};"
             f"scratch_s={times['scratch']:.3f};"
             f"speedup={times['scratch']/max(times['bb'],1e-9):.1f}x")
    return rows


if __name__ == "__main__":
    run()
