"""Paper Fig. 2 analogue + incremental-checkpoint dedup sweep.

Fig. 2: checkpoint time vs writer-rank count on the Burst Buffer vs the
(bandwidth-throttled) Lustre/CSCRATCH tier. Gromacs/ADH in the paper scaled
4→64 ranks with growing aggregate memory; here aggregate state grows with
rank count the same way. Expected shape (paper's finding): BB time stays low
and flat-ish; Lustre time grows with aggregate size — "performance on the
Burst Buffers is superior … and also scales better."

Dedup sweep (the paper's open item, "reducing the checkpoint overhead for
large-scale applications"): a steady-state training cadence where <20% of
leaves change between adjacent checkpoints. Full mode re-writes O(model)
bytes every step; incremental mode (content-addressed chunk store) writes
only the changed chunks — the sweep reports bytes written per step for both
modes and the resulting reduction factor.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_ckpt_overhead                # Fig 2
  PYTHONPATH=src python -m benchmarks.bench_ckpt_overhead --mode incremental
  PYTHONPATH=src python -m benchmarks.bench_ckpt_overhead --mode both
"""
from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.checkpoint import CheckpointManager

from .common import (abstract, bb_store, cleanup, emit, scratch_store,
                     synth_state)

RANKS = (4, 8, 16, 32, 64)
BYTES_PER_RANK = 12 << 20  # aggregate grows with ranks (ADH-style)

# dedup sweep defaults: 20 leaves, 2 change per step (10% churn < the 20%
# steady-state bound from the acceptance criterion)
SWEEP_LEAVES = 20
SWEEP_LEAF_BYTES = 2 << 20
SWEEP_STEPS = 4
SWEEP_CHANGED_PER_STEP = 2


def run():
    rows = []
    tmp = Path(tempfile.mkdtemp())
    for ranks in RANKS:
        agg = ranks * BYTES_PER_RANK
        state = synth_state(agg, shards=ranks)
        times = {}
        for tier_name, store in (("bb", bb_store(f"fig2-{ranks}")),
                                 ("scratch",
                                  scratch_store(f"fig2-{ranks}", tmp))):
            mgr = CheckpointManager(store, n_writers=min(ranks, 16),
                                    codec="raw", retain=1)
            t0 = time.monotonic()
            rep = mgr.save(state, 1)
            times[tier_name] = time.monotonic() - t0
            cleanup(store)
        rows.append((ranks, agg / 2**30, times["bb"], times["scratch"]))
        emit(f"fig2_ckpt_ranks{ranks}", times["bb"] * 1e6,
             f"agg_gib={agg/2**30:.2f};bb_s={times['bb']:.3f};"
             f"scratch_s={times['scratch']:.3f};"
             f"speedup={times['scratch']/max(times['bb'],1e-9):.1f}x")
    return rows


def _sweep_state(rng):
    side = max(int((SWEEP_LEAF_BYTES // 4) ** 0.5), 1)
    import jax.numpy as jnp
    return {"params": {
        f"w{i:02d}": jnp.asarray(
            rng.standard_normal((side, side), dtype=np.float32))
        for i in range(SWEEP_LEAVES)}}


def _mutate(state, step, rng):
    """Touch SWEEP_CHANGED_PER_STEP leaves (round-robin) — the steady-state
    '<20% of leaves changed' cadence."""
    import jax.numpy as jnp
    for k in range(SWEEP_CHANGED_PER_STEP):
        i = (step * SWEEP_CHANGED_PER_STEP + k) % SWEEP_LEAVES
        name = f"w{i:02d}"
        arr = np.asarray(state["params"][name])
        state["params"][name] = jnp.asarray(
            arr + rng.standard_normal(arr.shape, dtype=np.float32) * 1e-3)
    return state


def dedup_sweep(mode: str):
    """Steady-state bytes-written-per-step for one save mode. Returns the
    list of per-step written byte counts (step 1 is the cold full write)."""
    rng = np.random.default_rng(0)
    state = _sweep_state(rng)
    store = bb_store(f"dedup-{mode}")
    mgr = CheckpointManager(store, n_writers=4, codec="raw", retain=2,
                            mode=mode, chunk_size=1 << 20)
    written = []
    for step in range(1, SWEEP_STEPS + 1):
        if step > 1:
            state = _mutate(state, step, rng)
        t0 = time.monotonic()
        rep = mgr.save(state, step)
        dt = time.monotonic() - t0
        written.append(rep["written_bytes"])
        emit(f"dedup_{mode}_step{step}", dt * 1e6,
             f"written_mib={rep['written_bytes']/2**20:.2f};"
             f"payload_mib={rep['payload_bytes']/2**20:.2f};"
             + (f"dedup_ratio={rep.get('dedup_ratio', 1.0):.1f}x"
                if mode == "incremental" else "mode=full"))
    # sanity: the checkpoint must still restore bit-exact
    restored, _ = mgr.restore(abstract(state))
    for name, arr in state["params"].items():
        np.testing.assert_array_equal(np.asarray(arr),
                                      np.asarray(restored["params"][name]))
    cleanup(store)
    return written


def run_dedup():
    """Full-vs-incremental steady-state comparison; emits the reduction
    factor for the steady-state steps (2..N)."""
    full = dedup_sweep("full")
    incr = dedup_sweep("incremental")
    steady_full = sum(full[1:]) / max(len(full) - 1, 1)
    steady_incr = sum(incr[1:]) / max(len(incr) - 1, 1)
    reduction = steady_full / max(steady_incr, 1)
    emit("dedup_steady_state", 0,
         f"full_mib_per_step={steady_full/2**20:.2f};"
         f"incr_mib_per_step={steady_incr/2**20:.2f};"
         f"reduction={reduction:.1f}x")
    return {"full": full, "incremental": incr, "reduction": reduction}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="fig2",
                    choices=["fig2", "full", "incremental", "both"])
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.mode == "fig2":
        run()
    elif args.mode == "both":
        run_dedup()
    else:
        dedup_sweep(args.mode)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
