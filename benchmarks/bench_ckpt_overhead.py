"""Paper Fig. 2 analogue + incremental-checkpoint dedup and IO-pipeline
sweeps.

Fig. 2: checkpoint time vs writer-rank count on the Burst Buffer vs the
(bandwidth-throttled) Lustre/CSCRATCH tier. Gromacs/ADH in the paper scaled
4→64 ranks with growing aggregate memory; here aggregate state grows with
rank count the same way. Expected shape (paper's finding): BB time stays low
and flat-ish; Lustre time grows with aggregate size — "performance on the
Burst Buffers is superior … and also scales better."

Dedup sweep (the paper's open item, "reducing the checkpoint overhead for
large-scale applications"): a steady-state training cadence where <20% of
leaves change between adjacent checkpoints. Full mode re-writes O(model)
bytes every step; incremental mode (content-addressed chunk store) writes
only the changed chunks — the sweep reports bytes written AND save/restore
wall-clock per step for both modes.

IO sweep (``--mode io-sweep``): save + restore wall-clock of the pipelined
chunk engine (``--io-threads N``) against the serial baseline
(``io_threads=1`` = the PR-1 chunk-at-a-time path with a directory fsync
per object). Runs on a REAL (unthrottled) disk store so fsync costs are
physical, with a single writer rank so the sweep isolates the per-rank
chunk pipeline — in production each host runs one writer agent and the
chunk pool is where its parallelism lives.

CDC churn (``--mode cdc-churn``): a shifted-payload churn model — each
step inserts a few bytes near the front of a large byte-blob leaf, the
worst case for fixed-size chunking (every boundary moves) and the case
content-defined chunking exists for. Reports steady-state bytes written
under both schemes at equal average chunk size.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_ckpt_overhead                # Fig 2
  PYTHONPATH=src python -m benchmarks.bench_ckpt_overhead --mode both
  PYTHONPATH=src python -m benchmarks.bench_ckpt_overhead --mode io-sweep \
      --io-threads 8
  PYTHONPATH=src python -m benchmarks.bench_ckpt_overhead --mode cdc-churn
  PYTHONPATH=src python -m benchmarks.bench_ckpt_overhead --mode chunk-scan
  PYTHONPATH=src python -m benchmarks.bench_ckpt_overhead --mode overlap \
      --io-threads 8
  (--chunking cdc applies the content-defined chunker to the dedup sweeps;
   --tiny shrinks every workload for CI smoke runs)

Overlap mode (``--mode overlap``): per-checkpoint TRAIN-THREAD blocking
time (drain + device→host snapshot + residual wait) against the
end-to-end persist wall-clock of ``save(blocking=False)`` — the paper's
blocking-window metric. Every mode also appends its headline numbers to
the machine-readable ``BENCH_ckpt.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.checkpoint import CheckpointManager

from .common import (abstract, bb_store, bench_policy, bench_record,
                     cleanup, emit, io_sweep_compare, scratch_store,
                     synth_state)

RANKS = (4, 8, 16, 32, 64)
BYTES_PER_RANK = 12 << 20  # aggregate grows with ranks (ADH-style)

# dedup sweep defaults: 20 leaves, 2 change per step (10% churn < the 20%
# steady-state bound from the acceptance criterion)
SWEEP_LEAVES = 20
SWEEP_LEAF_BYTES = 2 << 20
SWEEP_STEPS = 4
SWEEP_CHANGED_PER_STEP = 2

IO_SWEEP_BYTES = 192 << 20       # pipelined-engine workload (disk store)
CHURN_BLOB_BYTES = 48 << 20      # cdc-churn byte-blob leaf
OVERLAP_BYTES = 96 << 20         # overlapped-save workload (disk store)


def run(tiny=False):
    rows = []
    tmp = Path(tempfile.mkdtemp())
    for ranks in RANKS[:2] if tiny else RANKS:
        agg = ranks * BYTES_PER_RANK // (8 if tiny else 1)
        state = synth_state(agg, shards=ranks)
        times = {}
        for tier_name, store in (("bb", bb_store(f"fig2-{ranks}")),
                                 ("scratch",
                                  scratch_store(f"fig2-{ranks}", tmp))):
            mgr = CheckpointManager(store, policy=bench_policy(
                n_writers=min(ranks, 16), codec="raw", retain=1))
            t0 = time.monotonic()
            rep = mgr.save(state, 1)
            times[tier_name] = time.monotonic() - t0
            cleanup(store)
        rows.append((ranks, agg / 2**30, times["bb"], times["scratch"]))
        emit(f"fig2_ckpt_ranks{ranks}", times["bb"] * 1e6,
             f"agg_gib={agg/2**30:.2f};bb_s={times['bb']:.3f};"
             f"scratch_s={times['scratch']:.3f};"
             f"speedup={times['scratch']/max(times['bb'],1e-9):.1f}x")
    return rows


def _sweep_state(rng, tiny=False):
    leaf_bytes = SWEEP_LEAF_BYTES // (8 if tiny else 1)
    side = max(int((leaf_bytes // 4) ** 0.5), 1)
    import jax.numpy as jnp
    return {"params": {
        f"w{i:02d}": jnp.asarray(
            rng.standard_normal((side, side), dtype=np.float32))
        for i in range(SWEEP_LEAVES)}}


def _mutate(state, step, rng):
    """Touch SWEEP_CHANGED_PER_STEP leaves (round-robin) — the steady-state
    '<20% of leaves changed' cadence."""
    import jax.numpy as jnp
    for k in range(SWEEP_CHANGED_PER_STEP):
        i = (step * SWEEP_CHANGED_PER_STEP + k) % SWEEP_LEAVES
        name = f"w{i:02d}"
        arr = np.asarray(state["params"][name])
        state["params"][name] = jnp.asarray(
            arr + rng.standard_normal(arr.shape, dtype=np.float32) * 1e-3)
    return state


def dedup_sweep(mode: str, *, chunking="fixed", io_threads=4, tiny=False):
    """Steady-state bytes-written-per-step for one save mode. Returns the
    list of per-step written byte counts (step 1 is the cold full write)."""
    rng = np.random.default_rng(0)
    state = _sweep_state(rng, tiny)
    store = bb_store(f"dedup-{mode}-{chunking}")
    mgr = CheckpointManager(store, policy=bench_policy(
        n_writers=4, codec="raw", retain=2, mode=mode,
        chunk_size=1 << 20, chunking=chunking, io_threads=io_threads))
    written = []
    for step in range(1, SWEEP_STEPS + 1):
        if step > 1:
            state = _mutate(state, step, rng)
        t0 = time.monotonic()
        rep = mgr.save(state, step)
        dt = time.monotonic() - t0
        written.append(rep["written_bytes"])
        emit(f"dedup_{mode}_{chunking}_step{step}", dt * 1e6,
             f"save_s={dt:.3f};"
             f"written_mib={rep['written_bytes']/2**20:.2f};"
             f"payload_mib={rep['payload_bytes']/2**20:.2f};"
             + (f"dedup_ratio={rep.get('dedup_ratio', 1.0):.1f}x"
                if mode == "incremental" else "mode=full"))
    # sanity: the checkpoint must still restore bit-exact — and report the
    # restore wall-clock alongside the write-side numbers
    t0 = time.monotonic()
    restored, _ = mgr.restore(abstract(state))
    restore_s = time.monotonic() - t0
    emit(f"dedup_{mode}_{chunking}_restore", restore_s * 1e6,
         f"restore_s={restore_s:.3f}")
    for name, arr in state["params"].items():
        np.testing.assert_array_equal(np.asarray(arr),
                                      np.asarray(restored["params"][name]))
    cleanup(store)
    return written


def run_dedup(chunking="fixed", io_threads=4, tiny=False):
    """Full-vs-incremental steady-state comparison; emits the reduction
    factor for the steady-state steps (2..N)."""
    full = dedup_sweep("full", io_threads=io_threads, tiny=tiny)
    incr = dedup_sweep("incremental", chunking=chunking,
                       io_threads=io_threads, tiny=tiny)
    steady_full = sum(full[1:]) / max(len(full) - 1, 1)
    steady_incr = sum(incr[1:]) / max(len(incr) - 1, 1)
    reduction = steady_full / max(steady_incr, 1)
    emit("dedup_steady_state", 0,
         f"chunking={chunking};"
         f"full_mib_per_step={steady_full/2**20:.2f};"
         f"incr_mib_per_step={steady_incr/2**20:.2f};"
         f"reduction={reduction:.1f}x")
    bench_record(f"dedup_{chunking}", {
        "tiny": tiny, "io_threads": io_threads,
        "full_mib_per_step": round(steady_full / 2**20, 3),
        "incr_mib_per_step": round(steady_incr / 2**20, 3),
        "dedup_reduction": round(reduction, 2),
    })
    return {"full": full, "incremental": incr, "reduction": reduction}


# ---------------------------------------------------------------------------
# IO-pipeline sweep: pipelined engine vs the serial baseline
# ---------------------------------------------------------------------------

def io_sweep(io_threads=8, chunking="fixed", tiny=False, reps=5):
    # 512 KiB chunks: the save-side sweep exercises the per-object
    # fsync/rename tax the pipelined engine batches away (the restore-side
    # sweep in bench_restart uses 1 MiB chunks, the read-optimal size)
    return io_sweep_compare("io_sweep", agg=IO_SWEEP_BYTES, shards=24,
                            seed=1, io_threads=io_threads,
                            chunking=chunking, tiny=tiny, reps=reps,
                            chunk_size=512 << 10, primary="save")


# ---------------------------------------------------------------------------
# overlapped (async) save: train-thread blocking time vs end-to-end persist
# ---------------------------------------------------------------------------

def overlap_bench(io_threads=8, tiny=False, reps=5):
    """How much of a checkpoint does the TRAINING THREAD actually pay?

    Per rep: one ``save(blocking=False)`` (the thread blocks only for
    drain + snapshot), then simulated training compute until the persist
    stage finishes, then ``wait()``. Reported per checkpoint:

      blocking_s   save() call duration + residual wait() stall — the
                   training-visible cost;
      persist_s    save-entry → COMMIT end-to-end (the persist stage's
                   wall-clock);
      overlap_frac 1 − blocking/persist — the fraction hidden behind
                   compute.

    A fresh random state per rep defeats dedup, so every round writes the
    full payload (the worst, honest case). Runs on a REAL disk store so
    fsync costs are physical. A sync-save rep pair anchors the numbers."""
    import shutil
    import tempfile

    import statistics

    from repro.core.storage import Tier, TieredStore

    agg = OVERLAP_BYTES // (16 if tiny else 1)
    reps = 1 if tiny else reps
    rows = []
    sync_s = []
    tmp = Path(tempfile.mkdtemp())
    store = TieredStore(Tier("disk", tmp / "overlap"))
    mgr = CheckpointManager(store, policy=bench_policy(
        n_writers=1, codec="raw", retain=2, mode="incremental",
        chunk_size=1 << 20, io_threads=io_threads))
    step = 0
    for rep in range(-1, reps):               # rep -1 = untimed warmup
        step += 1
        state = synth_state(agg, shards=12, seed=100 + step)
        rep_async = mgr.save(state, step, blocking=False)
        blocking = rep_async["blocking_s"]
        # simulated training steps overlapping the background persist —
        # short sleeps, like XLA compute that has released the GIL
        while mgr._persist.active:
            time.sleep(0.005)
        tw = time.monotonic()
        mgr.wait()
        blocking += time.monotonic() - tw     # residual stall, ~0
        persist = mgr.last_report["seconds"]
        # sync anchor on the same workload
        step += 1
        t0 = time.monotonic()
        mgr.save(synth_state(agg, shards=12, seed=200 + step), step)
        sync = time.monotonic() - t0
        if rep >= 0:
            rows.append((blocking, persist))
            sync_s.append(sync)
            emit(f"overlap_rep{rep}", blocking * 1e6,
                 f"blocking_s={blocking:.3f};persist_s={persist:.3f};"
                 f"sync_save_s={sync:.3f};"
                 f"blocking_frac={blocking / max(persist, 1e-9):.2f}")
    mgr.close()
    shutil.rmtree(tmp, ignore_errors=True)
    med_block = statistics.median(b for b, _ in rows)
    med_persist = statistics.median(p for _, p in rows)
    frac = statistics.median(b / max(p, 1e-9) for b, p in rows)
    emit("overlap_summary", med_block * 1e6,
         f"agg_mib={agg / 2**20:.0f};io_threads={io_threads};"
         f"blocking_s={med_block:.3f};persist_s={med_persist:.3f};"
         f"sync_save_s={statistics.median(sync_s):.3f};"
         f"blocking_frac={frac:.2f}")
    bench_record("overlap", {
        "agg_mib": agg / 2**20, "io_threads": io_threads, "reps": reps,
        "tiny": tiny,
        "blocking_s": round(med_block, 4),
        "persist_s": round(med_persist, 4),
        "sync_save_s": round(statistics.median(sync_s), 4),
        "blocking_frac": round(frac, 4),
    })
    return {"blocking_s": med_block, "persist_s": med_persist,
            "blocking_frac": frac}


def overlap_queue_sweep(io_threads=8, tiny=False, bursts=4,
                        depths=(1, 2, 3)):
    """Bursty checkpoint cadence vs the persist queue depth.

    The queue exists to decouple checkpoint CADENCE from persist LATENCY:
    steady-state throughput is still one persist worker (the disk is the
    disk), but a burst of saves — or a persist stretched by a slow-fsync
    phase — must not block the train thread. Protocol, per burst: TWO
    ``save(blocking=False)`` calls back-to-back, then simulated training
    compute until the queue drains. At depth 1 the second save of every
    burst drains the first round before it may snapshot (the PR-3
    behaviour), so the train thread eats ~the whole persist; at depth ≥ 2
    it is ADMITTED while round one persists and pays only its snapshot.

    Reported per depth: the second-save blocking median, the train-thread
    blocking fraction (Σ save() blocking ÷ batch wall-clock), and how
    many second saves were admitted while a prior round was still
    persisting (the queue genuinely overlapping, not just configured)."""
    import shutil
    import statistics
    import tempfile

    from repro.core.storage import Tier, TieredStore

    agg = OVERLAP_BYTES // (16 if tiny else 2)
    bursts = 2 if tiny else bursts
    tmp = Path(tempfile.mkdtemp())
    sweep = {}
    for depth in depths:
        store = TieredStore(Tier("disk", tmp / f"q{depth}"))
        mgr = CheckpointManager(store, policy=bench_policy(
            n_writers=1, codec="raw", retain=2, mode="incremental",
            chunk_size=1 << 20, io_threads=io_threads,
            persist_queue_depth=depth))
        mgr.save(synth_state(agg, shards=12, seed=9), 1, blocking=False)
        mgr.wait()                                  # warmup round
        blocking, second_blk = [], []
        overlapped = 0
        step = 1
        t0 = time.monotonic()
        for b in range(bursts):
            for pos in range(2):                    # the burst: 2 rounds
                step += 1
                state = synth_state(agg, shards=12,  # fresh: no dedup
                                    seed=1000 * depth + step)
                rep = mgr.save(state, step, blocking=False)
                if mgr._persist.inflight >= 2:
                    # admitted while a prior round persists — the queue
                    # is genuinely overlapping rounds
                    overlapped += 1
                blocking.append(rep["blocking_s"])
                if pos == 1:
                    second_blk.append(rep["blocking_s"])
            # simulated training compute until the burst drains — short
            # sleeps, like XLA compute that has released the GIL
            while mgr._persist.active:
                time.sleep(0.005)
        tw = time.monotonic()
        mgr.wait()
        drain_s = time.monotonic() - tw
        wall = time.monotonic() - t0
        frac = sum(blocking) / max(wall, 1e-9)
        sweep[str(depth)] = {
            "bursts": bursts,
            "blocking_s_median": round(statistics.median(blocking), 4),
            "second_save_blocking_s":
                round(statistics.median(second_blk), 4),
            "blocking_frac": round(frac, 4),
            "wall_s": round(wall, 4),
            "final_drain_s": round(drain_s, 4),
            "rounds_admitted_while_persisting": overlapped,
        }
        emit(f"overlap_queue_depth{depth}",
             statistics.median(second_blk) * 1e6,
             f"agg_mib={agg / 2**20:.0f};bursts={bursts};"
             f"second_save_blocking_s="
             f"{statistics.median(second_blk):.3f};"
             f"blocking_frac={frac:.3f};"
             f"admitted_while_persisting={overlapped}")
        mgr.close()
        shutil.rmtree(tmp / f"q{depth}", ignore_errors=True)
    shutil.rmtree(tmp, ignore_errors=True)
    d1 = sweep.get("1", {}).get("blocking_frac")
    d2 = sweep.get("2", {}).get("blocking_frac")
    bench_record("overlap_queue", {
        "agg_mib": agg / 2**20, "io_threads": io_threads, "tiny": tiny,
        "depths": sweep,
        "depth1_blocking_frac": d1, "depth2_blocking_frac": d2,
        "depth2_rounds_overlapped":
            sweep.get("2", {}).get("rounds_admitted_while_persisting"),
    })
    emit("overlap_queue_summary", 0,
         f"depth1_frac={d1};depth2_frac={d2};"
         f"depth2_overlapped="
         f"{sweep.get('2', {}).get('rounds_admitted_while_persisting')}")
    return sweep


# ---------------------------------------------------------------------------
# chunk-scan: CDC candidate-scan throughput, numpy oracle vs accelerated
# ---------------------------------------------------------------------------

SCAN_SIZES_MIB = (4, 8, 16, 32)  # one segment → multi-segment pipeline
SCAN_AVG_SIZE = 1 << 20          # the manager's default CDC average


def chunk_scan(tiny=False, reps=7):
    """A/B the CDC candidate scan: the numpy oracle against the
    accelerated backend (pallas on accelerator hosts, the XLA lax.scan
    pipeline otherwise), across payload sizes, with interleaved
    numpy/accel rep pairs per size.

    Two statistics per size: the PRIMARY speedup is the ratio of
    best-of-reps times (the classic timeit convention — min filters the
    reps a noisy-neighbor phase contaminated, symmetrically for both
    backends, so it measures the engines rather than the box's worst
    moment), and the median of per-pair ratios rides along as the
    phase-sensitive view. Cut-point parity is asserted on every size —
    a fast scan that moves one boundary re-writes dedup history."""
    import statistics

    from repro.core.cdc import GearChunker

    sizes = [1 << 20] if tiny else [m << 20 for m in SCAN_SIZES_MIB]
    reps = 2 if tiny else reps
    ck_ref = GearChunker(SCAN_AVG_SIZE, scan_backend="numpy")
    ck_acc = GearChunker(SCAN_AVG_SIZE, scan_backend="auto")
    backend = ck_acc.scanner.resolve(max(sizes))
    if backend == "numpy":
        # auto would pick the oracle at these sizes (tiny CI hosts): force
        # the accelerated engine so the A/B still measures it
        ck_acc = GearChunker(SCAN_AVG_SIZE, scan_backend="jnp")
        backend = "jnp"
    rng = np.random.default_rng(7)
    per_size = {}
    size_medians = []
    for size in sizes:
        payload = rng.bytes(size)
        assert ck_acc.cut_points(payload) == ck_ref.cut_points(payload), \
            "accelerated scan drifted from the numpy oracle"
        ck_acc.scanner.scan(payload)            # compile/warm
        ck_ref.scanner.scan(payload)
        t_np, t_acc = [], []
        for _ in range(reps):
            t0 = time.monotonic()
            ck_ref.scanner.scan(payload)
            t_np.append(time.monotonic() - t0)
            t0 = time.monotonic()
            ck_acc.scanner.scan(payload)
            t_acc.append(time.monotonic() - t0)
        ratios = [a / max(b, 1e-9) for a, b in zip(t_np, t_acc)]
        size_speedup = min(t_np) / max(min(t_acc), 1e-9)
        size_median = statistics.median(ratios)
        size_medians.append((size_speedup, size_median))
        np_mbps = size / min(t_np) / 1e6
        acc_mbps = size / min(t_acc) / 1e6
        per_size[size >> 20] = {
            "numpy_mbps": round(np_mbps, 1),
            "accel_mbps": round(acc_mbps, 1),
            "speedup": round(size_speedup, 2),
            "speedup_median_pair": round(size_median, 2),
        }
        emit(f"chunk_scan_{size >> 20}mib",
             min(t_acc) * 1e6,
             f"backend={backend};numpy_mbps={np_mbps:.1f};"
             f"accel_mbps={acc_mbps:.1f};"
             f"speedup={size_speedup:.2f}x;"
             f"median_pair={size_median:.2f}x")
    speedup = statistics.median([s for s, _ in size_medians])
    speedup_med = statistics.median([m for _, m in size_medians])

    # --- small-payload gap: half-octave staging buckets vs the pow2 /
    # 64-column ladder they replaced, on a sub-MIN_ACCEL payload. The
    # dispatch pads the payload to its staging bucket, so ladder shape IS
    # the overhead: 640 KiB buckets to 768 KiB (+20%) on the half-octave
    # ladder vs 1 MiB (+60%) on the old one. The "before" arm re-times
    # the SAME engine under the legacy ladder; cut parity is asserted so
    # a bucket change can never move a boundary. ---
    from repro.core import cdc_scan as cdc_scan_mod
    small = 640 << 10
    small_payload = rng.bytes(small)
    ck_small = GearChunker(SCAN_AVG_SIZE, scan_backend="jnp")
    assert ck_small.cut_points(small_payload) == \
        ck_ref.cut_points(small_payload), \
        "small-payload jnp scan drifted from the numpy oracle"

    def _pow2_floor64(cols):           # the pre-bucketing ladder
        b = 64
        while b < cols:
            b *= 2
        return b

    def _time_small():
        ck_small.scanner.scan(small_payload)    # warm/compile this ladder
        ts = []
        for _ in range(max(reps, 3)):
            t0 = time.monotonic()
            ck_small.scanner.scan(small_payload)
            ts.append(time.monotonic() - t0)
        return min(ts)

    t_after = _time_small()
    orig_bucket = cdc_scan_mod._bucket_cols
    cdc_scan_mod._bucket_cols = _pow2_floor64
    try:
        assert ck_small.cut_points(small_payload) == \
            ck_ref.cut_points(small_payload), \
            "staging bucket width changed the scan result"
        t_before = _time_small()
    finally:
        cdc_scan_mod._bucket_cols = orig_bucket
    small_gain = t_before / max(t_after, 1e-9)
    emit("chunk_scan_small_payload", t_after * 1e6,
         f"backend=jnp;payload_kib={small >> 10};"
         f"pow2_mbps={small / max(t_before, 1e-9) / 1e6:.1f};"
         f"bucketed_mbps={small / max(t_after, 1e-9) / 1e6:.1f};"
         f"bucket_speedup={small_gain:.2f}x")

    emit("chunk_scan_summary", 0,
         f"backend={backend};avg_chunk={SCAN_AVG_SIZE >> 10}K;"
         f"scan_speedup={speedup:.2f}x;"
         f"scan_speedup_median={speedup_med:.2f}x")
    bench_record("chunk_scan", {
        "tiny": tiny, "reps": reps, "backend": backend,
        "avg_chunk_kib": SCAN_AVG_SIZE >> 10,
        "per_size_mib": per_size,
        "scan_speedup": round(speedup, 3),
        "scan_speedup_median_pair": round(speedup_med, 3),
        "small_payload_kib": small >> 10,
        "small_pow2_mbps": round(small / max(t_before, 1e-9) / 1e6, 1),
        "small_bucketed_mbps": round(small / max(t_after, 1e-9) / 1e6, 1),
        "small_bucket_speedup": round(small_gain, 3),
    })
    return {"backend": backend, "speedup": speedup, "per_size": per_size}


def faults_bench(io_threads=8, tiny=False):
    """Resilience-layer overhead under a transient-fault storm
    (``--mode faults``): the same save+restore cadence run clean and
    under a recurring schedule of injected EIO / ENOSPC bursts / latency
    spikes on the fast tier, all inside the typed retry budget.

    ``fault_recovery_frac = t_clean / t_faulted`` — 1.0 means the storm
    cost nothing; the committed floor guards against the retry/backoff
    plumbing itself becoming the bottleneck (a recovery collapse shows
    up as the faulted arm taking multiples of the clean arm). A final
    fast-tier-read-only round pins the degraded-failover commit path."""
    import shutil

    from repro.core.faults import FaultPlane, wrap_store
    from repro.core.storage import Tier, TieredStore

    agg = (8 << 20) if tiny else (64 << 20)
    rounds = 3 if tiny else 5
    states = {s: synth_state(agg, shards=8, seed=s)
              for s in range(1, rounds + 1)}

    def _arm(tag, plane):
        base = Path(tempfile.mkdtemp(prefix=f"repro-bench-faults-{tag}-"))
        store = TieredStore(Tier("fast", base / "fast"),
                            Tier("slow", base / "slow"))
        if plane is not None:
            store = wrap_store(store, plane)
        mgr = CheckpointManager(store, policy=bench_policy(
            n_writers=4, codec="raw", retain=2, mode="incremental",
            chunk_size=1 << 18, io_threads=io_threads,
            io_retries=2, io_backoff_ms=2.0, io_deadline_s=30.0))
        t0 = time.monotonic()
        for s in range(1, rounds + 1):
            if plane is not None:
                # per-round storm: one hard EIO, an ENOSPC burst the
                # retry budget just covers, and a latency spike
                plane.add(op="write", kind="eio", tier="fast",
                          match=".obj")
                plane.add(op="write", kind="enospc", tier="fast",
                          match=".obj", nth=5, count=2)
                plane.add(op="write", kind="latency", tier="fast",
                          match=".obj", nth=9, count=4, latency_s=0.002)
            mgr.save(states[s], s)
            store.wait_drained()
            if plane is not None:
                plane.add(op="read", kind="eio", tier="fast",
                          match=".obj")
                plane.add(op="read", kind="latency", tier="fast",
                          match=".obj", nth=3, count=4, latency_s=0.002)
            restored, _ = mgr.restore(abstract(states[s]), step=s)
        dt = time.monotonic() - t0
        # the storm must never cost a byte
        for name, arr in states[rounds]["params"].items():
            np.testing.assert_array_equal(
                np.asarray(arr), np.asarray(restored["params"][name]))
        fired = 0 if plane is None else len(plane.fired())
        mgr.close()
        shutil.rmtree(base, ignore_errors=True)
        return dt, fired

    t_clean, _ = _arm("clean", None)
    t_faulted, fired = _arm("storm", FaultPlane(seed=7))
    frac = t_clean / max(t_faulted, 1e-9)
    emit("faults_storm", t_faulted * 1e6,
         f"clean_s={t_clean:.3f};faulted_s={t_faulted:.3f};"
         f"fired={fired};recovery_frac={frac:.3f}")

    # degraded failover: fast tier read-only mid-round → the round must
    # still COMMIT (marked), with the objects landing on the slow tier
    plane = FaultPlane(seed=7)
    base = Path(tempfile.mkdtemp(prefix="repro-bench-faults-degraded-"))
    store = wrap_store(TieredStore(Tier("fast", base / "fast"),
                                   Tier("slow", base / "slow")), plane)
    mgr = CheckpointManager(store, policy=bench_policy(
        n_writers=4, codec="raw", retain=1, mode="incremental",
        chunk_size=1 << 18, io_threads=io_threads,
        io_retries=1, io_backoff_ms=1.0, io_deadline_s=30.0))
    plane.add(op="write", kind="erofs", tier="fast", match=".obj",
              count=-1)
    t0 = time.monotonic()
    rep = mgr.save(states[1], 1)
    t_degraded = time.monotonic() - t0
    degraded_ok = bool(rep.get("degraded")) and \
        bool(mgr.load_manifest(1).get("degraded"))
    plane.clear()
    restored, _ = mgr.restore(abstract(states[1]), step=1)
    for name, arr in states[1]["params"].items():
        np.testing.assert_array_equal(
            np.asarray(arr), np.asarray(restored["params"][name]))
    mgr.close()
    shutil.rmtree(base, ignore_errors=True)
    emit("faults_degraded", t_degraded * 1e6,
         f"degraded_save_s={t_degraded:.3f};committed={degraded_ok}")

    bench_record("faults", {
        "tiny": tiny, "io_threads": io_threads, "rounds": rounds,
        "agg_mib": agg >> 20, "faults_fired": fired,
        "t_clean_s": round(t_clean, 3),
        "t_faulted_s": round(t_faulted, 3),
        "fault_recovery_frac": round(frac, 3),
        "t_degraded_save_s": round(t_degraded, 3),
        "degraded_commit": int(degraded_ok),
    })
    return {"fault_recovery_frac": frac, "degraded_commit": degraded_ok}


# ---------------------------------------------------------------------------
# CDC churn: shifted payloads, fixed vs content-defined at equal avg size
# ---------------------------------------------------------------------------

def cdc_churn(tiny=False, steps=4):
    import jax.numpy as jnp
    blob_bytes = CHURN_BLOB_BYTES // (16 if tiny else 1)
    rng = np.random.default_rng(3)
    base = bytearray(rng.bytes(blob_bytes))
    results = {}
    for chunking in ("fixed", "cdc"):
        store = bb_store(f"churn-{chunking}")
        # 256 KiB average: enough chunks per blob that "only chunks
        # overlapping the edit" is visible even in --tiny mode
        mgr = CheckpointManager(store, policy=bench_policy(
            n_writers=2, codec="raw", retain=2, mode="incremental",
            chunk_size=256 << 10, chunking=chunking))
        buf = bytes(base)
        written = []
        for step in range(1, steps + 1):
            if step > 1:
                # shifted churn: insert a few bytes near the front, keep
                # the leaf shape constant — every fixed-size boundary after
                # the edit moves
                pos = int(rng.integers(0, blob_bytes // 16))
                buf = (buf[:pos] + rng.bytes(24) + buf[pos:])[:blob_bytes]
            state = {"blob": jnp.asarray(np.frombuffer(buf, np.uint8))}
            t0 = time.monotonic()
            rep = mgr.save(state, step)
            dt = time.monotonic() - t0
            written.append(rep["written_bytes"])
            emit(f"cdc_churn_{chunking}_step{step}", dt * 1e6,
                 f"save_s={dt:.3f};"
                 f"written_mib={rep['written_bytes']/2**20:.2f}")
        restored, _ = mgr.restore(abstract(state))
        np.testing.assert_array_equal(np.asarray(restored["blob"]),
                                      np.frombuffer(buf, np.uint8))
        results[chunking] = sum(written[1:]) / max(len(written) - 1, 1)
        cleanup(store)
    advantage = results["fixed"] / max(results["cdc"], 1)
    emit("cdc_churn_steady_state", 0,
         f"fixed_mib_per_step={results['fixed']/2**20:.2f};"
         f"cdc_mib_per_step={results['cdc']/2**20:.2f};"
         f"cdc_advantage={advantage:.1f}x")
    return {"results": results, "advantage": advantage}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="fig2",
                    choices=["fig2", "full", "incremental", "both",
                             "io-sweep", "cdc-churn", "overlap",
                             "chunk-scan", "faults"])
    ap.add_argument("--chunking", default="fixed",
                    choices=["fixed", "cdc"])
    ap.add_argument("--io-threads", type=int, default=8)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: shrink every workload")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.mode == "fig2":
        run(tiny=args.tiny)
    elif args.mode == "both":
        run_dedup(chunking=args.chunking, io_threads=args.io_threads,
                  tiny=args.tiny)
    elif args.mode == "io-sweep":
        io_sweep(io_threads=args.io_threads, chunking=args.chunking,
                 tiny=args.tiny)
    elif args.mode == "cdc-churn":
        cdc_churn(tiny=args.tiny)
    elif args.mode == "chunk-scan":
        chunk_scan(tiny=args.tiny)
    elif args.mode == "faults":
        faults_bench(io_threads=args.io_threads, tiny=args.tiny)
    elif args.mode == "overlap":
        overlap_bench(io_threads=args.io_threads, tiny=args.tiny)
        overlap_queue_sweep(io_threads=args.io_threads, tiny=args.tiny)
    else:
        dedup_sweep(args.mode, chunking=args.chunking,
                    io_threads=args.io_threads, tiny=args.tiny)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
