"""Shard-codec A/B: host zstd vs the device-side byteplane pipeline.

Per-codec encode/decode throughput and compression ratio on params-like
f32 data (near-zero weights: constant sign/exponent bytes interleaved
with random mantissa bytes — the distribution the byteplane transform is
built for), plus the headline A/B the tentpole claims: end-to-end
``byteplane-zstd`` encode (device transform + host zstd over the
pre-conditioned stream) vs plain host ``zstd`` on the same 64 MB payload.

Protocol mirrors ``common.io_sweep_compare``: an untimed warmup rep
(absorbs the jit compile of the transform), then ``--reps`` interleaved
host/device rep pairs; the headline speedup is the MEDIAN OF PER-REP
PAIRED RATIOS, so both arms of each ratio see the same machine phase.

Without the optional ``zstandard`` package the A/B arms cannot run; the
per-codec lines for raw/int8/byteplane still print, but no ``codec``
section is recorded (the regression gate would otherwise flag the
floored speedup metrics as missing).
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax.numpy as jnp
import numpy as np

from repro.core.codec import (HAVE_ZSTD, byteplane_meta, contig_u8, decode,
                              encode, encode_preconditioned)
from repro.kernels.ckpt_codec import byteplane as bp

from .common import bench_record, emit

NBYTES = 64 << 20          # 64 MB f32 payload (the acceptance-criteria size)
TINY_NBYTES = 4 << 20      # still above MIN_ACCEL_BYTES so the device
                           # transform path is the one being timed


def _payload(nbytes: int) -> np.ndarray:
    rng = np.random.default_rng(1)
    return (rng.standard_normal(nbytes // 4) * 0.02).astype(np.float32)


def _per_codec(x: np.ndarray, reps: int) -> dict:
    """Median encode/decode wall-clock and ratio for every usable codec."""
    out = {}
    codecs = ("raw", "zstd", "int8", "byteplane", "byteplane-zstd") \
        if HAVE_ZSTD else ("raw", "int8", "byteplane")
    for codec in codecs:
        enc_s, dec_s = [], []
        for _ in range(reps):
            t0 = time.monotonic()
            payload, meta = encode(x, codec)
            enc_s.append(time.monotonic() - t0)
            t0 = time.monotonic()
            y = decode(payload, codec, x.shape, x.dtype, meta)
            dec_s.append(time.monotonic() - t0)
        err = float(np.max(np.abs(np.asarray(y, np.float32) - x)))
        ratio = x.nbytes / len(payload)
        enc, dec = statistics.median(enc_s), statistics.median(dec_s)
        out[codec] = {"enc_gbps": round(x.nbytes / enc / 1e9, 3),
                      "dec_gbps": round(x.nbytes / dec / 1e9, 3),
                      "ratio": round(ratio, 3)}
        emit(f"codec_{codec}", enc * 1e6,
             f"ratio={ratio:.2f}x;enc_gbps={x.nbytes/enc/1e9:.2f};"
             f"dec_gbps={x.nbytes/dec/1e9:.2f};max_err={err:.2e}")
    return out


def _ab_host_vs_device(x: np.ndarray, reps: int) -> dict:
    """The tentpole A/B: host ``encode(x, "zstd")`` vs the device
    pipeline the save path runs (jnp byteplane forward → host zstd over
    the pre-conditioned stream). Both arms produce a complete encoded
    payload; the device transform is forced to materialize on host
    (``np.asarray``) inside the timed region, exactly as the save path's
    ticket resolution does."""
    u8 = contig_u8(x)
    k = x.dtype.itemsize
    host_s, dev_s = [], []
    host_len = dev_len = 0
    for rep in range(-1, reps):        # rep -1 = untimed warmup (jit)
        t0 = time.monotonic()
        host_payload = encode(x, "zstd")[0]
        host_t = time.monotonic() - t0
        t0 = time.monotonic()
        t = np.asarray(bp.forward_jnp(jnp.asarray(u8), k))
        dev_payload = encode_preconditioned(t, "byteplane-zstd")
        dev_t = time.monotonic() - t0
        if rep >= 0:
            host_s.append(host_t)
            dev_s.append(dev_t)
            host_len, dev_len = len(host_payload), len(dev_payload)
    # sanity: the pipeline arm must be byte-identical to the host encoder
    ref = encode(x, "byteplane-zstd")
    assert dev_payload == ref[0], "device pipeline diverged from encode()"
    assert byteplane_meta(x) == ref[1]
    speedup = statistics.median(
        h / max(d, 1e-9) for h, d in zip(host_s, dev_s))
    # >1 means byteplane-zstd compresses TIGHTER than plain zstd
    ratio_frac = host_len / dev_len
    emit("codec_byteplane_vs_zstd", statistics.median(dev_s) * 1e6,
         f"speedup={speedup:.2f}x;ratio_frac={ratio_frac:.3f};"
         f"zstd_mib={host_len/2**20:.1f};byteplane_zstd_mib="
         f"{dev_len/2**20:.1f}")
    return {"byteplane_vs_zstd_speedup": round(speedup, 3),
            "byteplane_vs_zstd_ratio_frac": round(ratio_frac, 3),
            "host_zstd_s": round(statistics.median(host_s), 4),
            "byteplane_zstd_s": round(statistics.median(dev_s), 4)}


def run(tiny: bool = False, reps: int = 5) -> dict:
    nbytes = TINY_NBYTES if tiny else NBYTES
    reps = 1 if tiny else reps
    x = _payload(nbytes)
    per_codec = _per_codec(x, reps)
    if not HAVE_ZSTD:
        print("codec: zstandard not installed — skipping the "
              "byteplane-zstd A/B and the BENCH_ckpt.json record")
        return per_codec
    headline = _ab_host_vs_device(x, reps)
    bench_record("codec", dict(
        headline, payload_mib=nbytes / 2**20, reps=reps, tiny=tiny,
        per_codec=per_codec))
    return dict(per_codec, **headline)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="4 MB payload, single rep (CI smoke)")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args(argv)
    run(tiny=args.tiny, reps=args.reps)


if __name__ == "__main__":
    main()
