"""Shard-codec A/B: host zstd vs the device-side byteplane pipeline, and
the device entropy stage (byteplane-rans) vs the host zstd entropy stage
over the same pre-conditioned stream.

Per-codec encode/decode throughput and compression ratio on params-like
f32 data (near-zero weights: constant sign/exponent bytes interleaved
with random mantissa bytes — the distribution the byteplane transform is
built for), plus two headline A/Bs:

  * ``byteplane-zstd`` encode (device transform + host zstd over the
    pre-conditioned stream) vs plain host ``zstd`` — the transform
    tentpole;
  * ``byteplane-rans`` (device transform + DEVICE plane entropy coding,
    the chunk-encoded pipeline: chunks reach the host pre-compressed)
    vs ``byteplane-zstd`` — the entropy tentpole. Targets: ≥1.5× encode
    throughput at ≥0.90 of zstd's compression ratio.

Protocol mirrors ``common.io_sweep_compare``: an untimed warmup rep
(absorbs the jit compile of the transform), then ``--reps`` interleaved
host/device rep pairs; the headline speedup is the MEDIAN OF PER-REP
PAIRED RATIOS, so both arms of each ratio see the same machine phase.

Without the optional ``zstandard`` package the zstd arms cannot run; the
``codec`` section is still recorded — marked ``zstd_absent`` with the
zstd-comparison metrics listed in ``unavailable_metrics`` so the
regression gate skips (rather than flags) their floors — and the
rle/rans codec lines keep their real numbers.
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax.numpy as jnp
import numpy as np

from repro.core.codec import (HAVE_ZSTD, byteplane_meta, contig_u8, decode,
                              encode, encode_preconditioned)
from repro.kernels.ckpt_codec import byteplane as bp
from repro.kernels.ckpt_codec import entropy as ent

from .common import bench_record, emit

NBYTES = 64 << 20          # 64 MB f32 payload (the acceptance-criteria size)
TINY_NBYTES = 4 << 20      # still above MIN_ACCEL_BYTES so the device
                           # transform path is the one being timed

# the metrics only a zstd-capable environment can produce — the gate
# skips these floors when the recorded run says zstd was absent
_ZSTD_METRICS = ("byteplane_vs_zstd_speedup", "byteplane_vs_zstd_ratio_frac",
                 "rans_vs_zstd_speedup", "rans_ratio_frac")


def _payload(nbytes: int) -> np.ndarray:
    rng = np.random.default_rng(1)
    return (rng.standard_normal(nbytes // 4) * 0.02).astype(np.float32)


def _per_codec(x: np.ndarray, reps: int) -> dict:
    """Median encode/decode wall-clock and ratio for every usable codec."""
    out = {}
    codecs = ["raw", "int8", "byteplane", "byteplane-rle", "byteplane-rans"]
    if HAVE_ZSTD:
        codecs[1:1] = ["zstd"]
        codecs.append("byteplane-zstd")
    for codec in codecs:
        enc_s, dec_s = [], []
        for _ in range(reps):
            t0 = time.monotonic()
            payload, meta = encode(x, codec)
            enc_s.append(time.monotonic() - t0)
            t0 = time.monotonic()
            y = decode(payload, codec, x.shape, x.dtype, meta)
            dec_s.append(time.monotonic() - t0)
        err = float(np.max(np.abs(np.asarray(y, np.float32) - x)))
        ratio = x.nbytes / len(payload)
        enc, dec = statistics.median(enc_s), statistics.median(dec_s)
        out[codec] = {"enc_gbps": round(x.nbytes / enc / 1e9, 3),
                      "dec_gbps": round(x.nbytes / dec / 1e9, 3),
                      "ratio": round(ratio, 3)}
        emit(f"codec_{codec}", enc * 1e6,
             f"ratio={ratio:.2f}x;enc_gbps={x.nbytes/enc/1e9:.2f};"
             f"dec_gbps={x.nbytes/dec/1e9:.2f};max_err={err:.2e}")
    return out


def _rans_encode_device(u8_dev, k: int):
    """The chunk-encoded production pipeline in one dispatch shape:
    device byteplane forward → device plane entropy coding → materialize
    the ENCODED stream on host (what D2H shrinks to), mirroring the fused
    ticket resolution in ``save_path``."""
    t = bp.forward_jnp(u8_dev, itemsize=k)
    flags, dlens, out, total = ent.encode_stream_jnp(t, "byteplane-rans")
    return np.asarray(out)[: int(np.asarray(total))]


def _ab_host_vs_device(x: np.ndarray, reps: int) -> dict:
    """Transform tentpole A/B: host ``encode(x, "zstd")`` vs the device
    pipeline the save path runs (jnp byteplane forward → host zstd over
    the pre-conditioned stream). Both arms produce a complete encoded
    payload; the device transform is forced to materialize on host
    (``np.asarray``) inside the timed region, exactly as the save path's
    ticket resolution does."""
    u8 = contig_u8(x)
    k = x.dtype.itemsize
    host_s, dev_s = [], []
    host_len = dev_len = 0
    for rep in range(-1, reps):        # rep -1 = untimed warmup (jit)
        t0 = time.monotonic()
        host_payload = encode(x, "zstd")[0]
        host_t = time.monotonic() - t0
        t0 = time.monotonic()
        t = np.asarray(bp.forward_jnp(jnp.asarray(u8), k))
        dev_payload = encode_preconditioned(t, "byteplane-zstd")
        dev_t = time.monotonic() - t0
        if rep >= 0:
            host_s.append(host_t)
            dev_s.append(dev_t)
            host_len, dev_len = len(host_payload), len(dev_payload)
    # sanity: the pipeline arm must be byte-identical to the host encoder
    ref = encode(x, "byteplane-zstd")
    assert dev_payload == ref[0], "device pipeline diverged from encode()"
    assert byteplane_meta(x) == ref[1]
    speedup = statistics.median(
        h / max(d, 1e-9) for h, d in zip(host_s, dev_s))
    # >1 means byteplane-zstd compresses TIGHTER than plain zstd
    ratio_frac = host_len / dev_len
    emit("codec_byteplane_vs_zstd", statistics.median(dev_s) * 1e6,
         f"speedup={speedup:.2f}x;ratio_frac={ratio_frac:.3f};"
         f"zstd_mib={host_len/2**20:.1f};byteplane_zstd_mib="
         f"{dev_len/2**20:.1f}")
    return {"byteplane_vs_zstd_speedup": round(speedup, 3),
            "byteplane_vs_zstd_ratio_frac": round(ratio_frac, 3),
            "host_zstd_s": round(statistics.median(host_s), 4),
            "byteplane_zstd_s": round(statistics.median(dev_s), 4)}


def _ab_rans_vs_byteplane_zstd(x: np.ndarray, reps: int) -> dict:
    """Entropy tentpole A/B: ``byteplane-zstd`` (device transform, host
    zstd entropy stage — the full transformed stream crosses D2H) vs
    ``byteplane-rans`` (device transform + device entropy stage — only
    the ENCODED stream crosses D2H). Same payload, interleaved pairs.

    ``rans_ratio_frac`` is the rANS compression ratio as a fraction of
    zstd's on the same pre-conditioned stream (1.0 = parity; the
    acceptance floor asks ≥0.90 at ≥1.5× encode throughput)."""
    u8 = contig_u8(x)
    k = x.dtype.itemsize
    dev = jnp.asarray(u8)
    zstd_s, rans_s = [], []
    zstd_len = rans_len = 0
    for rep in range(-1, reps):        # rep -1 = untimed warmup (jit)
        t0 = time.monotonic()
        t = np.asarray(bp.forward_jnp(dev, k))
        zstd_payload = encode_preconditioned(t, "byteplane-zstd")
        zstd_t = time.monotonic() - t0
        t0 = time.monotonic()
        rans_payload = _rans_encode_device(dev, k)
        rans_t = time.monotonic() - t0
        if rep >= 0:
            zstd_s.append(zstd_t)
            rans_s.append(rans_t)
            zstd_len, rans_len = len(zstd_payload), len(rans_payload)
    # sanity: the device entropy stage must match the host oracle encoder
    assert rans_payload.tobytes() == encode(x, "byteplane-rans")[0], \
        "device entropy stage diverged from encode()"
    speedup = statistics.median(
        z / max(r, 1e-9) for z, r in zip(zstd_s, rans_s))
    ratio_frac = zstd_len / rans_len   # (n/rans_len) / (n/zstd_len)
    emit("codec_rans_vs_zstd", statistics.median(rans_s) * 1e6,
         f"speedup={speedup:.2f}x;ratio_frac={ratio_frac:.3f};"
         f"byteplane_zstd_mib={zstd_len/2**20:.1f};"
         f"byteplane_rans_mib={rans_len/2**20:.1f}")
    return {"rans_vs_zstd_speedup": round(speedup, 3),
            "rans_ratio_frac": round(ratio_frac, 3),
            "byteplane_zstd_enc_s": round(statistics.median(zstd_s), 4),
            "byteplane_rans_enc_s": round(statistics.median(rans_s), 4)}


def _rans_solo(x: np.ndarray, reps: int) -> dict:
    """No-zstd fallback numbers: absolute device-pipeline encode
    throughput and ratio for the chunk-encoded codec, so a zstd-less run
    still records something floorable about the entropy stage."""
    u8 = contig_u8(x)
    dev = jnp.asarray(u8)
    k = x.dtype.itemsize
    rans_s = []
    for rep in range(-1, reps):
        t0 = time.monotonic()
        payload = _rans_encode_device(dev, k)
        if rep >= 0:
            rans_s.append(time.monotonic() - t0)
    enc = statistics.median(rans_s)
    ratio = x.nbytes / len(payload)
    emit("codec_rans_solo", enc * 1e6,
         f"enc_gbps={x.nbytes/enc/1e9:.2f};ratio={ratio:.2f}x")
    return {"rans_enc_gbps": round(x.nbytes / enc / 1e9, 3),
            "rans_ratio": round(ratio, 3),
            "byteplane_rans_enc_s": round(enc, 4)}


def run(tiny: bool = False, reps: int = 5) -> dict:
    nbytes = TINY_NBYTES if tiny else NBYTES
    reps = 1 if tiny else reps
    x = _payload(nbytes)
    per_codec = _per_codec(x, reps)
    if HAVE_ZSTD:
        headline = dict(_ab_host_vs_device(x, reps),
                        **_ab_rans_vs_byteplane_zstd(x, reps))
        extra = {}
    else:
        print("codec: zstandard not installed — recording the section "
              "zstd-absent; the gate skips the zstd-comparison floors")
        headline = _rans_solo(x, reps)
        extra = {"zstd_absent": True,
                 "unavailable_metrics": list(_ZSTD_METRICS)}
    bench_record("codec", dict(
        headline, payload_mib=nbytes / 2**20, reps=reps, tiny=tiny,
        per_codec=per_codec, **extra))
    return dict(per_codec, **headline)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="4 MB payload, single rep (CI smoke)")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args(argv)
    run(tiny=args.tiny, reps=args.reps)


if __name__ == "__main__":
    main()
