"""Checkpoint-size/overhead reduction (the paper's stated future work):
raw vs zstd vs int8-block codecs — encode throughput, compression ratio,
and max quantization error on params-like data."""
from __future__ import annotations

import time

import numpy as np

from repro.core.codec import HAVE_ZSTD, decode, encode

from .common import emit

N = 16 << 20  # 64 MB f32


def run():
    rng = np.random.default_rng(1)
    x = (rng.standard_normal(N // 4) * 0.02).astype(np.float32)
    out = {}
    codecs = ("raw", "zstd", "int8") if HAVE_ZSTD else ("raw", "int8")
    for codec in codecs:
        t0 = time.monotonic()
        payload, meta = encode(x, codec)
        enc_s = time.monotonic() - t0
        t0 = time.monotonic()
        y = decode(payload, codec, x.shape, x.dtype, meta)
        dec_s = time.monotonic() - t0
        err = float(np.max(np.abs(np.asarray(y, np.float32) - x)))
        ratio = x.nbytes / len(payload)
        out[codec] = (enc_s, dec_s, ratio, err)
        emit(f"codec_{codec}", enc_s * 1e6,
             f"ratio={ratio:.2f}x;enc_gbps={x.nbytes/enc_s/1e9:.2f};"
             f"dec_gbps={x.nbytes/dec_s/1e9:.2f};max_err={err:.2e}")
    return out


if __name__ == "__main__":
    run()
