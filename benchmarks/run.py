# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Master benchmark harness.

Paper artifact ↔ bench map:
  Fig 2  (Gromacs ckpt time, BB vs Lustre, 4→64 ranks)  → bench_ckpt_overhead
  HPCG ¶ (512-rank ckpt 30s vs 600s; restart ~2.5×)     → bench_restart
  Fig 1  (top-application coverage)                     → bench_workload_sweep
  future work (ckpt overhead reduction)                 → bench_codec
  beyond-paper (overlap compute/IO)                     → bench_async_overlap
  §Roofline (from dry-run artifacts)                    → roofline
"""
import sys
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    from . import (bench_async_overlap, bench_ckpt_overhead, bench_codec,
                   bench_restart, bench_workload_sweep, roofline)
    print("name,us_per_call,derived")
    for mod in (bench_ckpt_overhead, bench_restart, bench_codec,
                bench_workload_sweep, bench_async_overlap, roofline):
        try:
            mod.run()
        except Exception as e:  # noqa — one bench failing must not hide others
            print(f"{mod.__name__},0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)


if __name__ == '__main__':
    main()
