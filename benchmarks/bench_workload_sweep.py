"""Paper Fig. 1 analogue ("top applications coverage"): transparent C/R
works across the whole assigned workload zoo — checkpoint + bit-exact
restore for all 10 architectures (reduced configs)."""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCH_IDS, CONFIGS, reduced
from repro.core.checkpoint import CheckpointManager
from repro.core.split_state import init_train_state
from repro.models import Model
from repro.optim import make_optimizer

from .common import abstract, bb_store, bench_policy, cleanup, emit


def run():
    ok = 0
    for arch in ARCH_IDS:
        cfg = reduced(CONFIGS[arch])
        model = Model(cfg)
        opt = make_optimizer(cfg)
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        store = bb_store(f"zoo-{arch}")
        mgr = CheckpointManager(store, policy=bench_policy(n_writers=2,
                                                           retain=1))
        t0 = time.monotonic()
        rep = mgr.save(state, 1)
        save_s = time.monotonic() - t0
        t0 = time.monotonic()
        restored, _ = mgr.restore(abstract(state))
        rest_s = time.monotonic() - t0
        exact = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(state),
                            jax.tree.leaves(restored)))
        ok += exact
        cleanup(store)
        emit(f"zoo_cr_{arch}", save_s * 1e6,
             f"bytes={rep['bytes']};restore_s={rest_s:.3f};exact={exact}")
    emit("zoo_cr_coverage", 0.0, f"archs_ok={ok}/{len(ARCH_IDS)}")
    return ok


if __name__ == "__main__":
    run()
