"""Paper HPCG paragraph analogue: checkpoint AND restart times on both
tiers at fixed large-ish state. The paper reports >20× BB speedup for
checkpointing and ~2.5× for restart (restart is read-bound + reconstruction
— less tier-sensitive), at 512 ranks / 5.8 TB aggregate.

``--mode io-sweep`` measures the RESTART side of the pipelined chunk
engine: one incremental checkpoint on a real (unthrottled) disk store,
restored by the serial baseline (``io_threads=1`` — the PR-1
chunk-at-a-time, digest-re-hash-every-chunk path) and by the pipelined
engine (``--io-threads N``: leaf-level fan-out, chunk prefetch, payload
crc32 as the end-to-end integrity gate). Save wall-clock for both engines
is reported alongside, writing to separate stores.
"""
from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

from repro.core.checkpoint import CheckpointManager

from .common import (abstract, bb_store, bench_policy, cleanup, emit,
                     io_sweep_compare, scratch_store, synth_state)

AGG = 256 << 20  # scaled-down 5.8 TB stand-in


def run(tiny=False):
    tmp = Path(tempfile.mkdtemp())
    agg = AGG // (16 if tiny else 1)
    state = synth_state(agg, shards=32)
    out = {}
    for tier_name, store in (("bb", bb_store("hpcg")),
                             ("scratch", scratch_store("hpcg", tmp))):
        mgr = CheckpointManager(store, policy=bench_policy(
            n_writers=8, codec="raw", retain=1))
        t0 = time.monotonic()
        mgr.save(state, 1)
        ckpt_s = time.monotonic() - t0
        t0 = time.monotonic()
        mgr.restore(abstract(state))
        rest_s = time.monotonic() - t0
        out[tier_name] = (ckpt_s, rest_s)
        cleanup(store)
    ck_speed = out["scratch"][0] / max(out["bb"][0], 1e-9)
    rs_speed = out["scratch"][1] / max(out["bb"][1], 1e-9)
    emit("hpcg_ckpt_restart", out["bb"][0] * 1e6,
         f"agg_gib={agg/2**30:.2f};bb_ckpt_s={out['bb'][0]:.3f};"
         f"scratch_ckpt_s={out['scratch'][0]:.3f};"
         f"bb_restart_s={out['bb'][1]:.3f};"
         f"scratch_restart_s={out['scratch'][1]:.3f};"
         f"ckpt_speedup={ck_speed:.1f}x;restart_speedup={rs_speed:.1f}x")
    return out


def io_sweep(io_threads=8, chunking="fixed", tiny=False, reps=5):
    # same 192 MiB / 24-shard workload as bench_ckpt_overhead's io-sweep,
    # at the read-optimal 1 MiB chunk size (the save sweep uses 512 KiB,
    # which stresses the write-side per-object fsync tax instead)
    return io_sweep_compare("restart_io_sweep", agg=192 << 20, shards=24,
                            seed=1, io_threads=io_threads,
                            chunking=chunking, tiny=tiny, reps=reps,
                            retain=1, primary="restore")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="tiers", choices=["tiers", "io-sweep"])
    ap.add_argument("--chunking", default="fixed",
                    choices=["fixed", "cdc"])
    ap.add_argument("--io-threads", type=int, default=8)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.mode == "io-sweep":
        io_sweep(io_threads=args.io_threads, chunking=args.chunking,
                 tiny=args.tiny)
    else:
        run(tiny=args.tiny)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
