"""Paper HPCG paragraph analogue: checkpoint AND restart times on both
tiers at fixed large-ish state. The paper reports >20× BB speedup for
checkpointing and ~2.5× for restart (restart is read-bound + reconstruction
— less tier-sensitive), at 512 ranks / 5.8 TB aggregate."""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core.checkpoint import CheckpointManager

from .common import (abstract, bb_store, cleanup, emit, scratch_store,
                     synth_state)

AGG = 256 << 20  # scaled-down 5.8 TB stand-in


def run():
    tmp = Path(tempfile.mkdtemp())
    state = synth_state(AGG, shards=32)
    out = {}
    for tier_name, store in (("bb", bb_store("hpcg")),
                             ("scratch", scratch_store("hpcg", tmp))):
        mgr = CheckpointManager(store, n_writers=8, codec="raw", retain=1)
        t0 = time.monotonic()
        mgr.save(state, 1)
        ckpt_s = time.monotonic() - t0
        t0 = time.monotonic()
        mgr.restore(abstract(state))
        rest_s = time.monotonic() - t0
        out[tier_name] = (ckpt_s, rest_s)
        cleanup(store)
    ck_speed = out["scratch"][0] / max(out["bb"][0], 1e-9)
    rs_speed = out["scratch"][1] / max(out["bb"][1], 1e-9)
    emit("hpcg_ckpt_restart", out["bb"][0] * 1e6,
         f"agg_gib={AGG/2**30:.2f};bb_ckpt_s={out['bb'][0]:.3f};"
         f"scratch_ckpt_s={out['scratch'][0]:.3f};"
         f"bb_restart_s={out['bb'][1]:.3f};"
         f"scratch_restart_s={out['scratch'][1]:.3f};"
         f"ckpt_speedup={ck_speed:.1f}x;restart_speedup={rs_speed:.1f}x")
    return out


if __name__ == "__main__":
    run()
