"""Paper HPCG paragraph analogue: checkpoint AND restart times on both
tiers at fixed large-ish state. The paper reports >20× BB speedup for
checkpointing and ~2.5× for restart (restart is read-bound + reconstruction
— less tier-sensitive), at 512 ranks / 5.8 TB aggregate.

``--mode io-sweep`` measures the RESTART side of the pipelined chunk
engine: one incremental checkpoint on a real (unthrottled) disk store,
restored by the serial baseline (``io_threads=1`` — the PR-1
chunk-at-a-time, digest-re-hash-every-chunk path) and by the pipelined
engine (``--io-threads N``: leaf-level fan-out, chunk prefetch, payload
crc32 as the end-to-end integrity gate). Save wall-clock for both engines
is reported alongside, writing to separate stores.

``--mode restore-stream`` attacks TIME-TO-FIRST-STEP (the MANA-2.0
lesson: the number a production redeploy feels is when step 0 runs, not
when the last byte lands): a cold restart whose only copy of the
checkpoint lives on the remote object-store tier, restored blocking
(full restore, then the step-0 frontier compute) vs STREAMING
(``restore_streaming``: fetches in first-use order, step-0 frontier
compute as soon as the frontier is resident, tail layers streaming in
behind the completion gate). Restored state is asserted bit-exact
leaf-by-leaf between the two engines every rep."""
from __future__ import annotations

import argparse
import shutil
import statistics
import tempfile
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core.checkpoint import CheckpointManager
from repro.core.storage import (RemoteTier, Tier, TieredStore,
                                mirror_to_tier)

from .common import (abstract, bb_store, bench_policy, bench_record,
                     cleanup, emit, io_sweep_compare, scratch_store,
                     synth_state)

AGG = 256 << 20  # scaled-down 5.8 TB stand-in
STREAM_AGG = 192 << 20          # the cold-remote restore-stream workload
REMOTE_LATENCY_S = 0.0005       # per ranged-GET request latency


def run(tiny=False):
    tmp = Path(tempfile.mkdtemp())
    agg = AGG // (16 if tiny else 1)
    state = synth_state(agg, shards=32)
    out = {}
    for tier_name, store in (("bb", bb_store("hpcg")),
                             ("scratch", scratch_store("hpcg", tmp))):
        mgr = CheckpointManager(store, policy=bench_policy(
            n_writers=8, codec="raw", retain=1))
        t0 = time.monotonic()
        mgr.save(state, 1)
        ckpt_s = time.monotonic() - t0
        t0 = time.monotonic()
        mgr.restore(abstract(state))
        rest_s = time.monotonic() - t0
        out[tier_name] = (ckpt_s, rest_s)
        cleanup(store)
    ck_speed = out["scratch"][0] / max(out["bb"][0], 1e-9)
    rs_speed = out["scratch"][1] / max(out["bb"][1], 1e-9)
    emit("hpcg_ckpt_restart", out["bb"][0] * 1e6,
         f"agg_gib={agg/2**30:.2f};bb_ckpt_s={out['bb'][0]:.3f};"
         f"scratch_ckpt_s={out['scratch'][0]:.3f};"
         f"bb_restart_s={out['bb'][1]:.3f};"
         f"scratch_restart_s={out['scratch'][1]:.3f};"
         f"ckpt_speedup={ck_speed:.1f}x;restart_speedup={rs_speed:.1f}x")
    return out


def io_sweep(io_threads=8, chunking="fixed", tiny=False, reps=5):
    # same 192 MiB / 24-shard workload as bench_ckpt_overhead's io-sweep,
    # at the read-optimal 1 MiB chunk size (the save sweep uses 512 KiB,
    # which stresses the write-side per-object fsync tax instead)
    return io_sweep_compare("restart_io_sweep", agg=192 << 20, shards=24,
                            seed=1, io_threads=io_threads,
                            chunking=chunking, tiny=tiny, reps=reps,
                            retain=1, primary="restore")


def layered_state(total_bytes: int, *, layers: int = 12, seed: int = 0):
    """Transformer-shaped synthetic state: embedding and LM head (2 units
    each) around `layers` indexed blocks (1 unit each) — the leaf names
    carry the first-use structure ``elastic.leaf_first_use_class`` reads."""
    units = layers + 4
    per = max(total_bytes // (4 * units), 4)
    side = max(int(per ** 0.5), 2)
    rng = np.random.default_rng(seed)

    def w(scale=1):
        return jnp.asarray(rng.standard_normal(
            (side * scale, side), dtype=np.float32))

    params = {"embed": w(2), "lm_head": w(2)}
    for k in range(layers):
        params[f"stage_0/b{k:02d}/w"] = w()
    return {"params": params, "step": jnp.asarray(1, jnp.int32)}


def _first_step_compute(names, leaf_of) -> float:
    """The step-0 stand-in: touch the frontier leaves the way a forward
    pass does (embedding + block 0), forcing materialization."""
    acc = 0.0
    for name in names:
        leaf = leaf_of(name)
        acc += float(jnp.sum(jnp.ravel(leaf)[:64]))
    return acc


def restore_stream(io_threads=8, tiny=False, reps=3):
    """Blocking vs streaming cold-remote restore; records ttfs_speedup."""
    agg = STREAM_AGG // (16 if tiny else 1)
    reps = 1 if tiny else reps
    state = layered_state(agg, seed=2)
    names = [f"params/{k}" for k in state["params"]] + ["step"]
    ab = abstract(state)
    remote_bw = float(agg)      # full remote transfer ≈ 1 s at any scale
    tmp = Path(tempfile.mkdtemp())

    # one checkpoint, written locally then mirrored to the "object store"
    # (the out-of-band `aws s3 sync` a production redeploy restores from)
    writer = TieredStore(Tier("writer", tmp / "writer"))
    mgr = CheckpointManager(writer, policy=bench_policy(
        n_writers=4, codec="raw", retain=1, mode="incremental",
        chunking="fixed", io_threads=io_threads))
    mgr.save(state, 1)
    mgr.close()
    mirror_to_tier(writer.fast, RemoteTier("upload", tmp / "remote"))

    def cold_mgr(tag, streaming):
        """Fresh empty fast tier + throttled remote = a true cold restart
        (fresh token bucket per rep, so the engines compare fairly)."""
        store = TieredStore(
            Tier("fast", tmp / tag),
            remote=RemoteTier("object-store", tmp / "remote",
                              bw_bytes_per_s=remote_bw,
                              request_latency_s=REMOTE_LATENCY_S))
        return CheckpointManager(store, policy=bench_policy(
            n_writers=4, codec="raw", retain=1, mode="incremental",
            chunking="fixed", io_threads=io_threads,
            streaming_restore=streaming))

    samples = []
    for rep in range(reps):
        m1 = cold_mgr(f"cold-b{rep}", False)
        t0 = time.monotonic()
        full, _ = m1.restore(ab)
        t_full = time.monotonic() - t0
        flat = dict(zip(names, [full["params"][k] for k in full["params"]]
                        + [full["step"]]))
        _first_step_compute([n for n in names
                             if "embed" in n or "/b00/" in n],
                            flat.__getitem__)
        t_first_blocking = time.monotonic() - t0
        m1.close()

        m2 = cold_mgr(f"cold-s{rep}", True)
        t0 = time.monotonic()
        stream, _ = m2.restore_streaming(ab)
        stream.wait_frontier()
        _first_step_compute(stream.frontier_names, stream.leaf)
        t_first_stream = time.monotonic() - t0
        streamed = stream.state()
        t_complete = time.monotonic() - t0
        m2.close()
        # bit-exact: streaming must place exactly the blocking bytes
        for k in full["params"]:
            np.testing.assert_array_equal(
                np.asarray(full["params"][k]),
                np.asarray(streamed["params"][k]))
        samples.append((t_full, t_first_blocking, t_first_stream,
                        t_complete))
    shutil.rmtree(tmp, ignore_errors=True)

    med = [statistics.median(s[i] for s in samples) for i in range(4)]
    t_full, t_first_blocking, t_first_stream, t_complete = med
    ttfs_speedup = t_first_blocking / max(t_first_stream, 1e-9)
    emit("restore_stream", t_first_stream * 1e6,
         f"agg_mib={agg/2**20:.0f};io_threads={io_threads};reps={reps};"
         f"full_restore_s={t_full:.3f};ttfs_blocking_s={t_first_blocking:.3f};"
         f"ttfs_stream_s={t_first_stream:.3f};"
         f"stream_complete_s={t_complete:.3f};"
         f"ttfs_speedup={ttfs_speedup:.2f}x")
    bench_record("restore_stream", {
        "agg_mib": agg / 2**20, "io_threads": io_threads, "reps": reps,
        "tiny": tiny, "remote_bw_mib_s": remote_bw / 2**20,
        "full_restore_s": round(t_full, 4),
        "ttfs_blocking_s": round(t_first_blocking, 4),
        "ttfs_stream_s": round(t_first_stream, 4),
        "stream_complete_s": round(t_complete, 4),
        "ttfs_speedup": round(ttfs_speedup, 3),
    })
    return {"ttfs_speedup": ttfs_speedup, "full_restore_s": t_full,
            "ttfs_stream_s": t_first_stream}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="tiers",
                    choices=["tiers", "io-sweep", "restore-stream"])
    ap.add_argument("--chunking", default="fixed",
                    choices=["fixed", "cdc"])
    ap.add_argument("--io-threads", type=int, default=8)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.mode == "io-sweep":
        io_sweep(io_threads=args.io_threads, chunking=args.chunking,
                 tiny=args.tiny)
    elif args.mode == "restore-stream":
        restore_stream(io_threads=args.io_threads, tiny=args.tiny)
    else:
        run(tiny=args.tiny)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
