"""Beyond-paper: async checkpointing hides file IO behind training compute.
Measures steps/sec with no / sync / async checkpointing every 2 steps on a
throttled tier (so the IO cost is non-trivial, as on Lustre)."""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.configs import CONFIGS, reduced
from repro.train.loop import Trainer, TrainerConfig

from .common import emit


def _run(mode: str, tmp: Path) -> float:
    cfg = reduced(CONFIGS["stablelm-1.6b"])
    tcfg = TrainerConfig(
        workdir=str(tmp / mode), batch=4, seq_len=64, log_every=1000,
        ckpt_every=0 if mode == "none" else 2,
        async_ckpt=(mode == "async"), codec="raw", n_writers=2,
        lustre_bw=80e6, burst_buffer=False)
    t = Trainer(cfg, tcfg).init_or_restore()
    t.fit(2)  # warmup + compile
    t0 = time.monotonic()
    t.fit(10)
    t.manager.wait()
    return 8 / (time.monotonic() - t0)


def run():
    tmp = Path(tempfile.mkdtemp())
    rates = {m: _run(m, tmp) for m in ("none", "sync", "async")}
    overhead_sync = (rates["none"] - rates["sync"]) / rates["none"] * 100
    overhead_async = (rates["none"] - rates["async"]) / rates["none"] * 100
    emit("async_ckpt_overlap", 1e6 / rates["async"],
         f"steps_per_s_none={rates['none']:.2f};sync={rates['sync']:.2f};"
         f"async={rates['async']:.2f};"
         f"overhead_sync={overhead_sync:.0f}%;"
         f"overhead_async={overhead_async:.0f}%")
    return rates


if __name__ == "__main__":
    run()
