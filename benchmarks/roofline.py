"""Roofline report: reads the dry-run artifacts and renders the per-cell
three-term table (§Roofline), flags the dominant bottleneck, and nominates
the three hillclimb cells (worst roofline fraction / most collective-bound /
most C/R-representative)."""
from __future__ import annotations

import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(mesh="single"):
    rows = []
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            rows.append(r)
    return rows


def table(rows):
    hdr = (f"{'arch':24s} {'shape':11s} {'comp_s':>8s} {'mem_s':>8s} "
           f"{'coll_s':>8s} {'dom':>5s} {'frac':>5s} {'useful':>6s} "
           f"{'HBM GiB':>8s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        t = r["roofline"]
        out.append(
            f"{r['arch']:24s} {r['shape']:11s} "
            f"{t['compute_s']:8.4f} {t['memory_s']:8.4f} "
            f"{t['collective_s']:8.4f} {t['dominant'][:4]:>5s} "
            f"{t['roofline_fraction']:5.2f} "
            f"{r['useful_flops_fraction']:6.2f} "
            f"{r['memory']['peak_bytes_est']/2**30:8.2f}")
    return "\n".join(out)


def nominate(rows):
    """The three hillclimb cells per the assignment.

    Decode cells are excluded from "worst fraction": a single decode token
    is inherently memory-bound (compute fraction ≈ 0 by construction), so
    the metric is only informative on train/prefill cells.
    """
    nondecode = [r for r in rows if r["shape"] in ("train_4k", "prefill_32k")]
    coll = max(rows, key=lambda r: r["roofline"]["collective_s"])
    picks = [("most-collective", coll["arch"], coll["shape"])]

    worst = min((r for r in nondecode
                 if (r["arch"], r["shape"]) != (coll["arch"], coll["shape"])),
                key=lambda r: r["roofline"]["roofline_fraction"])
    picks.append(("worst-fraction", worst["arch"], worst["shape"]))

    # most C/R-representative: biggest state ⇒ heaviest checkpoint (the
    # paper's scaling axis) — the largest train cell not already picked
    taken = {(a, s) for _, a, s in picks}
    big = max((r for r in rows if r["shape"] == "train_4k"
               and (r["arch"], r["shape"]) not in taken),
              key=lambda r: r["model_flops_global"])
    picks.append(("paper-representative", big["arch"], big["shape"]))
    return picks


def optimized_rows():
    """Best optimized variant per cell from artifacts/dryrun-opt*."""
    best = {}
    for d in sorted(ART.parent.glob("dryrun-opt*")):
        for p in d.glob("*__single.json"):
            r = json.loads(p.read_text())
            if r.get("status") != "ok":
                continue
            key = (r["arch"], r["shape"])
            if key not in best or (r["roofline"]["roofline_fraction"]
                                   > best[key]["roofline"]["roofline_fraction"]):
                best[key] = r
    return best


def run():
    rows = load("single")
    if not rows:
        print("roofline,0,no_dryrun_artifacts_yet")
        return
    print(table(rows))
    print()
    for tag, arch, shape in nominate(rows):
        print(f"hillclimb_{tag},0,{arch}x{shape}")
    opt = optimized_rows()
    for (arch, shape), r in sorted(opt.items()):
        base = next((b for b in rows
                     if (b["arch"], b["shape"]) == (arch, shape)), None)
        if base is None:
            continue
        f0 = base["roofline"]["roofline_fraction"]
        f1 = r["roofline"]["roofline_fraction"]
        ov = r.get("overrides", {})
        print(f"perf_{arch}x{shape},0,"
              f"frac {f0:.3f}->{f1:.3f};coll "
              f"{base['roofline']['collective_s']:.1f}->"
              f"{r['roofline']['collective_s']:.1f}s;hbm "
              f"{base['memory']['peak_bytes_est']/2**30:.1f}->"
              f"{r['memory']['peak_bytes_est']/2**30:.1f}GiB;{ov}")
    (ART.parent / "roofline_table.txt").write_text(table(rows))


if __name__ == "__main__":
    run()
