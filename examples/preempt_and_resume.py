"""Preempt-queue demo: a low-priority training job is preempted by a
high-priority arrival (the paper's scheduling-flexibility use case), takes a
final checkpoint at the step boundary, exits, and later resumes exactly.

    PYTHONPATH=src python examples/preempt_and_resume.py
"""
import logging
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
logging.basicConfig(level=logging.INFO, format="%(message)s")

from repro.configs import CONFIGS, reduced  # noqa: E402
from repro.core.preempt import PreemptQueue, PreemptionGuard  # noqa: E402
from repro.train.loop import Trainer, TrainerConfig  # noqa: E402


def main():
    cfg = reduced(CONFIGS["starcoder2-3b"])
    wd = tempfile.mkdtemp(prefix="repro-preempt-")
    tcfg = TrainerConfig(workdir=wd, batch=4, seq_len=64, ckpt_every=50,
                         seed=1, log_every=5)

    print("== low-priority job starts (target: 30 steps)")
    queue = PreemptQueue()
    job = Trainer(cfg, tcfg).init_or_restore()
    with PreemptionGuard() as guard:
        job.fit(30, guard=guard, stop_after=12)
        print("== high-priority job arrives -> preempting")
        queue.submit_high_priority(guard, job="realtime-inference")
        report = job.fit(30, guard=guard)
    print(f"== job exited: {report['status']} at step {report['step']}")
    assert report["status"] == "preempted"

    print("== nodes free for the high-priority job ... done; restarting")
    job2 = Trainer(cfg, tcfg).init_or_restore()
    print(f"== restored from step {job2.restored_from}")
    report2 = job2.fit(30)
    print(f"== finished: {report2['status']} at step {report2['step']}")
    assert report2["status"] == "completed" and report2["step"] == 30
    print("== coordinator metrics:", report2["ckpt_metrics"])


if __name__ == "__main__":
    main()
