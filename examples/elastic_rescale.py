"""Elastic rescale demo — the M×N property: checkpoint on one mesh, restore
on a different device count / mesh shape, keep training.

Spawns itself with --xla_force_host_platform_device_count=8 so the demo has
8 devices to re-shape (mirrors the dry-run rule: only subprocesses override
the device count).

    PYTHONPATH=src python examples/elastic_rescale.py
"""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

INNER = """
import os, sys, tempfile, logging
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, r"{src}")
logging.basicConfig(level=logging.INFO, format="%(message)s")
from repro.configs import CONFIGS, reduced
from repro.launch.mesh import make_host_mesh
from repro.train.loop import Trainer, TrainerConfig

cfg = reduced(CONFIGS["llama4-scout-17b-a16e"])   # MoE: richest sharding
wd = tempfile.mkdtemp(prefix="repro-elastic-")
tc = lambda: TrainerConfig(workdir=wd, batch=8, seq_len=64, ckpt_every=5,
                           seed=0, log_every=5)

print("== phase 1: train on a (2 data x 4 model) mesh")
t1 = Trainer(cfg, tc(), mesh=make_host_mesh((2, 4), ("data", "model")))
t1.init_or_restore(); t1.fit(5)
d1 = t1.params_digest()
print("   checkpointed at step 5; digest", d1[:16])

print("== phase 2: cluster shrank — restore on (4 data x 2 model)")
t2 = Trainer(cfg, tc(), mesh=make_host_mesh((4, 2), ("data", "model")))
t2.init_or_restore()
assert t2.params_digest() == d1, "restore must be value-exact across meshes"
print("   exact restore onto new topology; continuing training")
t2.fit(10)

print("== phase 3: scale-up — restore on (8 data x 1 model)")
t3 = Trainer(cfg, tc(), mesh=make_host_mesh((8, 1), ("data", "model")))
t3.init_or_restore()
print("   restored step:", t3.restored_from)
t3.fit(12)
print("== elastic rescale complete: 2x4 -> 4x2 -> 8x1, one checkpoint format")
"""


def main():
    code = INNER.format(src=str(ROOT / "src"))
    proc = subprocess.run([sys.executable, "-c", code])
    raise SystemExit(proc.returncode)


if __name__ == "__main__":
    main()
