"""Quickstart: train a small LM with transparent C/R, kill it, restore it,
and verify the continuation is bit-exact (the paper's Gromacs claim).

    PYTHONPATH=src python examples/quickstart.py
"""
import logging
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
logging.basicConfig(level=logging.INFO, format="%(message)s")

from repro.configs import CONFIGS, reduced  # noqa: E402
from repro.train.loop import Trainer, TrainerConfig  # noqa: E402


def main():
    cfg = reduced(CONFIGS["gemma3-1b"])
    wd = tempfile.mkdtemp(prefix="repro-quickstart-")
    print(f"== workdir {wd}")
    print("== reference run: 20 uninterrupted steps")
    ref = Trainer(cfg, TrainerConfig(workdir=wd + "/ref", batch=4, seq_len=64,
                                     ckpt_every=0, seed=42, log_every=5))
    ref.init_or_restore()
    ref.fit(20)
    ref_digest = ref.params_digest()

    print("== C/R run: 10 steps, async checkpoint every 5, then 'crash'")
    t = Trainer(cfg, TrainerConfig(workdir=wd + "/cr", batch=4, seq_len=64,
                                   ckpt_every=5, async_ckpt=True, seed=42,
                                   log_every=5))
    t.init_or_restore()
    t.fit(20, stop_after=10)
    del t  # simulated node failure — only the checkpoint survives

    print("== restart: lower half rebuilt, upper half restored")
    t2 = Trainer(cfg, TrainerConfig(workdir=wd + "/cr", batch=4, seq_len=64,
                                    ckpt_every=5, seed=42, log_every=5))
    t2.init_or_restore()
    print(f"   restored from step {t2.restored_from}")
    t2.fit(20)

    ok = t2.params_digest() == ref_digest
    print(f"== bit-exact resume: {ok}")
    assert ok
    print("== checkpoint metrics:", t2.manager.last_report)


if __name__ == "__main__":
    main()
