"""Serving with transparent C/R: batched greedy decoding is preempted
mid-generation, then restored — the completed outputs are token-identical to
an uninterrupted run.

    PYTHONPATH=src python examples/serve_with_cr.py
"""
import logging
import sys
import tempfile
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
logging.basicConfig(level=logging.INFO, format="%(message)s")

from repro.launch import serve  # noqa: E402


def main():
    wd = tempfile.mkdtemp(prefix="repro-serve-")
    print("== uninterrupted serving run (reference)")
    full = serve.run("stablelm-1.6b", n_requests=4, prompt_len=16,
                     gen_len=24, workdir=wd + "/ref", ckpt_every=0, seed=7)
    print(f"   {full['status']}  ~{full.get('tok_per_s', 0):.0f} tok/s")

    print("== serving run preempted at token 9")
    pre = serve.run("stablelm-1.6b", n_requests=4, prompt_len=16, gen_len=24,
                    workdir=wd + "/cr", ckpt_every=0, preempt_at=9, seed=7)
    assert pre["status"] == "preempted"

    print("== restored serving run finishes the batch")
    resumed = serve.run("stablelm-1.6b", n_requests=4, prompt_len=16,
                        gen_len=24, workdir=wd + "/cr", ckpt_every=0, seed=7)
    ok = np.array_equal(resumed["tokens"], full["tokens"])
    print(f"== token-exact continuation: {ok}")
    assert ok


if __name__ == "__main__":
    main()
