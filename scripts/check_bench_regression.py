#!/usr/bin/env python
"""CI gate: fail when the recorded perf trajectory regresses against the
committed baseline floors.

``BENCH_ckpt.json`` (repo root) carries, alongside the live benchmark
sections the benches rewrite, two COMMITTED floor sections:

  baseline        floors for full bench-box runs (the numbers a PR
                  commits after running the real sweeps);
  baseline_tiny   floors for ``--tiny`` CI smoke runs (noisy shared
                  runners — set loose, they exist to catch the
                  "pipelined engine became slower than serial" class of
                  regression, not 10% drift).

Every live section is compared against the floor set matching its
``tiny`` flag; a floored metric more than ``--threshold`` (default 20%)
below its floor fails the gate. Sections or metrics without a floor are
skipped — floors are opt-in and maintained deliberately.

Usage:
  python scripts/check_bench_regression.py [--bench BENCH_ckpt.json]
      [--threshold 0.2]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def check(doc: dict, threshold: float, out=print) -> list:
    failures = []
    checked = 0
    for section, rec in sorted(doc.items()):
        if section.startswith("baseline") or not isinstance(rec, dict):
            continue
        floors_key = "baseline_tiny" if rec.get("tiny") else "baseline"
        floors = doc.get(floors_key, {}).get(section)
        if not floors:
            continue
        unavailable = rec.get("unavailable_metrics") or ()
        for metric, floor in sorted(floors.items()):
            cur = rec.get(metric)
            if not isinstance(cur, (int, float)):
                if metric in unavailable:
                    # the run declared it could not produce this metric
                    # (e.g. zstd-comparison arms without the optional
                    # zstandard package) — skip the floor, don't flag it
                    out(f"  {'skipped':9s} {section}.{metric} "
                        f"(unavailable in the recorded run, floor {floor})")
                    continue
                failures.append(
                    f"{section}.{metric}: missing from the recorded run "
                    f"(floor {floor})")
                continue
            checked += 1
            limit = floor * (1.0 - threshold)
            verdict = "ok" if cur >= limit else "REGRESSED"
            out(f"  {verdict:9s} {section}.{metric} = {cur:.3f} "
                f"(floor {floor} − {threshold:.0%} → {limit:.3f}, "
                f"{floors_key})")
            if cur < limit:
                failures.append(
                    f"{section}.{metric}: {cur:.3f} < {limit:.3f}")
    if not checked:
        failures.append("no floored metrics were checked — did the "
                        "benchmarks run before this gate?")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", type=Path,
                    default=Path(__file__).resolve().parents[1]
                    / "BENCH_ckpt.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="allowed fractional drop below a floor")
    args = ap.parse_args(argv)
    try:
        doc = json.loads(args.bench.read_text())
    except (OSError, ValueError) as e:
        print(f"cannot read {args.bench}: {e}", file=sys.stderr)
        return 1
    print(f"bench regression gate over {args.bench}:")
    failures = check(doc, args.threshold)
    for f in failures:
        print(f"  !! {f}", file=sys.stderr)
    print("gate:", "FAIL" if failures else "PASS")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
