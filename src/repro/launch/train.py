"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a REDUCED config end-to-end on local devices (the full configs are
exercised by the dry-run; this box is CPU-only). Demonstrates the paper's
full production path: restore-on-start → train → periodic async checkpoints
→ preempt-safe exit, with the AOT compile cache standing in for
statically-linked-binary startup.
"""
from __future__ import annotations

import argparse
import logging

from ..configs import ARCH_IDS, get_config, reduced
from ..core.codec import CODECS
from ..train.loop import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--workdir", default="runs/train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--codec", default=None, choices=list(CODECS),
                    help="default: zstd if the zstandard package is "
                         "installed, else raw")
    ap.add_argument("--params-codec", default=None, choices=list(CODECS))
    ap.add_argument("--ckpt-mode", default="full",
                    choices=["full", "incremental"],
                    help="incremental = content-addressed dedup checkpoints")
    ap.add_argument("--chunk-size", type=int, default=1 << 20)
    ap.add_argument("--chunking", default="fixed", choices=["fixed", "cdc"],
                    help="cdc = content-defined chunking (dedup survives "
                         "byte-shifted payloads)")
    ap.add_argument("--scan-backend", default="auto",
                    choices=["auto", "numpy", "jnp", "pallas"],
                    help="cdc candidate-scan engine (auto = accelerated "
                         "for large payloads, numpy oracle below)")
    ap.add_argument("--io-threads", type=int, default=4,
                    help="chunk-IO pipeline width (1 = serial engine)")
    ap.add_argument("--persist-queue-depth", type=int, default=1,
                    help="async checkpoint rounds in flight at once "
                         "(>1 = snapshot round N+1 while round N "
                         "persists)")
    ap.add_argument("--host-bytes-budget", type=int, default=None,
                    help="cap on aggregate host snapshot bytes queued "
                         "rounds may pin (admission blocks instead of "
                         "OOMing the host)")
    ap.add_argument("--streaming-restore", action="store_true",
                    help="begin step 0 once the first-use frontier "
                         "(embedding + block 0) is resident; tail layers "
                         "stream in behind the completion gate")
    ap.add_argument("--remote-dir", default=None,
                    help="mount a cold object-store tier (simulated) at "
                         "this directory — cold restarts pull straight "
                         "from it via multipart ranged reads")
    ap.add_argument("--remote-bw", type=float, default=None,
                    help="remote tier bandwidth in bytes/s "
                         "(default unthrottled)")
    ap.add_argument("--remote-latency", type=float, default=0.0,
                    help="remote tier per-request latency in seconds")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--writers", type=int, default=4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sync-ckpt", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full-size config (only sane on real pods)")
    ap.add_argument("--preset", action="store_true",
                    help="apply the per-arch production parallelism preset")
    args = ap.parse_args(argv)

    logging.basicConfig(
        level=logging.INFO,
        format="%(asctime)s %(name)s %(levelname)s %(message)s")
    cfg = get_config(args.arch)
    if args.preset:
        from dataclasses import replace
        from ..configs.presets import preset_overrides
        ov = preset_overrides(args.arch)
        if ov:
            cfg = replace(cfg, **ov)
    if not args.full_config:
        cfg = reduced(cfg)
    tcfg = TrainerConfig(
        workdir=f"{args.workdir}/{args.arch}", batch=args.batch,
        seq_len=args.seq_len, ckpt_every=args.ckpt_every,
        async_ckpt=not args.sync_ckpt, codec=args.codec,
        params_codec=args.params_codec, ckpt_mode=args.ckpt_mode,
        chunk_size=args.chunk_size, chunking=args.chunking,
        scan_backend=args.scan_backend,
        io_threads=args.io_threads,
        persist_queue_depth=args.persist_queue_depth,
        host_bytes_budget=args.host_bytes_budget, replicas=args.replicas,
        n_writers=args.writers, grad_accum=args.grad_accum, seed=args.seed,
        streaming_restore=args.streaming_restore,
        remote_dir=args.remote_dir, remote_bw=args.remote_bw,
        remote_latency_s=args.remote_latency)
    trainer = Trainer(cfg, tcfg).init_or_restore()
    report = trainer.fit(args.steps)
    print(f"status={report['status']} step={report['step']} "
          f"ckpt={report['ckpt_metrics']}")
    last = trainer.manager.last_report
    if last:
        print(f"last ckpt: step={last['step']} persist={last['seconds']:.3f}s"
              f" blocked={last.get('blocking_s', last['seconds']):.3f}s"
              f" overlapped={last.get('overlapped', False)}")
    if report["history"]:
        print("final:", report["history"][-1])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
