"""Trip-weighted cost analysis over post-SPMD HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — for a
framework whose layers run under ``lax.scan`` that undercounts FLOPs by the
scan length. XLA annotates static trip counts
(``backend_config={"known_trip_count":{"n":...}}``), so we walk the HLO call
graph (ENTRY → while/fusion/call computations), multiply each computation's
intrinsic costs by its execution count, and report:

  * flops            — dot/convolution FLOPs (2·|result|·contraction)
  * hbm_bytes        — Σ (operand + result bytes) over compute ops; fusions
                       count only their boundary traffic (the right HBM model)
  * collectives      — result bytes and ring wire bytes per collective kind

All figures are per-device (the HLO module is the per-device SPMD program).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s([a-z][\w\-]*)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[\\\":{]+n[\\\":]+(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{(.+?)\}\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "copy-start", "copy-done", "iota", "partition-id", "replica-id",
} | set(COLLECTIVE_KINDS) | {k + "-start" for k in COLLECTIVE_KINDS} \
  | {k + "-done" for k in COLLECTIVE_KINDS}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def _split_args(line: str) -> str:
    i = line.find("(")
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return line[i + 1:j]
    return line[i + 1:]


class _Op:
    __slots__ = ("kind", "type_str", "line", "name")

    def __init__(self, name, kind, type_str, line):
        self.name = name
        self.kind = kind
        self.type_str = type_str
        self.line = line


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=")
_ARGNAME_RE = re.compile(r"%([\w.\-]+)")


def _parse_computations(text: str):
    """Returns (comps, symtab): symtab maps op name -> result type string
    (operand shapes are NOT printed inline in compiled HLO dumps)."""
    comps = {}
    symtab = {}
    cur = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line[0].isspace():
            m = _COMP_RE.match(line.strip().rstrip())
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = {"ops": [], "entry": line.lstrip().startswith("ENTRY")}
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            nm = _NAME_RE.match(line)
            name = nm.group(1) if nm else ""
            op = _Op(name, m.group(2), m.group(1), line)
            comps[cur]["ops"].append(op)
            if name:
                symtab[name] = m.group(1)
    return comps, symtab


def _operand_types(op: _Op, symtab: dict):
    args = _split_args(op.line)
    out = []
    for name in _ARGNAME_RE.findall(args):
        t = symtab.get(name)
        if t:
            out.append(t)
    return out


def _dot_flops(op: _Op, symtab: dict) -> float:
    result = 1
    for d in _first_shape_dims(op.type_str):
        result *= d
    lhs_m = _LHS_C_RE.search(op.line)
    contract = 1
    if lhs_m is not None:
        operands = _operand_types(op, symtab)
        if operands:
            lhs_dims = _first_shape_dims(operands[0])
            idxs = [int(i) for i in lhs_m.group(1).split(",") if i]
            for i in idxs:
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * result * contract


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}", 1)[0]
        return first.count(",") + 1
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    return total_devices


def _wire_factor(kind: str, group: int) -> float:
    if group <= 1:
        return 0.0
    f = (group - 1) / group
    if kind == "all-reduce":
        return 2.0 * f
    if kind == "collective-permute":
        return 1.0
    return f


def analyze(hlo_text: str, total_devices: int = 1) -> dict:
    comps, symtab = _parse_computations(hlo_text)

    # computations called by fusion ops / reduction lambdas: their interior
    # ops never touch HBM — flops still count, bytes do not.
    fused_bodies = set()
    lambda_bodies = set()
    for c in comps.values():
        for op in c["ops"]:
            if op.kind == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    fused_bodies.add(m.group(1))
            else:
                m = _TO_APPLY_RE.search(op.line)
                if m:
                    lambda_bodies.add(m.group(1))

    # --- per-computation intrinsic costs and call edges ---
    intr = {}
    edges = defaultdict(list)  # comp -> [(child, mult)]
    for name, c in comps.items():
        flops = 0.0
        bytes_ = 0.0
        count_bytes = name not in fused_bodies and name not in lambda_bodies
        colls = defaultdict(lambda: {"count": 0.0, "result_bytes": 0.0,
                                     "wire_bytes": 0.0, "max_group": 1})
        for op in c["ops"]:
            k = op.kind
            if k in ("dot", "convolution"):
                flops += _dot_flops(op, symtab)
            base = k[:-6] if k.endswith("-start") else k
            if base in COLLECTIVE_KINDS and not k.endswith("-done"):
                g = _group_size(op.line, total_devices)
                nb = _shape_bytes(op.type_str)
                s = colls[base]
                s["count"] += 1
                s["result_bytes"] += nb
                s["wire_bytes"] += nb * _wire_factor(base, g)
                s["max_group"] = max(s["max_group"], g)
            if k == "while":
                t = 1
                m = _TRIP_RE.search(op.line)
                if m:
                    t = int(m.group(1))
                b = _BODY_RE.search(op.line)
                cd = _COND_RE.search(op.line)
                if b:
                    edges[name].append((b.group(1), t))
                if cd:
                    edges[name].append((cd.group(1), t + 1))
            elif k == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    edges[name].append((m.group(1), 1))
            elif k in ("call", "custom-call", "reduce", "scatter", "sort",
                       "map", "reduce-window", "select-and-scatter"):
                m = _TO_APPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
                if m:
                    edges[name].append((m.group(1), 1))
            elif k == "conditional":
                m = _BRANCHES_RE.search(op.line)
                if m:
                    for br in m.group(1).split(","):
                        edges[name].append((br.strip().lstrip("%"), 1))
            if count_bytes and k not in _SKIP_BYTES_OPS:
                operand_bytes = sum(_shape_bytes(t)
                                    for t in _operand_types(op, symtab))
                bytes_ += _shape_bytes(op.type_str) + operand_bytes
        intr[name] = {"flops": flops, "bytes": bytes_, "colls": dict(colls)}

    # --- propagate multipliers from ENTRY ---
    entry = next((n for n, c in comps.items() if c["entry"]), None)
    memo = {}

    def total(name):
        if name in memo:
            return memo[name]
        base = intr.get(name, {"flops": 0.0, "bytes": 0.0, "colls": {}})
        f, b = base["flops"], base["bytes"]
        colls = {k: dict(v) for k, v in base["colls"].items()}
        memo[name] = {"flops": f, "bytes": b, "colls": colls}  # cycle guard
        for child, mult in edges.get(name, ()):
            ct = total(child)
            f += mult * ct["flops"]
            b += mult * ct["bytes"]
            for k, v in ct["colls"].items():
                s = colls.setdefault(k, {"count": 0.0, "result_bytes": 0.0,
                                         "wire_bytes": 0.0, "max_group": 1})
                s["count"] += mult * v["count"]
                s["result_bytes"] += mult * v["result_bytes"]
                s["wire_bytes"] += mult * v["wire_bytes"]
                s["max_group"] = max(s["max_group"], v["max_group"])
        memo[name] = {"flops": f, "bytes": b, "colls": colls}
        return memo[name]

    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collectives": {},
                "wire_bytes": 0.0}
    t = total(entry)
    wire = sum(v["wire_bytes"] for v in t["colls"].values())
    return {
        "flops": t["flops"],
        "hbm_bytes": t["bytes"],
        "collectives": t["colls"],
        "wire_bytes": wire,
    }


def op_census(hlo_text: str, ops=("fusion", "convolution", "dot", "scatter",
                                  "gather", "transpose",
                                  "dynamic-slice", "dynamic-update-slice",
                                  "while", "all-gather", "all-reduce",
                                  "reduce-scatter", "all-to-all",
                                  "collective-permute")):
    counts = {}
    for op in ops:
        counts[op] = len(re.findall(rf"\s{op}\(", hlo_text))
    return counts
