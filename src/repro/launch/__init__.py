from .mesh import HW, make_host_mesh, make_production_mesh

__all__ = ["HW", "make_host_mesh", "make_production_mesh"]
