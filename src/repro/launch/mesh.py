"""Production mesh definition (assigned): 16×16 single-pod, 2×16×16 multi-pod.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    # jax.sharding.AxisType landed after 0.4.37; explicit Auto axis types
    # are the default behaviour on older runtimes anyway
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(shape=None, axes=None):
    """Small mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n, 1), ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


# TPU v5e hardware model for the roofline (assigned constants).
HW = {
    "name": "tpu-v5e",
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link (~per direction)
    "chips_per_pod": 256,
}
