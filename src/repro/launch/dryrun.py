import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: .lower().compile() must succeed on the 16×16 single-pod mesh and
the 2×16×16 multi-pod mesh for every runnable cell, and its
memory_analysis()/cost_analysis()/HLO-collective census feed EXPERIMENTS.md
§Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import (CONFIGS, SHAPES, applicable, get_config, param_counts,
                       reduced)
from ..models import Model
from ..models.model import set_constrainer, set_exec_mesh
from ..optim import make_optimizer
from ..sharding.partition import (act_constrainer, batch_spec, cache_specs,
                                  mesh_axes, param_specs)
from ..core.split_state import (abstract_train_state, state_shardings,
                                with_shardings)
from ..train.steps import make_serve_fns, make_train_step
from .hlo_analysis import analyze, op_census
from .mesh import HW, make_production_mesh

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _sds(shape, dtype, sharding):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _attach(tree, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def input_specs(cfg, shape, mesh):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encoder":
        if shape.kind in ("train",):
            tree = {
                "features": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "mask": jax.ShapeDtypeStruct((B, S), jnp.bool_),
            }
        else:  # encode "prefill"
            tree = {"features": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                     jnp.bfloat16)}
    elif shape.kind == "decode":
        tree = {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
    else:
        tree = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return _attach(tree, batch_spec(tree, mesh, cfg))


def prepare_cell(arch, shape_name, mesh, overrides=None, *,
                 grad_accum=1, accum_dtype=None):
    """Build (jitted_fn, example_args) for one cell, with shardings attached."""
    from dataclasses import replace

    cfg = get_config(arch)
    if overrides:
        overrides = dict(overrides)
        ssm_chunk = overrides.pop("ssm_chunk", None)
        if ssm_chunk and cfg.ssm is not None:
            cfg = replace(cfg, ssm=replace(cfg.ssm, chunk_size=ssm_chunk))
        cfg = replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ax = mesh_axes(mesh)
    if cfg.n_heads and ax.tp > 1 and cfg.n_heads % ax.tp != 0:
        # heads don't divide TP: fall back to sequence-parallel attention
        # (see sharding/partition.py docstring)
        cfg = replace(cfg, seq_shard_attn=True)
    set_constrainer(act_constrainer(cfg, mesh))
    set_exec_mesh(mesh)
    model = Model(cfg)
    optimizer = make_optimizer(cfg)

    if shape.kind == "train":
        state = abstract_train_state(model, optimizer)
        sh = state_shardings(state, mesh, optimizer)
        state = _attach(state, sh)
        batch = input_specs(cfg, shape, mesh)
        step = make_train_step(model, optimizer, grad_accum=grad_accum,
                               accum_dtype=accum_dtype)
        fn = jax.jit(step, donate_argnums=(0,), out_shardings=(sh, None))
        return fn, (state, batch), cfg

    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    psh = param_specs(params, mesh)
    params = _attach(params, psh)

    if shape.kind == "prefill":
        prefill_fn, decode_fn, encode_fn = make_serve_fns(model)
        batch = input_specs(cfg, shape, mesh)
        if cfg.family == "encoder":
            fn = jax.jit(lambda p, feats: encode_fn(p, feats))
            return fn, (params, batch["features"]), cfg
        fn = jax.jit(prefill_fn)
        return fn, (params, batch["tokens"]), cfg

    # decode: one token with a KV cache of seq_len
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    csh = cache_specs(cache, mesh, cfg)
    cache = _attach(cache, csh)
    tokens = input_specs(cfg, shape, mesh)["tokens"]
    _, decode_fn, _ = make_serve_fns(model)
    fn = jax.jit(decode_fn, donate_argnums=(1,))
    return fn, (params, cache, tokens), cfg


def model_flops(cfg, shape) -> float:
    """Assigned formula: 6·N·D (train) / 2·N·D (inference), N = active matmul
    params incl. the LM head, D = tokens processed this step."""
    pc = param_counts(cfg)
    n = pc["n_active_matmul"] + cfg.d_model * cfg.vocab_size
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_cell(arch, shape_name, mesh_kind, *, keep_hlo=False, overrides=None,
             grad_accum=1, accum_dtype=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "skipped", "reason": reason}
    if overrides:
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}
    if grad_accum != 1:
        rec["grad_accum"] = grad_accum
        rec["accum_dtype"] = str(accum_dtype)
    if not ok:
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        fn, args, cfg2 = prepare_cell(arch, shape_name, mesh, overrides,
                                      grad_accum=grad_accum,
                                      accum_dtype=accum_dtype)
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        an = analyze(hlo, total_devices=n_chips)
        census = op_census(hlo)
        # trip-weighted per-device figures (cost_analysis counts loop bodies
        # once — see hlo_analysis docstring); raw values kept for comparison
        flops_dev = an["flops"]
        bytes_dev = an["hbm_bytes"]
        coll = {"per_kind": an["collectives"],
                "wire_bytes_per_device": an["wire_bytes"]}
        mf = model_flops(cfg2, shape)
        terms = roofline_terms(flops_dev, bytes_dev,
                               coll["wire_bytes_per_device"])
        rec.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "n_chips": n_chips,
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "raw_cost_analysis": {
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                "transcendentals": float(ca.get("transcendentals", 0.0)),
            },
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes_est": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            "collectives": coll,
            "op_census": census,
            "model_flops_global": mf,
            "model_flops_per_device": mf / n_chips,
            "useful_flops_fraction": (mf / n_chips) / flops_dev
            if flops_dev else 0.0,
            "roofline": terms,
        })
        if keep_hlo:
            hdir = ART_DIR / "hlo"
            hdir.mkdir(parents=True, exist_ok=True)
            (hdir / f"{arch}__{shape_name}__{mesh_kind}.txt").write_text(hlo)
    except Exception as e:  # noqa
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    finally:
        set_constrainer(None)
        set_exec_mesh(None)
    return rec


def roofline_terms(flops_dev, bytes_dev, wire_bytes_dev):
    t_c = flops_dev / HW["peak_flops_bf16"]
    t_m = bytes_dev / HW["hbm_bw"]
    t_n = wire_bytes_dev / HW["ici_bw"]
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))
    step = max(t_c, t_m, t_n)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom[1],
        "bound_step_s": step,
        "roofline_fraction": (t_c / step) if step else 0.0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--out", default=str(ART_DIR))
    ap.add_argument("--preset", action="store_true",
                    help="apply the per-arch production parallelism preset "
                         "(configs/presets.py; the §Perf winners)")
    ap.add_argument("--moe-impl", default=None, choices=["gspmd", "shard_map"])
    ap.add_argument("--dp-over-model", action="store_true")
    ap.add_argument("--remat", default=None,
                    choices=["nothing", "dots", "full", "offload_resid"])
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--seq-shard-resid", action="store_true")
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--accum-dtype", default=None)
    args = ap.parse_args()

    overrides = {}
    if args.moe_impl:
        overrides["moe_impl"] = args.moe_impl
    if args.dp_over_model:
        overrides["dp_over_model"] = True
    if args.remat:
        overrides["remat_policy"] = args.remat
    if args.attn_chunk:
        overrides["attn_chunk"] = args.attn_chunk
    if args.seq_shard_resid:
        overrides["seq_shard_resid"] = True
    if args.ssm_chunk:
        overrides["ssm_chunk"] = args.ssm_chunk

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in sorted(CONFIGS) for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape_name in cells:
        for mk in meshes:
            path = out_dir / f"{arch}__{shape_name}__{mk}.json"
            cell_over = dict(overrides)
            if args.preset:
                from ..configs.presets import preset_overrides
                cell_over = {**preset_overrides(arch), **cell_over}
            rec = run_cell(arch, shape_name, mk, keep_hlo=args.keep_hlo,
                           overrides=cell_over or None,
                           grad_accum=args.grad_accum,
                           accum_dtype=args.accum_dtype)
            path.write_text(json.dumps(rec, indent=1))
            tag = rec["status"]
            extra = ""
            if tag == "ok":
                r = rec["roofline"]
                extra = (f" compile={rec['compile_s']}s"
                         f" dom={r['dominant']}"
                         f" frac={r['roofline_fraction']:.2f}"
                         f" mem={rec['memory']['peak_bytes_est']/2**30:.2f}GiB")
            elif tag == "error":
                n_fail += 1
                extra = " " + rec["error"][:160]
            print(f"[{tag:7s}] {arch} × {shape_name} × {mk}{extra}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
