"""Checkpoint inspector / fsck — operational tooling for the C/R system.

The paper's production-hardening lessons (annotated region tables, attention
to warnings, debuggability) imply an operator workflow: before relying on a
checkpoint for a restart, *verify* it. This tool:

  * lists committed steps, the LATEST pointer, staging-dir litter;
  * prints the manifest summary (arch, config digest, lower-half descriptor,
    bytes by state role from the region registry);
  * ``--verify`` reads every shard (including buddy replicas), checks CRCs,
    and reports coverage per leaf — exit code 1 on any damage, so it slots
    into restart automation.

Usage:
  PYTHONPATH=src python -m repro.launch.inspect_ckpt <ckpt-root> [--step N]
      [--verify]
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from pathlib import Path

from ..core import atomic
from ..core.checkpoint import _unpack_shard
from ..core.elastic import ShardRange
from ..core.namespace import REPLICA_SUFFIX


def inspect(root: Path, step=None, verify=False, out=print):
    report = {"root": str(root), "ok": True, "problems": []}
    latest = atomic.read_latest(root)
    steps = atomic.list_committed_steps(root)
    staging = [d.name for d in root.iterdir()
               if d.is_dir() and ".tmp-" in d.name] if root.exists() else []
    report.update(latest=latest, steps=steps, staging=staging)
    out(f"checkpoint root: {root}")
    out(f"  committed steps: {steps or 'none'}   LATEST -> {latest}")
    if staging:
        out(f"  ! {len(staging)} orphaned staging dir(s) (crash litter; "
            f"gc with atomic.gc_staging)")
    if latest is not None and latest not in steps:
        report["problems"].append(f"LATEST={latest} is not a committed step")
    step = step if step is not None else latest
    if step is None:
        report["ok"] = not report["problems"]
        return report

    mdir = root / f"step_{step:08d}"
    manifest = json.loads((mdir / atomic.MANIFEST).read_text())
    extra = manifest.get("extra", {})
    out(f"  step {step}: format v{manifest['format']}  "
        f"arch={extra.get('arch', '?')}  "
        f"config={extra.get('config_digest', '?')[:12]}")
    lh = extra.get("lower_half", {})
    if lh:
        out(f"  lower half at save (informational): mesh="
            f"{lh.get('mesh_shape')} axes={lh.get('mesh_axes')} "
            f"{lh.get('runtime')}")
    by_role = defaultdict(lambda: [0, 0])
    for row in manifest.get("registry", []):
        by_role[row["role"]][0] += 1
        by_role[row["role"]][1] += row["nbytes"]
    for role, (n, b) in sorted(by_role.items()):
        out(f"    {role:8s} {n:5d} regions  {b/2**20:10.2f} MiB")
    n_shards = sum(len(r["shards"]) for r in manifest["leaves"].values())
    out(f"    {len(manifest['leaves'])} leaves, {n_shards} shards")
    report.update(step=step, leaves=len(manifest["leaves"]),
                  shards=n_shards, roles={k: v[1] for k, v in by_role.items()})

    if verify:
        good = bad = missing = replicas_ok = 0
        for name, rec in manifest["leaves"].items():
            covered = []
            for s in rec["shards"]:
                readable = False
                for i, fname in enumerate(s.get("replicas", [s["file"]])):
                    p = mdir / fname
                    if not p.exists():
                        continue
                    try:
                        rng, arr = _unpack_shard(p.read_bytes())
                        readable = True
                        if i > 0:
                            replicas_ok += 1
                        break
                    except Exception as e:  # noqa
                        report["problems"].append(
                            f"{name}: {fname}: {type(e).__name__}")
                if readable:
                    good += 1
                    covered.append(ShardRange(tuple(s["start"]),
                                              tuple(s["stop"])))
                else:
                    bad += 1
                    report["problems"].append(
                        f"{name}: shard {s['file']} unreadable on all "
                        f"replicas")
        out(f"  verify: {good} shard(s) ok, {bad} damaged"
            + (f", {replicas_ok} recovered via buddy replica"
               if replicas_ok else ""))
        report.update(verified=True, shards_ok=good, shards_bad=bad)
    report["ok"] = not report["problems"]
    for p in report["problems"]:
        out(f"  !! {p}")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("root", type=Path)
    ap.add_argument("--step", type=int, default=None)
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    sink = (lambda *_: None) if args.json else print
    rep = inspect(args.root, step=args.step, verify=args.verify, out=sink)
    if args.json:
        print(json.dumps(rep, indent=1, default=str))
    return 0 if rep["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
