"""Checkpoint inspector / fsck — operational tooling for the C/R system.

The paper's production-hardening lessons (annotated region tables, attention
to warnings, debuggability) imply an operator workflow: before relying on a
checkpoint for a restart, *verify* it. This tool:

  * lists committed steps, the LATEST pointer, staging-dir litter;
  * prints the manifest summary (arch, config digest, lower-half descriptor,
    bytes by state role from the region registry);
  * for incremental (v3 chunked) checkpoints: chunk-level stats — object
    count/bytes in the content-addressed store, per-step dedup ratio
    (logical payload bytes ÷ unique chunk bytes), orphaned / missing /
    refcount-drifted objects;
  * ``--verify`` reads every shard (including buddy replicas; chunked shards
    resolve and digest-check every chunk), checks CRCs, and reports coverage
    per leaf — exit code 1 on any damage, so it slots into restart
    automation.

Usage:
  PYTHONPATH=src python -m repro.launch.inspect_ckpt <ckpt-root> [--step N]
      [--verify] [--scrub] [--health] [--subscribers]
"""
from __future__ import annotations

import argparse
import json
import sys
import zlib
from collections import defaultdict
from pathlib import Path

from ..core import atomic, cas
from ..core.checkpoint import _unpack_shard
from ..core.codec import CHUNK_ENCODED, _np_dtype, entropy_block_stats
from ..core.codec import decode as codec_decode
from ..core.elastic import ShardRange
from ..core.namespace import REPLICA_SUFFIX
from ..core.storage import RemoteTier, Tier, TieredStore


def _chunk_store(root: Path) -> cas.ChunkStore:
    return cas.ChunkStore(TieredStore(Tier("inspect", root)))


def _tiered_store(root: Path, slow_root: Path | None = None,
                  remote_root: Path | None = None) -> TieredStore:
    """The cross-tier store view the scrub/health commands operate on —
    tier names match the runtime's default hierarchy roles."""
    return TieredStore(
        Tier("fast", root),
        Tier("slow", slow_root) if slow_root else None,
        drain_async=False,
        remote=RemoteTier("remote", remote_root) if remote_root else None)


def _all_manifests(store: TieredStore) -> list:
    """Every committed manifest on every mounted tier (deduped by step) —
    the scrub's mark set must span the whole hierarchy or a slow-tier-only
    step's chunks would read as dead."""
    manifests, seen = [], set()
    for tier in store.tiers():
        for s in atomic.list_committed_steps(tier.root):
            if s in seen:
                continue
            try:
                manifests.append(json.loads(
                    (atomic.committed_dir(tier.root, s) /
                     atomic.MANIFEST).read_text()))
                seen.add(s)
            except (OSError, ValueError):
                pass            # other tiers may hold a readable copy
    return manifests


def run_scrub(root: Path, slow_root: Path | None = None,
              remote_root: Path | None = None, sample: int | None = None,
              seed: int = 0, out=print) -> dict:
    """``inspect_ckpt --scrub``: re-hash live objects across the mounted
    tiers, quarantine corrupt copies (never the last one) and heal from a
    good replica/tier. Persists ``_CAS/last_scrub.json``."""
    store = _tiered_store(root, slow_root, remote_root)
    chunks = cas.ChunkStore(store)
    live = cas.live_chunk_refs(_all_manifests(store))
    rep = chunks.scrub(live, sample=sample, seed=seed)
    try:
        atomic.atomic_write_bytes(store.fast.root / cas.SCRUB_FILE,
                                  json.dumps(rep).encode())
    except OSError:
        pass
    out(f"scrub: {rep['scanned']} scanned, {rep['clean']} clean, "
        f"{rep['healed']} healed, {rep['quarantined']} quarantined, "
        f"{rep['unrecoverable']} unrecoverable")
    rep["ok"] = rep["unrecoverable"] == 0
    return rep


def run_health(root: Path, slow_root: Path | None = None,
               remote_root: Path | None = None, out=print) -> dict:
    """``inspect_ckpt --health``: the persisted per-tier error counters +
    circuit-breaker state (``_CAS/health.json``), the last scrub summary
    (``_CAS/last_scrub.json``), and the quarantine contents with digests.
    Reads files only — the writer process owns the live counters."""
    store = _tiered_store(root, slow_root, remote_root)
    chunks = cas.ChunkStore(store)
    rep: dict = {"tiers": {}, "last_scrub": None, "quarantine": []}
    tier = store.locate(cas.HEALTH_FILE)
    if tier is not None:
        try:
            rep["tiers"] = json.loads(tier.read_file(cas.HEALTH_FILE))
        except (OSError, ValueError):
            pass
    tier = store.locate(cas.SCRUB_FILE)
    if tier is not None:
        try:
            rep["last_scrub"] = json.loads(tier.read_file(cas.SCRUB_FILE))
        except (OSError, ValueError):
            pass
    for tier_name, rel, digest, replica, size in chunks.quarantine_entries():
        rep["quarantine"].append(
            {"tier": tier_name, "rel": rel, "digest": digest,
             "replica": replica, "bytes": size})
    if not rep["tiers"]:
        out("health: no recorded tier health (run a save or maintenance "
            "pass first)")
    for name, snap in rep["tiers"].items():
        br = snap.get("breaker", {})
        counters = snap.get("counters", {})
        errs = sum(v for k, v in counters.items() if k.endswith("_errors"))
        retries = sum(v for k, v in counters.items()
                      if k.endswith("_retries"))
        out(f"  tier {name}: breaker {br.get('state', '?')} "
            f"({br.get('trips', 0)} trip(s)), {errs} error(s), "
            f"{retries} retried")
        for k in sorted(counters):
            out(f"    {k}: {counters[k]}")
    ls = rep["last_scrub"]
    if ls:
        out(f"  last scrub: {ls.get('scanned', 0)} scanned, "
            f"{ls.get('healed', 0)} healed, "
            f"{ls.get('quarantined', 0)} quarantined, "
            f"{ls.get('unrecoverable', 0)} unrecoverable "
            f"(seed {ls.get('seed')})")
    out(f"  quarantine: {len(rep['quarantine'])} entr"
        f"{'y' if len(rep['quarantine']) == 1 else 'ies'}")
    for q in rep["quarantine"]:
        out(f"    [{q['tier']}] {q['digest']} (replica {q['replica']}, "
            f"{q['bytes']} B) -> {q['rel']}")
    rep["ok"] = not any(
        s.get("breaker", {}).get("state") == "open"
        for s in rep["tiers"].values())
    return rep


def run_subscribers(root: Path, out=print) -> dict:
    """``inspect_ckpt --subscribers``: the WeightSync view of this store —
    the current announcement (``_WS/ANNOUNCE``) and every replica status
    file subscribers publish after each sync (``_WS/subscribers/*.json``).
    Per replica: live/degraded state, last flipped step vs the announced
    one, cache residency, wire-byte split (peer vs source) and the last
    error if it is holding last-good. Exit 1 if any replica is degraded
    or lagging the announcement."""
    from ..core.weightsync import ANNOUNCE_REL, SUBSCRIBERS_DIR
    rep: dict = {"announce": None, "subscribers": []}
    try:
        rep["announce"] = json.loads((root / ANNOUNCE_REL).read_text())
    except (OSError, ValueError):
        pass
    ann = rep["announce"]
    if ann:
        out(f"  announce: step {ann.get('step')} seq {ann.get('seq')} "
            f"({ann.get('step_dir')})")
    else:
        out("  announce: none (no publisher has committed here)")
    sdir = root / SUBSCRIBERS_DIR
    for p in sorted(sdir.glob("*.json")) if sdir.is_dir() else []:
        try:
            rep["subscribers"].append(json.loads(p.read_text()))
        except (OSError, ValueError):
            rep["subscribers"].append(
                {"name": p.stem, "state": "unreadable"})
    if not rep["subscribers"]:
        out("  subscribers: none published")
    lagging = 0
    for s in rep["subscribers"]:
        c = s.get("counters", {})
        wire = c.get("wire_bytes", 0)
        peer = c.get("peer_bytes", 0)
        lag = (ann is not None and s.get("last_flipped_step") is not None
               and s["last_flipped_step"] < int(ann["step"]))
        bad = s.get("state") != "live" or lag
        lagging += bad
        out(f"  {'!' if bad else ' '} {s.get('name', '?'):16s} "
            f"{s.get('state', '?'):9s} step {s.get('last_flipped_step')}"
            + (f" (announced {ann['step']})" if lag else "")
            + f"  cache {s.get('cache_chunks', 0)} chunk(s) "
            f"{s.get('cache_bytes', 0)/2**20:.2f} MiB  "
            f"wire {wire/2**20:.2f} MiB "
            f"({peer/max(wire, 1)*100:.0f}% peer)  "
            f"syncs {c.get('syncs', 0)} flips {c.get('flips', 0)}")
        if s.get("last_error"):
            out(f"      last_error: {s['last_error']}")
    rep["ok"] = not lagging
    return rep


def _cas_report(root: Path, manifests: list, deep: bool = False,
                covered=frozenset()) -> dict:
    """Chunk-level stats for one storage root. The inspector sees a single
    tier, but the store may span several (burst buffer + scratch keep
    manifests with different retention), so the published ``refs.json`` —
    the last cross-tier mark set — also vouches for liveness: an object is
    an orphan only if neither this root's manifests nor the published refs
    reference it, and refcount drift is only flagged when refs UNDERCOUNT
    what this root's manifests require (overcounts are other tiers' steps).

    ``deep`` (--verify) reads + re-hashes live objects; the default
    status listing checks existence only, so plain inspect stays a
    metadata operation. ``covered`` digests — the ones the inspected
    step's own shard records reference — are skipped by the deep pass
    (existence check only): the per-shard crc/decode verification reads
    and digest-checks every one of them anyway, and reading them twice
    doubled verify IO."""
    store = _chunk_store(root)
    live = cas.live_chunk_refs(manifests)
    refs = store.load_refs()
    published = {d for d, n in refs.items() if n > 0}
    on_disk = store.digests_on_disk()
    missing = []
    deep_reads = 0
    for d in sorted(set(live)):
        if deep and d not in covered:
            deep_reads += 1
            try:
                store.get(d)
            except Exception:  # noqa — unreadable on this root, any cause
                missing.append(d)
        elif d not in on_disk:
            missing.append(d)
    orphans = sorted(on_disk - set(live) - published)
    drift = {d: (refs.get(d, 0), n) for d, n in live.items()
             if refs.get(d, 0) < n}
    stats = store.stats()
    return {
        "objects": stats["objects"],
        "object_bytes": stats["bytes"],
        "references": sum(live.values()),
        "orphans": len(orphans),
        "missing": len(missing),
        "ref_drift": len(drift),
        "deep_reads": deep_reads,
        "ok": not (orphans or missing or drift),
    }


def _codec_report(mdir: Path, manifest: dict, report: dict, out) -> None:
    """Per-codec encoded-vs-raw byte totals for the inspected step — the
    effective compression each codec delivered ON THIS DATA (a lossless
    pre-conditioner like ``byteplane`` is exactly 1.00x here; its payoff
    shows in the -zstd variant's ratio and in dedup). Chunked records
    carry ``payload_bytes`` in the manifest; inline (full-mode) shards
    cost one 4-byte header-length read each — no payload IO."""
    per: defaultdict = defaultdict(lambda: [0, 0, 0])  # shards, raw, enc
    for rec in manifest["leaves"].values():
        for s in rec["shards"]:
            shape = ShardRange(tuple(s["start"]), tuple(s["stop"])).shape
            numel = 1
            for d in shape:
                numel *= d
            raw = numel * _np_dtype(s["dtype"]).itemsize
            enc = s.get("payload_bytes")
            if enc is None and s.get("chunk_lens"):
                enc = sum(s["chunk_lens"])
            if enc is None and "chunks" not in s:
                for fname in s.get("replicas", [s["file"]]):
                    p = mdir / fname
                    if p.exists():
                        with p.open("rb") as f:
                            hlen = int.from_bytes(f.read(4), "little")
                        enc = p.stat().st_size - 4 - hlen
                        break
            if enc is None:            # v3/v4 chunked record, sizes unknown
                continue
            ent = per[s["codec"]]
            ent[0] += 1
            ent[1] += raw
            ent[2] += enc
    if not per:
        return
    report["codecs"] = {
        c: {"shards": n, "raw_bytes": raw, "encoded_bytes": enc,
            "ratio": round(raw / max(enc, 1), 3)}
        for c, (n, raw, enc) in sorted(per.items())}
    for c, (n, raw, enc) in sorted(per.items()):
        out(f"    codec {c:15s} {n:5d} shard(s)  "
            f"{raw/2**20:10.2f} MiB raw -> {enc/2**20:10.2f} MiB encoded  "
            f"({raw/max(enc, 1):.2f}x)")


def _entropy_planes(payload, raw_len: int, k: int, codec: str,
                    table) -> None:
    """Fold one chunk-encoded shard's block stats into the per-plane
    table: ``table[(codec, plane)] = [raw, encoded, blocks, n_raw_escape,
    n_rle, n_rans]``. The transformed stream lays the k byteplanes out
    contiguously (plane p = bytes ``p*(n//k) .. (p+1)*(n//k)``, ragged
    tail passed through at the end), so a block's plane is a pure
    function of its absolute raw offset; a block straddling a plane
    boundary is attributed to the plane holding its start."""
    plane_len = raw_len // max(k, 1)
    for off, blen, flag, enc_len in entropy_block_stats(payload, raw_len):
        if plane_len and off >= plane_len * k:
            plane = "tail"
        else:
            plane = min(off // plane_len, k - 1) if plane_len else 0
        ent = table[(codec, plane)]
        ent[0] += blen
        ent[1] += 3 + enc_len
        ent[2] += 1
        ent[3 + flag] += 1


def _emit_entropy_planes(table, report: dict, out) -> None:
    """Per-plane raw/encoded bytes and escape counts for the chunk-
    encoded codecs — the operator view of WHERE the entropy stage bites
    (sign/exponent planes compress; mantissa planes escape to raw)."""
    if not table:
        return
    planes = {}
    for (codec, plane), (raw, enc, nb, n_raw, n_rle, n_rans) \
            in sorted(table.items(), key=lambda kv: (kv[0][0],
                                                     str(kv[0][1]))):
        planes.setdefault(codec, {})[str(plane)] = {
            "raw_bytes": raw, "encoded_bytes": enc, "blocks": nb,
            "raw_escape_blocks": n_raw, "rle_blocks": n_rle,
            "rans_blocks": n_rans}
        out(f"    plane {codec}[{plane}]: "
            f"{raw/2**20:8.2f} MiB raw -> {enc/2**20:8.2f} MiB encoded "
            f"({raw/max(enc, 1):.2f}x)  blocks {nb} "
            f"[raw-escape {n_raw}, rle {n_rle}, rans {n_rans}]")
    report["entropy_planes"] = planes


def _step_dedup(root: Path, manifest: dict) -> dict | None:
    """Per-step dedup ratio: logical payload bytes of the step's chunked
    shards ÷ unique chunk object bytes they reference. Also counts shard
    records per chunking scheme (v4; v3 records are implicitly fixed)."""
    digests: set = set()
    payload = 0
    n_chunked = 0
    schemes: defaultdict = defaultdict(int)
    for rec in manifest["leaves"].values():
        for s in rec["shards"]:
            if "chunks" not in s:
                continue
            n_chunked += 1
            payload += s.get("payload_bytes", 0)
            digests.update(s["chunks"])
            schemes[s.get("chunking", "fixed")] += 1
    if not n_chunked:
        return None
    uniq = 0
    for d in digests:
        p = root / cas.object_rel(d)
        if not p.exists():              # primary lost, buddy replica serves
            p = root / cas.object_rel(d, 1)
        if p.exists():
            uniq += p.stat().st_size
    return {"chunked_shards": n_chunked, "chunks": len(digests),
            "payload_bytes": payload, "unique_chunk_bytes": uniq,
            "chunking": dict(schemes),
            "dedup_ratio": payload / max(uniq, 1)}


def _chunk_histogram(root: Path, manifest: dict, deep: bool = False) -> dict:
    """Per-scheme chunk-size distribution (p10/p50/p90) vs the configured
    bounds — misconfigured CDC bounds (avg too small for the leaf sizes,
    max force-cutting everything) show up here during fsck instead of as
    silent dedup loss.

    Sizes come free for v5 CDC records (``chunk_lens``) and fixed records
    (derived from ``chunk_size``); for older CDC records (v4 — no length
    lists) sizes require a stat per unique object, so those are only
    collected under ``--verify`` (``deep``)."""
    import numpy as np
    sizes: defaultdict = defaultdict(list)
    stat_digests: defaultdict = defaultdict(set)
    for rec in manifest["leaves"].values():
        for s in rec["shards"]:
            if "chunks" not in s:
                continue
            scheme = s.get("chunking", "fixed")
            lens = s.get("chunk_lens")
            if lens:
                sizes[scheme].extend(lens)
            elif scheme == "fixed" and s.get("chunk_size") \
                    and s.get("payload_bytes") is not None:
                k, payload = len(s["chunks"]), s["payload_bytes"]
                if k:
                    sizes[scheme].extend(
                        [s["chunk_size"]] * (k - 1)
                        + [payload - (k - 1) * s["chunk_size"]])
            elif deep:
                stat_digests[scheme].update(s["chunks"])
    for scheme, digests in stat_digests.items():
        for d in digests:
            p = root / cas.object_rel(d)
            if not p.exists():
                p = root / cas.object_rel(d, 1)
            if p.exists():
                sizes[scheme].append(p.stat().st_size)
    bounds = manifest.get("chunk_bounds")
    out = {}
    for scheme, ss in sorted(sizes.items()):
        if not ss:
            continue
        p10, p50, p90 = (int(v) for v in
                         np.percentile(ss, [10, 50, 90]))
        ent = {"chunks": len(ss), "p10": p10, "p50": p50, "p90": p90}
        if scheme == "cdc" and bounds:
            ent["configured"] = {"min": bounds[0], "avg": bounds[1],
                                 "max": bounds[2]}
        elif scheme == "fixed" and manifest.get("chunk_size"):
            ent["configured"] = {"size": manifest["chunk_size"]}
        out[scheme] = ent
    return out


def _policy_block(manifest: dict, report: dict, out) -> None:
    """Print the policy a v6 manifest embeds (the writer's effective
    configuration — what a zero-config restart will adopt). A corrupted
    block degrades to a WARNING, never a crash: restore does not depend
    on it (shard records are self-describing), so the inspector must not
    either. v≤5 manifests simply predate the block."""
    fmt = int(manifest.get("format", 0))
    if fmt < 6:
        out("  policy: not recorded (v≤5)")
        return
    try:
        from ..core.policy import CheckpointPolicy
        block = manifest.get("policy")
        if not isinstance(block, dict):
            raise ValueError("policy block missing or not a mapping")
        p = CheckpointPolicy.from_dict(block)
        report["policy"] = p.to_dict()
        ck, pl, du, co = p.chunking, p.pipeline, p.durability, p.codec
        out(f"  policy: mode={p.mode} writers={p.n_writers} "
            f"codec={co.codec or 'auto'}/{co.params_codec or 'auto'}")
        out(f"    chunking={ck.scheme}@{ck.chunk_size/2**10:.0f}K "
            f"scan={ck.scan_backend}  io_threads={pl.io_threads} "
            f"persist_queue={pl.persist_queue_depth}"
            + (f" host_budget={pl.host_bytes_budget/2**20:.0f}M"
               if pl.host_bytes_budget else "")
            + f"  replicas={du.replicas} retain={du.retain}")
    except Exception as e:  # noqa — untrusted manifest content, any shape
        report["policy_error"] = f"{type(e).__name__}: {e}"
        out(f"  ! policy block unreadable ({type(e).__name__}: {e}) — "
            f"restore is unaffected (shard records are self-describing); "
            f"zero-config restarts will NOT auto-adopt the writer's "
            f"settings for this step")


def _step_rels(manifest: dict, step_dir: str) -> list:
    """Every storage-relative path the inspected step depends on: its
    inline shard files (all replicas) plus the unique chunk objects its
    chunked shards reference."""
    rels: list = []
    digests: set = set()
    for rec in manifest["leaves"].values():
        for s in rec["shards"]:
            if "chunks" in s:
                digests.update(s["chunks"])
            else:
                for fname in s.get("replicas", [s["file"]]):
                    rels.append(f"{step_dir}/{fname}")
    rels.extend(cas.object_rel(d) for d in sorted(digests))
    return rels


def _tier_residency(tier_roots: dict, manifest: dict, step_dir: str,
                    report: dict, out) -> None:
    """Per-tier residency of the inspected step — how many of its files
    (shards + chunk objects) each tier holds. The restore hierarchy reads
    fast → slow → remote, so `fast 0/N, remote N/N` is the cold-restart
    shape: every byte will stream off the object store's ranged reads."""
    rels = _step_rels(manifest, step_dir)
    if not rels:
        return
    res = {}
    for name, root in tier_roots.items():
        if root is None:
            continue
        root = Path(root)
        present = sum(1 for r in rels if (root / r).exists())
        res[name] = {"present": present, "total": len(rels)}
    report["residency"] = res
    out("    residency: " + "  ".join(
        f"{name} {v['present']}/{v['total']}" for name, v in res.items()))


def _pending_rounds(root: Path, staging: list) -> list:
    """In-flight (pending-stage) rounds: staging dirs whose PENDING marker
    still parses. An overlapped save(blocking=False) legitimately keeps
    one of these alive while it persists in the background — the operator
    needs the owning step and its AGE to tell a live round from crash
    litter, not a blanket 'orphaned' verdict."""
    import time
    rounds = []
    for name in staging:
        marker = root / name / atomic.PENDING
        try:
            info = json.loads(marker.read_text())
            rounds.append({"dir": name, "step": int(info.get("step", -1)),
                           "age_s": round(max(time.time()
                                              - float(info.get("t", 0)), 0),
                                          1)})
        except (OSError, ValueError):
            # no/torn marker: either mid-commit (marker already cleared,
            # rename pending) or true litter — listed, but age unknown
            rounds.append({"dir": name, "step": None, "age_s": None})
    return sorted(rounds, key=lambda r: (r["age_s"] is None,
                                         -(r["age_s"] or 0)))


def inspect(root: Path, step=None, verify=False, out=print,
            slow_root: Path | None = None, remote_root: Path | None = None):
    report = {"root": str(root), "ok": True, "problems": []}
    latest = atomic.read_latest(root)
    steps = atomic.list_committed_steps(root)
    staging = [d.name for d in root.iterdir()
               if d.is_dir() and ".tmp-" in d.name] if root.exists() else []
    report.update(latest=latest, steps=steps, staging=staging)
    out(f"checkpoint root: {root}")
    out(f"  committed steps: {steps or 'none'}   LATEST -> {latest}")
    if staging:
        pending = _pending_rounds(root, staging)
        report["pending_rounds"] = pending
        for pr in pending:
            if pr["age_s"] is not None:
                out(f"  ~ in-flight round: step {pr['step']} "
                    f"age {pr['age_s']}s ({pr['dir']}) — an overlapped "
                    f"save in progress, or crash litter if the age keeps "
                    f"growing")
            else:
                out(f"  ! staging dir without a readable PENDING marker: "
                    f"{pr['dir']} (mid-commit or crash litter; "
                    f"gc with atomic.gc_staging)")
    if latest is not None and latest not in steps:
        report["problems"].append(f"LATEST={latest} is not a committed step")
    step = step if step is not None else latest
    if step is None:
        report["ok"] = not report["problems"]
        return report

    mdir = root / f"step_{step:08d}"
    manifest = json.loads((mdir / atomic.MANIFEST).read_text())
    extra = manifest.get("extra", {})
    out(f"  step {step}: format v{manifest['format']}  "
        f"mode={manifest.get('mode', 'full')}  "
        f"arch={extra.get('arch', '?')}  "
        f"config={extra.get('config_digest', '?')[:12]}")
    _policy_block(manifest, report, out)
    lh = extra.get("lower_half", {})
    if lh:
        out(f"  lower half at save (informational): mesh="
            f"{lh.get('mesh_shape')} axes={lh.get('mesh_axes')} "
            f"{lh.get('runtime')}")
    by_role = defaultdict(lambda: [0, 0])
    for row in manifest.get("registry", []):
        by_role[row["role"]][0] += 1
        by_role[row["role"]][1] += row["nbytes"]
    for role, (n, b) in sorted(by_role.items()):
        out(f"    {role:8s} {n:5d} regions  {b/2**20:10.2f} MiB")
    n_shards = sum(len(r["shards"]) for r in manifest["leaves"].values())
    out(f"    {len(manifest['leaves'])} leaves, {n_shards} shards")
    report.update(step=step, leaves=len(manifest["leaves"]),
                  shards=n_shards, mode=manifest.get("mode", "full"),
                  roles={k: v[1] for k, v in by_role.items()})
    _codec_report(mdir, manifest, report, out)
    _tier_residency({"fast": root, "slow": slow_root,
                     "remote": remote_root},
                    manifest, mdir.name, report, out)

    dedup = _step_dedup(root, manifest)
    if dedup is not None:
        report["dedup"] = dedup
        schemes = "+".join(f"{v}×{k}" for k, v in
                           sorted(dedup["chunking"].items()))
        out(f"    chunked: {dedup['chunked_shards']} shard(s) [{schemes}], "
            f"{dedup['chunks']} unique chunk(s), dedup ratio "
            f"{dedup['dedup_ratio']:.2f}x "
            f"({dedup['payload_bytes']/2**20:.2f} MiB logical / "
            f"{dedup['unique_chunk_bytes']/2**20:.2f} MiB stored)")
        hist = _chunk_histogram(root, manifest, deep=verify)
        if hist:
            report["chunk_hist"] = hist
            for scheme, h in hist.items():
                cfg = h.get("configured", {})
                cfg_s = ("  configured " + "/".join(
                    f"{k}={v/2**10:.0f}K" for k, v in cfg.items())
                    if cfg else "")
                out(f"    {scheme} chunk sizes: p10 {h['p10']/2**10:.1f}K  "
                    f"p50 {h['p50']/2**10:.1f}K  p90 {h['p90']/2**10:.1f}K"
                    f"{cfg_s}")
    if (root / cas.CAS_DIR).exists():
        # manifests are only needed for the CAS mark set — full-mode roots
        # skip these reads entirely. An unreadable historical manifest is a
        # damage finding under --verify, informational otherwise (the
        # plain listing is a status query about the inspected step).
        all_manifests = []
        for s in steps:
            try:
                all_manifests.append(json.loads(
                    (root / f"step_{s:08d}" / atomic.MANIFEST).read_text()))
            except (OSError, ValueError):
                if verify:
                    report["problems"].append(
                        f"step {s}: unreadable manifest")
        # the per-shard verify pass below reads + digest-checks every chunk
        # the inspected step references — the deep CAS pass only needs to
        # read the digests OTHER retained steps pin (halves verify IO)
        covered = {d for rec in manifest["leaves"].values()
                   for s in rec["shards"] if "chunks" in s
                   for d in s["chunks"]} if verify else frozenset()
        report["cas"] = _cas_report(root, all_manifests, deep=verify,
                                    covered=covered)
        c = report["cas"]
        out(f"    CAS: {c['objects']} object(s) "
            f"{c['object_bytes']/2**20:.2f} MiB, "
            f"{c['references']} reference(s), {c['orphans']} orphan(s), "
            f"{c['missing']} missing, {c['ref_drift']} ref drift(s)")
        if verify:
            if c["missing"]:
                report["problems"].append(
                    f"CAS: {c['missing']} referenced chunk object(s) missing")
            if c["orphans"]:
                report["problems"].append(
                    f"CAS: {c['orphans']} orphaned chunk object(s) "
                    f"(unreclaimed by GC)")
            if c["ref_drift"]:
                report["problems"].append(
                    f"CAS: refs.json drifts from committed manifests on "
                    f"{c['ref_drift']} digest(s) (stale cache; next GC "
                    f"repairs)")

    if verify:
        chunk_store = _chunk_store(root)
        good = bad = replicas_ok = 0
        plane_table: defaultdict = defaultdict(lambda: [0] * 6)
        for name, rec in manifest["leaves"].items():
            for s in rec["shards"]:
                if "chunks" in s:
                    try:
                        payload = chunk_store.read_payload(
                            s["chunks"], s.get("payload_bytes"))
                        if (zlib.crc32(payload) & 0xFFFFFFFF) != s["crc32"]:
                            raise ValueError("payload crc mismatch")
                        rng = ShardRange(tuple(s["start"]), tuple(s["stop"]))
                        codec_decode(payload, s["codec"], rng.shape,
                                     s["dtype"], s.get("meta", {}))
                        if s["codec"] in CHUNK_ENCODED:
                            # payload is the ENCODED stream (v7 records:
                            # crc/lens describe stored bytes) — walk its
                            # block framing for the per-plane view
                            raw_len = s.get("raw_payload_bytes")
                            if raw_len is None:
                                numel = 1
                                for d in rng.shape:
                                    numel *= d
                                raw_len = numel * \
                                    _np_dtype(s["dtype"]).itemsize
                            k = (s.get("meta") or {}).get("bp") or \
                                _np_dtype(s["dtype"]).itemsize
                            _entropy_planes(payload, int(raw_len), int(k),
                                            s["codec"], plane_table)
                        good += 1
                    except Exception as e:  # noqa
                        bad += 1
                        report["problems"].append(
                            f"{name}: chunked shard unreadable "
                            f"({type(e).__name__}: {e})")
                    continue
                readable = False
                for i, fname in enumerate(s.get("replicas", [s["file"]])):
                    p = mdir / fname
                    if not p.exists():
                        continue
                    try:
                        _unpack_shard(p.read_bytes())
                        readable = True
                        if i > 0:
                            replicas_ok += 1
                        break
                    except Exception as e:  # noqa
                        report["problems"].append(
                            f"{name}: {fname}: {type(e).__name__}")
                if readable:
                    good += 1
                else:
                    bad += 1
                    report["problems"].append(
                        f"{name}: shard {s['file']} unreadable on all "
                        f"replicas")
        _emit_entropy_planes(plane_table, report, out)
        out(f"  verify: {good} shard(s) ok, {bad} damaged"
            + (f", {replicas_ok} recovered via buddy replica"
               if replicas_ok else ""))
        report.update(verified=True, shards_ok=good, shards_bad=bad)
    report["ok"] = not report["problems"]
    for p in report["problems"]:
        out(f"  !! {p}")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("root", type=Path)
    ap.add_argument("--step", type=int, default=None)
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--slow-root", type=Path, default=None,
                    help="slow (scratch) tier root — adds its per-tier "
                         "residency column for the inspected step")
    ap.add_argument("--remote-root", type=Path, default=None,
                    help="remote object-store tier root — adds its "
                         "per-tier residency column")
    ap.add_argument("--scrub", action="store_true",
                    help="re-hash live chunk objects across the mounted "
                         "tiers; quarantine corrupt copies and heal from "
                         "a good replica/tier")
    ap.add_argument("--scrub-sample", type=int, default=None,
                    help="scrub a seeded N-digest sample instead of the "
                         "full live set")
    ap.add_argument("--scrub-seed", type=int, default=0,
                    help="seed for --scrub-sample (replayable subset)")
    ap.add_argument("--subscribers", action="store_true",
                    help="print the WeightSync announcement and every "
                         "published replica status (state, flipped step, "
                         "cache residency, peer/source wire split)")
    ap.add_argument("--health", action="store_true",
                    help="print per-tier error counters, circuit-breaker "
                         "state, quarantine contents and the last scrub "
                         "summary")
    args = ap.parse_args(argv)
    sink = (lambda *_: None) if args.json else print
    if args.scrub or args.health or args.subscribers:
        rep = {}
        if args.scrub:
            rep["scrub"] = run_scrub(
                args.root, slow_root=args.slow_root,
                remote_root=args.remote_root, sample=args.scrub_sample,
                seed=args.scrub_seed, out=sink)
        if args.health:
            rep["health"] = run_health(
                args.root, slow_root=args.slow_root,
                remote_root=args.remote_root, out=sink)
        if args.subscribers:
            rep["subscribers"] = run_subscribers(args.root, out=sink)
        rep["ok"] = all(r["ok"] for r in rep.values())
        if args.json:
            print(json.dumps(rep, indent=1, default=str))
        return 0 if rep["ok"] else 1
    rep = inspect(args.root, step=args.step, verify=args.verify, out=sink,
                  slow_root=args.slow_root, remote_root=args.remote_root)
    if args.json:
        print(json.dumps(rep, indent=1, default=str))
    return 0 if rep["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
