"""Serving launcher with checkpointable serving state.

The paper's preempt-queue use case applies to inference too: a low-priority
serving job must vacate nodes for real-time work. Here the *serving* upper
half — params + KV caches + request-queue cursor — checkpoints and restores
mid-decode, and generation continues token-exactly.

``python -m repro.launch.serve --arch gemma3-1b --requests 16``
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, reduced
from ..core.checkpoint import CheckpointManager
from ..core.policy import CheckpointPolicy
from ..core.storage import default_store
from ..models import Model
from ..train.steps import make_serve_fns

log = logging.getLogger("repro.serve")


class ServeState:
    """Checkpointable serving upper half."""

    def __init__(self, params, cache, out_tokens, cursor):
        self.tree = {"params": params, "cache": cache,
                     "out_tokens": out_tokens,
                     "cursor": jax.numpy.asarray(cursor, jax.numpy.int32)}


def run(arch: str, *, n_requests=8, prompt_len=32, gen_len=32,
        workdir="runs/serve", ckpt_every=16, preempt_at=None,
        full_config=False, seed=0):
    cfg = get_config(arch) if full_config else reduced(get_config(arch))
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode serving path")
    model = Model(cfg)
    prefill_fn, decode_fn, _ = make_serve_fns(model)
    prefill_fn = jax.jit(prefill_fn, static_argnames=('cache_len',))
    decode_fn = jax.jit(decode_fn)
    manager = CheckpointManager(default_store(f"{workdir}/{arch}"),
                                policy=CheckpointPolicy(n_writers=2))

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, (n_requests, prompt_len),
                           dtype=np.int32)
    params = model.init(jax.random.PRNGKey(seed))

    latest = manager.latest_step()
    if latest is None:
        tok, cache = prefill_fn(params, jax.numpy.asarray(prompts),
                                cache_len=prompt_len + gen_len)
        out = np.full((n_requests, gen_len), -1, np.int32)
        out[:, 0] = np.asarray(tok)
        cursor = 1
        log.info("prefilled %d requests", n_requests)
    else:
        abstract = jax.eval_shape(lambda: {
            "params": params,
            "cache": model.init_cache(n_requests, prompt_len + gen_len),
            "out_tokens": np.zeros((n_requests, gen_len), np.int32),
            "cursor": np.zeros((), np.int32)})
        state, extra = manager.restore(abstract, None, step=latest)
        params, cache = state["params"], state["cache"]
        out = np.array(state["out_tokens"])  # copy: jax arrays are read-only
        cursor = int(state["cursor"])
        log.info("restored serving state at token %d", cursor)

    t0 = time.time()
    while cursor < gen_len:
        tok, cache = decode_fn(params, cache, jax.numpy.asarray(out[:, cursor - 1]))
        out[:, cursor] = np.asarray(tok)
        cursor += 1
        if ckpt_every and cursor % ckpt_every == 0:
            state = {"params": params, "cache": cache,
                     "out_tokens": jax.numpy.asarray(out),
                     "cursor": jax.numpy.asarray(cursor, jax.numpy.int32)}
            rep = manager.save(state, cursor, extra={"arch": arch})
            log.info("serving checkpoint @token %d (%.2fs, %.1f MB)",
                     cursor, rep["seconds"], rep["bytes"] / 1e6)
        if preempt_at is not None and cursor == preempt_at:
            state = {"params": params, "cache": cache,
                     "out_tokens": jax.numpy.asarray(out),
                     "cursor": jax.numpy.asarray(cursor, jax.numpy.int32)}
            manager.save(state, cursor, extra={"arch": arch})
            log.info("preempted at token %d — state persisted", cursor)
            return {"status": "preempted", "cursor": cursor, "tokens": out}
    dt = time.time() - t0
    return {"status": "completed", "cursor": cursor, "tokens": out,
            "tok_per_s": n_requests * (gen_len - 1) / max(dt, 1e-9)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--workdir", default="runs/serve")
    ap.add_argument("--ckpt-every", type=int, default=16)
    ap.add_argument("--preempt-at", type=int, default=None)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)
    rep = run(args.arch, n_requests=args.requests,
              prompt_len=args.prompt_len, gen_len=args.gen_len,
              workdir=args.workdir, ckpt_every=args.ckpt_every,
              preempt_at=args.preempt_at)
    print({k: v for k, v in rep.items() if k != "tokens"})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
