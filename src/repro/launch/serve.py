"""Serving launcher with checkpointable serving state.

The paper's preempt-queue use case applies to inference too: a low-priority
serving job must vacate nodes for real-time work. Here the *serving* upper
half — params + KV caches + request-queue cursor — checkpoints and restores
mid-decode, and generation continues token-exactly.

``python -m repro.launch.serve --arch gemma3-1b --requests 16``

With ``--weight-sync <store-root>`` the server also subscribes to a
trainer-side ``WeightPublisher``: between decode steps it polls the
store's announcement, pulls only the chunks its cache misses, and
hot-swaps the params pytree atomically — serving never blocks on a full
restore, and a failed sync holds the last-good weights.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, reduced
from ..core.checkpoint import CheckpointManager
from ..core.policy import CheckpointPolicy
from ..core.storage import default_store
from ..models import Model
from ..train.steps import make_serve_fns

log = logging.getLogger("repro.serve")


class ServeState:
    """Checkpointable serving upper half."""

    def __init__(self, params, cache, out_tokens, cursor):
        self.tree = {"params": params, "cache": cache,
                     "out_tokens": out_tokens,
                     "cursor": jax.numpy.asarray(cursor, jax.numpy.int32)}


def _hot_swap(params, sub, last_step):
    """Poll the WeightSync subscriber between decode steps and, on a new
    flip, rebuild the params pytree from the flipped host arrays (leaf
    names match ``leaf_paths`` under the ``params/`` root — the same
    naming the publisher's manifest uses). Any sync failure holds the
    serving params as-is: the subscriber already degraded to last-good."""
    from ..core.split_state import leaf_paths
    sub.sync()
    step, arrays = sub.current()
    if step is None or step == last_step:
        return params, last_step
    flat = {}
    missing = []
    for name, leaf in leaf_paths({"params": params}):
        host = arrays.get(name)
        if host is None:
            missing.append(name)
            continue
        flat[name] = jax.numpy.asarray(host, dtype=leaf.dtype)
    if missing:
        log.warning("weight-sync step %s misses %d leaf(s) (e.g. %s) — "
                    "holding current params", step, len(missing),
                    missing[0])
        return params, last_step
    swapped = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params),
        [flat[n] for n, _ in leaf_paths({"params": params})])
    log.info("hot-swapped params to published step %s", step)
    return swapped, step


def run(arch: str, *, n_requests=8, prompt_len=32, gen_len=32,
        workdir="runs/serve", ckpt_every=16, preempt_at=None,
        full_config=False, seed=0, weight_sync=None, weight_sync_name=None):
    cfg = get_config(arch) if full_config else reduced(get_config(arch))
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode serving path")
    model = Model(cfg)
    prefill_fn, decode_fn, _ = make_serve_fns(model)
    prefill_fn = jax.jit(prefill_fn, static_argnames=('cache_len',))
    decode_fn = jax.jit(decode_fn)
    manager = CheckpointManager(default_store(f"{workdir}/{arch}"),
                                policy=CheckpointPolicy(n_writers=2))
    sub, ws_step = None, None
    if weight_sync is not None:
        from ..core.storage import Tier, TieredStore
        from ..core.weightsync import WeightSubscriber
        sub = WeightSubscriber(
            TieredStore(Tier("ws-src", weight_sync)),
            f"{workdir}/{arch}/ws-cache",
            name=weight_sync_name or f"serve-{arch}",
            leaf_filter=lambda n: n.startswith("params/"))
        log.info("weight-sync: subscribed to %s", weight_sync)

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, (n_requests, prompt_len),
                           dtype=np.int32)
    params = model.init(jax.random.PRNGKey(seed))

    latest = manager.latest_step()
    if latest is None:
        tok, cache = prefill_fn(params, jax.numpy.asarray(prompts),
                                cache_len=prompt_len + gen_len)
        out = np.full((n_requests, gen_len), -1, np.int32)
        out[:, 0] = np.asarray(tok)
        cursor = 1
        log.info("prefilled %d requests", n_requests)
    else:
        abstract = jax.eval_shape(lambda: {
            "params": params,
            "cache": model.init_cache(n_requests, prompt_len + gen_len),
            "out_tokens": np.zeros((n_requests, gen_len), np.int32),
            "cursor": np.zeros((), np.int32)})
        state, extra = manager.restore(abstract, None, step=latest)
        params, cache = state["params"], state["cache"]
        out = np.array(state["out_tokens"])  # copy: jax arrays are read-only
        cursor = int(state["cursor"])
        log.info("restored serving state at token %d", cursor)

    t0 = time.time()
    while cursor < gen_len:
        if sub is not None:
            params, ws_step = _hot_swap(params, sub, ws_step)
        tok, cache = decode_fn(params, cache, jax.numpy.asarray(out[:, cursor - 1]))
        out[:, cursor] = np.asarray(tok)
        cursor += 1
        if ckpt_every and cursor % ckpt_every == 0:
            state = {"params": params, "cache": cache,
                     "out_tokens": jax.numpy.asarray(out),
                     "cursor": jax.numpy.asarray(cursor, jax.numpy.int32)}
            rep = manager.save(state, cursor, extra={"arch": arch})
            log.info("serving checkpoint @token %d (%.2fs, %.1f MB)",
                     cursor, rep["seconds"], rep["bytes"] / 1e6)
        if preempt_at is not None and cursor == preempt_at:
            state = {"params": params, "cache": cache,
                     "out_tokens": jax.numpy.asarray(out),
                     "cursor": jax.numpy.asarray(cursor, jax.numpy.int32)}
            manager.save(state, cursor, extra={"arch": arch})
            log.info("preempted at token %d — state persisted", cursor)
            if sub is not None:
                sub.close()
            return {"status": "preempted", "cursor": cursor, "tokens": out}
    dt = time.time() - t0
    rep = {"status": "completed", "cursor": cursor, "tokens": out,
           "tok_per_s": n_requests * (gen_len - 1) / max(dt, 1e-9)}
    if sub is not None:
        rep["weight_sync_step"] = ws_step
        sub.close()
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--workdir", default="runs/serve")
    ap.add_argument("--ckpt-every", type=int, default=16)
    ap.add_argument("--preempt-at", type=int, default=None)
    ap.add_argument("--weight-sync", default=None, metavar="STORE_ROOT",
                    help="subscribe to a WeightSync publisher's store root "
                         "and hot-swap params between decode steps")
    ap.add_argument("--weight-sync-name", default=None,
                    help="subscriber name published back to the source "
                         "(inspect_ckpt --subscribers)")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)
    rep = run(args.arch, n_requests=args.requests,
              prompt_len=args.prompt_len, gen_len=args.gen_len,
              workdir=args.workdir, ckpt_every=args.ckpt_every,
              preempt_at=args.preempt_at, weight_sync=args.weight_sync,
              weight_sync_name=args.weight_sync_name)
    print({k: v for k, v in rep.items() if k != "tokens"})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
