"""Production training loop with first-class C/R (the paper's integration
point): restore-on-start, periodic async checkpoints, preemption handling,
drain-before-snapshot, coordinator-supervised writes, elastic restart.

The Trainer owns the *lower half* (mesh, jitted step, pipeline objects) and
treats the *upper half* (TrainState + DataState) as opaque checkpointable
data — the split-process discipline as code structure.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from pathlib import Path

import jax
import numpy as np

from ..core.checkpoint import CheckpointManager
from ..core.policy import CheckpointPolicy
from ..core.preempt import PreemptionGuard
from ..core.split_state import (abstract_train_state, config_digest,
                                init_train_state, lower_half_descriptor,
                                state_shardings)
from ..core.storage import TieredStore, default_store
from ..data.pipeline import DataState, SyntheticPipeline
from ..launch.mesh import make_host_mesh
from ..models import Model
from ..models.model import set_constrainer
from ..optim import make_optimizer
from ..sharding.partition import act_constrainer, batch_spec
from .steps import make_train_step

log = logging.getLogger("repro.train")


@dataclass
class TrainerConfig:
    workdir: str
    batch: int = 8
    seq_len: int = 128
    ckpt_every: int = 20
    async_ckpt: bool = True
    retain: int = 3
    n_writers: int = 4
    codec: str | None = None        # None = best available (zstd, else raw)
    params_codec: str | None = None
    ckpt_mode: str = "full"         # "incremental" = CAS dedup checkpoints
    chunk_size: int = 1 << 20
    chunking: str = "fixed"         # "cdc" = content-defined (shift-tolerant)
    scan_backend: str = "auto"      # cdc candidate scan engine (cdc_scan)
    io_threads: int = 4             # chunk-IO pipeline width (1 = serial)
    persist_queue_depth: int = 1    # async rounds in flight (>1 = queue)
    host_bytes_budget: int | None = None  # cap on queued snapshot bytes
    replicas: int = 1
    seed: int = 0
    log_every: int = 10
    grad_accum: int = 1
    burst_buffer: bool = False      # /dev/shm tier (benchmarks turn this on)
    lustre_bw: float | None = None  # None = unthrottled slow tier
    streaming_restore: bool = False  # begin step 0 at the first-use frontier
    remote_dir: str | None = None   # mount a cold object-store tier
    remote_bw: float | None = None  # None = unthrottled remote tier
    remote_latency_s: float = 0.0   # per-request latency of the remote tier


class Trainer:
    def __init__(self, model_cfg, tcfg: TrainerConfig, *, mesh=None,
                 store: TieredStore | None = None):
        self.cfg = model_cfg
        self.tcfg = tcfg
        # ---- lower half bring-up (the "trivial MPI application") ----
        self.mesh = mesh if mesh is not None else make_host_mesh()
        set_constrainer(act_constrainer(model_cfg, self.mesh))
        self.model = Model(model_cfg)
        self.optimizer = make_optimizer(model_cfg)
        self.pipeline = SyntheticPipeline(model_cfg, batch=tcfg.batch,
                                          seq_len=tcfg.seq_len)
        self._abstract = abstract_train_state(self.model, self.optimizer)
        self._shardings = state_shardings(self._abstract, self.mesh,
                                          self.optimizer)
        self.step_fn = jax.jit(
            make_train_step(self.model, self.optimizer,
                            grad_accum=tcfg.grad_accum),
            donate_argnums=(0,), out_shardings=(self._shardings, None))
        store = store or default_store(tcfg.workdir,
                                       burst_buffer=tcfg.burst_buffer,
                                       lustre_bw=tcfg.lustre_bw,
                                       remote_dir=tcfg.remote_dir,
                                       remote_bw=tcfg.remote_bw,
                                       remote_latency_s=tcfg.remote_latency_s)
        # TrainerConfig's flat checkpoint fields compose into the policy
        # object (the canonical constructor), with REPRO_CKPT_* env
        # overrides merged last — an operator can retune a queued job's
        # checkpoint pipeline without editing launch scripts
        policy = CheckpointPolicy().with_overrides(
            mode=tcfg.ckpt_mode, n_writers=tcfg.n_writers,
            codec=tcfg.codec, params_codec=tcfg.params_codec,
            replicas=tcfg.replicas, retain=tcfg.retain,
            chunk_size=tcfg.chunk_size, chunking=tcfg.chunking,
            scan_backend=tcfg.scan_backend, io_threads=tcfg.io_threads,
            persist_queue_depth=tcfg.persist_queue_depth,
            host_bytes_budget=tcfg.host_bytes_budget,
            streaming_restore=tcfg.streaming_restore)
        self.manager = CheckpointManager(
            store, policy=CheckpointPolicy.from_env(base=policy))
        # ---- upper half ----
        self.state = None
        self.data_state: DataState | None = None
        self.py_step = 0
        self.history: list = []
        self.restored_from = None
        self._restore_stream = None     # in-flight streaming restore
        self._pending_batch = None      # step-0 input staged during the tail

    # ------------------------------------------------------------------
    def _extra(self) -> dict:
        return {
            "data_state": self.data_state.to_json(),
            "arch": self.cfg.arch_id,
            "config_digest": config_digest(self.cfg),
            "lower_half": lower_half_descriptor(self.mesh, self.cfg).to_json(),
            "py_step": self.py_step,
        }

    def init_or_restore(self):
        latest = self.manager.latest_step()
        if latest is None:
            rng = jax.random.PRNGKey(self.tcfg.seed)
            init = jax.jit(
                lambda r: init_train_state(self.model, self.optimizer, r),
                out_shardings=self._shardings)
            self.state = init(rng)
            self.data_state = self.pipeline.init_state(self.tcfg.seed)
            self.py_step = 0
            log.info("initialized fresh state (seed=%d)", self.tcfg.seed)
        elif self.manager.policy.restore.streaming:
            # streaming restore-behind: every leaf fetch is in flight in
            # first-use order; fit() begins step 0 once the frontier is
            # resident and drains the tail behind the completion gate
            self._restore_stream, extra = self.manager.restore_streaming(
                self._abstract, self._shardings, step=latest)
            self.data_state = DataState.from_json(extra["data_state"])
            self.py_step = int(extra.get("py_step", latest))
            self.restored_from = latest
            log.info("restoring step %d STREAMING (%d leaves in flight, "
                     "frontier %d)", latest, len(self._restore_stream.names),
                     len(self._restore_stream.frontier_names))
        else:
            self.state, extra = self.manager.restore(
                self._abstract, self._shardings, step=latest)
            self.data_state = DataState.from_json(extra["data_state"])
            self.py_step = int(extra.get("py_step", latest))
            self.restored_from = latest
            log.info("restored step %d (upper half) onto mesh %s "
                     "(lower half rebuilt)", latest,
                     tuple(self.mesh.devices.shape))
        return self

    def save(self, *, blocking: bool = True):
        if self._restore_stream is not None:
            self._finish_streaming_restore()
        return self.manager.save(self.state, self.py_step,
                                 extra=self._extra(), blocking=blocking)

    def _finish_streaming_restore(self):
        """Begin step 0 at the first-use frontier: once the frontier is
        resident, stage the step-0 batch (pipeline fetch + host→device
        transfer overlap the still-streaming tail), then cross the
        completion gate — every remaining leaf placed as it lands, the
        full state whole and bit-exact before the first ``step_fn``."""
        stream, self._restore_stream = self._restore_stream, None
        t0 = time.monotonic()
        stream.wait_frontier()
        t_frontier = time.monotonic() - t0
        log.info("restore frontier resident in %.3fs (%d/%d leaves "
                 "landed) — beginning step 0 behind the completion gate",
                 t_frontier, stream.landed_count(), len(stream.names))
        batch, next_ds = self.pipeline.next(self.data_state)
        batch = jax.device_put(batch, batch_spec(batch, self.mesh))
        self._pending_batch = (batch, next_ds)
        self.state = stream.state()
        log.info("restore stream complete in %.3fs (tail %.3fs behind "
                 "the frontier)", time.monotonic() - t0,
                 time.monotonic() - t0 - t_frontier)

    # ------------------------------------------------------------------
    def fit(self, n_steps: int, *, guard: PreemptionGuard | None = None,
            stop_after: int | None = None) -> dict:
        """Run until `n_steps` total steps (absolute), a preemption signal,
        or `stop_after` additional steps (tests). Returns a status report."""
        assert self.state is not None or self._restore_stream is not None, \
            "call init_or_restore() first"
        if self._restore_stream is not None:
            self._finish_streaming_restore()
        own_guard = guard is None
        guard = guard or PreemptionGuard()
        # SIGTERM mid-persist: flip the manager's fast-flush flag from the
        # signal handler so the in-flight overlapped round skips
        # non-essential maintenance and lands promptly
        guard.add_callback(self.manager.request_fast_flush)
        status = "completed"
        steps_done = 0
        if own_guard:
            guard.__enter__()
        try:
            while self.py_step < n_steps:
                if guard.should_preempt:
                    self.manager.wait()
                    rep = self.save(blocking=True)
                    # the preemption checkpoint must be FULLY durable —
                    # including its slow-tier copy — before the process
                    # answers the eviction: the burst buffer may not
                    # survive the node reassignment
                    self.manager.store.wait_drained()
                    log.info("preempted at step %d; checkpoint %.3fs",
                             self.py_step, rep["seconds"])
                    status = "preempted"
                    break
                if self._pending_batch is not None:
                    # step-0 input staged while the restore tail streamed
                    batch, next_ds = self._pending_batch
                    self._pending_batch = None
                else:
                    batch, next_ds = self.pipeline.next(self.data_state)
                    batch = jax.device_put(batch,
                                           batch_spec(batch, self.mesh))
                t0 = time.monotonic()
                self.state, metrics = self.step_fn(self.state, batch)
                self.data_state = next_ds
                self.py_step += 1
                steps_done += 1
                if self.py_step % self.tcfg.log_every == 0 or \
                        self.py_step == n_steps:
                    m = {k: float(v) for k, v in metrics.items()}
                    m.update(step=self.py_step,
                             step_s=time.monotonic() - t0)
                    self.history.append(m)
                    log.info("step %5d loss=%.4f (%.2fs)", self.py_step,
                             m.get("loss", float("nan")), m["step_s"])
                if self.tcfg.ckpt_every and \
                        self.py_step % self.tcfg.ckpt_every == 0:
                    rep = self.save(blocking=not self.tcfg.async_ckpt)
                    if rep.get("async"):
                        # the train thread paid only the snapshot barrier;
                        # persist overlaps the steps that follow
                        log.info("ckpt step %d: blocked %.3fs "
                                 "(snapshot %.3fs), persist overlapped",
                                 self.py_step, rep["blocking_s"],
                                 rep["snapshot_s"])
                if stop_after is not None and steps_done >= stop_after:
                    status = "paused"
                    break
            self.manager.wait()
            if status == "completed" and (
                    not self.manager.latest_step()
                    or self.manager.latest_step() < self.py_step):
                self.save(blocking=True)
        finally:
            if own_guard:
                guard.__exit__(None, None, None)
        return {"status": status, "step": self.py_step,
                "history": self.history,
                "ckpt_metrics": dict(self.manager.coordinator.metrics)}

    def params_digest(self) -> str:
        """Bit-exactness probe: order-stable hash of all params bytes."""
        import hashlib
        if self._restore_stream is not None:
            self._finish_streaming_restore()
        h = hashlib.sha256()
        from ..core.split_state import leaf_paths
        for name, leaf in leaf_paths(self.state["params"]):
            h.update(name.encode())
            h.update(np.ascontiguousarray(jax.device_get(leaf)).tobytes())
        return h.hexdigest()
