from .steps import make_serve_fns, make_train_step

__all__ = ["make_serve_fns", "make_train_step"]
