"""Jittable train / serve step functions (the programs the dry-run lowers)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..optim import lr_schedule


def make_train_step(model, optimizer, *, lr_fn=None, grad_accum: int = 1,
                    accum_dtype=None):
    """Returns train_step(state, batch) -> (state, metrics).

    grad_accum > 1 splits the batch leading dim into microbatches and
    accumulates grads under lax.scan — this divides the remat-saved
    per-layer residual stack by `grad_accum` (the peak-memory whale for
    1T-class models) and amortizes the DP all-reduce. `accum_dtype`
    defaults to f32; "bfloat16" halves the accumulator for very large
    models (trade documented in EXPERIMENTS §Perf).
    """
    lr_fn = lr_fn or lr_schedule

    def loss_fn(p, b):
        return model.loss(p, b)

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            adt = jnp.dtype(accum_dtype) if accum_dtype else jnp.float32

            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                        + x.shape[1:]), b)

            def body(acc, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(a.dtype), acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            grads, (losses, metrics) = jax.lax.scan(body, zeros, micro(batch))
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
            loss = losses.mean()

        lr = lr_fn(state["step"])
        new_params, new_opt = optimizer.update(grads, state["opt"], params, lr)
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
            "rng": state["rng"],
        }
        metrics = dict(metrics)
        metrics["lr"] = lr
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return new_state, metrics

    return train_step


def make_serve_fns(model, *, greedy: bool = True):
    """Returns (prefill_fn, decode_fn) for serving.

    prefill_fn(params, tokens)        -> (next_token (B,), cache)
    decode_fn(params, cache, token)   -> (next_token (B,), cache)
    """

    def sample(logits):
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def prefill_fn(params, tokens, cache_len=0):
        # cache_len: total decode capacity (prompt + generation)
        logits, cache = model.prefill(params, tokens, cache_len=cache_len)
        return sample(logits), cache

    def decode_fn(params, cache, token):
        logits, cache = model.decode_step(params, cache, token)
        return sample(logits), cache

    def encode_fn(params, features):
        return model.encode(params, features)

    return prefill_fn, decode_fn, encode_fn
