from .model import Model, chunked_xent
from . import layers, moe, rglru, ssm

__all__ = ["Model", "chunked_xent", "layers", "moe", "rglru", "ssm"]
