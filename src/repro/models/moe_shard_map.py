"""Expert-parallel MoE via shard_map — the §Perf hillclimb path.

Baseline observation (kimi-k2 train_4k, 16×16 mesh): GSPMD resolves the
dispatch einsums by contracting the model-sharded d_model dim and psumming
(G, E, C, F) partials over TP — ~11 TB/device of all-reduce wire traffic per
step (collective term 322 s vs 9 s compute).

This path expresses the canonical EP schedule explicitly:

  slice tokens over "model" → local top-k route → local (E, C, D) dispatch
  → all_to_all over "model" (tokens to their expert shard)
  → local expert FFNs with FSDP-gathered (E/tp, D, F) weights
  → reverse all_to_all → local combine → all_gather token slices.

Per-layer per-device wire (kimi train): 2 × 0.62 GB a2a + 0.44 GB gather +
~2 GB weight FSDP gathers ≈ 3.3 GB fwd — a predicted ~35× collective
reduction. Falls back to the GSPMD path when the local token count or expert
count doesn't divide TP (tiny decode batches).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
try:
    from jax import shard_map as _shard_map       # jax ≥ 0.6 top-level fn
except ImportError:                               # 0.4.x experimental
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import PartitionSpec as P


import inspect as _inspect

try:
    _CHECK_KW = ("check_vma"
                 if "check_vma" in _inspect.signature(_shard_map).parameters
                 else "check_rep")
except (ValueError, TypeError):        # builtins without a signature
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """Version shim: the experimental 0.4.x API spells check_vma as
    check_rep; everything else matches. The kwarg is probed once at import
    — a per-call try/except TypeError would mask genuine TypeErrors from
    bad specs as a confusing check_rep error."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: check_vma})

from .layers import _act
from .moe import _positions_in_expert, capacity


def applicable(cfg, mesh_axes_info, tokens_per_device: int) -> bool:
    m = cfg.moe
    ax = mesh_axes_info
    if ax.model is None or ax.tp <= 1:
        return False
    if m.n_experts % ax.tp or tokens_per_device % ax.tp:
        return False
    return True


def moe_apply_shard_map(params, x, cfg, mesh, ax):
    """x: (B, S, D) batch-sharded over ax.batch. Returns (y, aux).

    With cfg.seq_shard_resid the input arrives sequence-sharded over
    "model" — each device's block IS its token slice, so the entry
    dynamic-slice and the exit all_gather disappear (Megatron-SP × EP
    composition)."""
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    tp = ax.tp
    model_ax = ax.model
    fsdp_ax = ax.fsdp
    seq_sharded = bool(getattr(cfg, "seq_shard_resid", False))
    B, S, D = x.shape
    t_loc = (B // ax.batch_size) * S
    g = t_loc // tp
    C = capacity(m, g)
    E_loc = E // tp
    act = _act(cfg.act)
    batch = ax.batch or None

    def gather_fsdp(w, axis):
        if fsdp_ax is None:
            return w
        return jax.lax.all_gather(w, fsdp_ax, axis=axis, tiled=True)

    def body(xb, router, wg, wu, wd):
        # xb: seq-sharded -> (B_loc, S/tp, D) IS the slice; else
        #     (B_loc, S, D) replicated over "model" -> take slice mi
        router = gather_fsdp(router, 0).astype(jnp.float32)   # (D, E)
        wg_l = gather_fsdp(wg, 1)                              # (E_loc, D, F)
        wu_l = gather_fsdp(wu, 1)
        wd_l = gather_fsdp(wd, 2)                              # (E_loc, F, D)

        xt = xb.reshape(-1, D)
        if seq_sharded:
            xs = xt                                            # (g, D)
        else:
            mi = jax.lax.axis_index(model_ax)
            xs = jax.lax.dynamic_slice_in_dim(xt, mi * g, g, 0)  # (g, D)

        # ---- local routing ----
        logits = jnp.einsum("td,de->te", xs.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
        topi = jax.lax.stop_gradient(topi)
        pos, _ = _positions_in_expert(topi.reshape(-1), E)
        within = pos < C
        e_flat = topi.reshape(-1)
        p_flat = jnp.where(within, pos, C)

        # ---- dispatch (scatter; no matmul FLOPs) ----
        src = jnp.repeat(xs, k, axis=0).astype(x.dtype)        # (g*k, D)
        buf = jnp.zeros((E, C, D), x.dtype).at[e_flat, p_flat].set(
            src * within[:, None].astype(x.dtype), mode="drop")

        # ---- EP exchange: tokens travel to their expert's shard ----
        bufr = buf.reshape(tp, E_loc, C, D)
        recv = jax.lax.all_to_all(bufr, model_ax, split_axis=0,
                                  concat_axis=0)               # (tp,E_loc,C,D)
        xin = recv.transpose(1, 0, 2, 3).reshape(E_loc, tp * C, D)

        # ---- local expert FFNs (the only matmuls) ----
        h = act(jnp.einsum("ecd,edf->ecf", xin, wg_l)) * \
            jnp.einsum("ecd,edf->ecf", xin, wu_l)
        out = jnp.einsum("ecf,efd->ecd", h, wd_l)              # (E_loc,tpC,D)

        # ---- reverse exchange + combine ----
        outr = out.reshape(E_loc, tp, C, D).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(outr, model_ax, split_axis=0,
                                  concat_axis=0)
        buf_out = back.reshape(E, C, D)
        y = buf_out[e_flat, p_flat]                            # (g*k, D)
        w = (topw.reshape(-1) * within).astype(y.dtype)
        y = (y * w[:, None]).reshape(g, k, D).sum(axis=1)

        # ---- reassemble the full local token set ----
        if seq_sharded:
            y_out = y.reshape(xb.shape)      # stays sequence-sharded (SP)
        else:
            y_full = jax.lax.all_gather(y, model_ax, axis=0, tiled=True)
            y_out = y_full.reshape(xb.shape)

        # ---- aux (global means) ----
        all_axes = tuple(a for a in ((ax.batch or ()) + (model_ax,)) if a)
        me = probs.mean(axis=0)
        ce = jax.nn.one_hot(topi[:, 0], E).mean(axis=0)
        lb = E * jnp.sum(jax.lax.pmean(me, all_axes)
                         * jax.lax.pmean(ce, all_axes))
        z = jax.lax.pmean(
            jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2),
            all_axes)
        drop = jax.lax.pmean(1.0 - within.mean(), all_axes)
        return y_out, lb, z, drop

    x_spec = P(batch, model_ax if seq_sharded else None, None)
    in_specs = (
        x_spec,                                     # x
        P(ax.fsdp, None),                           # router (D, E)
        P(model_ax, ax.fsdp, None),                 # wg (E, D, F)
        P(model_ax, ax.fsdp, None),                 # wu
        P(model_ax, None, ax.fsdp),                 # wd (E, F, D)
    )
    out_specs = (x_spec, P(), P(), P())
    y, lb, z, drop = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False)(
        x, params["router"], params["wg"], params["wu"], params["wd"])
    aux = {"load_balance_loss": lb, "router_z_loss": z, "drop_fraction": drop}
    return y, aux
