"""Composable model zoo: one Model class covering all 5 assigned families.

Public API (pure functions of (params, inputs) — the checkpointable
"upper half" never references meshes or devices):

  model = Model(cfg)
  params                    = model.init(rng)
  loss, metrics             = model.loss(params, batch)
  last_logits, cache        = model.prefill(params, tokens)
  logits, cache             = model.decode_step(params, cache, tokens)
  logits                    = model.encode(params, features)       (encoder)

Layers execute under lax.scan over *stages* (repeating block patterns) with
stacked params — HLO size is O(pattern), not O(n_layers).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import (ATTN_GLOBAL, ATTN_LOCAL, RGLRU, SSM, ModelConfig,
                            Stage, build_stages)
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import (_pdt, apply_norm, attention_decode, attention_full,
                     attention_local, apply_rope, causal_conv1d,
                     conv_pos_embed, init_mlp, init_norm, mlp_apply,
                     rmsnorm, rope_table, _softcap)
from .moe import init_moe, moe_apply

# sharding constraint hook — installed by repro.sharding at jit time; identity
# by default so pure-CPU tests never touch mesh state.
_constrain = lambda x, name: x

# execution context for explicitly-collective paths (shard_map MoE): the
# launcher provides the mesh; None keeps the model mesh-free (CPU tests).
_exec = {"mesh": None, "ax": None}


def set_constrainer(fn):
    global _constrain
    _constrain = fn if fn is not None else (lambda x, name: x)


def set_exec_mesh(mesh):
    if mesh is None:
        _exec["mesh"] = _exec["ax"] = None
    else:
        from ..sharding.partition import mesh_axes
        _exec["mesh"] = mesh
        _exec["ax"] = mesh_axes(mesh)


def _offload_resid_policy():
    """Host-offload the per-layer residual inputs instead of keeping them in
    HBM: the remat-saved (layers, B, S, D) stack is the 1T-model peak-memory
    whale (52 GiB/device on kimi train_4k) and PCIe-offloading it costs ~2 s
    vs ~50 s of extra FSDP weight gathers under grad-accum microbatching."""
    return jax.checkpoint_policies.save_and_offload_only_these_names(
        names_which_can_be_saved=[],
        names_which_can_be_offloaded=["resid_in"],
        offload_src="device", offload_dst="pinned_host")


REMAT_POLICIES = {
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "full": jax.checkpoint_policies.everything_saveable,
    "offload_resid": _offload_resid_policy,
}


def _resolve_policy(name):
    p = REMAT_POLICIES[name]
    return p() if callable(p) and name == "offload_resid" else p


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.stages = build_stages(cfg)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        dt = _pdt(cfg)
        k_embed, k_stages, k_head = jax.random.split(key, 3)
        params = {
            "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model))
                      * 0.02).astype(dt),
            "final_norm": init_norm(cfg, cfg.d_model),
        }
        if cfg.positional == "conv":
            params["pos_conv"] = {
                "w": (jax.random.normal(k_head, (128, cfg.d_model))
                      * 0.02).astype(dt)}
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size))
                / math.sqrt(cfg.d_model)).astype(dt)
        for si, stage in enumerate(self.stages):
            ks = jax.random.fold_in(k_stages, si)
            keys = jax.random.split(ks, stage.repeat)
            params[f"stage_{si}"] = jax.vmap(
                lambda k: self._init_pattern(k, stage))(keys)
        return params

    def _init_pattern(self, key, stage: Stage):
        cfg = self.cfg
        p = {}
        for j, kind in enumerate(stage.kinds):
            kj = jax.random.fold_in(key, j)
            p[f"b{j}"] = self._init_block(kj, kind, stage.moe)
        return p

    def _init_block(self, key, kind, moe: bool):
        cfg = self.cfg
        dt = _pdt(cfg)
        ks = jax.random.split(key, 8)
        p = {"norm_in": init_norm(cfg, cfg.d_model)}
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            s = 1.0 / math.sqrt(d)
            p["q"] = (jax.random.normal(ks[0], (d, H, hd)) * s).astype(dt)
            p["k"] = (jax.random.normal(ks[1], (d, K, hd)) * s).astype(dt)
            p["v"] = (jax.random.normal(ks[2], (d, K, hd)) * s).astype(dt)
            p["o"] = (jax.random.normal(ks[3], (H, hd, d))
                      / math.sqrt(H * hd)).astype(dt)
            if cfg.use_bias:
                p["q_b"] = jnp.zeros((H, hd), dt)
                p["k_b"] = jnp.zeros((K, hd), dt)
                p["v_b"] = jnp.zeros((K, hd), dt)
                p["o_b"] = jnp.zeros((d,), dt)
            if cfg.qk_norm:
                p["q_norm"] = init_norm(cfg, hd)
                p["k_norm"] = init_norm(cfg, hd)
        elif kind == RGLRU:
            p["rglru"] = rglru_mod.init_rglru(ks[0], cfg)
        elif kind == SSM:
            p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
        if kind != SSM:  # mamba2 block has no separate MLP
            p["norm_mlp"] = init_norm(cfg, cfg.d_model)
            if moe:
                p["moe"] = init_moe(ks[4], cfg, cfg.d_model)
            else:
                ff = cfg.d_ff
                if cfg.moe is not None and not moe:
                    ff = cfg.moe.dense_d_ff or cfg.d_ff
                p["mlp"] = init_mlp(ks[4], cfg, cfg.d_model, ff)
        if cfg.post_norm:
            p["norm_post"] = init_norm(cfg, cfg.d_model)
            if kind != SSM:
                p["norm_post_mlp"] = init_norm(cfg, cfg.d_model)
        return p

    # ------------------------------------------------------------------
    # block application
    # ------------------------------------------------------------------
    def _ropes(self, positions):
        """Precompute rope tables once per forward — hoisted out of the layer
        scan (loop-invariant; observed 48 duplicated per-layer copies when
        computed inside remat'd scan bodies)."""
        cfg = self.cfg
        out = {}
        if cfg.positional != "rope":
            return out
        kinds = set(cfg.layer_kinds)
        if ATTN_GLOBAL in kinds:
            out[ATTN_GLOBAL] = rope_table(positions, cfg.head_dim,
                                          cfg.rope_theta, cfg.rope_pct)
        if ATTN_LOCAL in kinds:
            theta = cfg.rope_theta_local or cfg.rope_theta
            out[ATTN_LOCAL] = rope_table(positions, cfg.head_dim, theta,
                                         cfg.rope_pct)
        return out

    def _qkv(self, p, x, kind, ropes):
        cfg = self.cfg
        q = jnp.einsum("bsd,dhk->bshk", x, p["q"])
        k = jnp.einsum("bsd,dhk->bshk", x, p["k"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["v"])
        if cfg.use_bias and "q_b" in p:
            q, k, v = q + p["q_b"], k + p["k_b"], v + p["v_b"]
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"]["scale"])
            k = rmsnorm(k, p["k_norm"]["scale"])
        rope = ropes.get(kind)
        if rope is not None:
            cos, sin, rot = rope
            q = apply_rope(q, cos, sin, rot)
            k = apply_rope(k, cos, sin, rot)
        return q, k, v

    def _attn_sequence(self, p, x, kind, ropes):
        """Full-sequence attention (train / prefill), returns (out, (k, v))."""
        cfg = self.cfg
        q, k, v = self._qkv(p, x, kind, ropes)
        suffix = "_local" if kind == ATTN_LOCAL else ""
        q = _constrain(q, "attn_q" + suffix)
        k = _constrain(k, "attn_kv" + suffix)
        v = _constrain(v, "attn_kv" + suffix)
        common = dict(softcap=cfg.attn_softcap, scale=cfg.attn_scale or None,
                      chunk=cfg.attn_chunk)
        # Attention scan bodies are per-step remat units (flash-style bwd,
        # see layers.py) — score blocks never stack across chunks.
        if kind == ATTN_LOCAL:
            o = attention_local(q, k, v, window=cfg.window, causal=cfg.causal,
                                **common)
        else:
            # seq-sharded attention keeps q whole (no q-chunk scan): per-device
            # memory is bounded by the sequence sharding itself.
            cq = q.shape[1] if cfg.seq_shard_attn else 0
            o = attention_full(q, k, v, causal=cfg.causal, chunk_q=cq,
                               **common)
        o = _constrain(o, "attn_q" + suffix)
        out = jnp.einsum("bshk,hkd->bsd", o, p["o"])
        if cfg.use_bias and "o_b" in p:
            out = out + p["o_b"]
        return out, (k, v)

    def _mlp_part(self, p, x, moe):
        cfg = self.cfg
        h = apply_norm(p["norm_mlp"], x, cfg)
        if moe:
            y, aux = self._moe(p, h)
        else:
            y, aux = mlp_apply(p["mlp"], h, cfg), {}
        if cfg.post_norm:
            y = apply_norm(p["norm_post_mlp"], y, cfg)
        return y, aux

    def _block_sequence(self, p, x, kind, moe, ropes, *, want_cache,
                        cache_len=0):
        """One block over a full sequence. Returns (x, aux, new_cache)."""
        cfg = self.cfg
        h = apply_norm(p["norm_in"], x, cfg)
        h = _constrain(h, "resid")
        new_cache = None
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            o, (k, v) = self._attn_sequence(p, h, kind, ropes)
            if want_cache:
                new_cache = self._build_attn_cache(kind, k, v, cache_len)
        elif kind == RGLRU:
            if want_cache:
                o, st = rglru_mod.rglru_forward(p["rglru"], h, cfg,
                                                return_state=True)
                new_cache = st
            else:
                o = rglru_mod.rglru_forward(p["rglru"], h, cfg)
        elif kind == SSM:
            if want_cache:
                o, st = ssm_mod.ssd_forward(p["ssm"], h, cfg, return_state=True)
                new_cache = st
            else:
                o = ssm_mod.ssd_forward(p["ssm"], h, cfg)
        else:  # pragma: no cover
            raise ValueError(kind)
        if cfg.post_norm:
            o = apply_norm(p["norm_post"], o, cfg)
        x = x + o
        aux = {}
        if kind != SSM:
            y, aux = self._mlp_part(p, x, moe)
            x = x + y
        x = _constrain(x, "resid")
        return x, aux, new_cache

    def _moe(self, p, h):
        cfg = self.cfg
        if cfg.moe_impl == "shard_map" and _exec["mesh"] is not None:
            from .moe_shard_map import applicable as _smap_ok
            from .moe_shard_map import moe_apply_shard_map
            ax = _exec["ax"]
            B, S, D = h.shape
            if B % ax.batch_size == 0 and \
                    _smap_ok(cfg, ax, (B // ax.batch_size) * S):
                y, aux = moe_apply_shard_map(p["moe"], h, cfg,
                                             _exec["mesh"], ax)
                if cfg.moe.n_shared_experts:
                    y = y + mlp_apply(p["moe"]["shared"], h, cfg)
                return y, aux
        h = _constrain(h, "moe_in")
        return moe_apply(p["moe"], h, cfg)

    def _build_attn_cache(self, kind, k, v, cache_len):
        """Convert prefill K/V into a decode cache of capacity cache_len
        (ring buffer of size window for local attention)."""
        cfg = self.cfg
        B, S, K, hd = k.shape
        if kind == ATTN_LOCAL:
            W = min(cfg.window, cache_len)
            n = min(S, W)
            slots = (jnp.arange(S - n, S)) % W
            ck = jnp.zeros((B, W, K, hd), k.dtype).at[:, slots].set(k[:, S - n:])
            cv = jnp.zeros((B, W, K, hd), v.dtype).at[:, slots].set(v[:, S - n:])
            return {"k": ck, "v": cv}
        ck = jnp.zeros((B, cache_len, K, hd), k.dtype)
        cv = jnp.zeros((B, cache_len, K, hd), v.dtype)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k[:, :cache_len], 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v[:, :cache_len], 0, axis=1)
        return {"k": ck, "v": cv}

    def _block_decode(self, p, x, kind, moe, cache, pos, ropes):
        """One block for a single token. cache: this block's state."""
        cfg = self.cfg
        h = apply_norm(p["norm_in"], x, cfg)
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            q, k, v = self._qkv(p, h, kind, ropes)
            if kind == ATTN_LOCAL:
                W = cache["k"].shape[1]
                slot = pos % W
                kv_len = jnp.minimum(pos + 1, W)
            else:
                slot = pos
                kv_len = pos + 1
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
            o = attention_decode(q, ck, cv, kv_len=kv_len,
                                 softcap=cfg.attn_softcap,
                                 scale=cfg.attn_scale or None)
            o = jnp.einsum("bshk,hkd->bsd", o, p["o"])
            if cfg.use_bias and "o_b" in p:
                o = o + p["o_b"]
            new_cache = {"k": ck, "v": cv}
        elif kind == RGLRU:
            o, new_cache = rglru_mod.rglru_decode_step(p["rglru"], h, cfg, cache)
        elif kind == SSM:
            o, new_cache = ssm_mod.ssd_decode_step(p["ssm"], h, cfg, cache)
        else:  # pragma: no cover
            raise ValueError(kind)
        if cfg.post_norm:
            o = apply_norm(p["norm_post"], o, cfg)
        x = x + o
        if kind != SSM:
            y, _ = self._mlp_part(p, x, moe)
            x = x + y
        return x, new_cache

    # ------------------------------------------------------------------
    # stage application (scan over stacked layers)
    # ------------------------------------------------------------------
    def _run_stages_sequence(self, params, x, positions, *, want_cache,
                             cache_len=0, remat=True):
        cfg = self.cfg
        ropes = self._ropes(positions)
        aux_tot = {}
        caches = {}
        for si, stage in enumerate(self.stages):
            sp = params[f"stage_{si}"]

            def body(xc, layer_p, _stage=stage):
                if cfg.remat_policy == "offload_resid":
                    from jax.ad_checkpoint import checkpoint_name
                    xc = checkpoint_name(xc, "resid_in")
                auxs = {}
                new_c = {}
                for j, kind in enumerate(_stage.kinds):
                    xc, aux, nc = self._block_sequence(
                        layer_p[f"b{j}"], xc, kind, _stage.moe, ropes,
                        want_cache=want_cache, cache_len=cache_len)
                    for k2, v2 in aux.items():
                        auxs[k2] = auxs.get(k2, 0.0) + v2
                    if want_cache:
                        new_c[f"b{j}"] = nc
                return xc, (auxs, new_c)

            if remat and not want_cache:
                body = jax.checkpoint(
                    body, policy=_resolve_policy(cfg.remat_policy),
                    prevent_cse=False)

            x, (auxs, stage_cache) = jax.lax.scan(body, x, sp)
            for k2, v2 in auxs.items():
                aux_tot[k2] = aux_tot.get(k2, 0.0) + jnp.sum(v2)
            if want_cache:
                caches[f"stage_{si}"] = stage_cache
        return x, aux_tot, caches

    def _run_stages_decode(self, params, cache, x, pos):
        ropes = self._ropes(pos[None])
        new_cache = {"pos": pos + 1}
        for si, stage in enumerate(self.stages):
            sp = params[f"stage_{si}"]
            sc = cache[f"stage_{si}"]

            def body(xc, pc, _stage=stage):
                layer_p, layer_c = pc
                new_c = {}
                for j, kind in enumerate(_stage.kinds):
                    xc, nc = self._block_decode(
                        layer_p[f"b{j}"], xc, kind, _stage.moe,
                        layer_c[f"b{j}"], pos, ropes)
                    new_c[f"b{j}"] = nc
                return xc, new_c

            x, nsc = jax.lax.scan(body, x, (sp, sc))
            new_cache[f"stage_{si}"] = nsc
        return x, new_cache

    # ------------------------------------------------------------------
    # embedding / head
    # ------------------------------------------------------------------
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        return x

    def _head_weights(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _logits_last(self, params, x_last):
        """x_last: (B, d) -> (B, V) float32 logits."""
        w = self._head_weights(params)
        logits = jnp.einsum("bd,dv->bv", x_last, w,
                            preferred_element_type=jnp.float32)
        return _softcap(logits, self.cfg.final_softcap)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        if cfg.family == "encoder":
            return self._encoder_loss(params, batch)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        positions = jnp.arange(S)
        x, aux, _ = self._run_stages_sequence(params, x, positions,
                                              want_cache=False)
        x = apply_norm(params["final_norm"], x, cfg)
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
        mask = jnp.concatenate(
            [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)],
            axis=1)
        # Megatron-SP mode: x stays sequence-sharded — a scan over seq chunks
        # would slice across the sharded dim, so compute the xent in one shot
        # (memory is already bounded by the seq × vocab sharding).
        xent_chunk = S if cfg.seq_shard_resid else 512
        nll = chunked_xent(x, self._head_weights(params), targets, mask,
                           softcap=cfg.final_softcap, chunk=xent_chunk)
        loss = nll
        metrics = {"nll": nll, **aux}
        if cfg.moe is not None and "load_balance_loss" in aux:
            loss = loss + cfg.moe.aux_loss_weight * aux["load_balance_loss"] \
                   + 1e-4 * aux["router_z_loss"]
        metrics["loss"] = loss
        return loss, metrics

    def _encoder_loss(self, params, batch):
        cfg = self.cfg
        feats, labels, mask = batch["features"], batch["labels"], batch["mask"]
        logits = self.encode(params, feats)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        correct = jnp.take_along_axis(logits, labels[..., None],
                                      axis=-1)[..., 0]
        m = mask.astype(jnp.float32)
        nll = jnp.sum((lse - correct) * m) / jnp.maximum(m.sum(), 1.0)
        return nll, {"loss": nll, "nll": nll}

    def encode(self, params, feats):
        """Encoder-only forward. feats: (B, S, d_model) precomputed frame
        embeddings (modality frontend is a stub per the assignment)."""
        cfg = self.cfg
        x = feats.astype(_pdt(cfg))
        if cfg.positional == "conv":
            x = conv_pos_embed(params["pos_conv"], x)
        positions = jnp.arange(x.shape[1])
        x, _, _ = self._run_stages_sequence(params, x, positions,
                                            want_cache=False)
        x = apply_norm(params["final_norm"], x, cfg)
        w = self._head_weights(params)
        return jnp.einsum("bsd,dv->bsv", x, w,
                          preferred_element_type=jnp.float32)

    def prefill(self, params, tokens, *, cache_len=0):
        """tokens: (B, S) -> (last_logits (B, V) f32, cache)."""
        cfg = self.cfg
        B, S = tokens.shape
        cache_len = cache_len or S
        x = self._embed(params, tokens)
        positions = jnp.arange(S)
        x, _, caches = self._run_stages_sequence(
            params, x, positions, want_cache=True, cache_len=cache_len,
            remat=False)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = self._logits_last(params, x[:, -1])
        caches["pos"] = jnp.asarray(S, jnp.int32)
        return logits, caches

    def decode_step(self, params, cache, tokens):
        """tokens: (B,) int32; cache from prefill/init_cache."""
        cfg = self.cfg
        pos = cache["pos"]
        x = self._embed(params, tokens[:, None])
        x, new_cache = self._run_stages_decode(params, cache, x, pos)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = self._logits_last(params, x[:, 0])
        return logits, new_cache

    def init_cache(self, batch, cache_len, *, pos=0):
        """Abstract-friendly cache allocator (zeros; used for decode dry-runs
        and serving). Mirrors the pytree produced by prefill()."""
        cfg = self.cfg
        dt = _pdt(cfg)
        caches = {"pos": jnp.asarray(pos, jnp.int32)}
        for si, stage in enumerate(self.stages):
            sc = {}
            for j, kind in enumerate(stage.kinds):
                R = stage.repeat
                if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                    L = min(cfg.window, cache_len) if kind == ATTN_LOCAL \
                        else cache_len
                    shp = (R, batch, L, cfg.n_kv_heads, cfg.head_dim)
                    sc[f"b{j}"] = {"k": jnp.zeros(shp, dt),
                                   "v": jnp.zeros(shp, dt)}
                elif kind == RGLRU:
                    st = rglru_mod.init_rglru_state(cfg, batch)
                    sc[f"b{j}"] = jax.tree.map(
                        lambda a: jnp.broadcast_to(a, (R,) + a.shape), st)
                elif kind == SSM:
                    st = ssm_mod.init_ssm_state(cfg, batch)
                    sc[f"b{j}"] = jax.tree.map(
                        lambda a: jnp.broadcast_to(a, (R,) + a.shape), st)
            caches[f"stage_{si}"] = sc
        return caches


# ---------------------------------------------------------------------------
# chunked cross-entropy (never materializes (B, S, V) logits)
# ---------------------------------------------------------------------------

def chunked_xent(x, w, targets, mask, *, softcap=0.0, chunk=512, z_loss=0.0):
    """Mean masked next-token NLL, scanning over sequence chunks.

    x: (B, S, d); w: (d, V); targets/mask: (B, S).
    """
    B, S, d = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (S + pad) // chunk
    xc = x.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, nc, chunk).transpose(1, 0, 2)

    # remat: the (B, chunk, V) logits of each step are recomputed in the
    # backward pass instead of being saved as scan residuals — without this
    # the xent scan alone holds nc×(B·chunk·V) f32 (observed 58 GiB/device on
    # gemma3 train_4k; ~0.5 GiB with remat).
    @partial(jax.checkpoint,
             policy=jax.checkpoint_policies.nothing_saveable,
             prevent_cse=False)
    def body(carry, xs):
        nll, zacc = carry
        xb, tb, mb = xs
        logits = jnp.einsum("bsd,dv->bsv", xb, w,
                            preferred_element_type=jnp.float32)
        logits = _softcap(logits, softcap)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        correct = jnp.take_along_axis(logits, tb[..., None], axis=-1)[..., 0]
        nll = nll + jnp.sum((lse - correct) * mb)
        zacc = zacc + jnp.sum(jnp.square(lse) * mb)
        return (nll, zacc), None

    (nll, zacc), _ = jax.lax.scan(body, (0.0, 0.0), (xc, tc, mc))
    denom = jnp.maximum(mask.sum(), 1.0)
    out = nll / denom
    if z_loss:
        out = out + z_loss * zacc / denom
    return out
