"""Shared neural-net layers: norms, rope, MLPs, chunked-online-softmax attention.

Attention here is the **XLA path**: a flash-style online-softmax computed with
``lax.scan`` over KV chunks so S×S score matrices are never materialized (this
is mandatory for the 32k-prefill and 500k-decode assigned shapes). The Pallas
kernel in ``repro.kernels.flash_attention`` implements the same math for the
TPU target and is validated against ``attention_reference`` below.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, *, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    # gemma convention: scale is a (1 + s) multiplier
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias, *, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def apply_norm(params, x, cfg):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params.get("bias"))


def init_norm(cfg, d):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), _pdt(cfg))}  # (1+s) convention
    p = {"scale": jnp.ones((d,), _pdt(cfg))}
    if cfg.use_bias:
        p["bias"] = jnp.zeros((d,), _pdt(cfg))
    return p


def _pdt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------

def rope_table(positions, head_dim, theta, rope_pct=1.0):
    """cos/sin tables for (partial) rotary embedding.

    positions: (...,) int32 -> (cos, sin) each (..., rot_dim/2) float32.
    """
    rot_dim = int(head_dim * rope_pct) // 2 * 2
    freqs = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang), rot_dim


def apply_rope(x, cos, sin, rot_dim):
    """x: (..., S, H, D); cos/sin: (S, rot/2) broadcast over batch/heads."""
    if rot_dim == 0:
        return x
    dt = x.dtype
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]  # (S, 1, rot/2) to broadcast over heads
    s = sin[..., :, None, :]
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    return jnp.concatenate([y1.astype(dt), y2.astype(dt), xp], axis=-1)


# ---------------------------------------------------------------------------
# mlp
# ---------------------------------------------------------------------------

def _act(name):
    return jax.nn.silu if name == "silu" else partial(jax.nn.gelu, approximate=True)


def mlp_apply(params, x, cfg):
    act = _act(cfg.act)
    if cfg.gated_mlp:
        g = jnp.einsum("...d,df->...f", x, params["wg"])
        u = jnp.einsum("...d,df->...f", x, params["wu"])
        h = act(g) * u
    else:
        h = jnp.einsum("...d,df->...f", x, params["wi"])
        if "bi" in params:
            h = h + params["bi"]
        h = act(h)
    y = jnp.einsum("...f,fd->...d", h, params["wd"])
    if "bd" in params:
        y = y + params["bd"]
    return y


def init_mlp(key, cfg, d, ff):
    dt = _pdt(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    if cfg.gated_mlp:
        p = {
            "wg": (jax.random.normal(k1, (d, ff)) * s_in).astype(dt),
            "wu": (jax.random.normal(k2, (d, ff)) * s_in).astype(dt),
            "wd": (jax.random.normal(k3, (ff, d)) * s_out).astype(dt),
        }
    else:
        p = {
            "wi": (jax.random.normal(k1, (d, ff)) * s_in).astype(dt),
            "wd": (jax.random.normal(k3, (ff, d)) * s_out).astype(dt),
        }
        if cfg.use_bias:
            p["bi"] = jnp.zeros((ff,), dt)
    if cfg.use_bias:
        p["bd"] = jnp.zeros((d,), dt)
    return p


# ---------------------------------------------------------------------------
# attention — XLA chunked online-softmax paths
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _softcap(s, cap):
    if cap and cap > 0.0:
        return jnp.tanh(s / cap) * cap
    return s


def attention_reference(q, k, v, *, causal, window=0, softcap=0.0, scale=None,
                        q_start=0):
    """Naive O(S²) oracle. q:(B,Sq,H,D) k,v:(B,Skv,K,D). Used by tests only."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale or 1.0 / math.sqrt(D)
    qf = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf * scale, k.astype(jnp.float32))
    s = _softcap(s, softcap)
    qpos = q_start + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def _expand_kv(k, n_heads):
    """(B, S, K, D) -> (B, S, H, D) by repeating each KV head H/K times.

    GQA sharding note: attention score einsums index heads by H (not (K, G))
    so the head dim shards cleanly over the "model" mesh axis whenever
    H % tp == 0 even if K < tp. The repeat is a gather; when H is sharded,
    each device only materializes its own head slice.
    """
    K = k.shape[2]
    if K == n_heads:
        return k
    return jnp.repeat(k, n_heads // K, axis=2)


def attention_full(q, k, v, *, causal, softcap=0.0, scale=None, chunk=1024,
                   chunk_q=0, q_start=0):
    """Online-softmax, doubly chunked (q and kv) — never builds S×S and keeps
    per-step score blocks at (B, H, chunk_q, chunk) regardless of S.

    q: (B, Sq, H, D); k, v: (B, Skv, K, D) with H % K == 0.
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    scale = scale or 1.0 / math.sqrt(D)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    ck = min(chunk, Skv)
    cq = min(chunk_q or chunk, Sq)
    pad_k = (-Skv) % ck
    pad_q = (-Sq) % cq
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nk = (Skv + pad_k) // ck
    nq = (Sq + pad_q) // cq
    kc = k.reshape(B, nk, ck, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ck, H, D).transpose(1, 0, 2, 3, 4)
    qc = (q.reshape(B, nq, cq, H, D) * scale).astype(q.dtype).transpose(1, 0, 2, 3, 4)

    def q_body(_, xs_q):
        qi, qb = xs_q

        # flash-style backward: remat each kv step so (B,H,cq,ck) score
        # blocks are recomputed per-chunk in the VJP instead of being saved
        # stacked across the scan (they dominated peak memory otherwise)
        @partial(jax.checkpoint,
                 policy=jax.checkpoint_policies.nothing_saveable,
                 prevent_cse=False)
        def kv_body(carry, xs_kv):
            m, l, acc = carry
            ki, kb, vb = xs_kv
            s = jnp.einsum("bqhd,bchd->bhqc", qb, kb,
                           preferred_element_type=jnp.float32)
            s = _softcap(s, softcap)
            qpos = q_start + qi * cq + jnp.arange(cq)
            kpos = ki * ck + jnp.arange(ck)
            msk = (kpos[None, :] < Skv) & (qpos[:, None] < q_start + Sq)
            if causal:
                msk = msk & (qpos[:, None] >= kpos[None, :])
            s = jnp.where(msk[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqc,bchd->bhqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                      (jnp.arange(nk), kc, vc))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o.transpose(0, 2, 1, 3)  # (B, cq, H, D)

    _, oc = jax.lax.scan(q_body, None, (jnp.arange(nq), qc))
    o = oc.transpose(1, 0, 2, 3, 4).reshape(B, nq * cq, H, D)
    return o[:, :Sq].astype(q.dtype)


def attention_local(q, k, v, *, window, softcap=0.0, scale=None, chunk=1024,
                    causal=True):
    """Sliding-window attention, linear in S: scan over q chunks, each
    attending a static (chunk + window)-wide KV span. Requires q/k aligned
    (self-attention over the same positions)."""
    B, S, H, D = q.shape
    scale = scale or 1.0 / math.sqrt(D)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    cq = min(chunk, S)
    pad_q = (-S) % cq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nq = (S + pad_q) // cq
    W = window
    kp = jnp.pad(k, ((0, 0), (W, pad_q), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (W, pad_q), (0, 0), (0, 0)))
    qc = q.reshape(B, nq, cq, H, D).transpose(1, 0, 2, 3, 4)
    span = cq + W

    @partial(jax.checkpoint,
             policy=jax.checkpoint_policies.nothing_saveable,
             prevent_cse=False)
    def body(_, xs):
        i, qb = xs
        qb = (qb * scale).astype(q.dtype)
        kb = jax.lax.dynamic_slice_in_dim(kp, i * cq, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, i * cq, span, axis=1)
        s = jnp.einsum("bqhd,bshd->bhqs", qb, kb,
                       preferred_element_type=jnp.float32)
        s = _softcap(s, softcap)
        # absolute positions: q = i*cq + aq ; kv = i*cq + ak - W
        aq = jnp.arange(cq)[:, None]
        ak = jnp.arange(span)[None, :]
        qpos = i * cq + aq
        kpos = i * cq + ak - W
        msk = (kpos >= 0) & (qpos < S)
        if causal:
            msk &= (qpos >= kpos) & (qpos - kpos < W)
        else:
            msk &= jnp.abs(qpos - kpos) < W
        s = jnp.where(msk[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshd->bqhd", p.astype(vb.dtype), vb,
                       preferred_element_type=jnp.float32)
        return None, o

    _, oc = jax.lax.scan(body, None, (jnp.arange(nq), qc))
    o = oc.transpose(1, 0, 2, 3, 4).reshape(B, nq * cq, H, D)
    return o[:, :S].astype(q.dtype)


def attention_decode(q, k, v, *, kv_len, window=0, softcap=0.0, scale=None,
                     pos=None):
    """Single-token decode over a (possibly ring-buffered) KV cache.

    q: (B, 1, H, D); k, v: (B, Smax, K, D); kv_len: number of valid entries.
    For ring buffers (window caches), entries are valid iff slot < min(len, Smax).
    """
    B, _, H, D = q.shape
    Smax, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale or 1.0 / math.sqrt(D)
    qf = (q.reshape(B, K, G, D) * scale)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k, preferred_element_type=jnp.float32)
    s = _softcap(s, softcap)
    valid = jnp.arange(Smax)[None, :] < jnp.minimum(kv_len, Smax)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# conv positional embedding (HuBERT) and causal conv1d (mamba/rglru)
# ---------------------------------------------------------------------------

def conv_pos_embed(params, x):
    """Depthwise same-padded conv positional embedding (w2v2/HuBERT style).

    Implemented as a real grouped convolution: the obvious
    stack-of-shifted-slices formulation materializes a width(=128)×
    activation tensor — 21 GiB/device at hubert train_4k (§Perf hillclimb:
    this one change removed ~80 GiB of peak temp)."""
    w = params["w"]  # (width, d)
    width, d = w.shape
    dn = jax.lax.conv_dimension_numbers(
        x.shape, (width, 1, d), ("NWC", "WIO", "NWC"))
    pos = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32).reshape(width, 1, d),
        window_strides=(1,),
        padding=[(width // 2, width - 1 - width // 2)],
        dimension_numbers=dn,
        feature_group_count=d)
    return x + jax.nn.gelu(pos).astype(x.dtype)


def causal_conv1d(x, w, b=None, *, state=None):
    """Causal depthwise conv. x: (B, S, C); w: (width, C).

    If state (B, width-1, C) is given, it is prepended (decode) and the new
    state returned; else zero history (train/prefill).
    """
    width = w.shape[0]
    if state is None:
        hist = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        hist = state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)
    out = jnp.zeros(x.shape, jnp.float32)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    new_state = xp[:, -(width - 1):] if width > 1 else hist
    return out.astype(x.dtype), new_state
