"""Mixture-of-Experts layer: top-k routing, capacity dispatch via scatter/gather.

Design notes (roofline-driven):
  * The classic GShard one-hot dispatch einsum costs O(T·E·C·D) matmul FLOPs —
    for kimi-k2 (E=384) that exceeds the expert FLOPs themselves and would
    poison the HLO-FLOPs roofline term. We instead dispatch with
    scatter/gather (no matmul FLOPs) so HLO compute ≈ active-parameter
    compute.
  * Tokens are processed in groups (GSPMD-friendly): group axis shards over
    ("pod","data"), expert axis of the packed buffer shards over "model" (EP);
    XLA inserts the all-to-all at the expert einsum boundary.
  * position-in-expert is computed with a chunked cumulative count (bounded
    memory, no (T·k, E) one-hot materialization).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import _act, _pdt, init_mlp, mlp_apply


def capacity(mcfg, group_tokens: int) -> int:
    c = math.ceil(mcfg.top_k * group_tokens * mcfg.capacity_factor / mcfg.n_experts)
    return max(16, -(-c // 16) * 16)  # round up to multiple of 16 (MXU lanes)


def init_moe(key, cfg, d):
    m = cfg.moe
    dt = _pdt(cfg)
    ks = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(m.d_expert)
    p = {
        "router": (jax.random.normal(ks[0], (d, m.n_experts)) * s_in).astype(jnp.float32),
        "wg": (jax.random.normal(ks[1], (m.n_experts, d, m.d_expert)) * s_in).astype(dt),
        "wu": (jax.random.normal(ks[2], (m.n_experts, d, m.d_expert)) * s_in).astype(dt),
        "wd": (jax.random.normal(ks[3], (m.n_experts, m.d_expert, d)) * s_out).astype(dt),
    }
    if m.n_shared_experts:
        shared_ff = m.d_expert * m.n_shared_experts
        p["shared"] = init_mlp(ks[4], cfg, d, shared_ff)
    return p


def _positions_in_expert(idx_flat, n_experts, *, block=2048):
    """Arrival-order position of each assignment within its expert.

    idx_flat: (N,) int32 expert ids (token-major ⇒ earlier tokens win
    capacity, GShard semantics). Returns (pos (N,), counts (E,)).
    Memory-bounded: processes N in blocks of `block` (cumsum over a
    (block, E) one-hot instead of (N, E)).
    """
    n = idx_flat.shape[0]
    pad = (-n) % block
    idx_p = jnp.pad(idx_flat, (0, pad), constant_values=n_experts)  # OOB pad
    blocks = idx_p.reshape(-1, block)

    def body(counts, ib):
        oh = jax.nn.one_hot(ib, n_experts, dtype=jnp.int32)  # (block, E)
        excl = jnp.cumsum(oh, axis=0) - oh
        pos_b = counts[None, :] + excl
        pos_b = jnp.take_along_axis(
            pos_b, jnp.clip(ib, 0, n_experts - 1)[:, None], axis=1)[:, 0]
        return counts + oh.sum(axis=0), pos_b

    counts, pos = jax.lax.scan(body, jnp.zeros((n_experts,), jnp.int32), blocks)
    return pos.reshape(-1)[:n], counts


def moe_apply(params, x, cfg, *, group_size=4096):
    """x: (B, S, D) -> (y, aux) with aux = {load_balance_loss, router_z_loss,
    drop_fraction}."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    g = min(group_size, T)
    assert T % g == 0, (T, g)
    G = T // g
    C = capacity(m, g)
    E, k = m.n_experts, m.top_k

    xt = x.reshape(G, g, D)
    # ---- routing (f32) ----
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                      # (G, g, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    topi = jax.lax.stop_gradient(topi)

    # ---- aux losses (switch-style load balance + z-loss) ----
    me = probs.mean(axis=(0, 1))                               # (E,)
    ce = jax.nn.one_hot(topi[..., 0], E).mean(axis=(0, 1))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

    # ---- positions within experts (per group, scanned: bounded memory) ----
    def per_group_pos(ti):
        return _positions_in_expert(ti.reshape(-1), E)        # (g*k,), (E,)
    pos, counts = jax.lax.map(per_group_pos, topi)            # (G,g*k),(G,E)
    pos = pos.reshape(G, g, k)
    within = pos < C                                           # capacity mask
    drop_frac = 1.0 - within.mean()

    # ---- dispatch: scatter tokens into (G, E, C, D) ----
    e_flat = topi.reshape(G, g * k)
    p_flat = jnp.where(within, pos, C).reshape(G, g * k)       # C slot = dropped

    def scatter_group(xg, eg, pg):
        buf = jnp.zeros((E, C, D), xg.dtype)
        src = jnp.repeat(xg, k, axis=0)                        # (g*k, D)
        return buf.at[eg, pg].set(src, mode="drop")

    buf = jax.vmap(scatter_group)(xt, e_flat, p_flat)          # (G, E, C, D)

    # ---- expert FFNs (batched einsum; E shards over "model" ⇒ EP) ----
    act = _act(cfg.act)
    h = act(jnp.einsum("gecd,edf->gecf", buf, params["wg"])) * \
        jnp.einsum("gecd,edf->gecf", buf, params["wu"])
    out = jnp.einsum("gecf,efd->gecd", h, params["wd"])        # (G, E, C, D)

    # ---- combine: gather back, weight, sum over k ----
    def gather_group(og, eg, pg):
        return og[eg, pg]                                      # (g*k, D)
    y = jax.vmap(gather_group)(out, e_flat, p_flat)            # (G, g*k, D)
    y = y.reshape(G, g, k, D)
    w = (topw * within).astype(y.dtype)
    y = jnp.einsum("gtkd,gtk->gtd", y, w)

    if m.n_shared_experts:
        y = y + mlp_apply(params["shared"], xt, cfg)

    aux = {"load_balance_loss": lb_loss, "router_z_loss": z_loss,
           "drop_fraction": drop_frac}
    return y.reshape(B, S, D), aux
