"""Mamba-2 SSD (state-space duality) block.

Training/prefill uses the chunked SSD algorithm as a single sequential
``lax.scan`` over chunks (memory-lean: per-chunk L×L decay blocks only, no
(S/L)-way batching of quadratic blocks). Decode is the O(1) recurrent update.
Equivalence chunked ⇔ recurrent is property-tested in tests/test_ssm.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import _pdt, causal_conv1d, rmsnorm


def dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nh = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, nh, conv_dim


def init_ssm(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nh, conv_dim = dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + nh
    dt = _pdt(cfg)
    ks = jax.random.split(key, 4)
    # dt_bias: inverse-softplus of dt ~ U[1e-3, 1e-1] (mamba2 init)
    u = jax.random.uniform(ks[2], (nh,), minval=math.log(1e-3), maxval=math.log(1e-1))
    dt0 = jnp.exp(u)
    return {
        "in_proj": (jax.random.normal(ks[0], (d, d_in_proj)) / math.sqrt(d)).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim)) / math.sqrt(s.d_conv)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": (dt0 + jnp.log(-jnp.expm1(-dt0))).astype(jnp.float32),
        "out_norm": jnp.zeros((d_inner,), dt),
        "out_proj": (jax.random.normal(ks[3], (d_inner, d)) / math.sqrt(d_inner)).astype(dt),
    }


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, nh, _ = dims(cfg)
    gn = s.n_groups * s.d_state
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: 2 * d_inner + 2 * gn]
    dt = zxbcdt[..., 2 * d_inner + 2 * gn:]
    return z, xBC, dt


def ssd_forward(params, x, cfg, *, state=None, return_state=False):
    """x: (B, S, D) -> y (B, S, D) [, new_state].

    state = {"conv": (B, w-1, conv_dim), "h": (B, nh, hd, N) f32} or None.
    """
    s = cfg.ssm
    B, S, D = x.shape
    d_inner, nh, conv_dim = dims(cfg)
    G, N, hd, L = s.n_groups, s.d_state, s.head_dim, s.chunk_size
    L = min(L, S)
    assert S % L == 0, (S, L)
    nc = S // L

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xBC, dtr = _split_proj(cfg, zxbcdt)
    conv_state = None if state is None else state["conv"]
    xBC, new_conv = causal_conv1d(xBC, params["conv_w"], params["conv_b"],
                                  state=conv_state)
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :d_inner].reshape(B, S, nh, hd)
    Bm = xBC[..., d_inner:d_inner + G * N].reshape(B, S, G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(params["A_log"])                                      # (nh,)

    rep = nh // G
    to_heads = lambda t: jnp.repeat(t, rep, axis=2)  # (B,L,G,N)->(B,L,nh,N)

    xc = xs.reshape(B, nc, L, nh, hd)
    Bc = Bm.reshape(B, nc, L, G, N)
    Cc = Cm.reshape(B, nc, L, G, N)
    dtc = dt.reshape(B, nc, L, nh)

    h0 = (jnp.zeros((B, nh, hd, N), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))

    def chunk_body(h, xs_c):
        xk, Bk, Ck, dtk = xs_c                       # (B,L,...)
        dA = dtk * A                                 # (B,L,nh) <= 0
        cum = jnp.cumsum(dA, axis=1)                 # (B,L,nh)
        Bh, Ch = to_heads(Bk), to_heads(Ck)          # (B,L,nh,N)
        xdt = (xk.astype(jnp.float32) *
               dtk[..., None])                        # (B,L,nh,hd)
        # intra-chunk (quadratic within chunk)
        cb = jnp.einsum("bihn,bjhn->bhij", Ch.astype(jnp.float32),
                        Bh.astype(jnp.float32))
        seg = cum[:, :, None] - cum[:, None, :]      # (B,i,j,nh)
        seg = jnp.transpose(seg, (0, 3, 1, 2))       # (B,nh,i,j)
        mask = jnp.tril(jnp.ones((L, L), bool))
        M = jnp.where(mask, jnp.exp(seg), 0.0)
        y = jnp.einsum("bhij,bjhp->bihp", cb * M, xdt)
        # inter-chunk contribution from carried state
        y = y + jnp.einsum("bihn,bhpn->bihp",
                           Ch.astype(jnp.float32) * jnp.exp(cum)[..., None],
                           h) * 1.0
        # state update
        w = jnp.exp(cum[:, -1:, :] - cum)            # (B,L,nh)
        s_c = jnp.einsum("bjhn,bjhp->bhpn", Bh.astype(jnp.float32) * w[..., None],
                         xdt)
        h = jnp.exp(cum[:, -1])[..., None, None] * h + s_c
        return h, y

    xs_seq = (xc.transpose(1, 0, 2, 3, 4), Bc.transpose(1, 0, 2, 3, 4),
              Cc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3))
    h_final, yc = jax.lax.scan(chunk_body, h0, xs_seq)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, hd)
    y = y + params["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    if return_state:
        return out, {"conv": new_conv, "h": h_final.astype(jnp.float32)}
    return out


def ssd_decode_step(params, x, cfg, state):
    """x: (B, 1, D); state {"conv","h"} -> (y (B,1,D), new_state)."""
    s = cfg.ssm
    B = x.shape[0]
    d_inner, nh, conv_dim = dims(cfg)
    G, N, hd = s.n_groups, s.d_state, s.head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xBC, dtr = _split_proj(cfg, zxbcdt)
    xBC, new_conv = causal_conv1d(xBC, params["conv_w"], params["conv_b"],
                                  state=state["conv"])
    xBC = jax.nn.silu(xBC)
    xs = xBC[:, 0, :d_inner].reshape(B, nh, hd)
    Bm = xBC[:, 0, d_inner:d_inner + G * N].reshape(B, G, N)
    Cm = xBC[:, 0, d_inner + G * N:].reshape(B, G, N)
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    rep = nh // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)   # (B,nh,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt * A)                                    # (B,nh)
    xdt = xs.astype(jnp.float32) * dt[..., None]            # (B,nh,hd)
    h = dA[..., None, None] * state["h"] + \
        jnp.einsum("bhn,bhp->bhpn", Bh, xdt)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h)
    y = y + params["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, {"conv": new_conv, "h": h}


def init_ssm_state(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    d_inner, nh, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), _pdt(cfg)),
        "h": jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype),
    }
