"""Griffin/RecurrentGemma recurrent block: conv + RG-LRU with diagonal gates.

Training/prefill uses ``jax.lax.associative_scan`` (parallel prefix over the
diagonal linear recurrence); decode is the O(1) update. Deviation from the
paper noted in DESIGN.md: Griffin's block-diagonal gate matrices are
simplified to per-channel (diagonal) gates — parameter counts stay within the
assigned 9B class and the recurrence semantics are unchanged.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import _pdt, causal_conv1d

_C = 8.0  # Griffin's fixed recurrence sharpness constant


def init_rglru(key, cfg):
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    dt = _pdt(cfg)
    ks = jax.random.split(key, 4)
    # init so a = exp(-c*softplus(L)*r) has decay ~U[0.9, 0.999] at r=1
    a0 = jax.random.uniform(ks[3], (w,), minval=0.9, maxval=0.999)
    sp = -jnp.log(a0) / _C                       # softplus(L) target
    lam = jnp.log(jnp.expm1(sp))
    return {
        "wx": (jax.random.normal(ks[0], (d, w)) / math.sqrt(d)).astype(dt),
        "wg": (jax.random.normal(ks[1], (d, w)) / math.sqrt(d)).astype(dt),
        "wo": (jax.random.normal(ks[2], (w, d)) / math.sqrt(w)).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (r.conv_width, w)) / math.sqrt(r.conv_width)).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "lam": lam.astype(jnp.float32),
        "gate_a_w": jnp.zeros((w,), jnp.float32),
        "gate_a_b": jnp.zeros((w,), jnp.float32),
        "gate_x_w": jnp.zeros((w,), jnp.float32),
        "gate_x_b": jnp.zeros((w,), jnp.float32),
    }


def _gates(params, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * params["gate_a_w"] + params["gate_a_b"])
    i = jax.nn.sigmoid(uf * params["gate_x_w"] + params["gate_x_b"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r        # <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i * uf)
    return a, b


def rglru_forward(params, x, cfg, *, state=None, return_state=False):
    """x: (B, S, D) -> (B, S, D). state = {"conv", "h"(B,W) f32} or None."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["wg"]))
    u = jnp.einsum("bsd,dw->bsw", x, params["wx"])
    conv_state = None if state is None else state["conv"]
    u, new_conv = causal_conv1d(u, params["conv_w"], params["conv_b"],
                                state=conv_state)
    a, b = _gates(params, u)                                 # (B,S,W) f32
    if state is not None:
        # fold carried hidden state into the first step
        b = b.at[:, 0].add(a[:, 0] * state["h"])

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (gate.astype(jnp.float32) * h).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, params["wo"])
    if return_state:
        return out, {"conv": new_conv, "h": h[:, -1].astype(jnp.float32)}
    return out


def rglru_decode_step(params, x, cfg, state):
    """x: (B, 1, D) -> (y, new_state)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["wg"]))
    u = jnp.einsum("bsd,dw->bsw", x, params["wx"])
    u, new_conv = causal_conv1d(u, params["conv_w"], params["conv_b"],
                                state=state["conv"])
    a, b = _gates(params, u)                                 # (B,1,W)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = (gate[:, 0].astype(jnp.float32) * h).astype(x.dtype)
    out = jnp.einsum("bw,wd->bd", y, params["wo"])[:, None]
    return out, {"conv": new_conv, "h": h}


def init_rglru_state(cfg, batch):
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, r.conv_width - 1, w), _pdt(cfg)),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
