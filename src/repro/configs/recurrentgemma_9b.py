"""RecurrentGemma 9B — Griffin hybrid: RG-LRU recurrent blocks + local attention, 2:1.

[arXiv:2402.19427; unverified]  38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000. Pattern: (recurrent, recurrent, local-attention) repeating;
window 2048; lru_width=4096; tied embeddings; gelu gated MLP.
Sub-quadratic: runs long_500k (state is O(1) for LRU, O(window) for local attn).
"""
from .base import ATTN_LOCAL, RGLRU, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    pattern=(RGLRU, RGLRU, ATTN_LOCAL),
    window=2048,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    rope_theta=10_000.0,
    act="gelu",
    gated_mlp=True,
    norm="rmsnorm",
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2402.19427; unverified",
)
