"""StarCoder2 3B — dense GQA, RoPE.

[arXiv:2402.19173; hf]  30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
LayerNorm + biases, non-gated gelu MLP (classic FFN), rope_theta ~1e6.
Treated as full attention per the assignment bracket ("GQA, RoPE").
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12_288,
    vocab_size=49_152,
    rope_theta=999_999.44,
    act="gelu",
    gated_mlp=False,
    use_bias=True,
    norm="layernorm",
    source="arXiv:2402.19173; hf:bigcode/starcoder2-3b",
)
