"""Assigned input shapes and the (arch × shape) applicability matrix."""
from __future__ import annotations

from dataclasses import dataclass

from .base import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether this (arch × shape) cell is runnable, with a reason when not.

    Rules from the assignment:
      - encoder-only archs have no autoregressive decode step;
      - long_500k needs sub-quadratic attention (SSM / hybrid / mostly-local).
    """
    if cfg.family == "encoder" and shape.kind == "decode":
        return False, "encoder-only arch: no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode excluded per assignment"
    return True, ""


def cells(configs: dict[str, ModelConfig]) -> list[tuple[str, str]]:
    """All runnable (arch, shape) cells, in deterministic order."""
    out = []
    for arch in sorted(configs):
        for shape in SHAPES.values():
            ok, _ = applicable(configs[arch], shape)
            if ok:
                out.append((arch, shape.name))
    return out
