"""Kimi K2 — trillion-param MoE (384 experts, top-8, 1 shared, first layer dense).

[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert)
vocab=163840. head_dim=128 per the public config (64*128=8192 != d_model — q/k/v
projections are rectangular). Optimizer: adafactor (1T params — Adam state would
not fit 256 chips).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,  # expert hidden width (assigned)
    vocab_size=163_840,
    moe=MoEConfig(
        n_experts=384,
        top_k=8,
        d_expert=2048,
        n_shared_experts=1,
        first_k_dense=1,
        dense_d_ff=18_432,
        capacity_factor=1.25,
    ),
    rope_theta=50_000.0,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    optimizer="adafactor",
    remat_policy="nothing",  # save nothing: 1T-param activations must recompute
    source="arXiv:2501.kimi2 (paper-table); unverified",
)
