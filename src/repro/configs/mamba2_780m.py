"""Mamba-2 780M — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]  48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128. expand=2 (d_inner=3072), head_dim=64 (48 SSM heads), conv=4,
chunked SSD with chunk 256. Tied embeddings. No separate MLP per block
(mamba block is the whole layer). Sub-quadratic: runs long_500k.
"""
from .base import SSM, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    pattern=(SSM,),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    positional="none",
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
