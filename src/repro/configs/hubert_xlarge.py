"""HuBERT X-Large — audio encoder-only transformer backbone.

[arXiv:2106.07447; unverified]  48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
(k-means cluster codebook). Encoder-only (bidirectional), conv positional
embedding, LayerNorm, non-gated gelu FFN. The modality FRONTEND IS A STUB per
the assignment: input_specs() supplies precomputed frame embeddings
(B, S, d_model) + cluster labels + mask; the CNN feature extractor is not
modeled. Loss = masked cluster prediction.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    positional="conv",
    act="gelu",
    gated_mlp=False,
    use_bias=True,
    norm="layernorm",
    source="arXiv:2106.07447; unverified",
)
