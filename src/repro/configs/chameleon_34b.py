"""Chameleon 34B — early-fusion VLM; VQ image tokens share the text vocab.

[arXiv:2405.09818; unverified]  48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536. qk-norm (critical for chameleon stability), silu gated MLP.
The VQ-VAE image tokenizer FRONTEND IS A STUB per the assignment: inputs are
token ids already containing image tokens (early fusion = one sequence).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab_size=65_536,
    qk_norm=True,
    rope_theta=10_000.0,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    optimizer="adafactor",
    source="arXiv:2405.09818; unverified",
)
