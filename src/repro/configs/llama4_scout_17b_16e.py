"""Llama-4 Scout 17B-active / 16-expert MoE, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L d_model=5120 40H (GQA kv=8)
d_ff=8192(expert) vocab=202048, MoE 16e top-1 + 1 shared expert, every layer MoE.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,  # expert hidden width (assigned)
    vocab_size=202_048,
    moe=MoEConfig(
        n_experts=16,
        top_k=1,
        d_expert=8192,
        n_shared_experts=1,
        capacity_factor=1.5,  # top-1 routing needs slack (Switch-style)
    ),
    rope_theta=500_000.0,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    optimizer="adafactor",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
