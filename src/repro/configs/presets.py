"""Production parallelism presets — the §Perf hillclimb results codified.

Each assigned architecture maps to the config overrides that won its
roofline iteration (EXPERIMENTS.md §Perf). Launchers apply these with
``--preset``; the defaults (no preset) remain the paper-faithful baseline so
both variants stay reproducible.
"""
from __future__ import annotations

# arch -> (train-time overrides, rationale)
PRESETS: dict = {
    "kimi-k2-1t-a32b": (
        {"moe_impl": "shard_map", "seq_shard_resid": True},
        "explicit EP all_to_all (7.2x collective) + Megatron-SP residuals"),
    "llama4-scout-17b-a16e": (
        {"moe_impl": "shard_map", "seq_shard_resid": True},
        "EP + SP: frac 0.059 -> 0.163"),
    "chameleon-34b": (
        {"seq_shard_resid": True},
        "SP shards residual/cotangent f32 buffers 16x: HBM 148 -> 22 GiB"),
    "gemma2-9b": (
        {"seq_shard_resid": True},
        "SP: HBM 35 -> 26 GiB"),
    "recurrentgemma-9b": (
        {"seq_shard_resid": True},
        "SP: frac 0.13 -> 0.19"),
    "starcoder2-3b": (
        {"seq_shard_resid": True},
        "SP (marginal; heads don't divide tp=16 so SP attn already active)"),
    "gemma3-1b": (
        {"dp_over_model": True},
        "H=4 heads can't shard tp=16: full-DP, frac 0.081 -> 0.245"),
    "stablelm-1.6b": (
        {"dp_over_model": True},
        "small dense: full-DP, frac 0.034 -> 0.077"),
    "hubert-xlarge": (
        {"dp_over_model": True},
        "encoder: full-DP + grouped conv fix, HBM 128 -> 2 GiB"),
    "mamba2-780m": (
        {"dp_over_model": True},
        "attention-free small model: full-DP; SSD chunk 1024 for prefill"),
}


def preset_overrides(arch_id: str) -> dict:
    return dict(PRESETS.get(arch_id, ({}, ""))[0])
