"""StableLM-2 1.6B — dense MHA (kv=32 = full), partial rotary.

[hf:stabilityai/stablelm-2-1_6b; unverified]  24L d_model=2048 32H (kv=32)
d_ff=5632 vocab=100352. LayerNorm, 25% rotary, gated silu MLP.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100_352,
    rope_theta=10_000.0,
    rope_pct=0.25,
    act="silu",
    gated_mlp=True,
    norm="layernorm",
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
