"""Gemma-2 9B — dense, local/global alternating, logit softcaps.

[arXiv:2408.00118; hf]  42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
head_dim=256, window 4096, attn softcap 50, final softcap 30, pre+post norms,
tied embeddings, gelu gated MLP.
"""
from .base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14_336,
    vocab_size=256_000,
    pattern=(ATTN_LOCAL, ATTN_GLOBAL),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    attn_scale=0.0625,  # 1/sqrt(query_pre_attn_scalar=256)
    rope_theta=10_000.0,
    act="gelu",
    gated_mlp=True,
    norm="rmsnorm",
    post_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2408.00118; hf:google/gemma-2-9b",
)
