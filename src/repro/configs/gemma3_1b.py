"""Gemma-3 1B — dense, 5:1 local:global attention, 128k-context design.

[hf:google/gemma-3-1b-pt; unverified]  26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144. head_dim=256, sliding window 512, local rope base 10k vs global 1M,
qk-norm, tied embeddings, gelu gated MLP.
"""
from .base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    pattern=(ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,),
    window=512,
    qk_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    act="gelu",
    gated_mlp=True,
    norm="rmsnorm",
    post_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
