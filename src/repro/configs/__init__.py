"""Architecture registry: all 10 assigned architectures, selectable via --arch."""
from __future__ import annotations

from .base import (ATTN_GLOBAL, ATTN_LOCAL, RGLRU, SSM, ModelConfig, MoEConfig,
                   RGLRUConfig, SSMConfig, Stage, build_stages, param_counts,
                   reduced)
from .shapes import SHAPES, ShapeSpec, applicable, cells

from . import (chameleon_34b, gemma2_9b, gemma3_1b, hubert_xlarge,
               kimi_k2_1t_a32b, llama4_scout_17b_16e, mamba2_780m,
               recurrentgemma_9b, stablelm_1_6b, starcoder2_3b)

_MODULES = (
    kimi_k2_1t_a32b, llama4_scout_17b_16e, gemma3_1b, stablelm_1_6b,
    starcoder2_3b, gemma2_9b, hubert_xlarge, recurrentgemma_9b, mamba2_780m,
    chameleon_34b,
)

CONFIGS: dict[str, ModelConfig] = {m.CONFIG.arch_id: m.CONFIG for m in _MODULES}
ARCH_IDS = tuple(sorted(CONFIGS))


def get_config(arch_id: str) -> ModelConfig:
    try:
        return CONFIGS[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(ARCH_IDS)}"
        ) from None


__all__ = [
    "ATTN_GLOBAL", "ATTN_LOCAL", "RGLRU", "SSM", "ModelConfig", "MoEConfig",
    "RGLRUConfig", "SSMConfig", "Stage", "build_stages", "param_counts",
    "reduced", "SHAPES", "ShapeSpec", "applicable", "cells", "CONFIGS",
    "ARCH_IDS", "get_config",
]
