"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig`` in
``src/repro/configs/<arch>.py`` using the exact assigned hyperparameters.
The config is the *only* thing the checkpoint format depends on besides the
state itself (split-state model: the lower half — mesh, executables — is
reconstructed from config at restore time, never persisted).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace

# Block kinds understood by the model zoo.
ATTN_GLOBAL = "attn_global"
ATTN_LOCAL = "attn_local"
RGLRU = "rglru"
SSM = "ssm"
BLOCK_KINDS = (ATTN_GLOBAL, ATTN_LOCAL, RGLRU, SSM)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # hidden width of each expert MLP
    n_shared_experts: int = 0     # always-on shared experts (DeepSeek/Kimi style)
    capacity_factor: float = 1.25
    first_k_dense: int = 0        # leading layers use a dense MLP (Kimi-K2 style)
    dense_d_ff: int = 0           # d_ff of those dense layers (0 -> d_expert)
    aux_loss_weight: float = 0.01
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD hyperparameters."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class RGLRUConfig:
    """Griffin/RecurrentGemma recurrent-block hyperparameters."""
    lru_width: int = 0            # 0 -> d_model
    conv_width: int = 4
    n_lru_heads: int = 0          # 0 -> block-diagonal heads off (single head)


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- block pattern (repeats to cover n_layers) ---
    pattern: tuple = (ATTN_GLOBAL,)
    window: int = 0               # sliding window for attn_local
    causal: bool = True
    # --- attention details ---
    qk_norm: bool = False
    attn_softcap: float = 0.0     # gemma2 logit soft-capping
    final_softcap: float = 0.0    # gemma2 final-logit soft-capping
    attn_scale: float = 0.0       # 0 -> 1/sqrt(head_dim)
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0  # rope base for local layers (0 -> rope_theta)
    rope_pct: float = 1.0          # fraction of head_dim rotated (stablelm: 0.25)
    positional: str = "rope"       # rope | conv | none
    # --- mlp ---
    act: str = "silu"              # silu | gelu
    gated_mlp: bool = True
    use_bias: bool = False
    # --- norms ---
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    post_norm: bool = False        # gemma2-style post-block norms
    # --- embeddings ---
    tie_embeddings: bool = False
    embed_scale: bool = False      # multiply embeddings by sqrt(d_model) (gemma)
    # --- sub-configs ---
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    # --- numerics / execution ---
    dtype: str = "bfloat16"
    optimizer: str = "adamw"
    remat_policy: str = "nothing"  # nothing | dots | full (what to SAVE);
                                   # "dots" saves every projection output —
                                   # 58 GiB/device on gemma3 train_4k
                                   # vs ~6 GiB for "nothing"
    scan_layers: bool = True
    attn_chunk: int = 1024         # kv-chunk size for online-softmax XLA path
    attn_impl: str = "xla"         # xla | pallas (pallas = TPU target path)
    seq_shard_attn: bool = False   # set by launcher when n_heads % tp != 0:
                                   # shard attention over sequence instead of
                                   # heads (no q-chunk scan; kv replicated)
    moe_impl: str = "gspmd"        # gspmd (baseline: XLA-chosen collectives)
                                   # | shard_map (explicit EP all-to-all —
                                   #   §Perf hillclimb, ~35x collective win)
    dp_over_model: bool = False    # small-model hillclimb: batch shards over
                                   # BOTH mesh axes (pure DP; model axis
                                   # carries batch instead of idle replicas)
    seq_shard_resid: bool = False  # Megatron-SP hillclimb: residual stream
                                   # sharded over "model" on the seq dim —
                                   # norms/residuals/logits shrink by tp and
                                   # TP all-reduces become reduce-scatter +
                                   # all-gather pairs
    # --- provenance ---
    source: str = ""

    def __post_init__(self):
        for k in self.pattern:
            if k not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {k!r}")
        if ATTN_LOCAL in self.pattern and self.window <= 0:
            raise ValueError("attn_local requires window > 0")

    # ---- derived ----
    @property
    def layer_kinds(self) -> tuple:
        """Per-layer block kind, length n_layers."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def moe_layer(self, i: int) -> bool:
        return self.moe is not None and i >= self.moe.first_k_dense

    @property
    def global_attn_fraction(self) -> float:
        kinds = self.layer_kinds
        n_attn = sum(k.startswith("attn") for k in kinds)
        if n_attn == 0:
            return 0.0
        return sum(k == ATTN_GLOBAL for k in kinds) / len(kinds)

    @property
    def subquadratic(self) -> bool:
        """True when 500k-token decode is tractable (assignment long_500k rule)."""
        kinds = set(self.layer_kinds)
        if kinds & {RGLRU, SSM}:
            return True
        # mostly-local attention (gemma3 5:1) with a bounded-window KV cache
        return self.window > 0 and self.global_attn_fraction <= 0.25


@dataclass(frozen=True)
class Stage:
    """A run of layers sharing one repeating block pattern.

    Layers inside a stage are executed with ``lax.scan`` over stacked params
    when ``repeat > 1`` — this keeps the HLO size O(pattern) instead of
    O(n_layers) (compile-time scalability for 61-layer MoEs).
    """
    kinds: tuple        # block kinds of ONE pattern repetition
    repeat: int         # number of repetitions (scan length)
    moe: bool           # MLPs in this stage are MoE
    layer_offset: int   # absolute index of first layer (for rope bases etc.)


def build_stages(cfg: ModelConfig) -> list[Stage]:
    kinds = list(cfg.layer_kinds)
    stages: list[Stage] = []
    start = 0
    # Peel leading dense layers of a MoE model into their own (unrolled) stage.
    if cfg.moe is not None and cfg.moe.first_k_dense > 0:
        k = cfg.moe.first_k_dense
        stages.append(Stage(tuple(kinds[:k]), 1, False, 0))
        start = k
    rest = kinds[start:]
    plen = len(cfg.pattern)
    n_full, rem = divmod(len(rest), plen)
    is_moe = cfg.moe is not None
    if n_full > 0:
        stages.append(Stage(tuple(rest[: plen * 1][:plen]), n_full, is_moe, start))
    if rem > 0:
        stages.append(
            Stage(tuple(rest[plen * n_full:]), 1, is_moe, start + plen * n_full)
        )
    assert sum(len(s.kinds) * s.repeat for s in stages) == cfg.n_layers
    return stages


def reduced(cfg: ModelConfig, *, seq_friendly: bool = True) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests.

    Preserves: family, pattern structure, norm/activation choices, MoE/SSM/LRU
    machinery. Shrinks: widths, depth, vocab, experts.
    """
    plen = len(cfg.pattern)
    n_layers = max(plen + 1, 3) if plen > 1 else 2
    moe = None
    if cfg.moe is not None:
        moe = replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2), d_expert=64,
            first_k_dense=min(cfg.moe.first_k_dense, 1), dense_d_ff=96,
        )
        if cfg.moe.first_k_dense > 0:
            n_layers = max(n_layers, 2)
    ssm = replace(cfg.ssm, d_state=16, head_dim=16, chunk_size=32) if cfg.ssm else None
    rglru = replace(cfg.rglru, lru_width=64) if cfg.rglru else None
    return replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=96,
        vocab_size=128,
        window=min(cfg.window, 16) if cfg.window else 0,
        moe=moe,
        ssm=ssm,
        rglru=rglru,
        dtype="float32",
        attn_chunk=32 if seq_friendly else cfg.attn_chunk,
        remat_policy="nothing",
    )


# ---------------------------------------------------------------------------
# Analytic parameter counts (for 6·N·D model-FLOPs roofline terms).
# ---------------------------------------------------------------------------

def param_counts(cfg: ModelConfig) -> dict:
    """Analytic total / active parameter counts (embedding included in total,
    excluded from `n_active_matmul` which feeds 6·N·D)."""
    d = cfg.d_model
    total = 0
    active = 0  # per-token matmul-participating params
    embed = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    for i, kind in enumerate(cfg.layer_kinds):
        # block mixer
        if kind.startswith("attn"):
            qkv = d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.n_kv_heads * cfg.head_dim
            out = cfg.n_heads * cfg.head_dim * d
            blk = qkv + out
        elif kind == RGLRU:
            w = cfg.rglru.lru_width or d
            # two input branches + output proj + conv + lru gates
            blk = 2 * d * w + w * d + cfg.rglru.conv_width * w + 3 * w
        elif kind == SSM:
            s = cfg.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            zxbcdt = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
            blk = zxbcdt + d_in * d + s.d_conv * (d_in + 2 * s.n_groups * s.d_state) + 3 * nh
        else:  # pragma: no cover
            raise ValueError(kind)
        total += blk
        active += blk
        # mlp
        mult = 3 if cfg.gated_mlp else 2
        if cfg.moe_layer(i):
            m = cfg.moe
            e_p = mult * d * m.d_expert
            total += m.n_experts * e_p + m.n_shared_experts * e_p + d * m.n_experts
            active += (m.top_k + m.n_shared_experts) * e_p + d * m.n_experts
        else:
            ff = (cfg.moe.dense_d_ff or cfg.d_ff) if (cfg.moe and not cfg.moe_layer(i)) else cfg.d_ff
            if kind == SSM:
                ff = 0  # mamba2 blocks have no separate MLP
            total += mult * d * ff
            active += mult * d * ff
    return {
        "n_total": total + embed,
        "n_active": active + embed,
        "n_total_matmul": total,
        "n_active_matmul": active,
        "n_embed": embed,
    }
