"""Logical-axis sharding resolver: DP/FSDP/TP/EP over the assigned meshes.

Parallelism layout (see DESIGN.md §6):
  * batch (DP)      → ("pod", "data")   — pods are pure data-parallel replicas
  * FSDP (ZeRO-3)   → "data"            — weight matrices shard their non-TP
                                          dim over "data"; XLA all-gathers per
                                          scanned layer
  * TP              → "model"           — attention heads, FFN hidden, vocab
  * EP              → "model"           — MoE expert dim
  * SP (fallback)   → "model" on the sequence dim of attention activations
                       when n_heads is not divisible by tp (gemma3 H=4,
                       scout H=40, starcoder2 H=24)

Every rule is divisibility-checked: a dim that does not divide the mesh axis
falls back to replication instead of failing to lower — the same graceful-
degradation philosophy the paper applies to its M×N portability problem.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ATTN_GLOBAL, ATTN_LOCAL, RGLRU, SSM


@dataclass(frozen=True)
class MeshAxes:
    batch: tuple          # axes for the batch/DP dimension, e.g. ("pod","data")
    fsdp: str | None      # axis for weight (ZeRO-3) sharding
    model: str | None     # axis for TP/EP
    batch_size: int
    fsdp_size: int
    tp: int


def mesh_axes(mesh: Mesh) -> MeshAxes:
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    if "pod" in names:
        batch = ("pod", "data")
    elif "data" in names:
        batch = ("data",)
    else:
        batch = ()
    fsdp = "data" if "data" in names else None
    model = "model" if "model" in names else None
    bs = 1
    for a in batch:
        bs *= sizes[a]
    return MeshAxes(batch, fsdp, model,
                    batch_size=bs,
                    fsdp_size=sizes.get("data", 1),
                    tp=sizes.get("model", 1))


def _div(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def _axis(ax: MeshAxes, which: str, dim: int):
    """Return the mesh axis for a logical axis iff the dim divides it."""
    if which == "model":
        return ax.model if ax.model and _div(dim, ax.tp) else None
    if which == "fsdp":
        return ax.fsdp if ax.fsdp and _div(dim, ax.fsdp_size) else None
    if which == "batch":
        return ax.batch if ax.batch and _div(dim, ax.batch_size) else None
    raise ValueError(which)


# ---------------------------------------------------------------------------
# parameter shardings (by leaf path)
# ---------------------------------------------------------------------------

def spec_for_param(path: tuple, shape: tuple, ax: MeshAxes) -> P:
    """path: tuple of str keys from tree_map_with_path."""
    names = [getattr(p, "key", str(p)) for p in path]
    leaf = names[-1]
    in_rglru = "rglru" in names
    in_ssm = "ssm" in names
    in_moe = "moe" in names and "shared" not in names

    def s(dims):  # helper: dims is list of logical axes per dim
        parts = [(_axis(ax, d, shape[i]) if d else None)
                 for i, d in enumerate(dims)]
        return P(*parts)

    nd = len(shape)
    if leaf == "embed":
        # vocab over TP only: sharding d_model over "data" here would force
        # the LM-head contraction onto an fsdp-sharded dim (per-chunk f32
        # logits all-reduces over "data" — observed 42 GB/device wire traffic)
        return s(["model", None])
    if leaf == "lm_head":
        return s([None, "model"])
    if leaf in ("q", "k", "v") and not (in_rglru or in_ssm):
        return s([None, "fsdp", "model", None][:nd] if nd == 4
                 else ["fsdp", "model", None])
    if leaf == "o" and nd >= 3:
        return s([None, "model", None, "fsdp"][:nd] if nd == 4
                 else ["model", None, "fsdp"])
    if leaf in ("wg", "wu", "wi"):
        if in_rglru:  # rglru wg: (R, d, w)
            return s([None, "fsdp", "model"][:nd])
        if nd == 4:   # moe experts (R, E, d, f)
            return s([None, "model", "fsdp", None])
        return s([None, "fsdp", "model"][:nd] if nd == 3
                 else ["fsdp", "model"])
    if leaf == "wd":
        if nd == 4:   # moe experts (R, E, f, d)
            return s([None, "model", None, "fsdp"])
        return s([None, "model", "fsdp"][:nd] if nd == 3
                 else ["model", "fsdp"])
    if leaf == "router":
        return s([None, "fsdp", None][:nd])
    if in_rglru:
        if leaf == "wx":
            return s([None, "fsdp", "model"][:nd])
        if leaf == "wo":
            return s([None, "model", "fsdp"][:nd])
        if leaf in ("lam", "gate_a_w", "gate_a_b", "gate_x_w", "gate_x_b",
                    "conv_b"):
            return s([None, "model"][:nd])
        if leaf == "conv_w":
            return s([None, None, "model"][:nd])
    if in_ssm:
        if leaf == "in_proj":
            return s([None, "fsdp", "model"][:nd])
        if leaf == "out_proj":
            return s([None, "model", "fsdp"][:nd])
        if leaf == "conv_w":
            return s([None, None, "model"][:nd])
        if leaf in ("conv_b", "out_norm"):
            return s([None, "model"][:nd])
        if leaf in ("A_log", "D", "dt_bias"):
            return s([None, "model"][:nd])
    # norms, biases, pos_conv, everything small: replicated
    return P()


def param_specs(abstract_params, mesh: Mesh):
    ax = mesh_axes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, spec_for_param(path, leaf.shape, ax)),
        abstract_params)


# ---------------------------------------------------------------------------
# activation constraints (installed into the model via set_constrainer)
# ---------------------------------------------------------------------------

def batch_axes_for(cfg, ax: MeshAxes, batch_dim: int):
    """DP axes for a given global batch size. With cfg.dp_over_model the
    "model" axis joins DP when the batch divides it (small-dense hillclimb:
    replicated-attention waste becomes extra data parallelism)."""
    if getattr(cfg, "dp_over_model", False) and ax.model:
        full = ax.batch + (ax.model,)
        if batch_dim % (ax.batch_size * ax.tp) == 0:
            return full
    return ax.batch


def act_constrainer(cfg, mesh: Mesh):
    ax = mesh_axes(mesh)
    tp = ax.tp
    heads_div = tp <= 1 or cfg.n_heads == 0 or cfg.n_heads % tp == 0
    kv_div = tp <= 1 or cfg.n_kv_heads == 0 or cfg.n_kv_heads % tp == 0
    batch = ax.batch or None
    model = ax.model
    if getattr(cfg, "dp_over_model", False) and model:
        # batch takes the model axis too; nothing else shards over it
        batch = ax.batch + (model,)
        model = None
        heads_div = True  # suppress the SP fallback specs below

    specs = {}
    if getattr(cfg, "seq_shard_resid", False) and model:
        specs["resid"] = P(batch, model, None)
    else:
        specs["resid"] = P(batch, None, None)
    if heads_div:
        specs["attn_q"] = P(batch, None, model, None)
        specs["attn_kv"] = P(batch, None, model if kv_div else None, None)
        specs["attn_q_local"] = specs["attn_q"]
        specs["attn_kv_local"] = specs["attn_kv"]
    else:
        if cfg.seq_shard_attn:
            # global attention: shard the q sequence dim (SP); kv replicated
            specs["attn_q"] = P(batch, model, None, None)
        else:
            specs["attn_q"] = P(batch, None, None, None)
        specs["attn_kv"] = P(batch, None, None, None)
        # local attention scans over q chunks — heads replicated fallback
        specs["attn_q_local"] = P(batch, None, None, None)
        specs["attn_kv_local"] = P(batch, None, None, None)
    d_div = tp <= 1 or cfg.d_model % tp == 0
    specs["moe_in"] = P(batch, None, model if d_div else None)

    def constrain(x, name):
        spec = specs.get(name)
        if spec is None:
            return x
        if x.ndim != len(spec):
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


# ---------------------------------------------------------------------------
# batch & cache shardings
# ---------------------------------------------------------------------------

def batch_spec(batch_shapes: dict, mesh: Mesh, cfg=None):
    """Shard every batch input on its leading (batch) dim when divisible."""
    ax = mesh_axes(mesh)

    def leaf(x):
        if not x.ndim:
            return NamedSharding(mesh, P())
        b = x.shape[0]
        axes = batch_axes_for(cfg, ax, b) if cfg is not None else ax.batch
        size = _size(mesh, axes) if axes else 1
        if not axes or b % size:
            axes = _axis(ax, "batch", b)
        return NamedSharding(mesh, P(axes, *([None] * (x.ndim - 1))))

    return jax.tree.map(leaf, batch_shapes)


def cache_specs(abstract_cache, mesh: Mesh, cfg):
    """Decode caches: (R, B, L, K, hd) attn / (R, B, ...) states.

    Batch shards over DP axes when divisible; otherwise the sequence dim of
    attention caches shards over "model" (long-context, batch=1 decode) and
    head/state dims shard over "model" when divisible.
    """
    ax = mesh_axes(mesh)

    def leaf(path, x):
        names = [getattr(p, "key", str(p)) for p in path]
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        if names[-1] in ("k", "v") and x.ndim == 5:
            R, B, L, K, hd = x.shape
            b_ax = _axis(ax, "batch", B)
            if b_ax is not None:
                k_ax = _axis(ax, "model", K)
                l_ax = _axis(ax, "model", L) if k_ax is None else None
                return NamedSharding(mesh, P(None, b_ax, l_ax, k_ax, None))
            # batch too small: shard the sequence dim over everything we can
            l_axes = tuple(a for a in ((ax.fsdp,) + ((ax.model,) if ax.model else ()))
                           if a) or None
            if l_axes and L % _size(mesh, l_axes) == 0:
                return NamedSharding(mesh, P(None, None, l_axes, None, None))
            return NamedSharding(mesh, P())
        # recurrent / conv states: (R, B, ...)
        R, B = x.shape[0], x.shape[1]
        b_ax = _axis(ax, "batch", B)
        rest = [None] * (x.ndim - 2)
        if x.ndim >= 3:
            m_ax = _axis(ax, "model", x.shape[2])
            if b_ax is not None or m_ax is not None:
                rest[0] = m_ax
        return NamedSharding(mesh, P(None, b_ax, *rest))

    return jax.tree_util.tree_map_with_path(leaf, abstract_cache)


def _size(mesh, axes):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    return n
