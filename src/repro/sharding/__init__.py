from .partition import (MeshAxes, act_constrainer, batch_spec, cache_specs,
                        mesh_axes, param_specs, spec_for_param)

__all__ = ["MeshAxes", "act_constrainer", "batch_spec", "cache_specs",
           "mesh_axes", "param_specs", "spec_for_param"]
