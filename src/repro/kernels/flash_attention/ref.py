"""Pure-jnp oracle for the flash-attention kernel (naive O(S²) attention)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None):
    """q: (B, H, Sq, D); k, v: (B, K, Sk, D). Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    kh = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vh = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kh)
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
        if not causal:
            mask &= kpos - qpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vh)
    return o.astype(q.dtype)
