"""jit'd public wrapper for the flash-attention kernel.

Accepts the model-layout (B, S, H, D) tensors used across repro.models and
handles transposition + padding. ``interpret=True`` executes the kernel body
on CPU for validation; on TPU the same call lowers to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, block_q=128, block_k=128, interpret=False):
    """q: (B, Sq, H, D); k, v: (B, Sk, K, D) -> (B, Sq, H, D)."""
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    ot = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                              softcap=softcap, scale=scale, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    return jnp.transpose(ot, (0, 2, 1, 3))
