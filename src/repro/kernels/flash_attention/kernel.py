"""Flash-attention Pallas TPU kernel (forward).

TPU adaptation notes (vs the CUDA algorithm):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the LAST dim is the
    sequential ("arbitrary") dimension on TPU — the online-softmax state
    (m, l, acc) lives in VMEM scratch and persists across kv iterations,
    replacing CUDA's shared-memory tile loop.
  * blocks are (block_q × head_dim) / (block_k × head_dim) VMEM tiles sized
    to MXU-friendly multiples of 128 lanes.
  * GQA is indexed, not materialized: the k/v BlockSpec index_map maps query
    head h to kv head h // group — no repeated KV in HBM (the XLA fallback
    path pays that 8× read amplification; the kernel does not).
  * causal/window masking skips fully-masked kv blocks via pl.when — the
    2× causal waste of the XLA online-softmax path disappears.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, softcap, sq, sk, block_q, block_k):
    j = pl.program_id(3)
    nk = pl.num_programs(3)
    i = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k

    # skip blocks that the causal/window mask rules out entirely
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window > 0:
        run = jnp.logical_and(
            run, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap and softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = (qpos < sq) & (kpos < sk)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
            if not causal:
                mask &= kpos - qpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=0, softcap=0.0,
                         scale=None, block_q=128, block_k=128,
                         interpret=False):
    """q: (B, H, Sq, D); k, v: (B, K, Sk, D) with H % K == 0. Returns
    (B, H, Sq, D). Sq/Sk padded to block multiples internally."""
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // block_q
    nk = (Sk + pk) // block_k
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        sq=Sq, sk=Sk, block_q=block_q, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, _G=G: (b, h // _G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, _G=G: (b, h // _G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pq, D), q.dtype),
        scratch_shapes=[
            _vmem((block_q,)),
            _vmem((block_q,)),
            _vmem((block_q, D)),
        ],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def _tpu_params():
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    except Exception:  # noqa - older pallas API
        return None
