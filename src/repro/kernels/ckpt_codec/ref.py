"""Oracle = the host-side numpy codec used by the checkpoint writer."""
from __future__ import annotations

import numpy as np

from ...core.codec import BLOCK, dequantize_int8, quantize_int8


def quantize_reference(x: np.ndarray):
    return quantize_int8(np.asarray(x))


def dequantize_reference(q: np.ndarray, scales: np.ndarray, n: int):
    return dequantize_int8(np.asarray(q), np.asarray(scales), n)
