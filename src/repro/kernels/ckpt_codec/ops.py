from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import BLOCK, dequantize_blocks_2d, quantize_blocks_2d


@partial(jax.jit, static_argnames=("interpret",))
def quantize_blocks(x, *, interpret=False):
    """x: any shape/float dtype -> (q int8 (padded flat,), scales (nb,), n)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, BLOCK)
    q, s = quantize_blocks_2d(xb, interpret=interpret)
    return q.reshape(-1), s


@partial(jax.jit, static_argnames=("n", "out_dtype", "interpret"))
def dequantize_blocks(q, scales, *, n, out_dtype=jnp.float32,
                      interpret=False):
    xb = dequantize_blocks_2d(q.reshape(-1, BLOCK), scales,
                              out_dtype=out_dtype, interpret=interpret)
    return xb.reshape(-1)[:n]
