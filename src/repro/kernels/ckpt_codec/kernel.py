"""On-device int8 block quantizer — checkpoint-overhead reduction kernel.

The paper's future work is "reducing the checkpoint overhead for large-scale
applications". Quantizing on-device BEFORE the device→host transfer shrinks
D2H traffic 2×(bf16)/4×(f32) at the snapshot boundary, which is the
synchronous part of the async checkpoint path (files are written in the
background, but the snapshot blocks the next train step).

Matches repro.core.codec.quantize_int8 bit-for-bit on CPU (property-tested):
symmetric per-256-block scales, round-half-to-even, clip to ±127.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256  # quantization granule (matches core.codec.BLOCK)


def _q_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)            # (rows, BLOCK)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_blocks_2d(xb, *, block_rows=512, interpret=False):
    """xb: (n_blocks, BLOCK) f32/bf16 -> (int8 (n_blocks, BLOCK),
    f32 scales (n_blocks,))."""
    n, width = xb.shape
    assert width == BLOCK, width
    block_rows = min(block_rows, max(n, 1))
    pad = (-n) % block_rows
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
    grid = ((n + pad) // block_rows,)
    q, s = pl.pallas_call(
        _q_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((n + pad,), jnp.float32),
        ],
        interpret=interpret,
    )(xb)
    return q[:n], s[:n]


def _dq_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...][:, None]).astype(o_ref.dtype)


def dequantize_blocks_2d(q, scales, *, out_dtype=jnp.float32, block_rows=512,
                         interpret=False):
    n = q.shape[0]
    block_rows = min(block_rows, max(n, 1))
    pad = (-n) % block_rows
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        scales = jnp.pad(scales, (0, pad))
    grid = ((n + pad) // block_rows,)
    out = pl.pallas_call(
        _dq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, BLOCK), out_dtype),
        interpret=interpret,
    )(q, scales)
    return out[:n]
