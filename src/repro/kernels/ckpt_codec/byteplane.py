"""Device-side byteplane pre-conditioning transform — checkpoint codec
front-end.

The paper's future work is "reducing the checkpoint overhead for
large-scale applications"; the save-path ceiling after the PR-4 scan
offload is the HOST touching every payload byte in the zstd stage. This
module runs the lossless byte-plane transpose + per-plane delta ON DEVICE
(the numpy oracle is ``repro.core.codec.byteplane_forward`` /
``byteplane_inverse``), so the bytes the host compresses arrive already
entropy-shaped — and the save path fuses this forward transform into the
same device round-trip as the CDC gear scan
(``core.cdc_scan.GearScanner.scan_transform_async``), keeping ONE dispatch
per payload.

Backends mirror ``core.cdc_scan``'s three-backend structure:

  numpy    the oracle (``core.codec``) — re-exported here for symmetry;
  jnp      ``forward_expr``/``inverse_expr``: traceable XLA expressions
           (the fused scan dispatch inlines ``forward_expr`` ahead of the
           gear-scan columns), plus jitted standalone entry points;
  pallas   explicit accelerator kernels. The forward kernel consumes the
           element rows and their one-element-shifted copy (built by XLA,
           which fuses the shift into the feeding pipeline) and writes the
           transposed delta planes per grid block. The inverse is one grid
           program per byte plane — the per-plane cumsum carry is
           inherently sequential, so each program owns a whole plane
           (VMEM-bounded: fine for shard-sized payloads; the restore path
           uses the host oracle anyway and this kernel exists for backend
           parity, pinned by interpret-mode tests).

All backends are property-tested byte-identical to the oracle
(``tests/test_byteplane.py``) — the transformed stream is the dedup
keyspace when a byteplane codec is active, so a backend that drifts by one
byte re-writes history.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.codec import byteplane_forward, byteplane_inverse  # noqa: F401
# ^ oracle re-export (the ref implementations, like .ref for the quantizer)

BLOCK_ELEMS = 64 << 10      # forward-kernel elements per grid program


# ---------------------------------------------------------------------------
# jnp expressions (traceable — shared with the fused scan dispatch)
# ---------------------------------------------------------------------------

def forward_expr(u8, itemsize: int):
    """Traceable forward transform of a flat uint8 stream. Matches the
    oracle bit-for-bit: plane-major delta bytes, ragged tail appended
    untransformed."""
    n = u8.shape[0]
    k = int(itemsize)
    ne = n // k
    if ne == 0:
        return u8
    x = u8[:ne * k].reshape(ne, k)
    prev = jnp.concatenate([jnp.zeros((1, k), jnp.uint8), x[:-1]])
    d = (x - prev).T.reshape(-1)
    return jnp.concatenate([d, u8[ne * k:]])


def inverse_expr(u8, itemsize: int):
    """Traceable inverse: per-plane cumsum mod 256, transposed back."""
    n = u8.shape[0]
    k = int(itemsize)
    ne = n // k
    if ne == 0:
        return u8
    d = u8[:ne * k].reshape(k, ne)
    x = jnp.cumsum(d, axis=1, dtype=jnp.uint8)     # wraps mod 256
    return jnp.concatenate([x.T.reshape(-1), u8[ne * k:]])


@partial(jax.jit, static_argnames=("itemsize",))
def forward_jnp(u8, *, itemsize: int):
    return forward_expr(u8, itemsize)


@partial(jax.jit, static_argnames=("itemsize",))
def inverse_jnp(u8, *, itemsize: int):
    return inverse_expr(u8, itemsize)


# ---------------------------------------------------------------------------
# pallas kernels
# ---------------------------------------------------------------------------

def _fwd_kernel(x_ref, p_ref, o_ref):
    # x: (block, k) element rows; p: the same rows shifted one element
    # down (row 0 of the stream is zeros, so d[0] = x[0] like the oracle)
    o_ref[...] = (x_ref[...] - p_ref[...]).T


def forward_planes_2d(x, prev, *, block_elems: int = BLOCK_ELEMS,
                      interpret: bool = False):
    """(ne, k) element rows + shifted rows → (k, ne) delta planes."""
    ne, k = x.shape
    block = min(block_elems, max(ne, 1))
    pad = (-ne) % block
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        prev = jnp.pad(prev, ((0, pad), (0, 0)))
    grid = ((ne + pad) // block,)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block, k), lambda i: (i, 0)),
                  pl.BlockSpec((block, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((k, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k, ne + pad), jnp.uint8),
        interpret=interpret,
    )(x, prev)
    return out[:, :ne]


def _inv_kernel(d_ref, o_ref):
    o_ref[...] = jnp.cumsum(d_ref[...], axis=1, dtype=jnp.uint8)


def inverse_planes_2d(d, *, interpret: bool = False):
    """(k, ne) delta planes → (k, ne) byte planes (cumsum mod 256). One
    grid program per plane: the carry chain is sequential, so a plane is
    the natural program granule."""
    k, ne = d.shape
    return pl.pallas_call(
        _inv_kernel,
        grid=(k,),
        in_specs=[pl.BlockSpec((1, ne), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, ne), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, ne), jnp.uint8),
        interpret=interpret,
    )(d)


def forward_pallas_expr(u8, itemsize: int, *, interpret: bool = False):
    """Traceable pallas forward (the fused pallas scan dispatch inlines
    this, mirroring ``forward_expr`` on the jnp side)."""
    n = u8.shape[0]
    k = int(itemsize)
    ne = n // k
    if ne == 0:
        return u8
    x = u8[:ne * k].reshape(ne, k)
    prev = jnp.concatenate([jnp.zeros((1, k), jnp.uint8), x[:-1]])
    d = forward_planes_2d(x, prev, interpret=interpret).reshape(-1)
    return jnp.concatenate([d, u8[ne * k:]])


@partial(jax.jit, static_argnames=("itemsize", "interpret"))
def forward_pallas(u8, *, itemsize: int, interpret: bool = False):
    return forward_pallas_expr(u8, itemsize, interpret=interpret)


@partial(jax.jit, static_argnames=("itemsize", "interpret"))
def inverse_pallas(u8, *, itemsize: int, interpret: bool = False):
    n = u8.shape[0]
    k = int(itemsize)
    ne = n // k
    if ne == 0:
        return u8
    d = u8[:ne * k].reshape(k, ne)
    x = inverse_planes_2d(d, interpret=interpret)
    return jnp.concatenate([x.T.reshape(-1), u8[ne * k:]])
