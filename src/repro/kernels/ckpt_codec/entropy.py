"""Device-side plane entropy stage (byteplane-rle / byteplane-rans).

jnp/XLA and Pallas backends for the numpy oracle in ``core.codec``
(``entropy_encode_blocks`` + ``assemble_block_stream``). The encoded
framing is defined THERE — every backend must produce byte-identical
streams (property-fuzzed in tests/test_entropy.py).

Structure mirrors ``byteplane.py``: the Pallas backend runs a real kernel
for the per-block RLE emission pass (one grid program per 4 KiB plane
block — runs never span blocks, so there is no halo) and shares the
traceable jnp glue (pair compaction, histogram, lane-interleaved rANS
scan, serialization, block-choice and final stream compaction) with the
jnp backend. Both exprs are inlined by the fused scan+transform+encode
dispatch in ``core.cdc_scan`` so one device round-trip returns candidate
bitmaps plus the pre-compressed stream, and D2H shrinks to the encoded
size plus two small per-block arrays.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.codec import (            # oracle constants = format contract
    ENTROPY_BLOCK, RANS_LANES, RANS_PROB_BITS, RANS_L,
    _RANS_STEPS, _LANE_MAX,
)

B = ENTROPY_BLOCK
L = RANS_LANES
S = _RANS_STEPS
_RANS_W = 1 + 3 * 256 + 4 * L + 2 * L + L * _LANE_MAX


def _block_layout(n: int):
    """Static (trace-time) block geometry for an n-byte stream."""
    nb = -(-n // B)
    blens = np.full(nb, B, np.int32)
    if nb:
        blens[-1] = n - (nb - 1) * B
    return nb, blens


# ---------------------------------------------------------------------------
# RLE emission pass — jnp expr and Pallas kernel
# ---------------------------------------------------------------------------
# Emission semantics (== oracle ``_rle_emissions``): greedy runs cut at
# every block boundary and capped at 255; position i emits a (run_len,
# value) pair iff the run ends at i or the cap is hit. Output is the
# per-position emit mask and capped run length; compaction is shared glue.

def _emission_common(x, idx, change, end, blen_last):
    seg_start = jax.lax.cummax(jnp.where(change, idx, 0), axis=1)
    pos = idx - seg_start
    end = end | (idx == blen_last)       # partial last block ends its run
    emit = end | (pos % 255 == 254)
    run = (pos % 255 + 1).astype(jnp.uint8)
    return emit, run


def _rle_emission_expr(blkmat, blens_np):
    """jnp emitter over the padded [nb, B] block matrix."""
    nb = blkmat.shape[0]
    idx = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32), (nb, B))
    one = jnp.ones((nb, 1), bool)
    change = jnp.concatenate([one, blkmat[:, 1:] != blkmat[:, :-1]], axis=1)
    end = jnp.concatenate([change[:, 1:], one], axis=1)
    last = jnp.asarray((blens_np - 1).astype(np.int32))[:, None]
    return _emission_common(blkmat, idx, change, end, last)


def _rle_kernel(n, x_ref, emit_ref, run_ref):
    b = pl.program_id(0)
    x = x_ref[...]                                      # [1, B]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, B), 1)
    one = jnp.ones((1, 1), bool)
    change = jnp.concatenate([one, x[:, 1:] != x[:, :-1]], axis=1)
    end = jnp.concatenate([change[:, 1:], one], axis=1)
    emit, run = _emission_common(x, idx, change, end, n - 1 - b * B)
    emit_ref[...] = emit
    run_ref[...] = run


def _rle_emission_pallas(blkmat, n, *, interpret=False):
    """Pallas emitter: one grid program per plane block."""
    nb = blkmat.shape[0]
    spec = pl.BlockSpec((1, B), lambda b: (b, 0))
    emit, run = pl.pallas_call(
        partial(_rle_kernel, n),
        grid=(nb,),
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((nb, B), jnp.bool_),
                   jax.ShapeDtypeStruct((nb, B), jnp.uint8)],
        interpret=interpret,
    )(blkmat)
    return emit, run


# ---------------------------------------------------------------------------
# shared traceable glue
# ---------------------------------------------------------------------------

def _rans_stage(blkmat, valid, rowm):
    """Histogram → quantize → lane-interleaved rANS scan → serialize.
    Returns (rans_data [nb, _RANS_W] u8, rans_lens [nb] i32, eligible)."""
    nb = blkmat.shape[0]
    rows = jnp.arange(nb)
    blens = valid.sum(axis=1).astype(jnp.int32)
    sym_i = blkmat.astype(jnp.int32)
    counts = jnp.zeros((nb, 256), jnp.int32).at[rowm, sym_i].add(
        valid.astype(jnp.int32), mode="drop")
    # quantize (== oracle _rans_quantize)
    T = 1 << RANS_PROB_BITS
    nz = counts > 0
    f = jnp.where(nz, jnp.maximum(
        1, (counts * T) // jnp.maximum(blens[:, None], 1)), 0)
    imax = jnp.argmax(counts, axis=1)
    f = f.at[rows, imax].add(T - f.sum(axis=1))
    eligible = f[rows, imax] >= 1
    nsyms = nz.sum(axis=1).astype(jnp.int32)
    cum = jnp.cumsum(f, axis=1) - f
    # encode: scan steps S-1 … 0 (reverse), carry = 16 lane states
    sym_steps = sym_i.reshape(nb, S, L).transpose(1, 0, 2)     # [S, nb, L]
    val_steps = valid.reshape(nb, S, L).transpose(1, 0, 2)
    rowg = jnp.arange(nb)[:, None]

    def step(x, inp):
        s, v = inp
        fv = jnp.where(v, f[rowg, s], 1).astype(jnp.uint32)
        cv = jnp.where(v, cum[rowg, s], 0).astype(jnp.uint32)
        x_max = fv << np.uint32(8 + 23 - RANS_PROB_BITS)
        e0 = v & (x >= x_max)
        b0 = (x & np.uint32(0xFF)).astype(jnp.uint8)
        x = jnp.where(e0, x >> np.uint32(8), x)
        e1 = v & (x >= x_max)
        b1 = (x & np.uint32(0xFF)).astype(jnp.uint8)
        x = jnp.where(e1, x >> np.uint32(8), x)
        xe = ((x // fv) << np.uint32(RANS_PROB_BITS)) + (x % fv) + cv
        x = jnp.where(v, xe, x)
        return x, (b0, e0, b1, e1)

    x0 = jnp.full((nb, L), np.uint32(RANS_L), jnp.uint32)
    states, (b0, e0, b1, e1) = jax.lax.scan(
        step, x0, (sym_steps[::-1], val_steps[::-1]))
    # scan ran t = S-1 … 0; ys index t' = S-1-t. Decode order is steps
    # ascending, second byte before first → restore step order, stack
    # (b1, b0) last.
    db = jnp.stack([b1, b0], axis=-1)[::-1]            # [S, nb, L, 2]
    dv = jnp.stack([e1, e0], axis=-1)[::-1]
    db = db.transpose(1, 2, 0, 3).reshape(nb, L, 2 * S)
    dv = dv.transpose(1, 2, 0, 3).reshape(nb, L, 2 * S)
    lane_len = dv.sum(axis=-1).astype(jnp.int32)       # [nb, L]
    pos = jnp.cumsum(dv, axis=-1) - 1
    li = jnp.broadcast_to(rows[:, None, None], dv.shape)
    lj = jnp.broadcast_to(jnp.arange(L)[None, :, None], dv.shape)
    lane_buf = jnp.zeros((nb, L, _LANE_MAX), jnp.uint8).at[
        li, lj, jnp.where(dv, pos, _LANE_MAX)].set(db, mode="drop")
    # serialize (== oracle _rans_serialize)
    data = jnp.zeros((nb, _RANS_W), jnp.uint8)
    data = data.at[:, 0].set(((nsyms - 1) & 0xFF).astype(jnp.uint8))
    rank = jnp.cumsum(nz, axis=1) - 1
    rowh = jnp.broadcast_to(rows[:, None], (nb, 256))
    scol = jnp.arange(256)[None, :]
    data = data.at[rowh, jnp.where(nz, 1 + rank, _RANS_W)].set(
        jnp.broadcast_to(scol, nz.shape).astype(jnp.uint8), mode="drop")
    fo = (1 + nsyms)[:, None]
    data = data.at[rowh, jnp.where(nz, fo + 2 * rank, _RANS_W)].set(
        (f & 0xFF).astype(jnp.uint8), mode="drop")
    data = data.at[rowh, jnp.where(nz, fo + 2 * rank + 1, _RANS_W)].set(
        (f >> 8).astype(jnp.uint8), mode="drop")
    o_states = 1 + 3 * nsyms                           # [nb]
    for byte in range(4):
        cols = o_states[:, None] + 4 * jnp.arange(L) + byte
        data = data.at[rowg, cols].set(
            ((states >> np.uint32(8 * byte))
             & np.uint32(0xFF)).astype(jnp.uint8), mode="drop")
    o_lens = o_states + 4 * L
    cols = o_lens[:, None] + 2 * jnp.arange(L)
    data = data.at[rowg, cols].set(
        (lane_len & 0xFF).astype(jnp.uint8), mode="drop")
    data = data.at[rowg, cols + 1].set(
        (lane_len >> 8).astype(jnp.uint8), mode="drop")
    o_bytes = o_lens + 2 * L
    lane_off = jnp.cumsum(lane_len, axis=1) - lane_len
    kcol = jnp.arange(_LANE_MAX)[None, None, :]
    kvalid = kcol < lane_len[:, :, None]
    dst = o_bytes[:, None, None] + lane_off[:, :, None] + kcol
    data = data.at[li, jnp.where(kvalid, dst, _RANS_W)].set(
        lane_buf, mode="drop")
    rans_lens = o_bytes + lane_len.sum(axis=1)
    return data, rans_lens, eligible


def _encode_expr(t, codec: str, emitter):
    """Shared encode: ``t`` is the transformed u8 stream (device array).
    Returns (flags u8 [nb], dlens i32 [nb], stream u8 [n + 3·nb],
    total i32 scalar) — host slices stream[:total]."""
    n = t.shape[0]
    nb, blens_np = _block_layout(n)
    if nb == 0:
        return (jnp.zeros(0, jnp.uint8), jnp.zeros(0, jnp.int32),
                jnp.zeros(0, jnp.uint8), jnp.zeros((), jnp.int32))
    pad = nb * B - n
    blkmat = jnp.concatenate(
        [t, jnp.zeros(pad, jnp.uint8)]).reshape(nb, B)
    blens = jnp.asarray(blens_np)
    colm = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[None, :], (nb, B))
    rowm = jnp.broadcast_to(jnp.arange(nb)[:, None], (nb, B))
    valid = colm < blens[:, None]
    emit, run = emitter(blkmat, blens_np)
    emit = emit & valid
    # pair compaction into [nb, B] (chosen rle rows always fit: len < B)
    npairs = emit.sum(axis=1).astype(jnp.int32)
    rle_lens = 2 * npairs
    rank = jnp.cumsum(emit, axis=1) - 1
    col0 = jnp.where(emit, 2 * rank, B + 1)
    rle_buf = jnp.zeros((nb, B + 2), jnp.uint8)
    rle_buf = rle_buf.at[rowm, col0].set(run, mode="drop")
    rle_buf = rle_buf.at[rowm, col0 + 1].set(blkmat, mode="drop")
    rle_buf = rle_buf[:, :B]
    flags = jnp.zeros(nb, jnp.uint8)
    dlens = blens.astype(jnp.int32)
    use_rle = rle_lens < dlens
    flags = jnp.where(use_rle, np.uint8(1), flags)
    dlens = jnp.where(use_rle, rle_lens, dlens)
    padded = jnp.where(use_rle[:, None], rle_buf, blkmat)
    if codec == "byteplane-rans":
        rans_data, rans_lens, eligible = _rans_stage(blkmat, valid, rowm)
        use_rans = eligible & (rans_lens < dlens)
        flags = jnp.where(use_rans, np.uint8(2), flags)
        dlens = jnp.where(use_rans, rans_lens, dlens)
        padded = jnp.where(use_rans[:, None], rans_data[:, :B], padded)
    padded = jnp.where(colm < dlens[:, None], padded, 0)
    # final framed-stream compaction (== oracle assemble_block_stream)
    block_lens = 3 + dlens
    offs = jnp.cumsum(block_lens) - block_lens
    total = jnp.sum(block_lens)
    out = jnp.zeros(n + 3 * nb, jnp.uint8)
    out = out.at[offs].set(flags, mode="drop")
    out = out.at[offs + 1].set((dlens & 0xFF).astype(jnp.uint8),
                               mode="drop")
    out = out.at[offs + 2].set((dlens >> 8).astype(jnp.uint8), mode="drop")
    dst = offs[:, None] + 3 + colm
    out = out.at[jnp.where(colm < dlens[:, None], dst, n + 3 * nb)].set(
        padded, mode="drop")
    return flags, dlens, out, total.astype(jnp.int32)


def encode_expr(t, codec: str):
    """jnp/XLA backend expr — inlined by the fused scan dispatch."""
    return _encode_expr(t, codec, _rle_emission_expr)


def encode_pallas_expr(t, codec: str, *, interpret: bool = False):
    """Pallas backend expr: RLE emission runs as a per-block kernel."""
    n = t.shape[0]
    return _encode_expr(
        t, codec,
        lambda blkmat, _bl: _rle_emission_pallas(
            blkmat, n, interpret=interpret))


@partial(jax.jit, static_argnames=("codec",))
def encode_stream_jnp(t, codec: str):
    return encode_expr(t, codec)


@partial(jax.jit, static_argnames=("codec", "interpret"))
def encode_stream_pallas(t, codec: str, interpret: bool = False):
    return encode_pallas_expr(t, codec, interpret=interpret)


def encode_stream(t_u8: np.ndarray, codec: str, backend: str = "jnp",
                  *, interpret: bool = False):
    """Host-callable wrapper: encode a transformed stream on device and
    return (stream np.uint8, block_lens np.int64) — the same contract as
    the oracle's ``plane_stream_encode``. Used by tests and bench."""
    dev = jnp.asarray(np.ascontiguousarray(t_u8).view(np.uint8))
    if backend == "pallas":
        flags, dlens, out, total = encode_stream_pallas(dev, codec, interpret)
    else:
        flags, dlens, out, total = encode_stream_jnp(dev, codec)
    total = int(np.asarray(total))
    stream = np.asarray(out)[:total]
    return stream, 3 + np.asarray(dlens, np.int64)
