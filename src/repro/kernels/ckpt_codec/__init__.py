from .ops import dequantize_blocks, quantize_blocks
from .ref import dequantize_reference, quantize_reference

__all__ = ["dequantize_blocks", "quantize_blocks", "dequantize_reference",
           "quantize_reference"]
