"""Fused RMSNorm Pallas kernel: one HBM round-trip per row block.

VMEM tiling: (block_rows, D) input tile + (D,) scale, f32 math inside the
tile, single fused multiply on the way out — the XLA fallback materializes
the f32 upcast and the mean-square reduction separately.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(
        o_ref.dtype)


def rmsnorm_rows(x, scale, *, eps=1e-6, block_rows=256, interpret=False):
    """x: (N, D); scale: (D,) — gemma (1+scale) convention."""
    N, D = x.shape
    block_rows = min(block_rows, max(N, 1))
    pad = (-N) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = ((N + pad) // block_rows,)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N + pad, D), x.dtype),
        interpret=interpret,
    )(x, scale)
    return out[:N]
