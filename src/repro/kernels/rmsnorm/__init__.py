from .ops import rmsnorm_fused
from .ref import rmsnorm_reference

__all__ = ["rmsnorm_fused", "rmsnorm_reference"]
