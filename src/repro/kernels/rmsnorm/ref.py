from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_reference(x, scale, *, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)
