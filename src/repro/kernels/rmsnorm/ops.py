from __future__ import annotations

from functools import partial

import jax

from .kernel import rmsnorm_rows


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_fused(x, scale, *, eps=1e-6, block_rows=256, interpret=False):
    """x: (..., D); scale: (D,)."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    out = rmsnorm_rows(flat, scale, eps=eps, block_rows=block_rows,
                       interpret=interpret)
    return out.reshape(shape)
