"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as <name>/{kernel.py, ops.py, ref.py}: the pallas_call with
explicit BlockSpec VMEM tiling, a jit'd wrapper, and the pure-jnp oracle it
is validated against (interpret=True on CPU; see tests/test_kernels_*).

The paper itself is infrastructure (C/R) with no kernel-level contribution -
these kernels serve the framework's perf-critical layers (attention at 32k,
norms) and the paper's stated future work of reducing checkpoint overhead
(ckpt_codec: on-device int8 block quantization before D2H transfer).
"""
