"""Restore-path pipeline stages: read planning, the host-side fetch
engine, and the byte-budgeted read cache.

The counterpart of ``core.save_path``: ``CheckpointManager.restore`` is
orchestration (manifest → plan → prefetch → device placement) and the
stages live here:

  RestorePlan     pure planning — per-leaf jobs pairing manifest shard
                  records with the CURRENT topology's index ranges
                  (``elastic.plan_reads`` does the range math);
  RestoreSession  the host-side fetch engine: leaf-level fan-out over the
                  restore pool, shard reads (fast tier → slow tier → buddy
                  replica), chunked-shard reassembly with the whole-payload
                  crc as the integrity gate, and — for FIXED chunking on
                  the pipelined engine — direct placement: chunks are
                  ``readinto`` a preallocated payload buffer at their known
                  offsets, skipping the join copy (the ROADMAP's read-side
                  direct placement item);
  ReadCache       LRU, byte-budgeted, safe under concurrent leaf fan-out.

``io_threads=1`` keeps the serial engine byte-for-byte: always-assemble,
digest-verified chunk-at-a-time reads, join-copy reassembly.
"""
from __future__ import annotations

import threading
import time
import zlib
from collections import OrderedDict

import msgpack
import numpy as np

from . import codec as codec_mod
from . import resilience
from .elastic import (ShardRange, assemble, leaf_first_use_class,
                      normalize_index, plan_reads)
from .errors import CorruptShardError, MissingShardError, warn


def unpack_shard(data: bytes):
    """Full-mode (v2) inline shard file → (ShardRange, array)."""
    hlen = int.from_bytes(data[:4], "little")
    header = msgpack.unpackb(data[4:4 + hlen])
    payload = data[4 + hlen:4 + hlen + header["payload_bytes"]]
    if (zlib.crc32(payload) & 0xFFFFFFFF) != header["crc32"]:
        raise CorruptShardError("payload crc mismatch", leaf=header["leaf"])
    rng = ShardRange(tuple(header["start"]), tuple(header["stop"]))
    arr = codec_mod.decode(payload, header["codec"], rng.shape,
                           header["global_dtype"], header["meta"])
    return rng, arr


class ReadCache:
    """LRU, byte-budgeted shard cache, safe under concurrent leaf fan-out.
    Re-inserting a key never double-counts its bytes, and a hit refreshes
    recency (LRU, not FIFO).

    A SINGLE entry larger than ``limit`` stays resident (eviction stops at
    one entry, deliberately): the freshly-inserted array is about to be
    consumed by the leaf that fetched it, and evicting it would only turn
    the next overlapping range read into a full re-fetch — an always-miss
    cache with extra copies. The budget bounds steady-state growth, not
    the instantaneous high-water mark of one oversized shard."""

    def __init__(self, limit: int = 1 << 30):
        self.limit = limit
        self._entries: OrderedDict = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()

    @property
    def entries(self) -> OrderedDict:
        return self._entries

    @property
    def nbytes(self) -> int:
        return self._bytes

    def get(self, key):
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return None
            self._entries.move_to_end(key)      # recency, not insertion
            return ent[1]

    def put(self, key, arr):
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                # re-insert (e.g. concurrent fills of the same shard) must
                # not double-count: a leaked byte total would eventually
                # exceed the limit forever and thrash the cache to one entry
                self._bytes -= old[1].nbytes
            self._entries[key] = (time.monotonic(), arr)
            self._bytes += arr.nbytes
            while self._bytes > self.limit and len(self._entries) > 1:
                _, (_, evicted) = self._entries.popitem(last=False)
                self._bytes -= evicted.nbytes

    def clear(self):
        with self._lock:
            self._entries.clear()
            self._bytes = 0


class RestorePlan:
    """Per-leaf restore jobs for the CURRENT topology. Pure planning: no
    IO, no device access. Each job pairs a manifest leaf record with the
    abstract leaf (shape/dtype), its target sharding, and the canonical
    numpy dtype (resolved on the main thread — pool workers never touch
    JAX dtype machinery)."""

    def __init__(self, jobs: list, step_dir: str,
                 written_policy: dict | None = None):
        self.jobs = jobs        # (name, rec, sds, sharding, np_dtype)
        self.step_dir = step_dir
        # manifest v6: the writer's recorded policy block rides the plan
        # (restore itself is record-driven; the manager adopts this for
        # FUTURE saves so dedup survives a config-drifted restart)
        self.written_policy = written_policy

    @classmethod
    def build(cls, manifest: dict, step_dir: str, names: list, flat: list,
              shard_flat: list, step: int) -> "RestorePlan":
        import jax.numpy as jnp
        leaves = manifest["leaves"]
        jobs = []
        for name, sds, sharding in zip(names, flat, shard_flat):
            rec = leaves.get(name)
            if rec is None:
                raise MissingShardError("leaf missing from checkpoint",
                                        leaf=name, step=step)
            np_dtype = np.asarray(jnp.zeros((), sds.dtype)).dtype
            jobs.append((name, rec, sds, sharding, np_dtype))
        pol = manifest.get("policy")
        return cls(jobs, step_dir,
                   written_policy=pol if isinstance(pol, dict) else None)

    def first_use_schedule(self, priority=None,
                           frontier_classes: int = 2) -> tuple:
        """(schedule, frontier): `schedule` is job indices in first-use
        order (``elastic.leaf_first_use_class`` unless a model supplies
        `priority`); `frontier` is the leading indices — the first
        `frontier_classes` DISTINCT classes (embedding + block 0 by
        default) that must be resident before step 0 begins."""
        pr = priority or leaf_first_use_class
        classes = [pr(job[0]) for job in self.jobs]
        schedule = sorted(range(len(self.jobs)),
                          key=lambda i: (classes[i], i))
        lead = sorted(set(classes))[:max(int(frontier_classes), 1)]
        lead = set(lead)
        frontier = [i for i in schedule if classes[i] in lead]
        return schedule, frontier

    @staticmethod
    def leaf_ranges(shape, sharding) -> list:
        """Index ranges THIS PROCESS needs from one leaf — what the
        host-fetch phase prefetches. Only addressable devices count: on a
        multi-host restore each host must read O(its shards), not
        O(global model). An un-enumerable sharding yields no prefetch
        ranges; the device callback then fetches lazily."""
        if sharding is None:
            return [ShardRange((0,) * len(shape), shape)]
        try:
            idx_map = sharding.addressable_devices_indices_map(shape)
        except Exception:  # noqa — exotic sharding: fall back to lazy cb
            return []
        seen, out = set(), []
        for idx in idx_map.values():
            if idx is None:
                continue
            rng = normalize_index(idx, shape)
            key = (rng.start, rng.stop)
            if key not in seen:
                seen.add(key)
                out.append(rng)
        return out


class RestoreSession:
    """Host-side fetch engine over one manager's store/pools/cache. Pure
    numpy + IO — every method here is safe on restore pool workers."""

    def __init__(self, store, chunks, executor, cache: ReadCache):
        self.store = store
        self.chunks = chunks
        self.executor = executor
        self.cache = cache

    # -- leaf-level ----------------------------------------------------
    def fetch_host(self, step_dir: str, job) -> dict:
        """One leaf's host-side fetch: {range key → host array} for every
        range THIS process needs. Pool-worker safe (pure numpy + IO)."""
        name, rec, sds, sharding, np_dtype = job
        fetch = self.leaf_fetcher(step_dir, name, rec, np_dtype)
        shape = tuple(sds.shape)
        return {(rng.start, rng.stop): fetch(rng)
                for rng in RestorePlan.leaf_ranges(shape, sharding)}

    def prefetch(self, plan: RestorePlan) -> list:
        """Phase 1 (blocking): fan the per-leaf host fetches out across
        the restore pool; returns, per job, {range key → host array}."""
        return self.executor.map_ordered(
            lambda job: self.fetch_host(plan.step_dir, job), plan.jobs)

    def prefetch_async(self, plan: RestorePlan, schedule=None) -> list:
        """Phase 1, streaming: dispatch every per-leaf host fetch and
        return its future — indexed by JOB position, submitted in
        `schedule` order (first-use), so pool workers drain the frontier
        first and each leaf releases to device placement as it lands
        instead of barriering on ``map_ordered``. On the serial engine
        ``submit`` runs inline, so the futures come back already resolved
        in schedule order — same bytes, no overlap."""
        futures: list = [None] * len(plan.jobs)
        for i in (schedule if schedule is not None
                  else range(len(plan.jobs))):
            futures[i] = self.executor.submit(
                self.fetch_host, plan.step_dir, plan.jobs[i])
        return futures

    def leaf_to_device(self, step_dir, job, prefetched):
        """Phase 2 (MAIN thread only): device array from prefetched host
        data, with a lazy fetch fallback for ranges the prefetch missed.
        JAX array construction never runs on pool workers."""
        import jax
        name, rec, sds, sharding, np_dtype = job
        shape = tuple(sds.shape)
        dtype = sds.dtype
        if sharding is None:
            full = prefetched[((0,) * len(shape), shape)]
            return jax.numpy.asarray(full, dtype=dtype)
        fetch = self.leaf_fetcher(step_dir, name, rec, np_dtype)

        def cb(index):
            rng = normalize_index(index, shape)
            key = (rng.start, rng.stop)
            if key not in prefetched:
                prefetched[key] = fetch(rng)
            return prefetched[key]

        return jax.make_array_from_callback(shape, sharding, cb)

    def leaf_fetcher(self, step_dir, name, rec, np_dtype):
        """Host-side range fetch for one leaf: plan reads over the saved
        shard ranges, read/decode each, assemble the target range.

        Pipelined engine only: when a single saved shard covers the target
        range EXACTLY (the common same-topology restore), its decoded
        array is returned as-is — no assemble copy, no coverage mask. The
        serial engine keeps the original always-assemble path (it is the
        benchmark baseline)."""
        available = [(ShardRange(tuple(s["start"]), tuple(s["stop"])), s)
                     for s in rec["shards"]]
        exact_ok = not self.executor.serial

        def fetch(target: ShardRange) -> np.ndarray:
            picks = plan_reads(target, available)
            if exact_ok and len(picks) == 1 and \
                    picks[0][0].start == target.start and \
                    picks[0][0].stop == target.stop:
                arr = self.read_shard(step_dir, picks[0][1])
                if arr.dtype == np_dtype and arr.shape == target.shape:
                    return arr
                # dtype/shape drift: fall through to the casting assemble
            pieces = [(rng, self.read_shard(step_dir, s))
                      for rng, s in picks]
            try:
                return assemble(target, pieces, np_dtype)
            except LookupError as e:
                raise MissingShardError(str(e), leaf=name) from None

        return fetch

    # -- shard-level ---------------------------------------------------
    def read_shard(self, step_dir: str, srec: dict) -> np.ndarray:
        if "chunks" in srec:
            return self.read_chunked_shard(srec)
        # step-scoped: shard file names repeat across steps, and a failed
        # restore can leave the cache populated for a different step
        key = f"{step_dir}/{srec['file']}"
        cached = self.cache.get(key)
        if cached is not None:
            return cached
        last_err = None
        for fname in srec.get("replicas", [srec["file"]]):
            rel = f"{step_dir}/{fname}"
            tier = self.store.locate(rel)
            if tier is None:
                last_err = MissingShardError("shard not on any tier",
                                             file=fname)
                continue
            try:
                if self.chunks.retry is not None:
                    raw = resilience.retry_io(
                        lambda: tier.read_file(rel), self.chunks.retry,
                        deadline=self.chunks._deadline,
                        health=self.store.health_for(tier),
                        op="shard_read")
                else:
                    raw = tier.read_file(rel)
                rng, arr = unpack_shard(raw)
                if fname != srec["file"]:
                    warn("CKPT_W_REPLICA", "primary shard unavailable; "
                         "restored from buddy replica", file=srec["file"])
                self.cache.put(key, arr)
                return arr
            except (CorruptShardError, OSError, ValueError) as e:
                last_err = e
                continue
        raise last_err if last_err else MissingShardError(
            "unreadable shard", file=srec["file"])

    def read_chunked_shard(self, srec: dict) -> np.ndarray:
        """v3/v4/v5 incremental shard: reassemble the encoded payload via
        the prefetch pipeline (each chunk resolved fast tier → slow tier →
        buddy replica, the whole-payload crc as the end-to-end integrity
        gate), then decode.

        The pipelined engine places reads directly whenever chunk offsets
        are knowable up front — fixed chunking by construction
        (``i × chunk_size``; v3 records carry no scheme field — they ARE
        fixed), and any scheme whose record carries a chunk LENGTH list
        (v5 CDC records) via the prefix-sum offsets. Either way the reads
        land straight in a preallocated payload buffer with no
        assemble/join copy. Pre-conditioned codecs (byteplane) store the
        TRANSFORMED stream, so direct placement reassembles exactly those
        bytes and ``decode`` applies the inverse transform afterwards,
        driven by the record's self-describing meta."""
        # meta participates in the key: it drives decode for
        # pre-conditioned and int8 payloads, so records that share chunk
        # digests but differ in interpretation must not collide
        key = ("cas", tuple(srec["chunks"]), srec["codec"], srec["dtype"],
               tuple(srec["start"]), tuple(srec["stop"]),
               tuple(sorted((srec.get("meta") or {}).items())))
        cached = self.cache.get(key)
        if cached is not None:
            return cached
        fixed = srec.get("chunking", "fixed") == "fixed"
        chunk_size = srec.get("chunk_size") or 0
        chunk_lens = srec.get("chunk_lens")
        chunk_raw_lens = srec.get("chunk_raw_lens")
        payload_bytes = srec.get("payload_bytes")
        crc32 = srec.get("crc32")
        if chunk_raw_lens is not None and chunk_lens is not None \
                and payload_bytes is not None and crc32 is not None:
            # manifest v7 chunk-encoded record: chunk_lens are ENCODED
            # lengths, so direct placement (and its crc-gated verified
            # fallback inside read_payload_direct) reassembles exactly
            # the stored entropy-coded stream
            payload = self.chunks.read_payload_direct(
                srec["chunks"], payload_bytes, crc32, chunk_lens)
        elif fixed and chunk_size > 0 and payload_bytes is not None \
                and crc32 is not None:
            payload = self.chunks.read_payload_fixed(
                srec["chunks"], payload_bytes, chunk_size, crc32)
        elif chunk_lens is not None and payload_bytes is not None \
                and crc32 is not None:
            payload = self.chunks.read_payload_direct(
                srec["chunks"], payload_bytes, crc32, chunk_lens)
        else:
            payload = self.chunks.read_payload(srec["chunks"],
                                               payload_bytes, crc32=crc32)
        rng = ShardRange(tuple(srec["start"]), tuple(srec["stop"]))
        if chunk_raw_lens is not None \
                and srec["codec"] in codec_mod.CHUNK_ENCODED:
            # per-chunk entropy decode AFTER placement, then the byteplane
            # inverse over the reassembled transformed stream
            enc_lens = chunk_lens if chunk_lens is not None \
                else [len(payload)]
            t = codec_mod.plane_decode_chunks(payload, enc_lens,
                                              chunk_raw_lens, srec["codec"])
            meta = srec.get("meta") or {}
            k = int(meta.get("bp")
                    or codec_mod._np_dtype(srec["dtype"]).itemsize)
            raw = codec_mod.byteplane_inverse(t, k)
            arr = raw.view(codec_mod._np_dtype(srec["dtype"])) \
                .reshape(rng.shape)
        else:
            arr = codec_mod.decode(payload, srec["codec"], rng.shape,
                                   srec["dtype"], srec.get("meta", {}))
        self.cache.put(key, arr)
        return arr


class RestoreStream:
    """Streaming restore-behind handle (``CheckpointManager.
    restore_streaming``): every leaf's host fetch is already in flight,
    submitted in first-use order; this object releases each leaf to device
    placement as it lands.

    The contract callers rely on:

      * ``wait_frontier()`` blocks only until the first-use frontier
        (embedding + block 0 by default) is RESIDENT — host data landed
        and placed on device — so step-0 preparation can begin while tail
        layers stream in behind;
      * any touch of an un-landed leaf (``leaf(name)`` or the full
        ``state()``) blocks on that leaf's future — the completion gate.
        Restored values are therefore bit-exact by construction: the same
        host fetch and the same device placement as the blocking path,
        only ordered differently;
      * device placement happens on the CALLING thread, never pool
        workers, and each leaf is placed exactly once (touches are
        memoized). The object is NOT thread-safe — one consumer thread
        drives it, like the blocking restore it replaces.
    """

    def __init__(self, session: RestoreSession, plan: RestorePlan,
                 futures: list, treedef, schedule: list, frontier: list,
                 finalize=None):
        self._session = session
        self._plan = plan
        self._futures = futures
        self._treedef = treedef
        self._schedule = schedule
        self._frontier = frontier
        self._finalize = finalize      # validation + cache clear, once
        self._placed: dict = {}
        self._state = None

    # -- introspection -------------------------------------------------
    @property
    def names(self) -> list:
        return [job[0] for job in self._plan.jobs]

    @property
    def frontier_names(self) -> list:
        return [self._plan.jobs[i][0] for i in self._frontier]

    def landed(self, name: str) -> bool:
        """True iff this leaf's host fetch has completed (placement may
        still be pending) — a touch of it would not block."""
        return self._futures[self._index(name)].done()

    def landed_count(self) -> int:
        return sum(1 for f in self._futures if f.done())

    # -- the stream ----------------------------------------------------
    def _index(self, name: str) -> int:
        for i, job in enumerate(self._plan.jobs):
            if job[0] == name:
                return i
        raise KeyError(name)

    def _place(self, i: int):
        if i not in self._placed:
            pre = self._futures[i].result()     # the completion gate
            self._placed[i] = self._session.leaf_to_device(
                self._plan.step_dir, self._plan.jobs[i], pre)
        return self._placed[i]

    def wait_frontier(self):
        """Block until the first-use frontier is resident on device;
        returns self (``stream.wait_frontier().leaf(...)``)."""
        for i in self._frontier:
            self._place(i)
        return self

    def leaf(self, name: str):
        """Device array for ONE leaf — blocks only on that leaf's future.
        Step-0 compute walks leaves in first-use order through this, so
        each touch overlaps the fetches still streaming behind it."""
        return self._place(self._index(name))

    def state(self):
        """Drain the stream: place every remaining leaf in first-use
        order as it lands, unflatten, run the finalize hook (registry
        validation + read-cache release). Idempotent — the gate that
        makes the restored state whole and bit-exact."""
        if self._state is not None:
            return self._state
        try:
            for i in self._schedule:
                self._place(i)
        except BaseException:
            # one failed leaf must not leave siblings running against a
            # caller that has moved on to raise/retry
            for f in self._futures:
                if f is not None and not f.done():
                    try:
                        f.result()
                    except BaseException:  # noqa — surfaced by the first
                        pass
            raise
        out = [self._placed[i] for i in range(len(self._plan.jobs))]
        import jax
        state = jax.tree_util.tree_unflatten(self._treedef, out)
        if self._finalize is not None:
            self._finalize(state)
        self._state = state
        return state
