"""Restore-path pipeline stages: read planning, the host-side fetch
engine, and the byte-budgeted read cache.

The counterpart of ``core.save_path``: ``CheckpointManager.restore`` is
orchestration (manifest → plan → prefetch → device placement) and the
stages live here:

  RestorePlan     pure planning — per-leaf jobs pairing manifest shard
                  records with the CURRENT topology's index ranges
                  (``elastic.plan_reads`` does the range math);
  RestoreSession  the host-side fetch engine: leaf-level fan-out over the
                  restore pool, shard reads (fast tier → slow tier → buddy
                  replica), chunked-shard reassembly with the whole-payload
                  crc as the integrity gate, and — for FIXED chunking on
                  the pipelined engine — direct placement: chunks are
                  ``readinto`` a preallocated payload buffer at their known
                  offsets, skipping the join copy (the ROADMAP's read-side
                  direct placement item);
  ReadCache       LRU, byte-budgeted, safe under concurrent leaf fan-out.

``io_threads=1`` keeps the serial engine byte-for-byte: always-assemble,
digest-verified chunk-at-a-time reads, join-copy reassembly.
"""
from __future__ import annotations

import threading
import time
import zlib
from collections import OrderedDict

import msgpack
import numpy as np

from . import codec as codec_mod
from .elastic import ShardRange, assemble, normalize_index, plan_reads
from .errors import CorruptShardError, MissingShardError, warn


def unpack_shard(data: bytes):
    """Full-mode (v2) inline shard file → (ShardRange, array)."""
    hlen = int.from_bytes(data[:4], "little")
    header = msgpack.unpackb(data[4:4 + hlen])
    payload = data[4 + hlen:4 + hlen + header["payload_bytes"]]
    if (zlib.crc32(payload) & 0xFFFFFFFF) != header["crc32"]:
        raise CorruptShardError("payload crc mismatch", leaf=header["leaf"])
    rng = ShardRange(tuple(header["start"]), tuple(header["stop"]))
    arr = codec_mod.decode(payload, header["codec"], rng.shape,
                           header["global_dtype"], header["meta"])
    return rng, arr


class ReadCache:
    """LRU, byte-budgeted shard cache, safe under concurrent leaf fan-out.
    Re-inserting a key never double-counts its bytes, and a hit refreshes
    recency (LRU, not FIFO)."""

    def __init__(self, limit: int = 1 << 30):
        self.limit = limit
        self._entries: OrderedDict = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()

    @property
    def entries(self) -> OrderedDict:
        return self._entries

    @property
    def nbytes(self) -> int:
        return self._bytes

    def get(self, key):
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return None
            self._entries.move_to_end(key)      # recency, not insertion
            return ent[1]

    def put(self, key, arr):
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                # re-insert (e.g. concurrent fills of the same shard) must
                # not double-count: a leaked byte total would eventually
                # exceed the limit forever and thrash the cache to one entry
                self._bytes -= old[1].nbytes
            self._entries[key] = (time.monotonic(), arr)
            self._bytes += arr.nbytes
            while self._bytes > self.limit and len(self._entries) > 1:
                _, (_, evicted) = self._entries.popitem(last=False)
                self._bytes -= evicted.nbytes

    def clear(self):
        with self._lock:
            self._entries.clear()
            self._bytes = 0


class RestorePlan:
    """Per-leaf restore jobs for the CURRENT topology. Pure planning: no
    IO, no device access. Each job pairs a manifest leaf record with the
    abstract leaf (shape/dtype), its target sharding, and the canonical
    numpy dtype (resolved on the main thread — pool workers never touch
    JAX dtype machinery)."""

    def __init__(self, jobs: list, step_dir: str,
                 written_policy: dict | None = None):
        self.jobs = jobs        # (name, rec, sds, sharding, np_dtype)
        self.step_dir = step_dir
        # manifest v6: the writer's recorded policy block rides the plan
        # (restore itself is record-driven; the manager adopts this for
        # FUTURE saves so dedup survives a config-drifted restart)
        self.written_policy = written_policy

    @classmethod
    def build(cls, manifest: dict, step_dir: str, names: list, flat: list,
              shard_flat: list, step: int) -> "RestorePlan":
        import jax.numpy as jnp
        leaves = manifest["leaves"]
        jobs = []
        for name, sds, sharding in zip(names, flat, shard_flat):
            rec = leaves.get(name)
            if rec is None:
                raise MissingShardError("leaf missing from checkpoint",
                                        leaf=name, step=step)
            np_dtype = np.asarray(jnp.zeros((), sds.dtype)).dtype
            jobs.append((name, rec, sds, sharding, np_dtype))
        pol = manifest.get("policy")
        return cls(jobs, step_dir,
                   written_policy=pol if isinstance(pol, dict) else None)

    @staticmethod
    def leaf_ranges(shape, sharding) -> list:
        """Index ranges THIS PROCESS needs from one leaf — what the
        host-fetch phase prefetches. Only addressable devices count: on a
        multi-host restore each host must read O(its shards), not
        O(global model). An un-enumerable sharding yields no prefetch
        ranges; the device callback then fetches lazily."""
        if sharding is None:
            return [ShardRange((0,) * len(shape), shape)]
        try:
            idx_map = sharding.addressable_devices_indices_map(shape)
        except Exception:  # noqa — exotic sharding: fall back to lazy cb
            return []
        seen, out = set(), []
        for idx in idx_map.values():
            if idx is None:
                continue
            rng = normalize_index(idx, shape)
            key = (rng.start, rng.stop)
            if key not in seen:
                seen.add(key)
                out.append(rng)
        return out


class RestoreSession:
    """Host-side fetch engine over one manager's store/pools/cache. Pure
    numpy + IO — every method here is safe on restore pool workers."""

    def __init__(self, store, chunks, executor, cache: ReadCache):
        self.store = store
        self.chunks = chunks
        self.executor = executor
        self.cache = cache

    # -- leaf-level ----------------------------------------------------
    def prefetch(self, plan: RestorePlan) -> list:
        """Phase 1: fan the per-leaf host fetches out across the restore
        pool; returns, per job, {range key → host array}."""
        def host(job):
            name, rec, sds, sharding, np_dtype = job
            fetch = self.leaf_fetcher(plan.step_dir, name, rec, np_dtype)
            shape = tuple(sds.shape)
            return {(rng.start, rng.stop): fetch(rng)
                    for rng in RestorePlan.leaf_ranges(shape, sharding)}

        return self.executor.map_ordered(host, plan.jobs)

    def leaf_to_device(self, step_dir, job, prefetched):
        """Phase 2 (MAIN thread only): device array from prefetched host
        data, with a lazy fetch fallback for ranges the prefetch missed.
        JAX array construction never runs on pool workers."""
        import jax
        name, rec, sds, sharding, np_dtype = job
        shape = tuple(sds.shape)
        dtype = sds.dtype
        if sharding is None:
            full = prefetched[((0,) * len(shape), shape)]
            return jax.numpy.asarray(full, dtype=dtype)
        fetch = self.leaf_fetcher(step_dir, name, rec, np_dtype)

        def cb(index):
            rng = normalize_index(index, shape)
            key = (rng.start, rng.stop)
            if key not in prefetched:
                prefetched[key] = fetch(rng)
            return prefetched[key]

        return jax.make_array_from_callback(shape, sharding, cb)

    def leaf_fetcher(self, step_dir, name, rec, np_dtype):
        """Host-side range fetch for one leaf: plan reads over the saved
        shard ranges, read/decode each, assemble the target range.

        Pipelined engine only: when a single saved shard covers the target
        range EXACTLY (the common same-topology restore), its decoded
        array is returned as-is — no assemble copy, no coverage mask. The
        serial engine keeps the original always-assemble path (it is the
        benchmark baseline)."""
        available = [(ShardRange(tuple(s["start"]), tuple(s["stop"])), s)
                     for s in rec["shards"]]
        exact_ok = not self.executor.serial

        def fetch(target: ShardRange) -> np.ndarray:
            picks = plan_reads(target, available)
            if exact_ok and len(picks) == 1 and \
                    picks[0][0].start == target.start and \
                    picks[0][0].stop == target.stop:
                arr = self.read_shard(step_dir, picks[0][1])
                if arr.dtype == np_dtype and arr.shape == target.shape:
                    return arr
                # dtype/shape drift: fall through to the casting assemble
            pieces = [(rng, self.read_shard(step_dir, s))
                      for rng, s in picks]
            try:
                return assemble(target, pieces, np_dtype)
            except LookupError as e:
                raise MissingShardError(str(e), leaf=name) from None

        return fetch

    # -- shard-level ---------------------------------------------------
    def read_shard(self, step_dir: str, srec: dict) -> np.ndarray:
        if "chunks" in srec:
            return self.read_chunked_shard(srec)
        # step-scoped: shard file names repeat across steps, and a failed
        # restore can leave the cache populated for a different step
        key = f"{step_dir}/{srec['file']}"
        cached = self.cache.get(key)
        if cached is not None:
            return cached
        last_err = None
        for fname in srec.get("replicas", [srec["file"]]):
            rel = f"{step_dir}/{fname}"
            tier = self.store.locate(rel)
            if tier is None:
                last_err = MissingShardError("shard not on any tier",
                                             file=fname)
                continue
            try:
                rng, arr = unpack_shard(tier.read_file(rel))
                if fname != srec["file"]:
                    warn("CKPT_W_REPLICA", "primary shard unavailable; "
                         "restored from buddy replica", file=srec["file"])
                self.cache.put(key, arr)
                return arr
            except (CorruptShardError, OSError, ValueError) as e:
                last_err = e
                continue
        raise last_err if last_err else MissingShardError(
            "unreadable shard", file=srec["file"])

    def read_chunked_shard(self, srec: dict) -> np.ndarray:
        """v3/v4/v5 incremental shard: reassemble the encoded payload via
        the prefetch pipeline (each chunk resolved fast tier → slow tier →
        buddy replica, the whole-payload crc as the end-to-end integrity
        gate), then decode.

        The pipelined engine places reads directly whenever chunk offsets
        are knowable up front — fixed chunking by construction
        (``i × chunk_size``; v3 records carry no scheme field — they ARE
        fixed), and any scheme whose record carries a chunk LENGTH list
        (v5 CDC records) via the prefix-sum offsets. Either way the reads
        land straight in a preallocated payload buffer with no
        assemble/join copy."""
        key = ("cas", tuple(srec["chunks"]), srec["codec"], srec["dtype"],
               tuple(srec["start"]), tuple(srec["stop"]))
        cached = self.cache.get(key)
        if cached is not None:
            return cached
        fixed = srec.get("chunking", "fixed") == "fixed"
        chunk_size = srec.get("chunk_size") or 0
        chunk_lens = srec.get("chunk_lens")
        payload_bytes = srec.get("payload_bytes")
        crc32 = srec.get("crc32")
        if fixed and chunk_size > 0 and payload_bytes is not None \
                and crc32 is not None:
            payload = self.chunks.read_payload_fixed(
                srec["chunks"], payload_bytes, chunk_size, crc32)
        elif chunk_lens is not None and payload_bytes is not None \
                and crc32 is not None:
            payload = self.chunks.read_payload_direct(
                srec["chunks"], payload_bytes, crc32, chunk_lens)
        else:
            payload = self.chunks.read_payload(srec["chunks"],
                                               payload_bytes, crc32=crc32)
        rng = ShardRange(tuple(srec["start"]), tuple(srec["stop"]))
        arr = codec_mod.decode(payload, srec["codec"], rng.shape,
                               srec["dtype"], srec.get("meta", {}))
        self.cache.put(key, arr)
        return arr
