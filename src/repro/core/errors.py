"""Structured, coded errors & warnings (paper Lesson 4: "better attention to
warnings and error messages from the beginning").

Every failure mode observed in the paper's production hardening has a code
here; tests assert on codes, not message text.
"""
from __future__ import annotations

import logging

log = logging.getLogger("repro.ckpt")


class CkptError(RuntimeError):
    code = "CKPT_E_GENERIC"

    def __init__(self, msg, **ctx):
        self.ctx = ctx
        super().__init__(f"[{self.code}] {msg}"
                         + (f" | {ctx}" if ctx else ""))


class SpaceError(CkptError):
    """Insufficient storage for the checkpoint image (paper: 'Applications
    with a large memory footprint may fail to checkpoint if there is
    insufficient storage space; a system warning is needed')."""
    code = "CKPT_E_SPACE"


class CorruptShardError(CkptError):
    """Checksum mismatch / unreadable shard payload."""
    code = "CKPT_E_CORRUPT"


class MissingShardError(CkptError):
    """Manifest references a shard file that does not exist on any tier or
    buddy replica."""
    code = "CKPT_E_MISSING"


class AbortedError(CkptError):
    """2-phase commit aborted (rank failure / keepalive timeout)."""
    code = "CKPT_E_ABORTED"


class NamespaceError(CkptError):
    """Upper-half leaf name collides with reserved lower-half namespace
    (the fd-conflict analogue)."""
    code = "CKPT_E_NAMESPACE"


class RegistryMismatchError(CkptError):
    """State-region table validation failed (Lesson 1 runtime checks)."""
    code = "CKPT_E_REGISTRY"


class NoCheckpointError(CkptError):
    code = "CKPT_E_NOCKPT"


class CodecUnavailableError(CkptError):
    """Requested codec needs an optional dependency that is not installed
    (e.g. codec='zstd' without the `zstandard` package — declared under the
    `compress` extra)."""
    code = "CKPT_E_CODEC"


class CASError(CkptError):
    """Content-addressed store invariant violation (digest mismatch,
    refcount drift, orphaned or missing chunk objects)."""
    code = "CKPT_E_CAS"


class StaleStateError(CkptError):
    """CHANGES_PENDING marker found — structure was mid-mutation (Lesson 3)."""
    code = "CKPT_E_PENDING"


def warn(code: str, msg: str, **ctx):
    log.warning("[%s] %s | %s", code, msg, ctx)
