"""The paper's primary contribution, adapted to JAX: MANA-style transparent,
topology-agnostic (M×N) checkpoint/restart with production hardening —
coordinator with keepalive, two-phase atomic commit, drain protocol,
two-tier storage, buddy redundancy, codecs, preemption, AOT restart cache.
See DESIGN.md for the paper↔module map (P1–P12).
"""
from .atomic import CrashInjector, CrashPoint
from .cas import ChunkStore
from .cdc import GearChunker
from .cdc_scan import GearScanner
from .checkpoint import CheckpointManager
from .chunk_exec import ChunkIOExecutor
from .coordinator import CheckpointCoordinator
from .drain import DrainCounters, quiesce_device_state
from .errors import (AbortedError, CASError, CkptError, CodecUnavailableError,
                     CorruptShardError, MissingShardError, NamespaceError,
                     NoCheckpointError, RegistryMismatchError, SpaceError)
from .faults import FaultPlane, FaultSpec, FaultyTier, wrap_store
from .policy import (CheckpointPolicy, ChunkingPolicy, CodecPolicy,
                     DurabilityPolicy, PipelinePolicy, RestorePolicy)
from .preempt import PreemptionGuard, PreemptQueue
from .resilience import (CircuitBreaker, Deadline, RetryPolicy, TierHealth,
                         is_tier_full, is_transient, retry_io)
from .restore_path import (ReadCache, RestorePlan, RestoreSession,
                           RestoreStream)
from .save_path import PersistStage, SavePlan, SaveSession
from .split_state import (abstract_train_state, config_digest,
                          init_train_state, leaf_paths,
                          lower_half_descriptor, state_shardings)
from .resilience import RemoteInconsistencyError
from .storage import RemoteTier, Tier, TieredStore, default_store
from .weightsync import (PeerTier, WeightPublisher, WeightSubscriber,
                         build_fleet)

__all__ = [
    "AbortedError", "CASError", "CheckpointCoordinator", "CheckpointManager",
    "CheckpointPolicy", "ChunkIOExecutor", "ChunkStore", "ChunkingPolicy",
    "CircuitBreaker", "CkptError", "CodecPolicy", "CodecUnavailableError",
    "CorruptShardError", "CrashInjector", "CrashPoint", "Deadline",
    "DrainCounters", "DurabilityPolicy", "FaultPlane", "FaultSpec",
    "FaultyTier", "GearChunker", "GearScanner",
    "MissingShardError", "NamespaceError",
    "NoCheckpointError", "PeerTier", "PersistStage", "PipelinePolicy",
    "PreemptQueue", "PreemptionGuard",
    "ReadCache", "RegistryMismatchError", "RemoteInconsistencyError",
    "RemoteTier", "RestorePlan",
    "RestorePolicy", "RestoreSession", "RestoreStream", "RetryPolicy",
    "SavePlan", "SaveSession", "SpaceError", "Tier", "TierHealth",
    "TieredStore", "WeightPublisher", "WeightSubscriber",
    "abstract_train_state", "build_fleet", "config_digest", "default_store",
    "init_train_state", "is_tier_full", "is_transient", "leaf_paths",
    "lower_half_descriptor",
    "quiesce_device_state", "retry_io", "state_shardings", "wrap_store",
]
