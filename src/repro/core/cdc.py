"""Content-defined chunking (FastCDC-style) for the content-addressed store.

Fixed-size chunking destroys dedup the moment a payload shifts by a byte:
every chunk boundary after the edit moves, every digest changes, and an
insert near the front of a leaf re-writes the whole leaf. Content-defined
chunking places boundaries where the *data* says to — a rolling hash over a
small window — so identical regions re-align to identical chunks no matter
how far the surrounding bytes shifted.

This implementation keeps FastCDC's cut discipline and replaces its
byte-at-a-time loop with a vectorizable rolling hash:

  * **Gear table** — 256 random 64-bit values derived deterministically
    from blake2b (boundaries, and therefore dedup, are stable across
    processes, machines and runs; no seed state to persist);
  * **Rolling hash** — the windowed gear sum ``H[i] = Σ gear[b[i-k]]``
    over the trailing ``WINDOW`` bytes, computed for every position with
    one table lookup + one ``cumsum`` + one subtraction over the whole
    payload (uint32 wraparound is the modulus). A boundary is a position
    where ``H & mask == 0``; each byte entering/leaving the window
    reshuffles all 32 bits, and sums of 64 table values are uniform, so
    cut spacing is geometric exactly as with the classic shift-gear hash —
    but the scan is vectorized instead of a Python loop, with selectable
    backends (``core.cdc_scan``): the numpy oracle, an XLA ``lax.scan``
    pipeline, or a Pallas accelerator kernel — all byte-identical;
  * **Normalized chunking with min/avg/max bounds** — FastCDC's two-mask
    scheme: below the average target a *stricter* mask (avg·2^NORM_BITS
    expected spacing) applies, past it a *looser* one, and ``max_size``
    force-cuts. This tightens the size distribution around the average,
    which is what makes "equal average chunk size" comparisons against
    fixed-size chunking fair.

Invariants (property-tested in ``tests/test_cdc.py``):

  * concatenating the chunks reproduces the payload exactly;
  * every chunk is ≤ ``max_size``; every chunk except the final one is
    ≥ ``min_size``;
  * chunking is deterministic;
  * after inserting/deleting a region, only chunks overlapping the edit
    (plus at most a couple of boundary-resync chunks) change digest.
"""
from __future__ import annotations

import numpy as np

from . import cdc_scan
from .cdc_scan import GEAR, WINDOW, GearScanner  # noqa: F401 — re-exports:
# the gear table and window are part of the on-disk dedup contract and
# tests pin them through this module

NORM_BITS = 2        # FastCDC normalization level (mask skew around avg)
MIN_DIV = 4          # default min_size = avg_size // MIN_DIV
MAX_MUL = 4          # default max_size = avg_size * MAX_MUL
MIN_AVG_SIZE = 4 * WINDOW   # below this min_size would undercut the window


class GearChunker:
    """FastCDC-style chunker with min/avg/max bounds.

    ``avg_size`` is the target average; boundaries are content-defined, so
    actual sizes are geometric around it, clamped to [min_size, max_size].
    """

    def __init__(self, avg_size: int, *, min_size: int | None = None,
                 max_size: int | None = None, scan_backend: str = "numpy"):
        if avg_size < MIN_AVG_SIZE:
            raise ValueError(
                f"avg_size must be >= {MIN_AVG_SIZE} (rolling-hash window "
                f"is {WINDOW} bytes), got {avg_size}")
        if avg_size > 1 << 28:
            raise ValueError("avg_size must be <= 2^28 (32-bit hash masks)")
        self.avg_size = int(avg_size)
        self.min_size = int(min_size or max(self.avg_size // MIN_DIV, WINDOW))
        self.max_size = int(max_size or self.avg_size * MAX_MUL)
        if not WINDOW <= self.min_size <= self.avg_size <= self.max_size:
            raise ValueError(
                f"need {WINDOW} <= min({self.min_size}) <= "
                f"avg({self.avg_size}) <= max({self.max_size})")
        bits = max(round(np.log2(self.avg_size)), 1)
        # low-bit masks: the windowed gear sum is uniform in all 32 bits,
        # so plain nested masks give the right hit probabilities and the
        # strict-candidate set is a subset of the loose one
        self.mask_strict = np.uint32((1 << (bits + NORM_BITS)) - 1)
        self.mask_loose = np.uint32((1 << max(bits - NORM_BITS, 1)) - 1)
        # candidate scan engine: "numpy" (the oracle), "jnp" / "pallas"
        # (accelerated, byte-identical — core.cdc_scan), or "auto"
        self.scan_backend = scan_backend
        self.scanner = GearScanner(int(self.mask_strict),
                                   int(self.mask_loose),
                                   backend=scan_backend)

    @classmethod
    def from_policy(cls, chunking, *, serial: bool = False):
        """The chunker a ``ChunkingPolicy`` describes — ``None`` for the
        fixed scheme. The serial engine pins the numpy oracle scan (it IS
        the PR-1 baseline; accelerated scans must not leak into it)."""
        if chunking.scheme != "cdc":
            return None
        return cls(int(chunking.chunk_size),
                   min_size=chunking.min_size, max_size=chunking.max_size,
                   scan_backend="numpy" if serial else chunking.scan_backend)

    # ------------------------------------------------------------------
    def _candidates(self, payload):
        """All candidate cut *end offsets* (strict set, loose set)."""
        return self.scanner.scan(payload)

    def cut_points(self, payload, candidates=None) -> list:
        """End offsets of every chunk (last one == len(payload)).

        ``candidates`` short-circuits the scan with a precomputed
        (strict, loose) pair — the save path scans payloads asynchronously
        (``scanner.scan_async``) so the scan of payload k+1 overlaps the
        chunk hash/write of payload k, then feeds the result back here."""
        strict, loose = (candidates if candidates is not None
                         else self._candidates(payload))
        return self.cut_points_n(len(payload), (strict, loose))

    def cut_points_n(self, n: int, candidates) -> list:
        """``cut_points`` when only the payload LENGTH is known — the
        fused transform+scan+entropy dispatch never materializes the
        transformed bytes on the host, so the save path cuts on
        ``(strict, loose)`` candidates plus the length alone."""
        if n == 0:
            return []
        if n <= self.min_size:
            return [n]
        strict, loose = candidates
        cuts = []
        pos = 0
        while n - pos > self.min_size:
            hi = min(pos + self.max_size, n)
            e = None
            j = int(np.searchsorted(strict, pos + self.min_size))
            if j < len(strict) and strict[j] <= min(pos + self.avg_size, hi):
                e = int(strict[j])
            else:
                j = int(np.searchsorted(loose, pos + self.avg_size + 1))
                if j < len(loose) and loose[j] <= hi:
                    e = int(loose[j])
            if e is None:
                if hi < n:
                    e = hi                 # force-cut at max_size
                else:
                    break                  # tail (≤ max_size) is one chunk
            cuts.append(e)
            pos = e
        if pos < n:
            cuts.append(n)
        return cuts

    @staticmethod
    def align_cuts(cuts: list, n: int, align: int) -> list:
        """Round content-defined cut end-offsets UP to ``align`` multiples
        (the final cut stays at ``n``), dropping duplicates. The chunk-
        encoded codecs cut on this grid so every chunk starts on a plane-
        block boundary: each chunk's entropy encoding is then BOTH a pure
        function of the chunk bytes (dedup-stable) and a contiguous slice
        of the whole-payload encoded stream the fused dispatch returns.
        Alignment shifts cuts by < align ≪ min_size, so the size bounds
        and boundary-resync properties of CDC survive."""
        out = []
        last = 0
        for c in cuts:
            a = min(-(-int(c) // align) * align, n)
            if a > last:
                out.append(a)
                last = a
        return out

    def chunk(self, payload, candidates=None) -> list:
        """Split ``payload`` into content-defined chunks.

        Returns zero-copy ``memoryview`` slices — the chunker never
        duplicates the payload; hashing, crc folding and object writes all
        accept buffer views (``payload`` may be bytes, a memoryview, or a
        contiguous uint8 ndarray)."""
        cuts = self.cut_points(payload, candidates=candidates)
        mv = memoryview(payload)
        out = []
        pos = 0
        for e in cuts:
            out.append(mv[pos:e])
            pos = e
        return out
