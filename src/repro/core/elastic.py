"""Elastic restore planner — the M×N portability core.

A checkpoint stores, per pytree leaf, shard files covering logical index
ranges of the global array. Restoring onto a NEW mesh asks, per device, for
some index range; the planner computes which saved files overlap and how to
assemble the requested block. Nothing about the saving topology (device
count, mesh shape, host count, sharding) is assumed — the direct analogue of
MANA's "restart under a different MPI / network than the one you
checkpointed under", strengthened to arbitrary re-sharding.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ShardRange:
    """Half-open logical index range [start, stop) per dim."""
    start: tuple
    stop: tuple

    @property
    def shape(self):
        return tuple(b - a for a, b in zip(self.start, self.stop))

    def size(self):
        n = 1
        for s in self.shape:
            n *= s
        return n


def normalize_index(index, global_shape) -> ShardRange:
    """jax shard .index (tuple of slices, possibly with Nones) → ShardRange."""
    start, stop = [], []
    for sl, dim in zip(index, global_shape):
        start.append(0 if sl.start is None else int(sl.start))
        stop.append(dim if sl.stop is None else int(sl.stop))
    return ShardRange(tuple(start), tuple(stop))


def overlap(a: ShardRange, b: ShardRange) -> ShardRange | None:
    start = tuple(max(x, y) for x, y in zip(a.start, b.start))
    stop = tuple(min(x, y) for x, y in zip(a.stop, b.stop))
    if any(p >= q for p, q in zip(start, stop)) and len(start) > 0:
        return None
    return ShardRange(start, stop)


def assemble(target: ShardRange, pieces, dtype) -> np.ndarray:
    """pieces: iterable of (ShardRange, np.ndarray) fully covering `target`.

    Raises if coverage is incomplete (missing shards are a restore error the
    caller maps to CKPT_E_MISSING).
    """
    out = np.empty(target.shape, dtype=dtype)
    covered = np.zeros(target.shape, dtype=bool) if target.shape else \
        np.zeros((), dtype=bool)
    for rng, arr in pieces:
        ov = overlap(rng, target)
        if ov is None and target.shape:
            continue
        if not target.shape:  # scalar
            out[...] = arr
            covered = np.ones((), bool)
            continue
        dst = tuple(slice(a - t, b - t)
                    for a, b, t in zip(ov.start, ov.stop, target.start))
        src = tuple(slice(a - s, b - s)
                    for a, b, s in zip(ov.start, ov.stop, rng.start))
        out[dst] = arr[src]
        covered[dst] = True
    if not bool(np.all(covered)):
        missing = int(covered.size - covered.sum()) if target.shape else 1
        raise LookupError(f"restore plan leaves {missing} elements uncovered "
                          f"for target {target}")
    return out


def plan_reads(target: ShardRange, available: list) -> list:
    """available: list of (ShardRange, handle). Returns the minimal subset
    (greedy by overlap size) that covers `target`."""
    picks = []
    remaining = target.size()
    # greedy: biggest overlaps first — avoids reading redundant replicas
    scored = []
    for rng, handle in available:
        ov = overlap(rng, target)
        if ov is not None or not target.shape:
            scored.append((ov.size() if ov else 1, rng, handle))
    scored.sort(key=lambda t: -t[0])
    seen = None
    for sz, rng, handle in scored:
        picks.append((rng, handle))
        remaining -= sz                      # upper bound (ignores overlap
        if remaining <= 0:                   # between picks — safe, we verify
            break                            # coverage in assemble())
    return picks
