"""Elastic restore planner — the M×N portability core.

A checkpoint stores, per pytree leaf, shard files covering logical index
ranges of the global array. Restoring onto a NEW mesh asks, per device, for
some index range; the planner computes which saved files overlap and how to
assemble the requested block. Nothing about the saving topology (device
count, mesh shape, host count, sharding) is assumed — the direct analogue of
MANA's "restart under a different MPI / network than the one you
checkpointed under", strengthened to arbitrary re-sharding.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ShardRange:
    """Half-open logical index range [start, stop) per dim."""
    start: tuple
    stop: tuple

    @property
    def shape(self):
        return tuple(b - a for a, b in zip(self.start, self.stop))

    def size(self):
        n = 1
        for s in self.shape:
            n *= s
        return n


def normalize_index(index, global_shape) -> ShardRange:
    """jax shard .index (tuple of slices, possibly with Nones) → ShardRange."""
    start, stop = [], []
    for sl, dim in zip(index, global_shape):
        start.append(0 if sl.start is None else int(sl.start))
        stop.append(dim if sl.stop is None else int(sl.stop))
    return ShardRange(tuple(start), tuple(stop))


def overlap(a: ShardRange, b: ShardRange) -> ShardRange | None:
    start = tuple(max(x, y) for x, y in zip(a.start, b.start))
    stop = tuple(min(x, y) for x, y in zip(a.stop, b.stop))
    if any(p >= q for p, q in zip(start, stop)) and len(start) > 0:
        return None
    return ShardRange(start, stop)


def assemble(target: ShardRange, pieces, dtype) -> np.ndarray:
    """pieces: iterable of (ShardRange, np.ndarray) fully covering `target`.

    Raises if coverage is incomplete (missing shards are a restore error the
    caller maps to CKPT_E_MISSING).
    """
    out = np.empty(target.shape, dtype=dtype)
    covered = np.zeros(target.shape, dtype=bool) if target.shape else \
        np.zeros((), dtype=bool)
    for rng, arr in pieces:
        ov = overlap(rng, target)
        if ov is None and target.shape:
            continue
        if not target.shape:  # scalar
            out[...] = arr
            covered = np.ones((), bool)
            continue
        dst = tuple(slice(a - t, b - t)
                    for a, b, t in zip(ov.start, ov.stop, target.start))
        src = tuple(slice(a - s, b - s)
                    for a, b, s in zip(ov.start, ov.stop, rng.start))
        out[dst] = arr[src]
        covered[dst] = True
    if not bool(np.all(covered)):
        missing = int(covered.size - covered.sum()) if target.shape else 1
        raise LookupError(f"restore plan leaves {missing} elements uncovered "
                          f"for target {target}")
    return out


def plan_reads(target: ShardRange, available: list) -> list:
    """available: list of (ShardRange, handle). Returns a small subset
    (greedy by overlap size) that covers `target`.

    Coverage is tracked per ELEMENT, not by an element-count bound: saved
    shards may partially overlap each other (e.g. ranges written under
    different topologies in one history), and a count that double-credits
    the overlap would stop picking before the target is actually covered.
    Shards contributing no new elements are skipped — redundant replicas
    are never read twice."""
    scored = []
    for rng, handle in available:
        ov = overlap(rng, target)
        if ov is not None or not target.shape:
            scored.append((ov.size() if ov else 1, ov, rng, handle))
    # greedy: biggest overlaps first — fewest reads, no redundant replicas
    scored.sort(key=lambda t: -t[0])
    if not target.shape:                     # scalar: any one source serves
        return [(rng, handle) for _, _, rng, handle in scored[:1]]
    if scored and scored[0][1] is not None \
            and scored[0][1].start == target.start \
            and scored[0][1].stop == target.stop:
        # exact cover by one source (the common same-topology restore):
        # answer in O(1), before allocating the coverage mask — this sits
        # on the restore hot path next to the assemble-skip fast path
        return [(scored[0][2], scored[0][3])]
    # partial covers: one bool mask (assemble allocates the same for its
    # coverage check right after) with per-element accounting — but only
    # slice-sized counts per candidate, never full-array scans
    covered = np.zeros(target.shape, dtype=bool)
    remaining = target.size()
    picks = []
    for _, ov, rng, handle in scored:
        if remaining <= 0:
            break
        dst = tuple(slice(a - t, b - t)
                    for a, b, t in zip(ov.start, ov.stop, target.start))
        sub = covered[dst]
        fresh = sub.size - int(np.count_nonzero(sub))
        if fresh == 0:
            continue                         # adds nothing new
        covered[dst] = True
        remaining -= fresh
        picks.append((rng, handle))
    return picks


# ---------------------------------------------------------------------------
# first-use ordering (streaming restore-behind)
# ---------------------------------------------------------------------------
# A forward pass touches the embedding first, then transformer blocks in
# index order, then the final norm / LM head; optimizer slots follow their
# layer. Streaming restore orders the fetch schedule by that first use so
# step 0 can begin once the leading classes are resident while tail layers
# stream in behind the completion gate.

_EMBED_RE = re.compile(
    r"(?:^|[/._-])(?:embed\w*|wte|wpe|tok_emb\w*|pos_emb\w*)")
_TAIL_RE = re.compile(
    r"(?:^|[/._-])(?:lm_head|head|final\w*|ln_f|out_norm)")
_BLOCK_RE = re.compile(
    r"(?:^|[/._-])(?:layers?|blocks?|stages?|h|b)_?(\d+)")

FIRST_USE_DEFAULT = 1 << 61      # unclassified: after all indexed blocks
FIRST_USE_TAIL = 1 << 62         # final norm / head: touched last


def leaf_first_use_class(name: str) -> int:
    """Config-derived first-use class of one leaf path (lower = touched
    earlier in step 0). Class 0 = embeddings and step counters; class
    1+k = the k-th indexed block, composing nested indices
    (``stage_1/b2`` orders after every block of ``stage_0``); tail heads
    and norms come last; unrecognized names land just before the tail —
    correctness never depends on this (an early touch of a late-classed
    leaf just blocks on its future), only time-to-first-step does."""
    n = name.lower()
    blocks = [int(m) for m in _BLOCK_RE.findall(n)]
    if blocks:
        cls = 1
        for b in blocks:
            cls = cls * 4096 + b
        return cls
    if _EMBED_RE.search(n):
        return 0
    if _TAIL_RE.search(n):
        return FIRST_USE_TAIL
    if any(tok in n for tok in ("step", "count", "rng", "key")):
        return 0                 # tiny scalars the loop needs immediately
    return FIRST_USE_DEFAULT


def first_use_order(names, priority=None) -> list:
    """Indices of `names` sorted by first-use class (stable within a
    class, so equal-class leaves keep manifest order)."""
    pr = priority or leaf_first_use_class
    return sorted(range(len(names)), key=lambda i: (pr(names[i]), i))
