"""Checkpoint coordinator — the DMTCP-coordinator analogue, production-
hardened per the paper: KeepAlive heartbeats (lost TCP packets / network
quiescence), explicit locks around every shared structure (the paper's
missing-locks races), two-phase commit, straggler detection, and failure
injection for tests.

Ranks here are writer workers (threads standing in for per-host writer
agents); the protocol — REGISTER → PREPARE(write shards) → ACK → COMMIT /
ABORT — is transport-independent, exactly as MANA's coordinator protocol is
MPI-independent.
"""
from __future__ import annotations

import threading
import time
from collections import Counter
from dataclasses import dataclass, field
from enum import Enum

from .errors import AbortedError, warn


class RankState(Enum):
    IDLE = "idle"
    PREPARING = "preparing"
    PREPARED = "prepared"
    FAILED = "failed"


@dataclass
class RankInfo:
    rank: int
    state: RankState = RankState.IDLE
    last_heartbeat: float = field(default_factory=time.monotonic)
    bytes_written: int = 0
    files: list = field(default_factory=list)
    chunks: Counter = field(default_factory=Counter)   # CAS digests referenced
    node: str = ""          # rank-to-node mapping (paper's debug instrumentation)


class Round:
    """One two-phase-commit checkpoint round."""

    def __init__(self, step: int, participants, overlapped: bool = False):
        self.step = step
        self.participants = set(participants)
        # True when the round persists in the background (async save) —
        # the training thread has already moved on past the snapshot
        self.overlapped = overlapped
        self.aborted = False
        self.abort_reason = ""
        self.prepared = set()
        self.failed = set()
        # CAS refcount delta accumulated from prepared ranks; published
        # atomically iff the round COMMITs (abort publishes nothing, so an
        # aborted round's chunk objects are orphans for the next GC sweep —
        # never counted references).
        self.chunk_refs: Counter = Counter()

    def done(self):
        return self.aborted or self.prepared >= self.participants


class CheckpointCoordinator:
    def __init__(self, n_ranks: int, *, keepalive_s: float = 10.0,
                 straggler_factor: float = 3.0, node_fmt: str = "nid{:05d}",
                 clock=time.monotonic):
        self.n_ranks = n_ranks
        self.keepalive_s = keepalive_s
        self.straggler_factor = straggler_factor
        # injectable monotonic clock: every keepalive/straggler decision
        # reads THIS, so timing tests advance a fake clock instead of
        # sleeping real wall-clock (which flakes on slow CI hosts)
        self._clock = clock
        self._lock = threading.Lock()          # paper: no unlocked shared state
        self._cv = threading.Condition(self._lock)
        self.ranks = {r: RankInfo(r, node=node_fmt.format(r))
                      for r in range(n_ranks)}
        for ri in self.ranks.values():
            ri.last_heartbeat = self._clock()
        self.round: Round | None = None
        self.history: list = []
        self.metrics = {"rounds": 0, "commits": 0, "aborts": 0,
                        "keepalive_timeouts": 0, "stragglers_flagged": 0}
        self._monitor: threading.Thread | None = None
        self._stop = threading.Event()
        # failure injection (tests)
        self._inject_fail: set = set()
        self._inject_delay: dict = {}

    # ------------------------------------------------------------------
    # failure injection API (tests / chaos drills)
    # ------------------------------------------------------------------
    def inject_failure(self, rank: int):
        with self._lock:
            self._inject_fail.add(rank)

    def inject_delay(self, rank: int, seconds: float):
        with self._lock:
            self._inject_delay[rank] = seconds

    # ------------------------------------------------------------------
    # rank-side API (called from writer threads)
    # ------------------------------------------------------------------
    def heartbeat(self, rank: int):
        with self._lock:
            self.ranks[rank].last_heartbeat = self._clock()

    def rank_begin(self, rank: int):
        with self._lock:
            delay = self._inject_delay.get(rank, 0.0)
            fail = rank in self._inject_fail
            self.ranks[rank].state = RankState.PREPARING
            self.ranks[rank].last_heartbeat = self._clock()
        if delay:
            time.sleep(delay)
        if fail:
            raise RuntimeError(f"injected failure on rank {rank}")

    def rank_prepared(self, rank: int, *, nbytes: int, files: list,
                      chunks=None):
        """`chunks`: digest→refcount Counter of every CAS chunk the rank's
        shards reference this round (dedup hits included — refcounts track
        references, not writes)."""
        with self._cv:
            ri = self.ranks[rank]
            ri.state = RankState.PREPARED
            ri.bytes_written = nbytes
            ri.files = files
            ri.chunks = Counter(chunks or {})
            ri.last_heartbeat = self._clock()
            if self.round and not self.round.aborted:
                self.round.prepared.add(rank)
                self.round.chunk_refs.update(ri.chunks)
            self._cv.notify_all()

    def rank_failed(self, rank: int, reason: str):
        with self._cv:
            self.ranks[rank].state = RankState.FAILED
            if self.round:
                self.round.failed.add(rank)
                self.round.aborted = True
                self.round.abort_reason = f"rank {rank}: {reason}"
            self._cv.notify_all()

    # ------------------------------------------------------------------
    # coordinator-side API
    # ------------------------------------------------------------------
    def begin_round(self, step: int, participants=None,
                    overlapped: bool = False) -> Round:
        """participants: rank ids taking part (retry rounds exclude ranks
        declared dead — the node-failure recovery path). ``overlapped``
        marks a round whose persist runs behind training compute."""
        with self._lock:
            assert self.round is None or self.round.done(), \
                "previous round still active"
            if participants is None:
                participants = range(self.n_ranks)
            self.round = Round(step, participants, overlapped=overlapped)
            for ri in self.ranks.values():
                ri.state = RankState.IDLE
                ri.last_heartbeat = self._clock()
            self.metrics["rounds"] += 1
        self._start_monitor()
        return self.round

    def wait_all_prepared(self, timeout: float | None = None) -> bool:
        """Barrier for phase 1. Returns True iff every rank acked PREPARED."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not self.round.done():
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    self.round.aborted = True
                    self.round.abort_reason = "phase-1 barrier timeout"
                    break
                self._cv.wait(remaining if remaining is None
                              else min(remaining, 0.1))
            ok = not self.round.aborted
        self._stop_monitor()
        return ok

    def finish_round(self, committed: bool, publish_refs=None):
        """COMMIT/ABORT. On COMMIT, `publish_refs` (if given) is invoked
        under the coordinator lock with the round's aggregated chunk-ref
        delta — the single atomic refcount publication point. On ABORT the
        delta is dropped: an abort leaks no references."""
        with self._lock:
            r = self.round
            self.metrics["commits" if committed else "aborts"] += 1
            if r.overlapped:
                self.metrics["overlapped_rounds"] = \
                    self.metrics.get("overlapped_rounds", 0) + 1
            self.history.append({
                "step": r.step, "committed": committed,
                "reason": r.abort_reason, "overlapped": r.overlapped,
                "bytes": sum(ri.bytes_written for ri in self.ranks.values()),
                "chunk_refs": sum(r.chunk_refs.values()),
            })
            self.round = None
            if committed and publish_refs is not None:
                self.metrics["ref_publishes"] = \
                    self.metrics.get("ref_publishes", 0) + 1
                publish_refs(dict(r.chunk_refs))

    def abort_reason(self) -> str:
        with self._lock:
            return self.round.abort_reason if self.round else ""

    def raise_if_aborted(self):
        with self._lock:
            if self.round and self.round.aborted:
                raise AbortedError("checkpoint round aborted",
                                   step=self.round.step,
                                   reason=self.round.abort_reason)

    # ------------------------------------------------------------------
    # keepalive monitor (paper: TCP KeepAlive fix for silent disconnects)
    # ------------------------------------------------------------------
    def _start_monitor(self):
        self._stop.clear()
        self._monitor = threading.Thread(target=self._watch, daemon=True)
        self._monitor.start()

    def _stop_monitor(self):
        self._stop.set()
        if self._monitor:
            self._monitor.join()
            self._monitor = None

    def _watch(self):
        t0 = self._clock()
        while not self._stop.is_set():
            # the poll cadence is real time (the monitor must keep waking),
            # but every timeout decision reads the injectable clock
            time.sleep(min(self.keepalive_s / 20, 0.05))
            now = self._clock()
            with self._cv:
                if self.round is None or self.round.done():
                    return
                for ri in self.ranks.values():
                    if ri.state == RankState.PREPARING and \
                            now - ri.last_heartbeat > self.keepalive_s:
                        self.metrics["keepalive_timeouts"] += 1
                        self.round.failed.add(ri.rank)
                        self.round.aborted = True
                        self.round.abort_reason = (
                            f"keepalive timeout on rank {ri.rank} "
                            f"({ri.node})")
                        self._cv.notify_all()
                        return
                # straggler flagging: a rank much slower than the median
                done = [r for r in self.ranks.values()
                        if r.state == RankState.PREPARED]
                if 0 < len(done) < self.n_ranks:
                    elapsed = now - t0
                    if elapsed > self.straggler_factor * max(
                            self.keepalive_s / 10, 0.05) and done:
                        lagging = [r.rank for r in self.ranks.values()
                                   if r.state == RankState.PREPARING]
                        if lagging:
                            self.metrics["stragglers_flagged"] += len(lagging)
                            warn("CKPT_W_STRAGGLER",
                                 "slow writer ranks detected",
                                 ranks=lagging[:8], elapsed=round(elapsed, 3))
                            t0 = now  # don't spam
