"""Shard payload codecs: raw | zstd | int8 block-quantization (+zstd).

The int8 codec addresses the paper's stated future work ("reducing the
checkpoint overhead for large-scale applications"): 4×/2× size reduction on
f32/bf16 leaves with per-block scales. The device-side quantizer has a Pallas
TPU kernel (repro.kernels.ckpt_codec) validated against the numpy encoder
here; on the host path we quantize with numpy after device→host transfer.

`zstandard` is an OPTIONAL dependency (the `compress` extra): raw and int8
work without it (int8 then stores its quantized payload uncompressed, flagged
in meta so decode stays self-describing); asking for codec="zstd" without the
package raises CodecUnavailableError with the install hint.
"""
from __future__ import annotations

import threading

import numpy as np

from .errors import CodecUnavailableError

try:
    import zstandard
    HAVE_ZSTD = True
except ModuleNotFoundError:           # optional dependency (compress extra)
    zstandard = None
    HAVE_ZSTD = False

BLOCK = 256
CODECS = ("raw", "zstd", "int8")

# zstandard (de)compressor objects are NOT thread-safe; the checkpoint writer
# runs N rank threads concurrently (observed: "Src size is incorrect" under
# shared compressors — the paper's missing-locks failure class). Thread-local
# instances instead of a lock keep ranks parallel.
_TL = threading.local()


def _require_zstd(op: str):
    if not HAVE_ZSTD:
        raise CodecUnavailableError(
            "codec requires the optional `zstandard` package "
            "(pip install 'repro[compress]')", op=op)


def _zc() -> "zstandard.ZstdCompressor":
    _require_zstd("compress")
    if not hasattr(_TL, "zc"):
        _TL.zc = zstandard.ZstdCompressor(level=3)
    return _TL.zc


def _zd() -> "zstandard.ZstdDecompressor":
    _require_zstd("decompress")
    if not hasattr(_TL, "zd"):
        _TL.zd = zstandard.ZstdDecompressor()
    return _TL.zd


def available(codec: str) -> bool:
    """True iff `codec` is usable in this environment."""
    if codec == "zstd":
        return HAVE_ZSTD
    return codec in CODECS


def default_codec() -> str:
    """Best lossless codec the environment supports."""
    return "zstd" if HAVE_ZSTD else "raw"


def _as_u16(x: np.ndarray) -> np.ndarray:
    return x.view(np.uint16) if x.dtype == np.dtype("bfloat16") else x


def encode(arr: np.ndarray, codec: str) -> tuple:
    """Returns (payload_bytes, meta_dict)."""
    if codec == "raw":
        return arr.tobytes(), {}
    if codec == "zstd":
        return _zc().compress(np.ascontiguousarray(arr).tobytes()), {}
    if codec == "int8":
        q, scales = quantize_int8(arr)
        blob = q.tobytes() + scales.tobytes()
        meta = {"q_bytes": q.nbytes, "s_bytes": scales.nbytes, "n": arr.size}
        if HAVE_ZSTD:
            return _zc().compress(blob), meta
        return blob, dict(meta, z=0)   # uncompressed, self-describing
    raise ValueError(f"unknown codec {codec!r}")


def decode(payload: bytes, codec: str, shape, dtype, meta: dict) -> np.ndarray:
    dtype = np.dtype(dtype) if not str(dtype).startswith("bfloat") else dtype
    if codec == "raw":
        return np.frombuffer(payload, dtype=_np_dtype(dtype)).reshape(shape)
    if codec == "zstd":
        raw = _zd().decompress(payload)
        return np.frombuffer(raw, dtype=_np_dtype(dtype)).reshape(shape)
    if codec == "int8":
        raw = payload if not meta.get("z", 1) else _zd().decompress(payload)
        q = np.frombuffer(raw[:meta["q_bytes"]], np.int8)
        scales = np.frombuffer(raw[meta["q_bytes"]:], np.float32)
        return dequantize_int8(q, scales, meta["n"]).astype(
            _np_dtype(dtype), copy=False).reshape(shape)
    raise ValueError(f"unknown codec {codec!r}")


def _np_dtype(dtype):
    s = str(dtype)
    if s == "bfloat16":
        import ml_dtypes  # ships with jax
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(s)


def quantize_int8(arr: np.ndarray) -> tuple:
    """Symmetric per-block int8 quantization over the flattened array.

    Matches repro.kernels.ckpt_codec (the Pallas TPU kernel oracle):
      scale_b = max(|x_b|) / 127 ;  q = round(x / scale) clipped to ±127.
    """
    x = np.asarray(arr).astype(np.float32).reshape(-1)
    n = x.size
    pad = (-n) % BLOCK
    if pad:
        x = np.concatenate([x, np.zeros(pad, np.float32)])
    xb = x.reshape(-1, BLOCK)
    amax = np.abs(xb).max(axis=1)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(xb / scale[:, None]), -127, 127).astype(np.int8)
    return q.reshape(-1)[: n + pad], scale


def dequantize_int8(q: np.ndarray, scales: np.ndarray, n: int) -> np.ndarray:
    xb = q.reshape(-1, BLOCK).astype(np.float32) * scales[:, None]
    return xb.reshape(-1)[:n]


def lossy(codec: str) -> bool:
    return codec == "int8"
