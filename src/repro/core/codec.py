"""Shard payload codecs: raw | zstd | int8 block-quantization (+zstd) |
byteplane pre-conditioning (± zstd).

The int8 codec addresses the paper's stated future work ("reducing the
checkpoint overhead for large-scale applications"): 4×/2× size reduction on
f32/bf16 leaves with per-block scales. The device-side quantizer has a Pallas
TPU kernel (repro.kernels.ckpt_codec) validated against the numpy encoder
here; on the host path we quantize with numpy after device→host transfer.

The byteplane codecs are LOSSLESS pre-conditioning: the payload's bytes are
transposed into per-byte-position planes (plane p holds byte p of every
element) and each plane is delta-coded mod 256. Params-like floats have
near-constant sign/exponent bytes interleaved with near-random mantissa
bytes; separating the planes turns the stream into long runs the entropy
stage compresses faster AND tighter, and lets zstd's incompressible-block
fast path skip the mantissa planes instead of grinding the matcher over
interleaved noise. ``byteplane`` stores the transformed stream as-is (a
size-preserving permutation — chunking/dedup operate on it directly);
``byteplane-zstd`` adds the host zstd stage. Both are self-describing via
``meta["bp"]`` (the element width) and invert on decode. The functions here
are the numpy ORACLE; the device-side jnp/Pallas backends
(``repro.kernels.ckpt_codec.byteplane``) are property-tested against them,
and the save path fuses the forward transform into the CDC gear-scan
dispatch (``core.cdc_scan.GearScanner.scan_transform_async``).

``byteplane-rle`` / ``byteplane-rans`` move the entropy stage itself onto
the device (nvCOMP/DietGPU-style): the transformed stream is encoded in
fixed 4 KiB plane blocks — RLE for the run-length-collapsing sign/exponent
planes, order-0 lane-interleaved rANS for mixed low-entropy blocks, and a
per-block "store raw" escape so incompressible mantissa planes pass through
untouched. These are CHUNK-ENCODED codecs: boundaries are still cut on the
transformed stream (rounded up to plane-block alignment), each chunk is
entropy-coded independently and deterministically (dedup-stable), and v7
manifests carry per-chunk (raw_len, enc_len) pairs so restore can place
encoded chunks directly and decode after placement.

`zstandard` is an OPTIONAL dependency (the `compress` extra): raw, int8 and
byteplane work without it (int8 then stores its quantized payload
uncompressed, flagged in meta so decode stays self-describing); asking for
codec="zstd" or "byteplane-zstd" without the package raises
CodecUnavailableError with the install hint.
"""
from __future__ import annotations

import threading

import numpy as np

from .errors import CodecUnavailableError

try:
    import zstandard
    HAVE_ZSTD = True
except ModuleNotFoundError:           # optional dependency (compress extra)
    zstandard = None
    HAVE_ZSTD = False

BLOCK = 256
CODECS = ("raw", "zstd", "int8", "byteplane", "byteplane-zstd",
          "byteplane-rle", "byteplane-rans")
# codecs whose encode is (byteplane transform → optional entropy stage):
# the save path may run the transform ON DEVICE, fused into the CDC scan
PRECONDITIONED = ("byteplane", "byteplane-zstd", "byteplane-rle",
                  "byteplane-rans")
# the device-entropy subset: the entropy stage is applied PER CHUNK of the
# transformed stream (chunk boundaries are still cut on the transformed
# bytes; the CAS stores each chunk's encoding, and the manifest records
# per-chunk (raw_len, enc_len) pairs). Encoding is a pure function of the
# chunk bytes, so identical chunks still dedup to identical objects.
CHUNK_ENCODED = ("byteplane-rle", "byteplane-rans")

# -- entropy-stage format constants (the on-disk contract) ------------------
# Plane blocks: the transformed stream is encoded in fixed-size blocks so
# the escape decision tracks the byte-plane structure (a 4 KiB block lies
# inside one plane for any realistically-sized shard). CDC cut points are
# rounded UP to this alignment when a chunk-encoded codec is active, so a
# chunk's encoding equals the concatenation of its blocks' encodings and
# the fused device dispatch can encode the whole payload once.
ENTROPY_BLOCK = 4096
RANS_LANES = 16          # lane-interleaved rANS states per block
RANS_PROB_BITS = 12      # quantized frequency precision (sum = 4096)
RANS_L = 1 << 23         # renormalization lower bound (byte renorm)
_RANS_STEPS = ENTROPY_BLOCK // RANS_LANES
_LANE_MAX = 2 * _RANS_STEPS       # emission bound: ≤2 bytes/symbol/lane
# fixed per-block rANS overhead: nsyms byte + 16×u32 states + 16×u16 lens
_RANS_FIXED = 1 + 4 * RANS_LANES + 2 * RANS_LANES

# zstandard (de)compressor objects are NOT thread-safe; the checkpoint writer
# runs N rank threads concurrently (observed: "Src size is incorrect" under
# shared compressors — the paper's missing-locks failure class). Thread-local
# instances instead of a lock keep ranks parallel.
_TL = threading.local()


def _require_zstd(op: str):
    if not HAVE_ZSTD:
        raise CodecUnavailableError(
            "codec requires the optional `zstandard` package "
            "(pip install 'repro[compress]')", op=op)


def _zc() -> "zstandard.ZstdCompressor":
    _require_zstd("compress")
    if not hasattr(_TL, "zc"):
        _TL.zc = zstandard.ZstdCompressor(level=3)
    return _TL.zc


def _zd() -> "zstandard.ZstdDecompressor":
    _require_zstd("decompress")
    if not hasattr(_TL, "zd"):
        _TL.zd = zstandard.ZstdDecompressor()
    return _TL.zd


def available(codec: str) -> bool:
    """True iff `codec` is usable in this environment."""
    if codec in ("zstd", "byteplane-zstd"):
        return HAVE_ZSTD
    return codec in CODECS


def default_codec() -> str:
    """Best lossless codec the environment supports."""
    return "zstd" if HAVE_ZSTD else "raw"


def _as_u16(x: np.ndarray) -> np.ndarray:
    return x.view(np.uint16) if x.dtype == np.dtype("bfloat16") else x


def contig_u8(arr) -> np.ndarray:
    """Flat C-contiguous uint8 view of ``arr`` — zero-copy when the array
    already is contiguous (the snapshot path's host arrays are)."""
    a = np.ascontiguousarray(arr)
    return a.reshape(-1).view(np.uint8)


# ---------------------------------------------------------------------------
# byteplane pre-conditioning — the numpy oracle
# ---------------------------------------------------------------------------

def byteplane_forward(data, itemsize: int) -> np.ndarray:
    """Byte-plane transpose + per-plane delta (mod 256) of a byte stream
    of ``itemsize``-byte elements. Size-preserving and lossless: plane p
    of the output holds ``x[j][p] - x[j-1][p]`` for every element j (the
    first element passes through), and any ragged tail (``len % itemsize``
    bytes) is appended untransformed. THE oracle the jnp/Pallas device
    backends are property-tested against — it defines the transformed
    stream that chunking, dedup and the manifest crc all operate on."""
    u8 = data if isinstance(data, np.ndarray) \
        else np.frombuffer(data, np.uint8)
    u8 = u8.reshape(-1).view(np.uint8)
    k = int(itemsize)
    if k <= 0:
        raise ValueError(f"itemsize must be positive, got {itemsize}")
    n = u8.size
    ne = n // k
    out = np.empty(n, np.uint8)
    if ne:
        x = u8[:ne * k].reshape(ne, k)
        d = out[:ne * k].reshape(k, ne)
        d[:, :] = x.T
        d[:, 1:] -= x[:-1].T           # uint8 wraparound is the modulus
    out[ne * k:] = u8[ne * k:]
    return out


def byteplane_inverse(data, itemsize: int) -> np.ndarray:
    """Exact inverse of ``byteplane_forward``: per-plane cumulative sum
    mod 256, then transpose back to element order."""
    u8 = data if isinstance(data, np.ndarray) \
        else np.frombuffer(data, np.uint8)
    u8 = u8.reshape(-1).view(np.uint8)
    k = int(itemsize)
    if k <= 0:
        raise ValueError(f"itemsize must be positive, got {itemsize}")
    n = u8.size
    ne = n // k
    out = np.empty(n, np.uint8)
    if ne:
        d = u8[:ne * k].reshape(k, ne)
        x = np.cumsum(d, axis=1, dtype=np.uint8)   # wraps mod 256
        out[:ne * k].reshape(ne, k)[:, :] = x.T
    out[ne * k:] = u8[ne * k:]
    return out


def byteplane_meta(arr: np.ndarray) -> dict:
    """The self-describing meta a byteplane payload carries: the element
    width the inverse transform needs (ONE source of truth — the host
    encoder and the fused device path must agree)."""
    return {"bp": int(arr.dtype.itemsize)}


# ---------------------------------------------------------------------------
# plane-aware entropy stage (byteplane-rle / byteplane-rans) — numpy oracle
# ---------------------------------------------------------------------------
# The transformed stream is encoded in ENTROPY_BLOCK-byte blocks. Each block
# is framed [flag u8][enc_len u16le][enc_len bytes] where flag is:
#   0 = raw escape (incompressible — mantissa planes pass through untouched)
#   1 = RLE: greedy maximal runs as (run_len u8 ∈ 1..255, value u8) pairs
#   2 = rANS: order-0, 12-bit quantized freqs, 16 interleaved lanes
# A smaller representation is chosen only when STRICTLY smaller (raw < rle
# < rans on ties), so the encoder is deterministic and a pure function of
# the block bytes — identical chunks still produce identical objects.
#
# rANS block body layout:
#   [nsyms-1 u8][sym u8 ×nsyms ascending][freq u16le ×nsyms]
#   [state u32le ×16][lane_len u16le ×16][lane0 bytes … lane15 bytes]
# Lane j owns symbols at indices j, j+16, j+32, … of the block; encode
# walks symbols in reverse, byte-renormalizing against RANS_L, and each
# lane's byte stream is serialized in DECODE consumption order.

def _rle_emissions(u8: np.ndarray, nb: int):
    """Vectorized greedy RLE over a whole stream, runs cut at every
    ENTROPY_BLOCK boundary. Returns (pair_buf [nb, 2·B] u8 zero-padded,
    rle_lens [nb] encoded byte counts)."""
    B = ENTROPY_BLOCK
    n = u8.size
    idx = np.arange(n, dtype=np.int64)
    change = np.empty(n, bool)
    change[0] = True
    if n > 1:
        change[1:] = u8[1:] != u8[:-1]
    change[::B] = True                       # runs never span blocks
    seg_start = np.maximum.accumulate(np.where(change, idx, 0))
    pos = idx - seg_start                    # 0-based position inside run
    end = np.empty(n, bool)
    if n > 1:
        end[:-1] = change[1:]
    end[-1] = True
    end[B - 1::B] = True                     # block boundary ends the run
    emit = end | (pos % 255 == 254)          # cap runs at 255
    e = np.flatnonzero(emit)
    blk = e // B
    npairs = np.bincount(blk, minlength=nb)
    starts = np.concatenate([[0], np.cumsum(npairs)])[:-1]
    rank = np.arange(e.size) - starts[blk]
    buf = np.zeros((nb, 2 * B), np.uint8)
    buf[blk, 2 * rank] = (pos[e] % 255 + 1).astype(np.uint8)
    buf[blk, 2 * rank + 1] = u8[e]
    return buf, 2 * npairs


def _rans_quantize(counts: np.ndarray, blens: np.ndarray):
    """Deterministic 12-bit frequency quantization, vectorized across
    blocks: f = max(1, c·4096 // n) for present symbols, the residual is
    absorbed by the first most-frequent symbol; blocks where that would
    drop it below 1 are rANS-ineligible."""
    nb = counts.shape[0]
    T = 1 << RANS_PROB_BITS
    nz = counts > 0
    f = np.where(
        nz, np.maximum(1, (counts * T) // np.maximum(blens[:, None], 1)), 0)
    imax = np.argmax(counts, axis=1)         # first occurrence on ties
    rows = np.arange(nb)
    f[rows, imax] += T - f.sum(axis=1)
    eligible = f[rows, imax] >= 1
    cum = np.cumsum(f, axis=1) - f           # exclusive per-symbol base
    return f, cum, nz.sum(axis=1), eligible


def _rans_encode_blocks(blkmat: np.ndarray, blens: np.ndarray,
                        f: np.ndarray, cum: np.ndarray):
    """Lane-interleaved rANS encode of every block at once. Returns
    (lane_buf [nb, 16, _LANE_MAX] u8 in decode order, lane_len [nb, 16],
    states [nb, 16] u32)."""
    nb = blkmat.shape[0]
    L, S = RANS_LANES, _RANS_STEPS
    sym = blkmat.reshape(nb, S, L).astype(np.int64)
    valid = (np.arange(ENTROPY_BLOCK).reshape(S, L)[None]
             < blens[:, None, None])
    rows = np.arange(nb)[:, None]
    x = np.full((nb, L), RANS_L, np.uint32)
    out_b = np.zeros((S, nb, L, 2), np.uint8)
    out_v = np.zeros((S, nb, L, 2), bool)
    for t in range(S - 1, -1, -1):
        s = sym[:, t, :]
        v = valid[:, t, :]
        fv = np.where(v, f[rows, s], 1).astype(np.uint32)
        cv = np.where(v, cum[rows, s], 0).astype(np.uint32)
        x_max = fv << np.uint32(8 + 23 - RANS_PROB_BITS)   # = ((L>>12)<<8)·f
        e0 = v & (x >= x_max)
        out_b[t, :, :, 0] = (x & 0xFF).astype(np.uint8)
        out_v[t, :, :, 0] = e0
        x = np.where(e0, x >> np.uint32(8), x)
        e1 = v & (x >= x_max)
        out_b[t, :, :, 1] = (x & 0xFF).astype(np.uint8)
        out_v[t, :, :, 1] = e1
        x = np.where(e1, x >> np.uint32(8), x)
        xe = ((x // fv) << np.uint32(RANS_PROB_BITS)) + (x % fv) + cv
        x = np.where(v, xe, x)
    # decode consumes the emission sequence reversed: steps ascending,
    # within a step the second byte before the first
    db = out_b[:, :, :, ::-1].transpose(1, 2, 0, 3).reshape(nb, L, 2 * S)
    dv = out_v[:, :, :, ::-1].transpose(1, 2, 0, 3).reshape(nb, L, 2 * S)
    lane_len = dv.sum(axis=-1).astype(np.int64)
    lane_buf = np.zeros((nb, L, _LANE_MAX), np.uint8)
    pos = np.cumsum(dv, axis=-1) - 1
    i, j, _ = np.nonzero(dv)
    lane_buf[i, j, pos[dv]] = db[dv]
    return lane_buf, lane_len, x


def _rans_serialize(f, nsyms, lane_buf, lane_len, states):
    """Pack rANS block bodies into a padded matrix [nb, W] + lengths."""
    nb = f.shape[0]
    L = RANS_LANES
    W = 1 + 3 * 256 + _RANS_FIXED - 1 + L * _LANE_MAX
    data = np.zeros((nb, W), np.uint8)
    rows = np.arange(nb)
    data[:, 0] = ((nsyms - 1) & 0xFF).astype(np.uint8)
    r_idx, s_idx = np.nonzero(f > 0)
    starts = np.concatenate([[0], np.cumsum(nsyms)])[:-1]
    rank = np.arange(r_idx.size) - starts[r_idx]
    data[r_idx, 1 + rank] = s_idx.astype(np.uint8)
    fo = 1 + nsyms[r_idx]
    fv = f[r_idx, s_idx].astype(np.int64)
    data[r_idx, fo + 2 * rank] = (fv & 0xFF).astype(np.uint8)
    data[r_idx, fo + 2 * rank + 1] = (fv >> 8).astype(np.uint8)
    o_states = 1 + 3 * nsyms                          # [nb]
    st = states.astype(np.uint32)
    for b in range(4):
        cols = o_states[:, None] + 4 * np.arange(L) + b
        data[rows[:, None], cols] = \
            ((st >> np.uint32(8 * b)) & 0xFF).astype(np.uint8)
    o_lens = o_states + 4 * L
    cols = o_lens[:, None] + 2 * np.arange(L)
    data[rows[:, None], cols] = (lane_len & 0xFF).astype(np.uint8)
    data[rows[:, None], cols + 1] = (lane_len >> 8).astype(np.uint8)
    o_bytes = o_lens + 2 * L                          # [nb]
    lane_off = np.cumsum(lane_len, axis=1) - lane_len  # [nb, L]
    i, j, k = np.nonzero(np.arange(_LANE_MAX)[None, None, :]
                         < lane_len[:, :, None])
    data[i, o_bytes[i] + lane_off[i, j] + k] = lane_buf[i, j, k]
    rans_lens = o_bytes + lane_len.sum(axis=1)
    return data, rans_lens


def entropy_encode_blocks(u8: np.ndarray, codec: str):
    """Oracle block encoder for a whole (sub)stream: returns
    (flags [nb], dlens [nb], padded [nb, ·] u8) where row b's first
    dlens[b] bytes are block b's encoded body. Pure numpy; the jnp/Pallas
    backends in ``kernels.ckpt_codec.entropy`` must match byte-for-byte."""
    if codec not in CHUNK_ENCODED:
        raise ValueError(f"codec {codec!r} has no entropy stage")
    B = ENTROPY_BLOCK
    n = u8.size
    nb = -(-n // B)
    if nb == 0:
        return (np.zeros(0, np.uint8), np.zeros(0, np.int64),
                np.zeros((0, B), np.uint8))
    pad = nb * B - n
    blkmat = np.concatenate([u8, np.zeros(pad, np.uint8)]).reshape(nb, B)
    blens = np.full(nb, B, np.int64)
    blens[-1] = n - (nb - 1) * B
    rle_buf, rle_lens = _rle_emissions(u8, nb)
    flags = np.zeros(nb, np.uint8)
    dlens = blens.copy()
    use_rle = rle_lens < dlens
    flags[use_rle] = 1
    dlens[use_rle] = rle_lens[use_rle]
    if codec == "byteplane-rans":
        valid = np.arange(B)[None, :] < blens[:, None]
        counts = np.bincount(
            (blkmat.astype(np.int64) + 256 * np.arange(nb)[:, None])[valid],
            minlength=256 * nb).reshape(nb, 256)
        f, cum, nsyms, eligible = _rans_quantize(counts, blens)
        lane_buf, lane_len, states = \
            _rans_encode_blocks(blkmat, blens, f, cum)
        rans_data, rans_lens = \
            _rans_serialize(f, nsyms, lane_buf, lane_len, states)
        use_rans = eligible & (rans_lens < dlens)
        flags[use_rans] = 2
        dlens[use_rans] = rans_lens[use_rans]
    padded = np.zeros((nb, B), np.uint8)
    raw_rows = flags == 0
    padded[raw_rows] = blkmat[raw_rows]
    rle_rows = flags == 1
    padded[rle_rows] = rle_buf[rle_rows, :B]
    if codec == "byteplane-rans":
        rans_rows = flags == 2
        padded[rans_rows] = rans_data[rans_rows, :B]
    keep = np.arange(B)[None, :] < dlens[:, None]
    padded[~keep] = 0                        # deterministic padding
    return flags, dlens, padded


def assemble_block_stream(flags, dlens, padded):
    """Serialize (flags, dlens, padded) into the framed block stream.
    Shared by every backend — the device paths return the same triple.
    Returns (stream np.uint8, block_lens [nb] incl. 3-byte headers)."""
    flags = np.asarray(flags, np.uint8)
    dlens = np.asarray(dlens, np.int64)
    padded = np.asarray(padded, np.uint8)
    nb = flags.size
    block_lens = 3 + dlens
    offs = np.cumsum(block_lens) - block_lens
    out = np.zeros(int(block_lens.sum()), np.uint8)
    out[offs] = flags
    out[offs + 1] = (dlens & 0xFF).astype(np.uint8)
    out[offs + 2] = (dlens >> 8).astype(np.uint8)
    total = int(dlens.sum())
    if total:
        blk = np.repeat(np.arange(nb), dlens)
        rank = np.arange(total) - np.repeat(np.cumsum(dlens) - dlens, dlens)
        out[offs[blk] + 3 + rank] = padded[blk, rank]
    return out, block_lens


def plane_stream_encode(u8, codec: str):
    """Encode a transformed stream (or one chunk of it — the format is
    position-independent) with the plane entropy stage. Returns
    (stream np.uint8, block_lens)."""
    u8 = u8 if isinstance(u8, np.ndarray) else np.frombuffer(u8, np.uint8)
    u8 = u8.reshape(-1).view(np.uint8)
    return assemble_block_stream(*entropy_encode_blocks(u8, codec))


def plane_encode_chunk(chunk, codec: str) -> bytes:
    """Per-chunk entropy encode — blocks are framed relative to the chunk
    start, so the result is a pure function of the chunk bytes (dedup-
    stable) and, when chunks are ENTROPY_BLOCK-aligned, concatenating the
    per-chunk encodings equals encoding the whole stream once (what the
    fused device dispatch produces)."""
    return plane_stream_encode(chunk, codec)[0].tobytes()


def _rans_decode_group(bodies, raw_lens, payload):
    """Vectorized rANS decode of a group of blocks: ``bodies`` is a list of
    (offset, enc_len) into ``payload``; returns list of np.uint8 arrays."""
    m = len(bodies)
    L, S = RANS_LANES, _RANS_STEPS
    f_rows, sym_rows, lane_mats, lane_lens, states = [], [], [], [], []
    for off, elen in bodies:
        body = payload[off:off + elen]
        ns = int(body[0]) + 1
        syms = body[1:1 + ns].astype(np.int64)
        freqs = body[1 + ns:1 + 3 * ns].view(np.uint8)
        freqs = (freqs[0::2].astype(np.int64)
                 | (freqs[1::2].astype(np.int64) << 8))
        p = 1 + 3 * ns
        st = body[p:p + 4 * L].reshape(L, 4).astype(np.uint32)
        states.append(st[:, 0] | (st[:, 1] << np.uint32(8))
                      | (st[:, 2] << np.uint32(16))
                      | (st[:, 3] << np.uint32(24)))
        p += 4 * L
        ll = body[p:p + 2 * L].reshape(L, 2).astype(np.int64)
        ll = ll[:, 0] | (ll[:, 1] << 8)
        p += 2 * L
        mat = np.zeros((L, _LANE_MAX), np.uint8)
        for j in range(L):
            mat[j, :ll[j]] = body[p:p + ll[j]]
            p += int(ll[j])
        lane_mats.append(mat)
        lane_lens.append(ll)
        fr = np.zeros(256, np.int64)
        fr[syms] = freqs
        f_rows.append(fr)
        sym_rows.append(np.repeat(syms, freqs))   # slot → symbol LUT
    f_full = np.stack(f_rows)
    cum_full = np.cumsum(f_full, axis=1) - f_full
    lut = np.stack(sym_rows)                      # [m, 4096]
    lanes = np.stack(lane_mats)                   # [m, L, _LANE_MAX]
    llen = np.stack(lane_lens)                    # [m, L]
    x = np.stack(states)                          # [m, L] u32
    ptr = np.zeros((m, L), np.int64)
    rows = np.arange(m)[:, None]
    cols = np.arange(L)[None, :]
    mask = np.uint32((1 << RANS_PROB_BITS) - 1)
    out = np.zeros((m, S, L), np.uint8)
    nsteps = (np.asarray(raw_lens)[:, None]
              - cols + L - 1) // L                # symbols per lane
    for t in range(S):
        act = t < nsteps
        slot = x & mask
        s = lut[rows, slot.astype(np.int64)]
        fv = f_full[rows, s].astype(np.uint32)
        cv = cum_full[rows, s].astype(np.uint32)
        x = np.where(act,
                     fv * (x >> np.uint32(RANS_PROB_BITS)) + slot - cv, x)
        for _ in range(2):                        # byte renorm, ≤2 reads
            need = act & (x < np.uint32(RANS_L)) & (ptr < llen)
            b = lanes[rows, cols, np.minimum(ptr, _LANE_MAX - 1)]
            x = np.where(need, (x << np.uint32(8)) | b, x)
            ptr = np.where(need, ptr + 1, ptr)
        out[:, t, :] = np.where(act, s, 0).astype(np.uint8)
    flat = out.reshape(m, ENTROPY_BLOCK)
    return [flat[i, :raw_lens[i]] for i in range(m)]


def plane_stream_decode(enc, raw_len: int, codec: str) -> np.ndarray:
    """Decode a framed block stream back to ``raw_len`` transformed bytes.
    Works on a whole-payload stream or a single chunk's encoding (same
    format). Raises ValueError on malformed framing."""
    if codec not in CHUNK_ENCODED:
        raise ValueError(f"codec {codec!r} has no entropy stage")
    payload = enc if isinstance(enc, np.ndarray) \
        else np.frombuffer(enc, np.uint8)
    payload = payload.reshape(-1).view(np.uint8)
    out = np.empty(raw_len, np.uint8)
    pos = 0
    done = 0
    rans_jobs, rans_dst = [], []
    while done < raw_len:
        if pos + 3 > payload.size:
            raise ValueError("entropy stream truncated (header)")
        flag = int(payload[pos])
        elen = int(payload[pos + 1]) | (int(payload[pos + 2]) << 8)
        pos += 3
        blen = min(ENTROPY_BLOCK, raw_len - done)
        if pos + elen > payload.size:
            raise ValueError("entropy stream truncated (body)")
        if flag == 0:
            if elen != blen:
                raise ValueError("raw block length mismatch")
            out[done:done + blen] = payload[pos:pos + elen]
        elif flag == 1:
            pairs = payload[pos:pos + elen]
            runs = pairs[0::2].astype(np.int64)
            vals = pairs[1::2]
            dec = np.repeat(vals, runs)
            if dec.size != blen:
                raise ValueError("rle block length mismatch")
            out[done:done + blen] = dec
        elif flag == 2:
            rans_jobs.append(((pos, elen), blen))
            rans_dst.append(done)
        else:
            raise ValueError(f"unknown entropy block flag {flag}")
        pos += elen
        done += blen
    if pos != payload.size:
        raise ValueError("entropy stream has trailing bytes")
    if rans_jobs:
        decs = _rans_decode_group([j[0] for j in rans_jobs],
                                  [j[1] for j in rans_jobs], payload)
        for dst, dec in zip(rans_dst, decs):
            out[dst:dst + dec.size] = dec
    return out


def plane_decode_chunks(payload, enc_lens, raw_lens, codec: str) -> np.ndarray:
    """Decode a concatenation of per-chunk encodings (the CAS payload a
    v7 manifest describes) back into the transformed stream."""
    u8 = payload if isinstance(payload, np.ndarray) \
        else np.frombuffer(payload, np.uint8)
    u8 = u8.reshape(-1).view(np.uint8)
    out = np.empty(int(sum(raw_lens)), np.uint8)
    eoff = roff = 0
    for elen, rlen in zip(enc_lens, raw_lens):
        out[roff:roff + rlen] = \
            plane_stream_decode(u8[eoff:eoff + elen], int(rlen), codec)
        eoff += int(elen)
        roff += int(rlen)
    if eoff != u8.size:
        raise ValueError("chunk-encoded payload has trailing bytes")
    return out


def entropy_block_stats(enc, raw_len: int):
    """Parse a framed block stream's headers WITHOUT decoding: yields
    (abs_offset, blen, flag, enc_len) per block — inspect_ckpt maps these
    onto byte planes for the per-plane report."""
    payload = enc if isinstance(enc, np.ndarray) \
        else np.frombuffer(enc, np.uint8)
    payload = payload.reshape(-1).view(np.uint8)
    pos = done = 0
    while done < raw_len:
        if pos + 3 > payload.size:
            raise ValueError("entropy stream truncated (header)")
        flag = int(payload[pos])
        elen = int(payload[pos + 1]) | (int(payload[pos + 2]) << 8)
        blen = min(ENTROPY_BLOCK, raw_len - done)
        yield done, blen, flag, elen
        pos += 3 + elen
        done += blen


def encode_preconditioned(transformed, codec: str):
    """Host stage of the device pre-conditioning pipeline: ``transformed``
    is the byteplane stream the device round-trip returned; this applies
    whatever entropy stage the codec adds. Byte-identical to
    ``encode(arr, codec)`` on the same array — property-tested.

    Chunk-encoded codecs return the stream UNCHANGED here: their entropy
    stage runs per chunk (after boundaries are cut on the transformed
    bytes), via ``plane_encode_chunk`` or the fused device dispatch."""
    if codec == "byteplane":
        return transformed
    if codec == "byteplane-zstd":
        return _zc().compress(transformed)
    if codec in CHUNK_ENCODED:
        return transformed
    raise ValueError(f"codec {codec!r} is not a preconditioned codec")


def encode(arr: np.ndarray, codec: str) -> tuple:
    """Returns (payload_bytes, meta_dict)."""
    if codec == "raw":
        return arr.tobytes(), {}
    if codec == "zstd":
        # compress straight from a C-contiguous view (zstandard accepts
        # the buffer protocol) — the old .tobytes() duplicated every
        # payload before the compressor even saw it
        return _zc().compress(contig_u8(arr)), {}
    if codec == "byteplane":
        t = byteplane_forward(contig_u8(arr), arr.dtype.itemsize)
        return t.tobytes(), byteplane_meta(arr)
    if codec == "byteplane-zstd":
        t = byteplane_forward(contig_u8(arr), arr.dtype.itemsize)
        return _zc().compress(t), byteplane_meta(arr)
    if codec in CHUNK_ENCODED:
        t = byteplane_forward(contig_u8(arr), arr.dtype.itemsize)
        return plane_stream_encode(t, codec)[0].tobytes(), byteplane_meta(arr)
    if codec == "int8":
        q, scales = quantize_int8(arr)
        blob = q.tobytes() + scales.tobytes()
        meta = {"q_bytes": q.nbytes, "s_bytes": scales.nbytes, "n": arr.size}
        if HAVE_ZSTD:
            return _zc().compress(blob), meta
        return blob, dict(meta, z=0)   # uncompressed, self-describing
    raise ValueError(f"unknown codec {codec!r}")


def decode(payload: bytes, codec: str, shape, dtype, meta: dict) -> np.ndarray:
    dtype = np.dtype(dtype) if not str(dtype).startswith("bfloat") else dtype
    if codec == "raw":
        return np.frombuffer(payload, dtype=_np_dtype(dtype)).reshape(shape)
    if codec == "zstd":
        raw = _zd().decompress(payload)
        return np.frombuffer(raw, dtype=_np_dtype(dtype)).reshape(shape)
    if codec in PRECONDITIONED:
        k = int(meta.get("bp") or _np_dtype(dtype).itemsize)
        if codec in CHUNK_ENCODED:
            raw_len = int(np.prod(shape, dtype=np.int64)) \
                * _np_dtype(dtype).itemsize
            u8 = plane_stream_decode(payload, raw_len, codec)
        elif codec == "byteplane":
            u8 = payload
        else:
            u8 = _zd().decompress(payload)
        raw = byteplane_inverse(u8, k)
        return raw.view(_np_dtype(dtype)).reshape(shape)
    if codec == "int8":
        raw = payload if not meta.get("z", 1) else _zd().decompress(payload)
        q = np.frombuffer(raw[:meta["q_bytes"]], np.int8)
        scales = np.frombuffer(raw[meta["q_bytes"]:], np.float32)
        return dequantize_int8(q, scales, meta["n"]).astype(
            _np_dtype(dtype), copy=False).reshape(shape)
    raise ValueError(f"unknown codec {codec!r}")


def _np_dtype(dtype):
    s = str(dtype)
    if s == "bfloat16":
        import ml_dtypes  # ships with jax
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(s)


def quantize_int8(arr: np.ndarray) -> tuple:
    """Symmetric per-block int8 quantization over the flattened array.

    Matches repro.kernels.ckpt_codec (the Pallas TPU kernel oracle):
      scale_b = max(|x_b|) / 127 ;  q = round(x / scale) clipped to ±127.
    """
    x = np.asarray(arr).astype(np.float32).reshape(-1)
    n = x.size
    pad = (-n) % BLOCK
    if pad:
        x = np.concatenate([x, np.zeros(pad, np.float32)])
    xb = x.reshape(-1, BLOCK)
    amax = np.abs(xb).max(axis=1)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(xb / scale[:, None]), -127, 127).astype(np.int8)
    return q.reshape(-1)[: n + pad], scale


def dequantize_int8(q: np.ndarray, scales: np.ndarray, n: int) -> np.ndarray:
    xb = q.reshape(-1, BLOCK).astype(np.float32) * scales[:, None]
    return xb.reshape(-1)[:n]


def lossy(codec: str) -> bool:
    return codec == "int8"
