"""Shard payload codecs: raw | zstd | int8 block-quantization (+zstd) |
byteplane pre-conditioning (± zstd).

The int8 codec addresses the paper's stated future work ("reducing the
checkpoint overhead for large-scale applications"): 4×/2× size reduction on
f32/bf16 leaves with per-block scales. The device-side quantizer has a Pallas
TPU kernel (repro.kernels.ckpt_codec) validated against the numpy encoder
here; on the host path we quantize with numpy after device→host transfer.

The byteplane codecs are LOSSLESS pre-conditioning: the payload's bytes are
transposed into per-byte-position planes (plane p holds byte p of every
element) and each plane is delta-coded mod 256. Params-like floats have
near-constant sign/exponent bytes interleaved with near-random mantissa
bytes; separating the planes turns the stream into long runs the entropy
stage compresses faster AND tighter, and lets zstd's incompressible-block
fast path skip the mantissa planes instead of grinding the matcher over
interleaved noise. ``byteplane`` stores the transformed stream as-is (a
size-preserving permutation — chunking/dedup operate on it directly);
``byteplane-zstd`` adds the host zstd stage. Both are self-describing via
``meta["bp"]`` (the element width) and invert on decode. The functions here
are the numpy ORACLE; the device-side jnp/Pallas backends
(``repro.kernels.ckpt_codec.byteplane``) are property-tested against them,
and the save path fuses the forward transform into the CDC gear-scan
dispatch (``core.cdc_scan.GearScanner.scan_transform_async``).

`zstandard` is an OPTIONAL dependency (the `compress` extra): raw, int8 and
byteplane work without it (int8 then stores its quantized payload
uncompressed, flagged in meta so decode stays self-describing); asking for
codec="zstd" or "byteplane-zstd" without the package raises
CodecUnavailableError with the install hint.
"""
from __future__ import annotations

import threading

import numpy as np

from .errors import CodecUnavailableError

try:
    import zstandard
    HAVE_ZSTD = True
except ModuleNotFoundError:           # optional dependency (compress extra)
    zstandard = None
    HAVE_ZSTD = False

BLOCK = 256
CODECS = ("raw", "zstd", "int8", "byteplane", "byteplane-zstd")
# codecs whose encode is (byteplane transform → optional entropy stage):
# the save path may run the transform ON DEVICE, fused into the CDC scan
PRECONDITIONED = ("byteplane", "byteplane-zstd")

# zstandard (de)compressor objects are NOT thread-safe; the checkpoint writer
# runs N rank threads concurrently (observed: "Src size is incorrect" under
# shared compressors — the paper's missing-locks failure class). Thread-local
# instances instead of a lock keep ranks parallel.
_TL = threading.local()


def _require_zstd(op: str):
    if not HAVE_ZSTD:
        raise CodecUnavailableError(
            "codec requires the optional `zstandard` package "
            "(pip install 'repro[compress]')", op=op)


def _zc() -> "zstandard.ZstdCompressor":
    _require_zstd("compress")
    if not hasattr(_TL, "zc"):
        _TL.zc = zstandard.ZstdCompressor(level=3)
    return _TL.zc


def _zd() -> "zstandard.ZstdDecompressor":
    _require_zstd("decompress")
    if not hasattr(_TL, "zd"):
        _TL.zd = zstandard.ZstdDecompressor()
    return _TL.zd


def available(codec: str) -> bool:
    """True iff `codec` is usable in this environment."""
    if codec in ("zstd", "byteplane-zstd"):
        return HAVE_ZSTD
    return codec in CODECS


def default_codec() -> str:
    """Best lossless codec the environment supports."""
    return "zstd" if HAVE_ZSTD else "raw"


def _as_u16(x: np.ndarray) -> np.ndarray:
    return x.view(np.uint16) if x.dtype == np.dtype("bfloat16") else x


def contig_u8(arr) -> np.ndarray:
    """Flat C-contiguous uint8 view of ``arr`` — zero-copy when the array
    already is contiguous (the snapshot path's host arrays are)."""
    a = np.ascontiguousarray(arr)
    return a.reshape(-1).view(np.uint8)


# ---------------------------------------------------------------------------
# byteplane pre-conditioning — the numpy oracle
# ---------------------------------------------------------------------------

def byteplane_forward(data, itemsize: int) -> np.ndarray:
    """Byte-plane transpose + per-plane delta (mod 256) of a byte stream
    of ``itemsize``-byte elements. Size-preserving and lossless: plane p
    of the output holds ``x[j][p] - x[j-1][p]`` for every element j (the
    first element passes through), and any ragged tail (``len % itemsize``
    bytes) is appended untransformed. THE oracle the jnp/Pallas device
    backends are property-tested against — it defines the transformed
    stream that chunking, dedup and the manifest crc all operate on."""
    u8 = data if isinstance(data, np.ndarray) \
        else np.frombuffer(data, np.uint8)
    u8 = u8.reshape(-1).view(np.uint8)
    k = int(itemsize)
    if k <= 0:
        raise ValueError(f"itemsize must be positive, got {itemsize}")
    n = u8.size
    ne = n // k
    out = np.empty(n, np.uint8)
    if ne:
        x = u8[:ne * k].reshape(ne, k)
        d = out[:ne * k].reshape(k, ne)
        d[:, :] = x.T
        d[:, 1:] -= x[:-1].T           # uint8 wraparound is the modulus
    out[ne * k:] = u8[ne * k:]
    return out


def byteplane_inverse(data, itemsize: int) -> np.ndarray:
    """Exact inverse of ``byteplane_forward``: per-plane cumulative sum
    mod 256, then transpose back to element order."""
    u8 = data if isinstance(data, np.ndarray) \
        else np.frombuffer(data, np.uint8)
    u8 = u8.reshape(-1).view(np.uint8)
    k = int(itemsize)
    if k <= 0:
        raise ValueError(f"itemsize must be positive, got {itemsize}")
    n = u8.size
    ne = n // k
    out = np.empty(n, np.uint8)
    if ne:
        d = u8[:ne * k].reshape(k, ne)
        x = np.cumsum(d, axis=1, dtype=np.uint8)   # wraps mod 256
        out[:ne * k].reshape(ne, k)[:, :] = x.T
    out[ne * k:] = u8[ne * k:]
    return out


def byteplane_meta(arr: np.ndarray) -> dict:
    """The self-describing meta a byteplane payload carries: the element
    width the inverse transform needs (ONE source of truth — the host
    encoder and the fused device path must agree)."""
    return {"bp": int(arr.dtype.itemsize)}


def encode_preconditioned(transformed, codec: str):
    """Host stage of the device pre-conditioning pipeline: ``transformed``
    is the byteplane stream the device round-trip returned; this applies
    whatever entropy stage the codec adds. Byte-identical to
    ``encode(arr, codec)`` on the same array — property-tested."""
    if codec == "byteplane":
        return transformed
    if codec == "byteplane-zstd":
        return _zc().compress(transformed)
    raise ValueError(f"codec {codec!r} is not a preconditioned codec")


def encode(arr: np.ndarray, codec: str) -> tuple:
    """Returns (payload_bytes, meta_dict)."""
    if codec == "raw":
        return arr.tobytes(), {}
    if codec == "zstd":
        # compress straight from a C-contiguous view (zstandard accepts
        # the buffer protocol) — the old .tobytes() duplicated every
        # payload before the compressor even saw it
        return _zc().compress(contig_u8(arr)), {}
    if codec == "byteplane":
        t = byteplane_forward(contig_u8(arr), arr.dtype.itemsize)
        return t.tobytes(), byteplane_meta(arr)
    if codec == "byteplane-zstd":
        t = byteplane_forward(contig_u8(arr), arr.dtype.itemsize)
        return _zc().compress(t), byteplane_meta(arr)
    if codec == "int8":
        q, scales = quantize_int8(arr)
        blob = q.tobytes() + scales.tobytes()
        meta = {"q_bytes": q.nbytes, "s_bytes": scales.nbytes, "n": arr.size}
        if HAVE_ZSTD:
            return _zc().compress(blob), meta
        return blob, dict(meta, z=0)   # uncompressed, self-describing
    raise ValueError(f"unknown codec {codec!r}")


def decode(payload: bytes, codec: str, shape, dtype, meta: dict) -> np.ndarray:
    dtype = np.dtype(dtype) if not str(dtype).startswith("bfloat") else dtype
    if codec == "raw":
        return np.frombuffer(payload, dtype=_np_dtype(dtype)).reshape(shape)
    if codec == "zstd":
        raw = _zd().decompress(payload)
        return np.frombuffer(raw, dtype=_np_dtype(dtype)).reshape(shape)
    if codec in PRECONDITIONED:
        u8 = payload if codec == "byteplane" else _zd().decompress(payload)
        k = int(meta.get("bp") or _np_dtype(dtype).itemsize)
        raw = byteplane_inverse(u8, k)
        return raw.view(_np_dtype(dtype)).reshape(shape)
    if codec == "int8":
        raw = payload if not meta.get("z", 1) else _zd().decompress(payload)
        q = np.frombuffer(raw[:meta["q_bytes"]], np.int8)
        scales = np.frombuffer(raw[meta["q_bytes"]:], np.float32)
        return dequantize_int8(q, scales, meta["n"]).astype(
            _np_dtype(dtype), copy=False).reshape(shape)
    raise ValueError(f"unknown codec {codec!r}")


def _np_dtype(dtype):
    s = str(dtype)
    if s == "bfloat16":
        import ml_dtypes  # ships with jax
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(s)


def quantize_int8(arr: np.ndarray) -> tuple:
    """Symmetric per-block int8 quantization over the flattened array.

    Matches repro.kernels.ckpt_codec (the Pallas TPU kernel oracle):
      scale_b = max(|x_b|) / 127 ;  q = round(x / scale) clipped to ±127.
    """
    x = np.asarray(arr).astype(np.float32).reshape(-1)
    n = x.size
    pad = (-n) % BLOCK
    if pad:
        x = np.concatenate([x, np.zeros(pad, np.float32)])
    xb = x.reshape(-1, BLOCK)
    amax = np.abs(xb).max(axis=1)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(xb / scale[:, None]), -127, 127).astype(np.int8)
    return q.reshape(-1)[: n + pad], scale


def dequantize_int8(q: np.ndarray, scales: np.ndarray, n: int) -> np.ndarray:
    xb = q.reshape(-1, BLOCK).astype(np.float32) * scales[:, None]
    return xb.reshape(-1)[:n]


def lossy(codec: str) -> bool:
    return codec == "int8"
