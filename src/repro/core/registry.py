"""Annotated state-region table with runtime checks — paper Lesson 1:

  "an annotated table of all memory regions, along with dynamic runtime
   checks, would help catch bugs early in the development phase."

Every upper-half leaf gets a registry row (name, shape, dtype, bytes, role,
sharding description). The table is validated (a) before save, (b) against
the manifest after restore — shape/dtype/name drift is caught at the
boundary with a coded error instead of corrupting training state.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass

import jax
import numpy as np

from .errors import RegistryMismatchError
from .namespace import check_leaf_name
from .split_state import leaf_paths


@dataclass(frozen=True)
class Region:
    name: str
    shape: tuple
    dtype: str
    nbytes: int
    role: str            # params | opt | step | rng | data | other
    sharding: str = ""


def _role(name: str) -> str:
    head = name.split("/", 1)[0]
    return head if head in ("params", "opt", "step", "rng") else "other"


def build_registry(state) -> list:
    rows = []
    for name, leaf in leaf_paths(state):
        check_leaf_name(name)
        shape = tuple(getattr(leaf, "shape", ()))
        dtype = str(getattr(leaf, "dtype", type(leaf).__name__))
        size = int(np.prod(shape)) if shape else 1
        itemsize = np.dtype("float32").itemsize
        try:
            itemsize = np.dtype(dtype).itemsize
        except TypeError:
            if dtype == "bfloat16":
                itemsize = 2
        sh = ""
        if hasattr(leaf, "sharding"):
            try:
                sh = str(getattr(leaf.sharding, "spec", ""))
            except Exception:  # noqa
                sh = ""
        rows.append(Region(name, shape, dtype, size * itemsize,
                           _role(name), sh))
    return rows


def registry_json(rows) -> list:
    return [asdict(r) for r in rows]


def validate_against(state, manifest_leaves: dict, *, strict: bool = True):
    """Post-restore runtime check: every state leaf must match the manifest's
    recorded region (name, shape, dtype)."""
    problems = []
    for name, leaf in leaf_paths(state):
        rec = manifest_leaves.get(name)
        if rec is None:
            problems.append(f"leaf {name!r} missing from manifest")
            continue
        if tuple(rec["shape"]) != tuple(leaf.shape):
            problems.append(
                f"{name}: shape {tuple(leaf.shape)} != saved "
                f"{tuple(rec['shape'])}")
        if str(rec["dtype"]) != str(leaf.dtype):
            problems.append(
                f"{name}: dtype {leaf.dtype} != saved {rec['dtype']}")
    extra = set(manifest_leaves) - {n for n, _ in leaf_paths(state)}
    if extra and strict:
        problems.append(f"manifest has {len(extra)} unknown leaves "
                        f"(e.g. {sorted(extra)[:3]})")
    if problems:
        raise RegistryMismatchError("state-region table validation failed",
                                    problems=problems[:10],
                                    n_problems=len(problems))
    return True


def total_bytes(rows) -> int:
    return sum(r.nbytes for r in rows)
