"""Quiescence / drain protocol — the paper's in-transit message discipline.

MANA: "to ensure that no in-transit MPI messages are lost due to
checkpointing, we delayed the final checkpoint until the count of total
bytes sent and received was equal."

JAX analogue, one level up the stack:
  1. device quiescence — ``jax.block_until_ready`` on the state pytree: no
     in-flight async dispatch may straddle the snapshot;
  2. writer quiescence — the async checkpoint writer tracks
     (enqueued_bytes, committed_bytes); the next snapshot (and shutdown)
     wait until the two counters are EQUAL — the same two-counter equality.
"""
from __future__ import annotations

import threading
import time

import jax


class DrainCounters:
    """Thread-safe sent/received byte accounting (paper's equality test)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.enqueued_bytes = 0
        self.committed_bytes = 0
        self.enqueued_items = 0
        self.committed_items = 0

    def enqueue(self, nbytes: int):
        with self._cv:
            self.enqueued_bytes += nbytes
            self.enqueued_items += 1

    def commit(self, nbytes: int):
        with self._cv:
            self.committed_bytes += nbytes
            self.committed_items += 1
            self._cv.notify_all()

    def drained(self) -> bool:
        with self._lock:
            return (self.enqueued_bytes == self.committed_bytes
                    and self.enqueued_items == self.committed_items)

    def wait(self, timeout: float | None = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not (self.enqueued_bytes == self.committed_bytes
                       and self.enqueued_items == self.committed_items):
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._cv.wait(remaining)
            return True

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "enqueued_bytes": self.enqueued_bytes,
                "committed_bytes": self.committed_bytes,
                "enqueued_items": self.enqueued_items,
                "committed_items": self.committed_items,
            }


def quiesce_device_state(state) -> float:
    """Block until no computation touching `state` is in flight. Returns the
    wait time (a reliability metric the trainer logs)."""
    t0 = time.monotonic()
    for leaf in jax.tree.leaves(state):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return time.monotonic() - t0
