"""AOT compiled-executable cache — the static-linking analogue.

Paper: "For best startup performance at scale, it is recommended to
broadcast a statically linked executable to all nodes." The JAX analogue of
startup cost is XLA compilation at restart; we serialize compiled
executables keyed by (config digest, input avals, mesh, jax version) so a
restarted (or newly scaled) job loads instead of recompiling.

Falls back to the persistent compilation cache dir, then to a no-op, when
executable serialization is unsupported on the runtime.
"""
from __future__ import annotations

import hashlib
import pickle
import time
from pathlib import Path

from .errors import warn


def _key(tag: str, avals_repr: str, mesh_repr: str) -> str:
    import jax
    blob = f"{tag}|{avals_repr}|{mesh_repr}|jax-{jax.__version__}"
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


class AotCache:
    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.stats = {"hits": 0, "misses": 0, "stores": 0, "errors": 0}

    def _path(self, key: str) -> Path:
        return self.root / f"{key}.aotexec"

    def load_or_compile(self, jitted, args, *, tag: str, mesh=None):
        """Returns (compiled, source) where source is 'cache' | 'compile'."""
        from jax.experimental import serialize_executable as se
        avals = repr(jax.tree.map(
            lambda x: (tuple(x.shape), str(x.dtype)), args)) \
            if args is not None else ""
        key = _key(tag, avals, repr(mesh))
        path = self._path(key)
        if path.exists():
            try:
                payload, in_tree, out_tree = pickle.loads(path.read_bytes())
                compiled = se.deserialize_and_load(payload, in_tree, out_tree)
                self.stats["hits"] += 1
                return compiled, "cache"
            except Exception as e:  # noqa — cache is best-effort
                self.stats["errors"] += 1
                warn("CKPT_W_AOT", "stale AOT cache entry; recompiling",
                     key=key, err=str(e)[:120])
        t0 = time.monotonic()
        compiled = jitted.lower(*args).compile()
        self.stats["misses"] += 1
        try:
            blob = pickle.dumps(se.serialize(compiled))
            tmp = path.with_suffix(".tmp")
            tmp.write_bytes(blob)
            tmp.rename(path)
            self.stats["stores"] += 1
        except Exception as e:  # noqa
            self.stats["errors"] += 1
            warn("CKPT_W_AOT", "executable serialization unavailable",
                 err=str(e)[:120])
        self.stats["last_compile_s"] = time.monotonic() - t0
        return compiled, "compile"


import jax  # noqa: E402  (bottom import keeps module import cheap)
