"""Split-state model — the JAX adaptation of MANA's split-process approach.

MANA tags application memory as *upper half* (checkpointed) and MPI/network
libraries as *lower half* (re-instantiated by a trivial MPI application on
restart). Here:

  upper half  = TrainState: {params, opt, step, rng} (+ DataState, held by
                the Trainer) — a pure pytree of logical global arrays.
                This is the ONLY thing checkpoints persist.
  lower half  = mesh, shardings, compiled executables, device buffers —
                derived from (config, current topology) at restore time by
                ``lower_half_bringup`` (the "trivial MPI application").

Because the upper half stores *logical* arrays (global shape + dtype + index
ranges per shard file), a checkpoint taken on one mesh restores onto any
other — the M×N portability property, strengthened to elasticity.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..sharding.partition import param_specs


# ---------------------------------------------------------------------------
# upper half
# ---------------------------------------------------------------------------

def init_train_state(model, optimizer, rng):
    """Concrete initial state (small models / examples; full-size states are
    only ever created abstractly or restored shard-by-shard)."""
    params = model.init(rng)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jax.numpy.zeros((), jax.numpy.int32),
        "rng": jax.random.key_data(jax.random.PRNGKey(0)),
    }


def abstract_train_state(model, optimizer, rng=None):
    """ShapeDtypeStruct pytree of the state — no allocation (dry-run path)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda r: init_train_state(model, optimizer, r), rng)


def state_shardings(abstract_state, mesh: Mesh, optimizer):
    ps = param_specs(abstract_state["params"], mesh)
    return {
        "params": ps,
        "opt": optimizer.state_sharding(ps, abstract_state["params"], mesh),
        "step": NamedSharding(mesh, P()),
        "rng": NamedSharding(mesh, P()),
    }


def with_shardings(abstract_state, shardings):
    """Attach shardings to a ShapeDtypeStruct tree (for jit .lower())."""
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        abstract_state, shardings)


def leaf_paths(tree):
    """Stable string path per leaf — checkpoint shard naming ("memory-region
    table" entries, Lesson 1)."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out


# ---------------------------------------------------------------------------
# lower half
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LowerHalfDescriptor:
    """Recorded in the manifest FOR INFORMATION ONLY — restore never requires
    any of it to match (that's the point of the split)."""
    mesh_shape: tuple
    mesh_axes: tuple
    n_devices: int
    runtime: str
    config_digest: str

    def to_json(self):
        return asdict(self)


def config_digest(cfg) -> str:
    from dataclasses import asdict as dc_asdict
    blob = json.dumps(dc_asdict(cfg), sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def lower_half_descriptor(mesh: Mesh, cfg) -> LowerHalfDescriptor:
    return LowerHalfDescriptor(
        mesh_shape=tuple(mesh.devices.shape),
        mesh_axes=tuple(mesh.axis_names),
        n_devices=mesh.devices.size,
        runtime=f"jax-{jax.__version__}",
        config_digest=config_digest(cfg),
    )
