"""Topology-agnostic checkpoint save/restore — MANA's split-process C/R as a
JAX subsystem.

Save path (two-phase commit, coordinator-supervised, async-capable):

  drain → host snapshot → [rank writers: encode+crc+write shards] → barrier
        → manifest (single handle, P7) → atomic rename commit → LATEST
        → refcount publication (incremental mode) → mark-and-sweep GC
        → background drain to the slow storage tier

Two save modes (``mode=``):

  full         every shard payload is written inline into the step directory
               (the v2 behaviour — O(model) bytes per checkpoint);
  incremental  encoded shard payloads are fixed-size-chunked into the
               content-addressed store (core.cas); the manifest records
               per-shard chunk digest lists, unchanged chunks dedup to zero
               write cost, and the steady-state checkpoint is O(changed
               chunks) — the paper's "reduce checkpoint overhead" open item.

Incremental chunking comes in two schemes (``chunking=``): ``fixed``
(fixed-size split) and ``cdc`` (FastCDC-style content-defined chunking,
``core.cdc``) — CDC keeps deduping when a payload shifts by a few bytes,
where fixed-size boundaries all move. The chunk data path is pipelined
across a bounded IO pool (``io_threads=``, ``core.chunk_exec``): writer
ranks hash+write chunks concurrently with one directory fsync per batch,
and restore prefetches chunks ahead of reassembly.

Manifest format v4 records the chunking scheme per shard record (and
manifest-wide); v3 (``mode``/``chunk_size``, chunked records) and v2
(inline shard files only) remain fully readable — mixed-history restores
and GC work across all three.

Restore path (elastic, P2/P6):

  manifest → per-device index ranges from the *current* sharding
           → plan_reads over saved ranges → leaf-level fan-out across the
             restore pool → read (fast tier → slow tier → buddy replica;
             chunked shards prefetch chunks the same way)
           → crc verify → decode → assemble →
           → jax.make_array_from_callback → registry validation

Nothing about the saving topology is required to match: different device
count, mesh shape, or sharding restores correctly (tested 1↔4↔8-device),
in both full and incremental modes.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
import zlib
from collections import Counter, OrderedDict
from pathlib import Path

import jax
import msgpack
import numpy as np

from . import atomic, cas, cdc, codec as codec_mod
from .atomic import NO_CRASH, CrashInjector
from .chunk_exec import DEFAULT_IO_THREADS, ChunkIOExecutor, cpu_cap
from .coordinator import CheckpointCoordinator
from .drain import DrainCounters, quiesce_device_state
from .elastic import ShardRange, normalize_index, assemble, plan_reads
from .errors import (AbortedError, CkptError, CodecUnavailableError,
                     CorruptShardError, MissingShardError, NoCheckpointError,
                     warn)
from .namespace import REPLICA_SUFFIX, UPPER_DIR, leaf_to_fname
from .registry import build_registry, registry_json, validate_against
from .split_state import leaf_paths
from .storage import TieredStore

FORMAT_VERSION = 4
# v2 = full-mode inline shards only; v3 = chunked records, implicitly
# fixed-size chunking (no per-record scheme field)
READABLE_FORMATS = (2, 3, 4)
MODES = ("full", "incremental")
CHUNKINGS = ("fixed", "cdc")


# ---------------------------------------------------------------------------
# shard files (full mode / v2)
# ---------------------------------------------------------------------------

def _pack_shard(leaf: str, rng: ShardRange, arr: np.ndarray, codec: str):
    payload, meta = codec_mod.encode(arr, codec)
    header = {
        "leaf": leaf,
        "global_dtype": str(arr.dtype),
        "start": list(rng.start),
        "stop": list(rng.stop),
        "codec": codec,
        "meta": meta,
        "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
        "payload_bytes": len(payload),
    }
    hb = msgpack.packb(header)
    return len(hb).to_bytes(4, "little") + hb + payload, header


def _unpack_shard(data: bytes):
    hlen = int.from_bytes(data[:4], "little")
    header = msgpack.unpackb(data[4:4 + hlen])
    payload = data[4 + hlen:4 + hlen + header["payload_bytes"]]
    if (zlib.crc32(payload) & 0xFFFFFFFF) != header["crc32"]:
        raise CorruptShardError("payload crc mismatch", leaf=header["leaf"])
    rng = ShardRange(tuple(header["start"]), tuple(header["stop"]))
    arr = codec_mod.decode(payload, header["codec"], rng.shape,
                           header["global_dtype"], header["meta"])
    return rng, arr


# ---------------------------------------------------------------------------
# manager
# ---------------------------------------------------------------------------

class CheckpointManager:
    def __init__(self, store: TieredStore, *, n_writers: int = 4,
                 codec: str | None = None, params_codec: str | None = None,
                 replicas: int = 1, retain: int = 3,
                 keepalive_s: float = 10.0, save_timeout_s: float = 600.0,
                 max_retries: int = 1, async_drain_to_slow: bool = True,
                 mode: str = "full",
                 chunk_size: int = cas.DEFAULT_CHUNK_SIZE,
                 chunking: str = "fixed",
                 io_threads: int = DEFAULT_IO_THREADS):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if chunking not in CHUNKINGS:
            raise ValueError(f"chunking must be one of {CHUNKINGS}, "
                             f"got {chunking!r}")
        self.store = store
        self.n_writers = n_writers
        self.mode = mode
        self.chunking = chunking
        # chunking="cdc": chunk_size becomes the content-defined AVERAGE
        # (min/avg/max = size/4, size, size*4 — FastCDC normalization);
        # the chunker is stateless and shared by every writer rank
        self._chunker = (cdc.GearChunker(chunk_size).chunk
                         if chunking == "cdc" else None)
        # None → best codec the environment supports (zstd needs the
        # optional `zstandard` package; raw always works)
        self.codec = codec or codec_mod.default_codec()
        self.params_codec = params_codec or self.codec  # int8 opt-in
        for c in {self.codec, self.params_codec}:
            if c not in codec_mod.CODECS:
                raise ValueError(f"unknown codec {c!r}")
            if not codec_mod.available(c):
                # fail fast with the real cause — otherwise every writer
                # rank dies on encode and the save aborts with an opaque
                # "no surviving writer ranks"
                raise CodecUnavailableError(
                    "codec requires the optional `zstandard` package "
                    "(pip install 'repro[compress]')", codec=c)
        self.replicas = replicas                    # 2 = buddy redundancy
        self.retain = retain
        self.save_timeout_s = save_timeout_s
        # node-failure recovery: a failed/dead writer rank is excluded and
        # its shards are redistributed to survivors, up to max_retries times
        self.max_retries = max_retries
        self.coordinator = CheckpointCoordinator(n_writers,
                                                 keepalive_s=keepalive_s)
        self.counters = DrainCounters()
        # always constructed: a full-mode manager must still RESTORE
        # checkpoints written incrementally (and vice versa)
        self.chunks = cas.ChunkStore(store, chunk_size=chunk_size,
                                     replicas=replicas,
                                     io_threads=io_threads)
        # background drains reuse the chunk pool so fast-tier reads overlap
        # throttled slow-tier writes (first manager on a store wins)
        if getattr(store, "io_executor", None) is None:
            store.io_executor = self.chunks.executor
        # leaf-level restore fan-out runs on its OWN pool: leaf tasks block
        # on chunk-prefetch futures, so sharing the chunk pool could
        # deadlock with every worker parked on a nested wait. Capped at
        # the core count — the leaf work (crc, join, decode, assemble) is
        # CPU/bandwidth bound, where extra threads only contend
        self._restore_exec = ChunkIOExecutor(
            min(io_threads, cpu_cap()) if io_threads > 1 else io_threads)
        self._async_thread: threading.Thread | None = None
        self._async_err = None
        self._read_cache: OrderedDict = OrderedDict()
        self._read_cache_bytes = 0
        self._read_cache_lock = threading.Lock()
        self._manifest_refs_cache: dict = {}   # (tier, step) → Counter
        self.read_cache_limit = 1 << 30
        self.last_report: dict = {}
        self.last_gc_report: dict = {}

    def close(self):
        """Drain async work and tear down the IO pools (idempotent)."""
        self.wait()
        self.chunks.close()
        self._restore_exec.shutdown(wait=False)

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def save(self, state, step: int, *, extra: dict | None = None,
             blocking: bool = True, crash: CrashInjector = NO_CRASH) -> dict:
        """Checkpoint `state` at `step`. With blocking=False the host
        snapshot is synchronous but file IO overlaps subsequent compute
        (drain protocol guarantees quiescence before the next round)."""
        t0 = time.monotonic()
        # P4: quiescence before snapshot
        self.wait()                                  # previous round drained
        wait_s = quiesce_device_state(state)
        registry = build_registry(state)
        items = self._snapshot(state)
        snap_s = time.monotonic() - t0
        total = sum(a.nbytes for _, _, a in items)
        self.store.fast.preflight(total // max(self._est_ratio(), 1))
        self.counters.enqueue(total)
        args = (items, registry, state, step, extra or {}, total, t0,
                snap_s, wait_s, crash)
        if blocking:
            return self._write_round(*args)
        self._async_thread = threading.Thread(
            target=self._async_entry, args=args, daemon=True)
        self._async_thread.start()
        return {"step": step, "async": True, "snapshot_s": snap_s,
                "bytes": total}

    def _est_ratio(self):
        return 2 if self.codec != "raw" else 1

    def _async_entry(self, *args):
        try:
            self._write_round(*args)
        except Exception as e:  # noqa
            self._async_err = e
            # counters must still drain or the trainer deadlocks
            self.counters.commit(args[5])

    def wait(self):
        """Drain the async writer (two-counter equality, P4)."""
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if not self.counters.drained():
            self.counters.wait(timeout=self.save_timeout_s)
        if self._async_err is not None:
            e, self._async_err = self._async_err, None
            raise e

    def _snapshot(self, state) -> list:
        """Device → host copy; one entry per unique logical shard range.
        The pipelined engine fans the per-shard host copies out over the
        (save-time idle) restore pool; the serial engine keeps the
        original inline copies."""
        pending = []
        for name, leaf in leaf_paths(state):
            if hasattr(leaf, "addressable_shards"):
                seen = set()
                gshape = leaf.shape
                for sh in leaf.addressable_shards:
                    rng = normalize_index(sh.index, gshape)
                    key = (rng.start, rng.stop)
                    if key in seen:
                        continue           # replicated copy — save once
                    seen.add(key)
                    pending.append((name, rng, sh.data))
            else:
                arr = np.asarray(leaf)
                rng = ShardRange((0,) * arr.ndim, arr.shape)
                pending.append((name, rng, arr))
        hosts = self._restore_exec.map_ordered(
            np.asarray, [data for _, _, data in pending])
        return [(name, rng, arr)
                for (name, rng, _), arr in zip(pending, hosts)]

    def _leaf_codec(self, leaf_name: str) -> str:
        if leaf_name.startswith("params/"):
            return self.params_codec
        return self.codec

    def _write_round(self, items, registry, state, step, extra, total, t0,
                     snap_s, wait_s, crash) -> dict:
        stage = atomic.staging_dir(self.store.root, step)
        stage.mkdir(parents=True, exist_ok=True)
        atomic.mark_pending(stage, {"step": step, "t": time.time()})
        coord = self.coordinator
        rel_stage = stage.name
        incremental = self.mode == "incremental"

        stats_lock = threading.Lock()
        stats = {"files": 0, "payload_bytes": 0, "written_bytes": 0,
                 "new_object_bytes": 0, "chunks": 0}
        manifest_shards = {}
        shard_records: dict = {}    # item index → chunked manifest record
        shard_order: dict = {}      # leaf name → [item indices]
        dead: set = set()

        def assign(alive: list):
            """Round-robin shard assignment over surviving ranks; the next
            alive rank writes the buddy replica (full mode — in incremental
            mode chunk objects carry their own replica copies)."""
            per_rank = {r: [] for r in alive}
            shards = {}
            order = {}
            for i, (name, rng, arr) in enumerate(items):
                r = alive[i % len(alive)]
                fname = f"{UPPER_DIR}/{leaf_to_fname(name)}/shard-{i:05d}.bin"
                per_rank[r].append((i, name, rng, arr, fname, False))
                order.setdefault(name, []).append(i)
                if incremental:
                    continue
                replicas = [fname]
                if self.replicas > 1 and len(alive) > 1:
                    buddy = alive[(i + 1) % len(alive)]
                    rf = fname + REPLICA_SUFFIX
                    per_rank[buddy].append((i, name, rng, arr, rf, True))
                    replicas.append(rf)
                shards.setdefault(name, []).append({
                    "file": fname, "replicas": replicas,
                    "start": list(rng.start), "stop": list(rng.stop),
                    "dtype": str(arr.dtype),
                    "codec": self._leaf_codec(name),
                })
            return per_rank, shards, order

        def writer(rank: int, work: list):
            try:
                coord.rank_begin(rank)
                nbytes = 0
                files = []
                rank_chunks: Counter = Counter()
                rank_dirs: set = set()     # fan-out dirs pending fsync
                for i, name, rng, arr, fname, is_replica in work:
                    codec_name = self._leaf_codec(name)
                    if incremental:
                        pipelined = not self.chunks.executor.serial
                        if pipelined and codec_name == "raw":
                            # zero-copy feed: the chunk pipeline consumes a
                            # uint8 VIEW of the host array — no tobytes()
                            # copy, and chunk slices stay views all the way
                            # into hash/crc/write
                            payload = np.ascontiguousarray(arr) \
                                .reshape(-1).view(np.uint8)
                            meta = {}
                        else:
                            payload, meta = codec_mod.encode(arr, codec_name)
                        crash.maybe(f"rank{rank}_before_write")
                        if pipelined:
                            digests, new_bytes, crc = self.chunks.put_payload(
                                payload, crash,
                                on_chunk=lambda: coord.heartbeat(rank),
                                chunker=self._chunker, want_crc=True,
                                dirs_out=rank_dirs)
                        else:
                            digests, new_bytes = self.chunks.put_payload(
                                payload, crash,
                                on_chunk=lambda: coord.heartbeat(rank),
                                chunker=self._chunker)
                            crc = zlib.crc32(payload) & 0xFFFFFFFF
                        crash.maybe(f"rank{rank}_after_chunk_write")
                        rank_chunks.update(digests)
                        nbytes += new_bytes
                        rec = {
                            "chunks": digests,
                            "chunk_size": self.chunks.chunk_size,
                            "chunking": self.chunking,
                            "start": list(rng.start), "stop": list(rng.stop),
                            "dtype": str(arr.dtype), "codec": codec_name,
                            "meta": meta,
                            "crc32": crc,
                            "payload_bytes": len(payload),
                        }
                        with stats_lock:
                            shard_records[i] = rec
                            stats["files"] += 1
                            stats["payload_bytes"] += len(payload)
                            stats["written_bytes"] += new_bytes
                            stats["new_object_bytes"] += new_bytes
                            stats["chunks"] += len(digests)
                    else:
                        data, header = _pack_shard(name, rng, arr, codec_name)
                        crash.maybe(f"rank{rank}_before_write")
                        self.store.fast.write_file(f"{rel_stage}/{fname}",
                                                   data)
                        nbytes += len(data)
                        files.append(fname)
                        with stats_lock:
                            stats["written_bytes"] += len(data)
                            if not is_replica:
                                stats["files"] += 1
                                stats["payload_bytes"] += \
                                    header["payload_bytes"]
                    coord.heartbeat(rank)
                if rank_dirs:
                    # one durability barrier per rank, fanned over the
                    # chunk pool — PREPARED may only be acked once every
                    # object this rank wrote is findable after a crash
                    self.chunks.fsync_dirs(rank_dirs, crash)
                    coord.heartbeat(rank)
                coord.rank_prepared(rank, nbytes=nbytes, files=files,
                                    chunks=rank_chunks)
            except Exception as e:  # noqa
                coord.rank_failed(rank, f"{type(e).__name__}: {e}")

        ok = False
        reason = ""
        for attempt in range(self.max_retries + 1):
            alive = [r for r in range(self.n_writers) if r not in dead]
            if not alive:
                reason = "no surviving writer ranks"
                break
            for k in stats:
                stats[k] = 0
            shard_records.clear()
            per_rank, manifest_shards, shard_order = assign(alive)
            coord.begin_round(step, participants=alive)
            threads = [threading.Thread(target=writer, args=(r, per_rank[r]),
                                        daemon=True) for r in alive]
            for t in threads:
                t.start()
            ok = coord.wait_all_prepared(timeout=self.save_timeout_s)
            reason = coord.abort_reason()
            newly_dead = set(coord.round.failed) if coord.round else set()
            for t in threads:
                t.join()
            if ok:
                break
            coord.finish_round(False)
            dead |= newly_dead or set(alive)  # timeout w/o blame: give up
            if attempt < self.max_retries and newly_dead:
                warn("CKPT_W_RETRY",
                     "writer rank(s) failed; redistributing their shards "
                     "to survivors and retrying",
                     dead=sorted(dead), step=step, reason=reason)
        if not ok:
            # ABORT leaks nothing: no manifest, no LATEST move, and no
            # refcounts published — chunk objects a dead rank managed to
            # write are unreferenced orphans that the next sweep reclaims
            shutil.rmtree(stage, ignore_errors=True)
            self.counters.commit(total)
            raise AbortedError("checkpoint aborted", step=step, reason=reason)

        # phase 2: manifest = commit record (single handle, P7)
        if incremental:
            leaves = {
                name: {"shape": list(leaf.shape), "dtype": str(leaf.dtype),
                       "shards": [shard_records[i]
                                  for i in shard_order.get(name, [])]}
                for name, leaf in leaf_paths(state)
            }
        else:
            leaves = {
                name: {"shape": list(leaf.shape), "dtype": str(leaf.dtype),
                       "shards": manifest_shards.get(name, [])}
                for name, leaf in leaf_paths(state)
            }
        manifest = {
            "format": FORMAT_VERSION,
            "mode": self.mode,
            "step": step,
            "created": time.time(),
            "chunk_size": self.chunks.chunk_size if incremental else None,
            "chunking": self.chunking if incremental else None,
            "leaves": leaves,
            "registry": registry_json(registry),
            "extra": extra,
        }
        crash.maybe("before_manifest")
        atomic.atomic_write_bytes(stage / atomic.MANIFEST,
                                  json.dumps(manifest).encode(), crash)
        atomic.clear_pending(stage)
        final = atomic.committed_dir(self.store.root, step)
        atomic.commit_dir(stage, final, crash)
        crash.maybe("before_latest_write")
        atomic.write_latest(self.store.root, step, crash)
        # COMMIT phase: the coordinator publishes the round's aggregated
        # chunk refcounts atomically; the digests are captured first so the
        # new objects can be drained to the slow tier below
        round_digests = sorted(coord.round.chunk_refs) if coord.round else []
        coord.finish_round(
            True,
            publish_refs=(
                (lambda refs: self.chunks.apply_refs(refs, crash))
                if incremental else None))
        self.counters.commit(total)
        self.last_gc_report = self._gc_locked(crash=crash)
        self.store.drain_step(
            final.name,
            extra_files=[cas.object_rel(d, r)
                         for d in round_digests
                         for r in range(self.chunks.replicas)])
        dt = time.monotonic() - t0
        report = {
            "step": step, "mode": self.mode, "bytes": total,
            "payload_bytes": stats["payload_bytes"],
            "written_bytes": stats["written_bytes"],
            "files": stats["files"], "seconds": dt,
            "snapshot_s": snap_s, "drain_wait_s": wait_s,
            "throughput_gbps": total / dt / 1e9 if dt else 0.0,
            "compression_ratio": total / max(stats["payload_bytes"], 1),
        }
        if incremental:
            # dedup ratio compares logical payload to per-copy object
            # bytes — new_object_bytes counts physical IO across replica
            # copies, which would read as 0.5× dedup on a cold save with
            # buddy redundancy
            per_copy = stats["new_object_bytes"] / self.chunks.replicas
            report.update(
                chunks=stats["chunks"],
                new_object_bytes=stats["new_object_bytes"],
                dedup_ratio=stats["payload_bytes"] / max(per_copy, 1))
        self.last_report = report
        return report

    # ------------------------------------------------------------------
    # GC: step retirement + CAS mark-and-sweep
    # ------------------------------------------------------------------
    def _live_chunk_refs(self, tiers=None, errors: list | None = None) \
            -> Counter:
        """Mark phase: chunk refcounts implied by every committed manifest
        on the given tiers (default: all — old steps may survive on the
        slow tier after fast-tier retirement and their chunks stay live).
        Committed manifests are immutable, so per-(tier, step) ref counters
        are memoized: each save only parses the manifest it just wrote
        instead of re-reading the whole run history.

        An unreadable manifest does NOT silently contribute zero refs: the
        same step's copy on another tier is still consulted (a step only
        counts as seen once successfully parsed), and any step that stays
        unreadable everywhere is appended to `errors` so a destructive
        caller can fail safe instead of sweeping that step's chunks."""
        full_scan = tiers is None
        tiers = self.store.tiers() if full_scan else tiers
        live: Counter = Counter()
        seen_steps: set = set()
        failed_steps: dict = {}
        valid_keys: set = set()
        for tier in tiers:
            for s in atomic.list_committed_steps(tier.root):
                key = (tier.name, s)
                valid_keys.add(key)
                if s in seen_steps:
                    continue
                refs = self._manifest_refs_cache.get(key)
                if refs is None:
                    mpath = atomic.committed_dir(tier.root, s) \
                        / atomic.MANIFEST
                    try:
                        refs = cas.live_chunk_refs(
                            [json.loads(mpath.read_text())])
                    except (OSError, ValueError):
                        failed_steps[s] = tier.name
                        continue
                    self._manifest_refs_cache[key] = refs
                seen_steps.add(s)
                live.update(refs)
        if errors is not None:
            errors.extend((t, s) for s, t in failed_steps.items()
                          if s not in seen_steps)
        if full_scan:                      # drop memo entries of retired steps
            for key in list(self._manifest_refs_cache):
                if key not in valid_keys:
                    del self._manifest_refs_cache[key]
        return live

    def gc(self, *, crash: CrashInjector = NO_CRASH) -> dict:
        """Retire fast-tier steps beyond `retain`, clear staging litter,
        then mark-and-sweep the content-addressed store. Crash-safe: the
        mark set derives only from committed manifests, so a crash at any
        point here is repaired by the next gc() — committed checkpoints
        never lose chunks. Serializes with an in-flight async save: a
        round's fresh chunks are unreferenced until its manifest commits,
        and sweeping mid-round would reap them."""
        self.wait()
        return self._gc_locked(crash=crash, force_sweep=True)

    def _gc_locked(self, *, crash: CrashInjector = NO_CRASH,
                   force_sweep: bool = False) -> dict:
        """GC body — called directly by the save round itself (which IS
        the async thread, so it must not self-join via wait()).

        The destructive mark-and-sweep is O(total objects + history), so
        the per-save path only runs it when retention actually dropped a
        step (that's when objects become garbage in bulk); an explicit
        gc() always sweeps, which is how aborted-round orphans are
        reclaimed on demand."""
        # a step being drained to the slow tier MUST land before retirement
        # and marking — otherwise retiring its fast copy mid-copy would
        # leave its manifest on no tier and sweep would reap its chunks
        self.store.wait_drained()
        steps = atomic.list_committed_steps(self.store.root)
        dropped = steps[:-self.retain] if self.retain else []
        for s in dropped:
            shutil.rmtree(atomic.committed_dir(self.store.root, s),
                          ignore_errors=True)
        atomic.gc_staging(self.store.root)
        no_sweep = {"swept": 0, "swept_bytes": 0, "kept": 0, "kept_bytes": 0,
                    "tmp_removed": 0, "evicted": 0, "evicted_bytes": 0}
        if not (dropped or force_sweep):
            return {"steps_dropped": [],
                    "cas": dict(no_sweep, skipped=True)}
        errors: list = []
        live = self._live_chunk_refs(errors=errors)
        fast_errors: list = []
        fast_live = (self._live_chunk_refs(tiers=[self.store.fast],
                                           errors=fast_errors)
                     if self.store.slow is not None else None)
        if fast_errors:
            # eviction's mark set is incomplete (a fast-tier manifest is
            # unreadable even though the slow copy may be fine) — evicting
            # on it would silently demote a retained step to slow-tier
            # bandwidth, so skip eviction this round
            warn("CKPT_W_GC", "unreadable fast-tier manifest(s); skipping "
                 "burst-buffer eviction this round", steps=fast_errors[:8])
            fast_live = None
        crash.maybe("after_gc_mark")
        if errors:
            # fail safe: with any committed manifest unreadable the mark
            # set is incomplete, and sweeping would permanently delete
            # chunks a committed checkpoint still needs
            warn("CKPT_W_GC", "unreadable committed manifest(s); skipping "
                 "the CAS sweep (fail-safe) — repair or remove the damaged "
                 "step(s) and rerun gc()", steps=errors[:8])
            return {"steps_dropped": dropped,
                    "cas": dict(no_sweep, skipped=True,
                                unreadable_manifests=errors)}
        report = {"steps_dropped": dropped,
                  "cas": self.chunks.sweep(live, crash,
                                           fast_live=fast_live)}
        return report

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def latest_step(self):
        """Newest restorable step. A crash between the commit rename and
        the LATEST write leaves LATEST one step behind the newest committed
        dir; trusting the pointer alone would make a restarted trainer
        re-save that step and die on FileExistsError forever, so the answer
        is max(LATEST, newest committed step on any tier)."""
        latest = atomic.read_latest(self.store.root)
        committed = [s for tier in self.store.tiers()
                     for s in atomic.list_committed_steps(tier.root)]
        newest = max(committed, default=None)
        if latest is None or (newest is not None and newest > latest):
            return newest
        return latest

    def load_manifest(self, step: int) -> dict:
        rel = f"{atomic.committed_dir(Path('.'), step).name}/{atomic.MANIFEST}"
        tier = self.store.locate(rel)
        if tier is None:
            raise NoCheckpointError("no manifest for step", step=step)
        manifest = json.loads(tier.read_file(rel))
        fmt = int(manifest.get("format", 0))
        if fmt not in READABLE_FORMATS:
            raise CkptError("unsupported manifest format", format=fmt,
                            readable=list(READABLE_FORMATS), step=step)
        return manifest

    def restore(self, abstract_state, shardings=None, *, step: int | None = None,
                validate: bool = True):
        """Restore onto the CURRENT topology. `abstract_state`: pytree of
        ShapeDtypeStruct (or arrays — shapes/dtypes used); `shardings`:
        matching tree of Shardings or None for single-device.

        Two phases: (1) every leaf's host-side data (read → chunk
        prefetch → crc → decode → assemble) is fetched with leaf-level
        fan-out across the restore pool; (2) device arrays are built on
        the calling thread — JAX array construction never runs on pool
        workers."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise NoCheckpointError("no committed checkpoint found",
                                    root=str(self.store.root))
        manifest = self.load_manifest(step)
        step_dir = atomic.committed_dir(Path("."), step).name
        leaves = manifest["leaves"]

        flat, treedef = jax.tree_util.tree_flatten(abstract_state)
        shard_flat = (treedef.flatten_up_to(shardings)
                      if shardings is not None else [None] * len(flat))
        names = [n for n, _ in leaf_paths(abstract_state)]
        jobs = []
        for name, sds, sharding in zip(names, flat, shard_flat):
            rec = leaves.get(name)
            if rec is None:
                raise MissingShardError("leaf missing from checkpoint",
                                        leaf=name, step=step)
            # canonical numpy target dtype, resolved on the main thread
            np_dtype = np.asarray(jax.numpy.zeros((), sds.dtype)).dtype
            jobs.append((name, rec, sds, sharding, np_dtype))

        def host(job):
            name, rec, sds, sharding, np_dtype = job
            fetch = self._leaf_fetcher(step_dir, name, rec, np_dtype)
            shape = tuple(sds.shape)
            return {(rng.start, rng.stop): fetch(rng)
                    for rng in self._leaf_ranges(shape, sharding)}

        prefetched = self._restore_exec.map_ordered(host, jobs)
        out = [self._leaf_to_device(step_dir, job, pre)
               for job, pre in zip(jobs, prefetched)]
        state = jax.tree_util.tree_unflatten(treedef, out)
        if validate:
            validate_against(state, leaves)
        with self._read_cache_lock:
            self._read_cache.clear()
            self._read_cache_bytes = 0
        return state, manifest.get("extra", {})

    def _leaf_fetcher(self, step_dir, name, rec, np_dtype):
        """Host-side range fetch for one leaf: plan reads over the saved
        shard ranges, read/decode each, assemble the target range. Pure
        numpy + IO — safe on restore pool workers.

        Pipelined engine only: when a single saved shard covers the target
        range EXACTLY (the common same-topology restore), its decoded
        array is returned as-is — no assemble copy, no coverage mask. The
        serial engine keeps the original always-assemble path (it is the
        benchmark baseline)."""
        available = [(ShardRange(tuple(s["start"]), tuple(s["stop"])), s)
                     for s in rec["shards"]]
        exact_ok = not self._restore_exec.serial

        def fetch(target: ShardRange) -> np.ndarray:
            picks = plan_reads(target, available)
            if exact_ok and len(picks) == 1 and \
                    picks[0][0].start == target.start and \
                    picks[0][0].stop == target.stop:
                arr = self._read_shard(step_dir, picks[0][1])
                if arr.dtype == np_dtype and arr.shape == target.shape:
                    return arr
                # dtype/shape drift: fall through to the casting assemble
            pieces = [(rng, self._read_shard(step_dir, s))
                      for rng, s in picks]
            try:
                return assemble(target, pieces, np_dtype)
            except LookupError as e:
                raise MissingShardError(str(e), leaf=name) from None

        return fetch

    @staticmethod
    def _leaf_ranges(shape, sharding):
        """Index ranges THIS PROCESS needs from one leaf — what the
        host-fetch phase prefetches. Only addressable devices count: on a
        multi-host restore each host must read O(its shards), not
        O(global model). An un-enumerable sharding yields no prefetch
        ranges; the device callback then fetches lazily."""
        if sharding is None:
            return [ShardRange((0,) * len(shape), shape)]
        try:
            idx_map = sharding.addressable_devices_indices_map(shape)
        except Exception:  # noqa — exotic sharding: fall back to lazy cb
            return []
        seen, out = set(), []
        for idx in idx_map.values():
            if idx is None:
                continue
            rng = normalize_index(idx, shape)
            key = (rng.start, rng.stop)
            if key not in seen:
                seen.add(key)
                out.append(rng)
        return out

    def _leaf_to_device(self, step_dir, job, prefetched):
        """Phase 2 (main thread): device array from prefetched host data,
        with a lazy fetch fallback for ranges the prefetch missed."""
        name, rec, sds, sharding, np_dtype = job
        shape = tuple(sds.shape)
        dtype = sds.dtype
        if sharding is None:
            full = prefetched[((0,) * len(shape), shape)]
            return jax.numpy.asarray(full, dtype=dtype)
        fetch = self._leaf_fetcher(step_dir, name, rec, np_dtype)

        def cb(index):
            rng = normalize_index(index, shape)
            key = (rng.start, rng.stop)
            if key not in prefetched:
                prefetched[key] = fetch(rng)
            return prefetched[key]

        return jax.make_array_from_callback(shape, sharding, cb)

    def _read_shard(self, step_dir: str, srec: dict) -> np.ndarray:
        if "chunks" in srec:
            return self._read_chunked_shard(srec)
        # step-scoped: shard file names repeat across steps, and a failed
        # restore can leave the cache populated for a different step
        key = f"{step_dir}/{srec['file']}"
        cached = self._cache_get(key)
        if cached is not None:
            return cached
        last_err = None
        for fname in srec.get("replicas", [srec["file"]]):
            rel = f"{step_dir}/{fname}"
            tier = self.store.locate(rel)
            if tier is None:
                last_err = MissingShardError("shard not on any tier",
                                             file=fname)
                continue
            try:
                rng, arr = _unpack_shard(tier.read_file(rel))
                if fname != srec["file"]:
                    warn("CKPT_W_REPLICA", "primary shard unavailable; "
                         "restored from buddy replica", file=srec["file"])
                self._cache_put(key, arr)
                return arr
            except (CorruptShardError, OSError, ValueError) as e:
                last_err = e
                continue
        raise last_err if last_err else MissingShardError(
            "unreadable shard", file=srec["file"])

    def _read_chunked_shard(self, srec: dict) -> np.ndarray:
        """v3/v4 incremental shard: reassemble the encoded payload via the
        prefetch pipeline (each chunk resolved fast tier → slow tier →
        buddy replica, the whole-payload crc as the end-to-end integrity
        gate), then decode."""
        key = ("cas", tuple(srec["chunks"]), srec["codec"], srec["dtype"],
               tuple(srec["start"]), tuple(srec["stop"]))
        cached = self._cache_get(key)
        if cached is not None:
            return cached
        payload = self.chunks.read_payload(srec["chunks"],
                                           srec.get("payload_bytes"),
                                           crc32=srec["crc32"])
        rng = ShardRange(tuple(srec["start"]), tuple(srec["stop"]))
        arr = codec_mod.decode(payload, srec["codec"], rng.shape,
                               srec["dtype"], srec.get("meta", {}))
        self._cache_put(key, arr)
        return arr

    # ------------------------------------------------------------------
    # read cache: LRU, byte-budgeted, safe under concurrent leaf fan-out
    # ------------------------------------------------------------------
    def _cache_get(self, key):
        with self._read_cache_lock:
            ent = self._read_cache.get(key)
            if ent is None:
                return None
            self._read_cache.move_to_end(key)     # recency, not insertion
            return ent[1]

    def _cache_put(self, key, arr):
        with self._read_cache_lock:
            old = self._read_cache.pop(key, None)
            if old is not None:
                # re-insert (e.g. concurrent fills of the same shard) must
                # not double-count: a leaked byte total would eventually
                # exceed the limit forever and thrash the cache to one entry
                self._read_cache_bytes -= old[1].nbytes
            self._read_cache[key] = (time.monotonic(), arr)
            self._read_cache_bytes += arr.nbytes
            while self._read_cache_bytes > self.read_cache_limit \
                    and len(self._read_cache) > 1:
                _, (_, evicted) = self._read_cache.popitem(last=False)
                self._read_cache_bytes -= evicted.nbytes
