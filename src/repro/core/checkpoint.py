"""Topology-agnostic checkpoint save/restore — MANA's split-process C/R as a
JAX subsystem.

Save path (two-phase commit, coordinator-supervised, async-capable):

  drain → host snapshot → [rank writers: encode+crc+write shards] → barrier
        → manifest (single handle, P7) → atomic rename commit → LATEST
        → background drain to the slow storage tier → GC old steps

Restore path (elastic, P2/P6):

  manifest → per-device index ranges from the *current* sharding
           → plan_reads over saved ranges → read (fast tier → slow tier →
             buddy replica) → crc verify → decode → assemble →
             jax.make_array_from_callback → registry validation

Nothing about the saving topology is required to match: different device
count, mesh shape, or sharding restores correctly (tested 1↔4↔8-device).
"""
from __future__ import annotations

import json
import shutil
import threading
import time
import zlib
from collections import OrderedDict
from pathlib import Path

import jax
import msgpack
import numpy as np

from . import atomic, codec as codec_mod
from .atomic import NO_CRASH, CrashInjector
from .coordinator import CheckpointCoordinator
from .drain import DrainCounters, quiesce_device_state
from .elastic import ShardRange, normalize_index, assemble, plan_reads
from .errors import (AbortedError, CorruptShardError, MissingShardError,
                     NoCheckpointError, warn)
from .namespace import REPLICA_SUFFIX, UPPER_DIR, leaf_to_fname
from .registry import build_registry, registry_json, validate_against
from .split_state import leaf_paths
from .storage import TieredStore

FORMAT_VERSION = 2


# ---------------------------------------------------------------------------
# shard files
# ---------------------------------------------------------------------------

def _pack_shard(leaf: str, rng: ShardRange, arr: np.ndarray, codec: str):
    payload, meta = codec_mod.encode(arr, codec)
    header = {
        "leaf": leaf,
        "global_dtype": str(arr.dtype),
        "start": list(rng.start),
        "stop": list(rng.stop),
        "codec": codec,
        "meta": meta,
        "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
        "payload_bytes": len(payload),
    }
    hb = msgpack.packb(header)
    return len(hb).to_bytes(4, "little") + hb + payload, header


def _unpack_shard(data: bytes):
    hlen = int.from_bytes(data[:4], "little")
    header = msgpack.unpackb(data[4:4 + hlen])
    payload = data[4 + hlen:4 + hlen + header["payload_bytes"]]
    if (zlib.crc32(payload) & 0xFFFFFFFF) != header["crc32"]:
        raise CorruptShardError("payload crc mismatch", leaf=header["leaf"])
    rng = ShardRange(tuple(header["start"]), tuple(header["stop"]))
    arr = codec_mod.decode(payload, header["codec"], rng.shape,
                           header["global_dtype"], header["meta"])
    return rng, arr


# ---------------------------------------------------------------------------
# manager
# ---------------------------------------------------------------------------

class CheckpointManager:
    def __init__(self, store: TieredStore, *, n_writers: int = 4,
                 codec: str = "zstd", params_codec: str | None = None,
                 replicas: int = 1, retain: int = 3,
                 keepalive_s: float = 10.0, save_timeout_s: float = 600.0,
                 max_retries: int = 1, async_drain_to_slow: bool = True):
        self.store = store
        self.n_writers = n_writers
        self.codec = codec
        self.params_codec = params_codec or codec   # int8 opt-in for params
        self.replicas = replicas                    # 2 = buddy redundancy
        self.retain = retain
        self.save_timeout_s = save_timeout_s
        # node-failure recovery: a failed/dead writer rank is excluded and
        # its shards are redistributed to survivors, up to max_retries times
        self.max_retries = max_retries
        self.coordinator = CheckpointCoordinator(n_writers,
                                                 keepalive_s=keepalive_s)
        self.counters = DrainCounters()
        self._async_thread: threading.Thread | None = None
        self._async_err = None
        self._read_cache: OrderedDict = OrderedDict()
        self._read_cache_bytes = 0
        self.read_cache_limit = 1 << 30
        self.last_report: dict = {}

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def save(self, state, step: int, *, extra: dict | None = None,
             blocking: bool = True, crash: CrashInjector = NO_CRASH) -> dict:
        """Checkpoint `state` at `step`. With blocking=False the host
        snapshot is synchronous but file IO overlaps subsequent compute
        (drain protocol guarantees quiescence before the next round)."""
        t0 = time.monotonic()
        # P4: quiescence before snapshot
        self.wait()                                  # previous round drained
        wait_s = quiesce_device_state(state)
        registry = build_registry(state)
        items = self._snapshot(state)
        snap_s = time.monotonic() - t0
        total = sum(a.nbytes for _, _, a in items)
        self.store.fast.preflight(total // max(self._est_ratio(), 1))
        self.counters.enqueue(total)
        args = (items, registry, state, step, extra or {}, total, t0,
                snap_s, wait_s, crash)
        if blocking:
            return self._write_round(*args)
        self._async_thread = threading.Thread(
            target=self._async_entry, args=args, daemon=True)
        self._async_thread.start()
        return {"step": step, "async": True, "snapshot_s": snap_s,
                "bytes": total}

    def _est_ratio(self):
        return 2 if self.codec != "raw" else 1

    def _async_entry(self, *args):
        try:
            self._write_round(*args)
        except Exception as e:  # noqa
            self._async_err = e
            # counters must still drain or the trainer deadlocks
            self.counters.commit(args[5])

    def wait(self):
        """Drain the async writer (two-counter equality, P4)."""
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if not self.counters.drained():
            self.counters.wait(timeout=self.save_timeout_s)
        if self._async_err is not None:
            e, self._async_err = self._async_err, None
            raise e

    def _snapshot(self, state) -> list:
        """Device → host copy; one entry per unique logical shard range."""
        items = []
        for name, leaf in leaf_paths(state):
            if hasattr(leaf, "addressable_shards"):
                seen = set()
                gshape = leaf.shape
                for sh in leaf.addressable_shards:
                    rng = normalize_index(sh.index, gshape)
                    key = (rng.start, rng.stop)
                    if key in seen:
                        continue           # replicated copy — save once
                    seen.add(key)
                    items.append((name, rng, np.asarray(sh.data)))
            else:
                arr = np.asarray(leaf)
                rng = ShardRange((0,) * arr.ndim, arr.shape)
                items.append((name, rng, arr))
        return items

    def _leaf_codec(self, leaf_name: str) -> str:
        if leaf_name.startswith("params/"):
            return self.params_codec
        return self.codec

    def _write_round(self, items, registry, state, step, extra, total, t0,
                     snap_s, wait_s, crash) -> dict:
        stage = atomic.staging_dir(self.store.root, step)
        stage.mkdir(parents=True, exist_ok=True)
        atomic.mark_pending(stage, {"step": step, "t": time.time()})
        coord = self.coordinator
        rel_stage = stage.name

        stats_lock = threading.Lock()
        stats = {"files": 0, "payload_bytes": 0}
        manifest_shards = {}
        dead: set = set()

        def assign(alive: list):
            """Round-robin shard assignment over surviving ranks; the next
            alive rank writes the buddy replica."""
            per_rank = {r: [] for r in alive}
            shards = {}
            for i, (name, rng, arr) in enumerate(items):
                r = alive[i % len(alive)]
                fname = f"{UPPER_DIR}/{leaf_to_fname(name)}/shard-{i:05d}.bin"
                per_rank[r].append((name, rng, arr, fname, False))
                replicas = [fname]
                if self.replicas > 1 and len(alive) > 1:
                    buddy = alive[(i + 1) % len(alive)]
                    rf = fname + REPLICA_SUFFIX
                    per_rank[buddy].append((name, rng, arr, rf, True))
                    replicas.append(rf)
                shards.setdefault(name, []).append({
                    "file": fname, "replicas": replicas,
                    "start": list(rng.start), "stop": list(rng.stop),
                    "dtype": str(arr.dtype),
                    "codec": self._leaf_codec(name),
                })
            return per_rank, shards

        def writer(rank: int, work: list):
            try:
                coord.rank_begin(rank)
                nbytes = 0
                files = []
                for name, rng, arr, fname, is_replica in work:
                    data, header = _pack_shard(name, rng, arr,
                                               self._leaf_codec(name))
                    crash.maybe(f"rank{rank}_before_write")
                    self.store.fast.write_file(f"{rel_stage}/{fname}", data)
                    nbytes += len(data)
                    files.append(fname)
                    coord.heartbeat(rank)
                    if not is_replica:
                        with stats_lock:
                            stats["files"] += 1
                            stats["payload_bytes"] += header["payload_bytes"]
                coord.rank_prepared(rank, nbytes=nbytes, files=files)
            except Exception as e:  # noqa
                coord.rank_failed(rank, f"{type(e).__name__}: {e}")

        ok = False
        reason = ""
        for attempt in range(self.max_retries + 1):
            alive = [r for r in range(self.n_writers) if r not in dead]
            if not alive:
                reason = "no surviving writer ranks"
                break
            stats["files"] = stats["payload_bytes"] = 0
            per_rank, manifest_shards = assign(alive)
            coord.begin_round(step, participants=alive)
            threads = [threading.Thread(target=writer, args=(r, per_rank[r]),
                                        daemon=True) for r in alive]
            for t in threads:
                t.start()
            ok = coord.wait_all_prepared(timeout=self.save_timeout_s)
            reason = coord.abort_reason()
            newly_dead = set(coord.round.failed) if coord.round else set()
            for t in threads:
                t.join()
            coord.finish_round(ok)
            if ok:
                break
            dead |= newly_dead or set(alive)  # timeout w/o blame: give up
            if attempt < self.max_retries and newly_dead:
                warn("CKPT_W_RETRY",
                     "writer rank(s) failed; redistributing their shards "
                     "to survivors and retrying",
                     dead=sorted(dead), step=step, reason=reason)
        if not ok:
            shutil.rmtree(stage, ignore_errors=True)
            self.counters.commit(total)
            raise AbortedError("checkpoint aborted", step=step, reason=reason)

        # phase 2: manifest = commit record (single handle, P7)
        manifest = {
            "format": FORMAT_VERSION,
            "step": step,
            "created": time.time(),
            "leaves": {
                name: {"shape": list(leaf.shape), "dtype": str(leaf.dtype),
                       "shards": manifest_shards.get(name, [])}
                for name, leaf in leaf_paths(state)
            },
            "registry": registry_json(registry),
            "extra": extra,
        }
        crash.maybe("before_manifest")
        atomic.atomic_write_bytes(stage / atomic.MANIFEST,
                                  json.dumps(manifest).encode(), crash)
        atomic.clear_pending(stage)
        final = atomic.committed_dir(self.store.root, step)
        atomic.commit_dir(stage, final, crash)
        atomic.write_latest(self.store.root, step, crash)
        self.counters.commit(total)
        self._gc()
        self.store.drain_step(final.name)
        dt = time.monotonic() - t0
        report = {
            "step": step, "bytes": total,
            "payload_bytes": stats["payload_bytes"],
            "files": stats["files"], "seconds": dt,
            "snapshot_s": snap_s, "drain_wait_s": wait_s,
            "throughput_gbps": total / dt / 1e9 if dt else 0.0,
            "compression_ratio": total / max(stats["payload_bytes"], 1),
        }
        self.last_report = report
        return report

    def _gc(self):
        steps = atomic.list_committed_steps(self.store.root)
        for s in steps[:-self.retain] if self.retain else []:
            shutil.rmtree(atomic.committed_dir(self.store.root, s),
                          ignore_errors=True)
        atomic.gc_staging(self.store.root)

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def latest_step(self):
        s = atomic.read_latest(self.store.root)
        if s is not None:
            return s
        for tier in self.store.tiers():
            steps = atomic.list_committed_steps(tier.root)
            if steps:
                return steps[-1]
        return None

    def load_manifest(self, step: int) -> dict:
        rel = f"{atomic.committed_dir(Path('.'), step).name}/{atomic.MANIFEST}"
        tier = self.store.locate(rel)
        if tier is None:
            raise NoCheckpointError("no manifest for step", step=step)
        return json.loads(tier.read_file(rel))

    def restore(self, abstract_state, shardings=None, *, step: int | None = None,
                validate: bool = True):
        """Restore onto the CURRENT topology. `abstract_state`: pytree of
        ShapeDtypeStruct (or arrays — shapes/dtypes used); `shardings`:
        matching tree of Shardings or None for single-device."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise NoCheckpointError("no committed checkpoint found",
                                    root=str(self.store.root))
        manifest = self.load_manifest(step)
        step_dir = atomic.committed_dir(Path("."), step).name
        leaves = manifest["leaves"]

        flat, treedef = jax.tree_util.tree_flatten(abstract_state)
        shard_flat = (treedef.flatten_up_to(shardings)
                      if shardings is not None else [None] * len(flat))
        names = [n for n, _ in leaf_paths(abstract_state)]
        out = []
        for name, sds, sharding in zip(names, flat, shard_flat):
            rec = leaves.get(name)
            if rec is None:
                raise MissingShardError("leaf missing from checkpoint",
                                        leaf=name, step=step)
            out.append(self._restore_leaf(step_dir, name, rec, sds, sharding))
        state = jax.tree_util.tree_unflatten(treedef, out)
        if validate:
            validate_against(state, leaves)
        self._read_cache.clear()
        self._read_cache_bytes = 0
        return state, manifest.get("extra", {})

    def _restore_leaf(self, step_dir, name, rec, sds, sharding):
        shape = tuple(sds.shape)
        dtype = sds.dtype
        available = [(ShardRange(tuple(s["start"]), tuple(s["stop"])), s)
                     for s in rec["shards"]]

        def fetch(target: ShardRange) -> np.ndarray:
            picks = plan_reads(target, available)
            pieces = [(rng, self._read_shard(step_dir, s))
                      for rng, s in picks]
            try:
                return assemble(target, pieces, np.asarray(
                    jax.numpy.zeros((), dtype)).dtype)
            except LookupError as e:
                raise MissingShardError(str(e), leaf=name) from None

        if sharding is None:
            full = fetch(ShardRange((0,) * len(shape), shape))
            return jax.numpy.asarray(full, dtype=dtype)

        cache = {}

        def cb(index):
            rng = normalize_index(index, shape)
            key = (rng.start, rng.stop)
            if key not in cache:
                cache[key] = fetch(rng)
            return cache[key]

        return jax.make_array_from_callback(shape, sharding, cb)

    def _read_shard(self, step_dir: str, srec: dict) -> np.ndarray:
        key = srec["file"]
        if key in self._read_cache:
            return self._read_cache[key][1]
        last_err = None
        for fname in srec.get("replicas", [srec["file"]]):
            rel = f"{step_dir}/{fname}"
            tier = self.store.locate(rel)
            if tier is None:
                last_err = MissingShardError("shard not on any tier",
                                             file=fname)
                continue
            try:
                rng, arr = _unpack_shard(tier.read_file(rel))
                if fname != srec["file"]:
                    warn("CKPT_W_REPLICA", "primary shard unavailable; "
                         "restored from buddy replica", file=srec["file"])
                self._cache_put(key, arr)
                return arr
            except (CorruptShardError, OSError, ValueError) as e:
                last_err = e
                continue
        raise last_err if last_err else MissingShardError(
            "unreadable shard", file=srec["file"])

    def _cache_put(self, key, arr):
        self._read_cache[key] = (time.monotonic(), arr)
        self._read_cache_bytes += arr.nbytes
        while self._read_cache_bytes > self.read_cache_limit \
                and len(self._read_cache) > 1:
            _, (_, old) = self._read_cache.popitem(last=False)
            self._read_cache_bytes -= old.nbytes
