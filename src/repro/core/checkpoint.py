"""Topology-agnostic checkpoint save/restore — MANA's split-process C/R as a
JAX subsystem. This module is ORCHESTRATION ONLY: planning and IO live in
the staged pipeline engines (``core.save_path`` / ``core.restore_path``).

Save pipeline (two-phase commit, coordinator-supervised):

  stage 0  snapshot   drain → device→host copy (the only part the training
                      thread ever blocks on);
  stage 1  write      ``save_path.write_shards``: SavePlan assignment +
                      per-rank writer threads feeding a rank-wide
                      SaveSession queue (chunks flow across shard
                      boundaries with no per-shard drain bubble), one
                      batched durability fsync per rank, retrying 2PC
                      phase 1;
  stage 2  commit     manifest (single handle, P7) → atomic rename →
                      LATEST → refcount publication (incremental mode);
  stage 3  maintain   retention GC + CAS mark-and-sweep, then background
                      drain to the slow storage tier.

With ``blocking=False`` stages 1–3 run on the ``PersistStage`` thread and
overlap subsequent training steps; a preemption signal can request a
fast-flush (skip stage-3 maintenance, never the commit or the drain) so
the round lands and the process exits promptly.

Configuration is a composed, frozen ``CheckpointPolicy`` (``core.policy``):
``mode="full"`` writes every shard inline (v2 layout); ``incremental``
chunks encoded payloads into the content-addressed store (``core.cas``) —
unchanged chunks dedup to zero write cost. The chunking section picks
``fixed`` or ``cdc`` (FastCDC-style, ``core.cdc``, with a selectable
candidate-scan backend — numpy oracle / XLA / Pallas, ``core.cdc_scan``);
the pipeline section sizes the chunk pool and the bounded multi-round
persist queue (``persist_queue_depth``, ``host_bytes_budget``). Manifest
format v6 embeds the writer's effective policy, so restore and the
inspector adopt the writer's chunking/scan/codec settings with zero
caller configuration; v5 (chunk length lists for direct placement),
v4, v3 and v2 stay fully readable, including mixed histories.

Restore pipeline (elastic, P2/P6): manifest → RestorePlan (per-leaf jobs
against the CURRENT sharding, ``elastic.plan_reads``) → RestoreSession
prefetch (leaf fan-out, chunk prefetch, fixed-chunking direct placement
into preallocated buffers, crc gate) → device arrays built on the calling
thread → registry validation. Nothing about the saving topology is
required to match (tested 1↔4↔8-device, both modes).
"""
from __future__ import annotations

import json
import shutil
import time
from collections import Counter
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np

from . import atomic, cas, cdc
from . import codec as codec_mod
from . import resilience, save_path
from .atomic import NO_CRASH, CrashInjector
from .chunk_exec import ChunkIOExecutor, cpu_cap
from .coordinator import CheckpointCoordinator
from .drain import DrainCounters, quiesce_device_state
from .errors import (AbortedError, CkptError, NoCheckpointError, SpaceError,
                     warn)
from .policy import (CHUNKINGS, MODES, CheckpointPolicy,
                     policy_from_manifest)
from .registry import build_registry, registry_json, validate_against
from .restore_path import (ReadCache, RestorePlan, RestoreSession,
                           RestoreStream, unpack_shard)
from .save_path import PersistStage, pack_shard, write_shards
from .split_state import leaf_paths
from .storage import TieredStore

FORMAT_VERSION = 7
# v2 = full-mode inline shards only; v3 = chunked records, implicitly
# fixed-size chunking (no per-record scheme field); v4 = chunking scheme
# per shard record; v5 = CDC shard records additionally carry their chunk
# LENGTH list (restore-side direct placement for content-defined chunks);
# v6 = the manifest embeds the writer's effective CheckpointPolicy, so
# restore and the inspector adopt the writer's chunking/scan/codec
# settings with zero caller configuration; v7 = chunk-encoded codec
# records (byteplane-rle/-rans) carry per-chunk (raw_len, enc_len) pairs:
# chunk_lens stay PHYSICAL (encoded bytes — offsets/crc describe what is
# read) and chunk_raw_lens drive the plane entropy decode after placement
READABLE_FORMATS = (2, 3, 4, 5, 6, 7)

# inspector/test compatibility: the shard codecs live with their pipeline
# stages now, but these names have external users
_pack_shard = pack_shard
_unpack_shard = unpack_shard


class CheckpointManager:
    """``CheckpointManager(store, policy=CheckpointPolicy(...))`` is the
    canonical constructor; every historical flat kwarg still works behind
    a single ``DeprecationWarning`` (``CheckpointPolicy.from_legacy_kwargs``
    maps each onto its policy field with identical validation)."""

    def __init__(self, store: TieredStore,
                 policy: CheckpointPolicy | None = None, **legacy):
        if legacy:
            if policy is not None:
                raise TypeError(
                    "pass either policy=CheckpointPolicy(...) or legacy "
                    "flat kwargs, not both")
            policy = CheckpointPolicy.from_legacy_kwargs(**legacy)
        elif policy is None:
            policy = CheckpointPolicy()
        self.store = store
        self.policy = policy
        io_threads = policy.pipeline.io_threads
        # retain is the one knob operators tune at runtime (drop history
        # before an explicit gc()), so it stays a plain mutable attribute
        self.retain = policy.durability.retain
        self.coordinator = CheckpointCoordinator(
            policy.n_writers, keepalive_s=policy.durability.keepalive_s)
        self.counters = DrainCounters()
        # always constructed: a full-mode manager must still RESTORE
        # checkpoints written incrementally (and vice versa)
        self.chunks = cas.ChunkStore.from_policy(store, policy)
        # the tiered store shares the manager's retry budget so background
        # drain copies get the same bounded-retry treatment (None on the
        # serial engine: from_policy already dropped it — fail-fast)
        store.io_retry = self.chunks.retry
        # background drains reuse the chunk pool so fast-tier reads overlap
        # throttled slow-tier writes (first manager on a store wins)
        if getattr(store, "io_executor", None) is None:
            store.io_executor = self.chunks.executor
        store.apply_pipeline_policy(policy.pipeline)
        if hasattr(store, "apply_restore_policy"):
            store.apply_restore_policy(policy.restore)
        # leaf-level restore fan-out runs on its OWN pool: leaf tasks block
        # on chunk-prefetch futures, so sharing the chunk pool could
        # deadlock with every worker parked on a nested wait. Capped at
        # the core count — the leaf work (crc, join, decode, assemble) is
        # CPU/bandwidth bound, where extra threads only contend
        self._restore_exec = ChunkIOExecutor(
            min(io_threads, cpu_cap()) if io_threads > 1 else io_threads)
        # the multi-round persist queue: the serial engine is pinned to
        # depth 1 (it IS the PR-1 baseline)
        self._persist = PersistStage(
            depth=policy.pipeline.effective_queue_depth,
            host_bytes_budget=policy.pipeline.host_bytes_budget)
        self._cache = ReadCache(policy.pipeline.read_cache_bytes)
        self._restore = RestoreSession(store, self.chunks,
                                       self._restore_exec, self._cache)
        self._manifest_refs_cache: dict = {}   # (tier, step) → Counter
        self.last_report: dict = {}
        self.last_gc_report: dict = {}
        # post-COMMIT hooks, called as hook(step, manifest) once the round
        # is durable (LATEST moved, refcounts published) but before the
        # slow-tier drain — the weightsync publisher announces here. A
        # hook failure warns and never aborts the save.
        self.on_commit: list = []
        self._bind_write_policy(policy)

    def _bind_write_policy(self, policy: CheckpointPolicy):
        """(Re)bind the write-side engines — codec resolution and the CDC
        chunker — to `policy`. Called at construction and by manifest-v6
        policy adoption on restore (pipeline/durability are never adopted:
        pool widths and failure clocks belong to THIS process). Atomic:
        every engine is built before anything is assigned, so a policy
        that parses but can't build (cdc below the scan window, an
        unavailable codec) leaves the manager exactly as it was."""
        # None → best codec the environment supports (zstd needs the
        # optional `zstandard` package; raw always works); resolution
        # fails fast with the real cause — otherwise every writer rank
        # dies on encode and the save aborts with an opaque "no surviving
        # writer ranks"
        codec, params_codec = policy.codec.resolved()
        # chunking="cdc": chunk_size becomes the content-defined AVERAGE
        # (min/avg/max = size/4, size, size*4 — FastCDC normalization);
        # the chunker is stateless and shared by every writer rank.
        # scan_backend picks the candidate-scan engine (core.cdc_scan);
        # the serial engine is pinned to the numpy oracle — it IS the
        # PR-1 baseline, and accelerated scans must not leak into it
        chunker = cdc.GearChunker.from_policy(
            policy.chunking, serial=policy.pipeline.serial)
        self.policy = policy
        self.codec, self.params_codec = codec, params_codec
        self._chunker = chunker
        # byteplane codecs: run the forward transform on device, fused
        # into the CDC scan dispatch (auto: pipelined engine only — the
        # serial engine is pinned to the host oracle, PR-1 purity)
        self.device_precondition = policy.codec.precondition_enabled(
            policy.pipeline.serial)
        # chunk-encoded codecs: run the plane entropy stage (RLE/rANS)
        # on device too, fused into the same dispatch — same serial
        # pinning (the serial engine is the host-oracle PR-1 baseline)
        self.device_entropy = policy.codec.entropy_enabled(
            policy.pipeline.serial)
        self.chunks.chunk_size = int(policy.chunking.chunk_size)

    # ---- policy-backed views (the pre-policy attribute surface) ----
    @property
    def mode(self) -> str:
        return self.policy.mode

    @property
    def chunking(self) -> str:
        return self.policy.chunking.scheme

    @property
    def n_writers(self) -> int:
        return self.policy.n_writers

    @property
    def replicas(self) -> int:
        return self.policy.durability.replicas

    @property
    def max_retries(self) -> int:
        """Node-failure recovery: a failed/dead writer rank is excluded
        and its shards redistributed to survivors, up to this many
        times."""
        return self.policy.durability.max_retries

    @property
    def save_timeout_s(self) -> float:
        return self.policy.durability.save_timeout_s

    def close(self):
        """Drain async work and tear down the IO pools (idempotent)."""
        self.wait()
        self.store.wait_drained()
        self.chunks.close()
        self._restore_exec.shutdown(wait=False)

    # ------------------------------------------------------------------
    # save: stage 0 (snapshot) inline, stages 1–3 inline or overlapped
    # ------------------------------------------------------------------
    def save(self, state, step: int, *, extra: dict | None = None,
             blocking: bool = True, crash: CrashInjector = NO_CRASH) -> dict:
        """Checkpoint `state` at `step`. With blocking=False only the
        device→host snapshot (plus queue admission, at
        ``persist_queue_depth>1``) is synchronous; chunk/hash/write/
        2PC-COMMIT run on the persist stage and overlap subsequent
        training steps. At depth 1 the drain protocol guarantees
        quiescence before the next round; deeper queues admit round N+1's
        snapshot while round N persists, gated by the host byte budget."""
        t0 = time.monotonic()
        queued = (not blocking) and self._persist.depth > 1
        est = 0
        admit_s = 0.0
        if queued:
            # multi-round persist queue: block only for ADMISSION — a free
            # in-flight slot under the host byte budget — so round N+1
            # snapshots while round N persists. Estimated from device
            # metadata because the budget gate must run BEFORE this
            # round's host copy exists. A failed earlier round surfaces
            # HERE (depth-1 parity: its wait() raises on the next save) —
            # never silently, checkpoints after it would be a lie.
            self._persist.raise_pending()
            est = save_path.estimate_snapshot_bytes(state)
            admit_s = self._persist.admit(est)
        else:
            # P4: quiescence before snapshot (depth-1 behaviour — and the
            # serial engine's only path: byte-for-byte the PR-1 baseline)
            self.wait()                              # previous round drained
        degraded_hint = False
        try:
            wait_s = quiesce_device_state(state)
            registry = build_registry(state)
            items = self._snapshot(state)
            snap_s = time.monotonic() - t0
            total = sum(a.nbytes for _, _, a in items)
            # P8 preflight must see the WHOLE queue's unwritten footprint:
            # earlier admitted rounds' chunks may not have hit the tier
            # yet, so their snapshot bytes (minus this round's own
            # reservation) are added to the requirement
            pending = max(self._persist.inflight_bytes - est, 0) \
                if queued else 0
            required = (total + pending) // max(self._est_ratio(), 1)
            try:
                self.store.fast.preflight(required)
            except SpaceError:
                # degraded-mode save (pipelined engine only): a full fast
                # tier fails the round over to the hierarchy below instead
                # of aborting — writers land objects via _put_degraded and
                # the manifest commits with a `degraded` marker. Serial
                # stays fail-fast (PR-1 purity).
                fallback = self.store.slow or self.store.remote
                if self.chunks.retry is None or fallback is None:
                    raise
                warn("CKPT_W_DEGRADED",
                     "fast tier failed capacity preflight; saving "
                     "degraded through the lower tier(s)",
                     step=step, tier=fallback.name)
                fallback.preflight(required)
                degraded_hint = True
        except BaseException:
            if queued:
                # the admission reservation must not leak — a stuck slot
                # would wedge every later admit() at the depth bound
                self._persist.release(est)
            raise
        self.counters.enqueue(total)

        # exactly-once counter drain for this round: the abort path inside
        # the round AND the persist stage's error handler both reach for
        # it, and a double commit would skew the two-counter equality (P4)
        # forever — the trainer's next wait() would stall to timeout
        counted = {"done": False}

        def commit_total():
            if not counted["done"]:
                counted["done"] = True
                self.counters.commit(total)

        args = (items, registry, state, step, extra or {}, total, t0,
                snap_s, wait_s, crash, commit_total, degraded_hint)
        if blocking:
            try:
                return self._write_round(*args, overlapped=False)
            except BaseException:
                # ANY failure (not just the abort path, which drains its
                # own counters) must drain exactly once — e.g. an OSError
                # on the manifest write would otherwise skew the P4
                # equality and stall every later save in counters.wait()
                commit_total()
                raise
        self._persist.submit(
            lambda: self._write_round(*args, overlapped=True),
            # counters must still drain or the trainer deadlocks
            on_error=lambda e: commit_total(),
            nbytes=est, reserved=queued)
        return {"step": step, "async": True, "snapshot_s": snap_s,
                "admit_s": admit_s,
                "blocking_s": time.monotonic() - t0, "bytes": total}

    def _est_ratio(self):
        # plain byteplane is a size-preserving permutation — no entropy
        # stage, so its preflight estimate must not assume shrinkage
        return 2 if self.codec not in ("raw", "byteplane") else 1

    def _effective_policy_dict(self) -> dict:
        """The policy block a v6 manifest embeds: ``self.policy`` with the
        codec section pinned to the RESOLVED codecs (a reader must see
        what was written, not this writer's "best available")."""
        pd = self.policy.to_dict()
        pd["codec"] = {"codec": self.codec,
                       "params_codec": self.params_codec}
        return pd

    def _maybe_adopt_manifest_policy(self, manifest: dict, step: int):
        """Manifest-v6 policy reconciliation: when the caller's
        chunking/codec config differs from what the checkpoint's writer
        recorded, the MANIFEST wins — restore itself is record-driven
        either way, but a drifted caller would silently mis-deduplicate
        every FUTURE save against the restored history (new chunk grid →
        zero dedup). A corrupted policy block degrades to a warning, never
        a failed restore."""
        if int(manifest.get("format", 0)) < 6:
            return
        try:
            written = policy_from_manifest(manifest)
        except Exception as e:  # noqa — untrusted block, any shape
            warn("CKPT_W_POLICY",
                 "manifest carries an unreadable policy block; restoring "
                 "on the caller's policy (shard records are "
                 "self-describing)", step=step,
                 error=f"{type(e).__name__}: {e}")
            return
        if written is None:
            return
        adopted = []
        new_chunking = self.policy.chunking
        if written.chunking != new_chunking:
            new_chunking = written.chunking
            adopted.append("chunking")
        new_codec = self.policy.codec
        wc, wp = written.codec.codec, written.codec.params_codec
        if wc is not None and \
                (wc, wp or wc) != (self.codec, self.params_codec):
            if all(codec_mod.available(c) for c in {wc, wp or wc}):
                # codec NAMES are adopted (they define the stored bytes);
                # device_precondition / device_entropy stay the reader's —
                # machine-local perf knobs producing identical bytes, and
                # the writer's device may not exist here
                new_codec = replace(
                    written.codec,
                    device_precondition=self.policy.codec
                    .device_precondition,
                    device_entropy=self.policy.codec.device_entropy)
                adopted.append("codec")
            else:
                warn("CKPT_W_POLICY",
                     "checkpoint writer's codec is unavailable in this "
                     "environment; keeping the caller's codec",
                     writer_codec=wc, step=step)
        if not adopted:
            return
        warn("CKPT_W_POLICY",
             "caller policy differs from the checkpoint writer's; "
             "adopting the manifest's settings so future saves keep "
             "deduplicating against this history",
             adopted=adopted, step=step)
        # queued persist rounds read the live chunker/chunk_size: quiesce
        # them before the rebind, or an in-flight round would chunk on two
        # grids and record bounds its records weren't produced with
        self.wait()
        try:
            self._bind_write_policy(replace(self.policy,
                                            chunking=new_chunking,
                                            codec=new_codec))
        except Exception as e:  # noqa — e.g. bounds GearChunker rejects
            # a block that PARSES but can't build an engine (cdc with a
            # sub-window average, min > avg, …) must also degrade to a
            # warning — restore never depends on the write-side engines
            warn("CKPT_W_POLICY",
                 "writer policy is unusable in this process; keeping the "
                 "caller's policy", step=step,
                 error=f"{type(e).__name__}: {e}")

    def wait(self):
        """Drain the persist stage (two-counter equality, P4)."""
        self._persist.wait()
        if not self.counters.drained():
            self.counters.wait(timeout=self.save_timeout_s)

    def request_fast_flush(self):
        """Preemption hook (signal-handler safe): ask the in-flight
        overlapped round to skip non-essential maintenance and land."""
        self._persist.request_fast_flush()

    def _snapshot(self, state) -> list:
        """Stage 0: device → host copy (``save_path.snapshot_items``) —
        the only part of an overlapped save the training thread waits on.
        Kept as an instance method so tests can interpose topologies."""
        return save_path.snapshot_items(state, self._restore_exec)

    def _leaf_codec(self, leaf_name: str) -> str:
        if leaf_name.startswith("params/"):
            return self.params_codec
        return self.codec

    def _write_round(self, items, registry, state, step, extra, total, t0,
                     snap_s, wait_s, crash, commit_total,
                     degraded_hint: bool = False,
                     overlapped: bool = False) -> dict:
        stage = atomic.staging_dir(self.store.root, step)
        stage.mkdir(parents=True, exist_ok=True)
        atomic.mark_pending(stage, {"step": step, "t": time.time()})
        incremental = self.mode == "incremental"
        pre_degraded = self.chunks.degraded_writes

        # ---- stage 1: plan + write (retrying 2PC phase 1) ----
        outcome = write_shards(
            items=items, alive_hint=self.n_writers,
            coordinator=self.coordinator, chunks=self.chunks,
            store=self.store, rel_stage=stage.name, step=step,
            incremental=incremental, chunking=self.chunking,
            chunker=self._chunker, replicas=self.replicas,
            leaf_codec=self._leaf_codec, max_retries=self.max_retries,
            save_timeout_s=self.save_timeout_s, crash=crash,
            overlapped=overlapped,
            device_precondition=self.device_precondition,
            device_entropy=self.device_entropy)
        if not outcome.ok:
            # ABORT leaks nothing: no manifest, no LATEST move, and no
            # refcounts published — chunk objects a dead rank managed to
            # write are unreferenced orphans that the next sweep reclaims
            shutil.rmtree(stage, ignore_errors=True)
            commit_total()
            raise AbortedError("checkpoint aborted", step=step,
                               reason=outcome.reason)
        stats = outcome.stats

        # ---- stage 2: manifest = commit record (single handle, P7) ----
        leaf_specs = [(name, leaf.shape, str(leaf.dtype))
                      for name, leaf in leaf_paths(state)]
        leaves = outcome.plan.manifest_leaves(
            leaf_specs, outcome.shard_records if incremental else None)
        manifest = {
            "format": FORMAT_VERSION,
            "mode": self.mode,
            "step": step,
            "created": time.time(),
            "chunk_size": self.chunks.chunk_size if incremental else None,
            "chunking": self.chunking if incremental else None,
            # CDC bound triple (min/avg/max): lets the inspector compare
            # the realized chunk-size distribution against what was asked
            "chunk_bounds": ([self._chunker.min_size, self._chunker.avg_size,
                              self._chunker.max_size]
                             if incremental and self._chunker is not None
                             else None),
            # v6: the writer's EFFECTIVE policy (codec resolved) rides the
            # manifest, so a restarted job adopts the writer's
            # chunking/scan/codec settings with zero caller configuration
            "policy": self._effective_policy_dict(),
            "leaves": leaves,
            "registry": registry_json(registry),
            "extra": extra,
        }
        degraded = bool(degraded_hint or
                        self.chunks.degraded_writes > pre_degraded)
        if degraded:
            # only present when True: older readers' lenient from_dict
            # ignores the key, and clean manifests stay byte-identical
            manifest["degraded"] = True
            warn("CKPT_W_DEGRADED",
                 "round committed degraded: objects written past the "
                 "fast tier; restore reads them from the lower tier(s)",
                 step=step,
                 objects=self.chunks.degraded_writes - pre_degraded)
        crash.maybe("before_manifest")
        atomic.atomic_write_bytes(stage / atomic.MANIFEST,
                                  json.dumps(manifest).encode(), crash)
        atomic.clear_pending(stage)
        final = atomic.committed_dir(self.store.root, step)
        atomic.commit_dir(stage, final, crash)
        crash.maybe("before_latest_write")
        atomic.write_latest(self.store.root, step, crash)
        # COMMIT phase: the coordinator publishes the round's aggregated
        # chunk refcounts atomically; the digests are captured first so the
        # new objects can be drained to the slow tier below
        coord = self.coordinator
        round_digests = sorted(coord.round.chunk_refs) if coord.round else []
        coord.finish_round(
            True,
            publish_refs=(
                (lambda refs: self.chunks.apply_refs(refs, crash))
                if incremental else None))
        commit_total()
        for hook in list(self.on_commit):
            # announcement plane: distribution is best-effort, durability
            # is not — a publisher failure must never abort a committed
            # save
            try:
                hook(step, manifest)
            except Exception as e:  # noqa: BLE001
                warn("CKPT_W_HOOK", "on_commit hook failed",
                     step=step, detail=f"{e.__class__.__name__}: {e}")

        # ---- stage 3: maintenance + slow-tier drain ----
        if overlapped and self._persist.fast_flush_requested:
            # preemption fast-flush: the commit above is durable; skip the
            # O(objects + history) sweep so the process can exit. The drain
            # below still runs — a committed round must reach the slow tier
            # or later deduped rounds would reference fast-only objects.
            self.last_gc_report = {"skipped": True, "reason": "fast-flush"}
        else:
            self.last_gc_report = self._gc_locked(crash=crash)
        self.store.drain_step(
            final.name,
            extra_files=[cas.object_rel(d, r)
                         for d in round_digests
                         for r in range(self.chunks.replicas)])
        dt = time.monotonic() - t0
        report = {
            "step": step, "mode": self.mode, "bytes": total,
            "payload_bytes": stats["payload_bytes"],
            "written_bytes": stats["written_bytes"],
            "files": stats["files"], "seconds": dt,
            "snapshot_s": snap_s, "drain_wait_s": wait_s,
            "overlapped": overlapped,
            "blocking_s": snap_s if overlapped else dt,
            "throughput_gbps": total / dt / 1e9 if dt else 0.0,
            "compression_ratio": total / max(stats["payload_bytes"], 1),
            "degraded": degraded,
        }
        if incremental:
            # dedup ratio compares logical payload to per-copy object
            # bytes — new_object_bytes counts physical IO across replica
            # copies, which would read as 0.5× dedup on a cold save with
            # buddy redundancy
            per_copy = stats["new_object_bytes"] / self.chunks.replicas
            report.update(
                chunks=stats["chunks"],
                new_object_bytes=stats["new_object_bytes"],
                dedup_ratio=stats["payload_bytes"] / max(per_copy, 1))
        self.last_report = report
        return report

    # ------------------------------------------------------------------
    # GC: step retirement + CAS mark-and-sweep
    # ------------------------------------------------------------------
    def _live_chunk_refs(self, tiers=None, errors: list | None = None) \
            -> Counter:
        """Mark phase (``save_path.collect_live_refs``), memoized per
        (tier, step) so each save only parses the manifest it just wrote."""
        return save_path.collect_live_refs(self.store,
                                           self._manifest_refs_cache,
                                           tiers=tiers, errors=errors)

    def gc(self, *, crash: CrashInjector = NO_CRASH) -> dict:
        """Retire fast-tier steps beyond `retain`, clear staging litter,
        then mark-and-sweep the content-addressed store. Crash-safe: the
        mark set derives only from committed manifests, so a crash at any
        point here is repaired by the next gc() — committed checkpoints
        never lose chunks. Serializes with an in-flight async save: a
        round's fresh chunks are unreferenced until its manifest commits,
        and sweeping mid-round would reap them."""
        self.wait()
        return self._gc_locked(crash=crash, force_sweep=True)

    def scrub(self, *, sample: int | None = None, seed: int = 0,
              should_stop=None, crash: CrashInjector = NO_CRASH) -> dict:
        """Re-hash the live object set (or a seeded `sample`), quarantine
        corrupt copies and heal them from a good replica/tier
        (``ChunkStore.scrub``). Runs through the maintenance pass with
        ``retain=0`` so NO retention is applied — scrubbing must never
        drop history. Returns the maintenance report; the scrub summary
        is under ``report["scrub"]`` and persisted to
        ``_CAS/last_scrub.json`` for the offline inspector."""
        self.wait()
        self.store.wait_drained()
        return save_path.run_maintenance(
            self.store, self.chunks, 0, self._live_chunk_refs,
            crash=crash, scrub=True, scrub_sample=sample, scrub_seed=seed,
            should_stop=should_stop)

    def _gc_locked(self, *, crash: CrashInjector = NO_CRASH,
                   force_sweep: bool = False) -> dict:
        """Stage-3 body (``save_path.run_maintenance``) — called directly
        by the save round itself (which IS the persist thread, so it must
        not self-join via wait())."""
        return save_path.run_maintenance(
            self.store, self.chunks, self.retain, self._live_chunk_refs,
            crash=crash, force_sweep=force_sweep)

    # ------------------------------------------------------------------
    # restore: manifest → RestorePlan → prefetch → device placement
    # ------------------------------------------------------------------
    def latest_step(self):
        """Newest restorable step. A crash between the commit rename and
        the LATEST write leaves LATEST one step behind the newest committed
        dir; trusting the pointer alone would make a restarted trainer
        re-save that step and die on FileExistsError forever, so the answer
        is max(LATEST, newest committed step on any tier)."""
        latest = atomic.read_latest(self.store.root)
        committed = [s for tier in self.store.tiers()
                     for s in atomic.list_committed_steps(tier.root)]
        newest = max(committed, default=None)
        if latest is None or (newest is not None and newest > latest):
            return newest
        return latest

    def load_manifest(self, step: int) -> dict:
        rel = f"{atomic.committed_dir(Path('.'), step).name}/{atomic.MANIFEST}"
        tier = self.store.locate(rel)
        if tier is None:
            raise NoCheckpointError("no manifest for step", step=step)
        if self.chunks.retry is not None:
            manifest = json.loads(resilience.retry_io(
                lambda: tier.read_file(rel), self.chunks.retry,
                health=self.store.health_for(tier), op="manifest_read"))
        else:
            manifest = json.loads(tier.read_file(rel))
        fmt = int(manifest.get("format", 0))
        if fmt not in READABLE_FORMATS:
            raise CkptError("unsupported manifest format", format=fmt,
                            readable=list(READABLE_FORMATS), step=step)
        return manifest

    def _plan_restore(self, abstract_state, shardings, step):
        """Shared restore prelude: resolve the step, load + reconcile the
        manifest, and build the per-leaf plan against the CURRENT
        topology. Returns (step, manifest, step_dir, plan, treedef)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise NoCheckpointError("no committed checkpoint found",
                                    root=str(self.store.root))
        # one shared IO-retry deadline for the whole restore round
        self.chunks.begin_io_window()
        manifest = self.load_manifest(step)
        # v6: the writer's recorded policy wins over a mismatched caller —
        # logged reconciliation, and future saves dedup against history
        self._maybe_adopt_manifest_policy(manifest, step)
        step_dir = atomic.committed_dir(Path("."), step).name
        flat, treedef = jax.tree_util.tree_flatten(abstract_state)
        shard_flat = (treedef.flatten_up_to(shardings)
                      if shardings is not None else [None] * len(flat))
        names = [n for n, _ in leaf_paths(abstract_state)]
        plan = RestorePlan.build(manifest, step_dir, names, flat,
                                 shard_flat, step)
        return step, manifest, step_dir, plan, treedef

    @staticmethod
    def _drain_futures(futures):
        """After a failed leaf: absorb the in-flight siblings so no pool
        worker is left running against a caller that has moved on."""
        for f in futures:
            if f is not None and not f.done():
                try:
                    f.result()
                except BaseException:  # noqa — surfaced by the first
                    pass

    def restore(self, abstract_state, shardings=None, *, step: int | None = None,
                validate: bool = True, leaf_priority=None):
        """Restore onto the CURRENT topology. `abstract_state`: pytree of
        ShapeDtypeStruct (or arrays — shapes/dtypes used); `shardings`:
        matching tree of Shardings or None for single-device.

        Pipelined engine: per-leaf host fetches are dispatched in
        FIRST-USE order (``elastic.leaf_first_use_class``, or a
        model-supplied `leaf_priority`) and each leaf releases to device
        placement as it lands — placement of early leaves overlaps the
        fetches still streaming behind them, no ``map_ordered`` barrier.
        The serial engine keeps the original two-phase path byte-for-byte
        (it is the PR-1 baseline). Device arrays are built on the calling
        thread either way — JAX array construction never runs on pool
        workers."""
        step, manifest, step_dir, plan, treedef = self._plan_restore(
            abstract_state, shardings, step)
        if self._restore_exec.serial:
            prefetched = self._restore.prefetch(plan)
            out = [self._restore.leaf_to_device(step_dir, job, pre)
                   for job, pre in zip(plan.jobs, prefetched)]
        else:
            schedule, _ = plan.first_use_schedule(
                leaf_priority, self.policy.restore.frontier_classes)
            futures = self._restore.prefetch_async(plan, schedule)
            try:
                out = [self._restore.leaf_to_device(step_dir, job,
                                                    futures[i].result())
                       for i, job in enumerate(plan.jobs)]
            except BaseException:
                self._drain_futures(futures)
                raise
        state = jax.tree_util.tree_unflatten(treedef, out)
        if validate:
            validate_against(state, manifest["leaves"])
        self._cache.clear()
        return state, manifest.get("extra", {})

    def restore_streaming(self, abstract_state, shardings=None, *,
                          step: int | None = None, validate: bool = True,
                          leaf_priority=None):
        """Streaming restore-behind: returns ``(RestoreStream, extra)``
        with every per-leaf host fetch already in flight in first-use
        order. ``stream.wait_frontier()`` blocks only until the leading
        first-use classes (``policy.restore.frontier_classes``) are
        resident, so the caller begins step-0 preparation while tail
        leaves stream in; any touch of an un-landed leaf — including the
        final ``stream.state()`` completion gate — blocks on that leaf's
        future, so the restored state is bit-exact with the blocking path
        by construction. Registry validation and the read-cache release
        run once, inside the completion gate."""
        _, manifest, _, plan, treedef = self._plan_restore(
            abstract_state, shardings, step)
        schedule, frontier = plan.first_use_schedule(
            leaf_priority, self.policy.restore.frontier_classes)
        futures = self._restore.prefetch_async(plan, schedule)

        def finalize(state):
            if validate:
                validate_against(state, manifest["leaves"])
            self._cache.clear()

        stream = RestoreStream(self._restore, plan, futures, treedef,
                               schedule, frontier, finalize=finalize)
        return stream, manifest.get("extra", {})

    # ------------------------------------------------------------------
    # compatibility shims: tests and operator tooling reach these names
    # ------------------------------------------------------------------
    def _read_shard(self, step_dir: str, srec: dict) -> np.ndarray:
        return self._restore.read_shard(step_dir, srec)

    def _cache_get(self, key):
        return self._cache.get(key)

    def _cache_put(self, key, arr):
        self._cache.put(key, arr)

    @property
    def _read_cache(self):
        return self._cache.entries

    @property
    def _read_cache_bytes(self) -> int:
        return self._cache.nbytes

    @property
    def read_cache_limit(self) -> int:
        return self._cache.limit

    @read_cache_limit.setter
    def read_cache_limit(self, v: int):
        self._cache.limit = v
