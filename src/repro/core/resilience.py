"""Typed IO-failure handling for the tiered store — the paper's
production-hardening lesson applied to storage: a flaky filesystem, a
filling burst buffer or a slow metadata server must degrade a checkpoint
round, not abort it.

Three small primitives, consumed by ``storage``/``cas``/``save_path``/
``restore_path``:

  * **classification** — ``is_transient`` / ``is_tier_full`` split
    ``OSError`` into errors worth retrying on the SAME tier (EIO, EAGAIN,
    EBUSY, NFS staleness, timeouts), errors that condemn the tier for
    this round (ENOSPC / EDQUOT / EROFS — retrying a full disk is just a
    slower failure; the caller fails over to the next tier), and
    everything else (permanent: raise immediately);
  * **bounded retry** — ``retry_io`` with decorrelated-jitter backoff
    (AWS-style: ``sleep ~ U(base, 3·prev)``, capped) under a
    ``Deadline`` budget, so a round's aggregate retry stall is bounded
    by ``DurabilityPolicy.io_deadline_s`` rather than
    retries × sites × backoff;
  * **per-tier circuit breaker** — ``CircuitBreaker`` opens after a run
    of consecutive errors and readers/writers deprioritize (never hard-
    skip) the tier until a half-open probe succeeds; ``TierHealth``
    aggregates the breaker with per-op error/retry counters for
    ``inspect_ckpt --health``.

The serial (``io_threads=1``) engine never constructs a retry policy —
it keeps the PR-1 fail-fast semantics byte-for-byte; every helper here
treats ``policy=None`` as "call the function once, raise what it
raises".
"""
from __future__ import annotations

import errno
import random
import threading
import time
from dataclasses import dataclass

# errors worth retrying against the SAME tier: the device may answer the
# next attempt (EIO covers the flaky-NFS / dying-disk reads the paper's
# production runs hit; ESTALE/EREMOTEIO are their NFS spellings)
TRANSIENT_ERRNOS = frozenset(
    e for e in (errno.EIO, errno.EAGAIN, errno.EINTR, errno.EBUSY,
                errno.ETIMEDOUT, getattr(errno, "ESTALE", None),
                getattr(errno, "EREMOTEIO", None))
    if e is not None)

# errors that condemn the tier for the rest of the round: retrying a
# full or read-only filesystem is just a slower failure — the caller
# should fail over to the next tier instead
TIER_FULL_ERRNOS = frozenset(
    e for e in (errno.ENOSPC, getattr(errno, "EDQUOT", None), errno.EROFS)
    if e is not None)


class RemoteInconsistencyError(OSError):
    """An object store answered, but inconsistently: a multipart ranged
    GET came back short (``truncated_get``) or the HEAD-advertised size
    disagreed with the GET body (``stale_head`` — read-after-overwrite
    staleness). Both are the remote-tier spellings of "ask again": the
    object itself is content-addressed and immutable, so a re-issued
    request against a healed replica returns the right bytes. Typed as
    ``OSError(EIO)`` so every existing errno-based classifier already
    treats it as transient; carried as its own class so callers (and
    tests) can tell a remote protocol inconsistency from a dying local
    disk."""

    def __init__(self, msg: str, *, rel: str | None = None,
                 kind: str = "inconsistent"):
        super().__init__(errno.EIO, msg)
        self.rel = rel
        self.kind = kind


def is_transient(exc: BaseException) -> bool:
    """True for errors a bounded same-tier retry may absorb. ENOSPC is
    deliberately included: transient space pressure (a concurrent GC or
    eviction freeing the burst buffer) is common, and the retry budget
    bounds the cost when it is not transient — callers that can fail
    over check ``is_tier_full`` AFTER retries are exhausted."""
    return isinstance(exc, OSError) and \
        (exc.errno in TRANSIENT_ERRNOS or exc.errno in TIER_FULL_ERRNOS)


def is_tier_full(exc: BaseException) -> bool:
    """True when the error condemns the TIER (full / quota / read-only),
    i.e. failing over to the next tier is the productive response."""
    return isinstance(exc, OSError) and exc.errno in TIER_FULL_ERRNOS


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded same-tier retry: up to `retries` re-attempts, decorrelated
    jitter starting at `backoff_ms`, all attempts of a round sharing one
    `deadline_s` IO budget (see ``ChunkStore.begin_io_window``)."""
    retries: int = 2
    backoff_ms: float = 5.0
    deadline_s: float = 30.0

    @classmethod
    def from_durability(cls, durability) -> "RetryPolicy":
        return cls(retries=int(durability.io_retries),
                   backoff_ms=float(durability.io_backoff_ms),
                   deadline_s=float(durability.io_deadline_s))


class Deadline:
    """Monotonic time budget shared across every retry loop of one round
    — the aggregate stall bound. ``budget_s=None`` never expires."""

    def __init__(self, budget_s: float | None,
                 clock=time.monotonic):
        self._clock = clock
        self._until = None if budget_s is None else clock() + float(budget_s)

    def remaining(self) -> float:
        if self._until is None:
            return float("inf")
        return self._until - self._clock()

    def expired(self) -> bool:
        return self.remaining() <= 0


# jitter source for the backoff — nondeterministic on purpose (it decides
# only how long to sleep, never what happens), so concurrent writers
# hitting the same sick tier don't retry in lockstep
_jitter = random.Random()


def retry_io(fn, policy: RetryPolicy | None, *, deadline: Deadline | None
             = None, health: "TierHealth | None" = None, op: str = "io",
             classify=is_transient, sleep=time.sleep):
    """Run `fn`, retrying transient ``OSError`` up to ``policy.retries``
    times with decorrelated-jitter backoff, never sleeping past
    `deadline`. ``policy=None`` (the serial engine) calls `fn` exactly
    once. Only ``OSError`` is ever caught — injected crash points,
    corruption errors and everything typed stay fail-fast. `health`
    records each attempt's outcome for the per-tier counters/breaker."""
    if policy is None:
        return fn()
    if deadline is None:
        deadline = Deadline(policy.deadline_s)
    base = max(float(policy.backoff_ms), 0.0) / 1000.0
    prev = base
    attempt = 0
    while True:
        try:
            out = fn()
        except OSError as e:
            if health is not None:
                health.record_error(op)
            if not classify(e) or attempt >= int(policy.retries) \
                    or deadline.expired():
                raise
            attempt += 1
            if health is not None:
                health.note_retry(op)
            # decorrelated jitter: sleep ~ U(base, 3·prev), capped at
            # 100× base and at the remaining deadline budget
            prev = _jitter.uniform(base, max(prev * 3.0, base))
            prev = min(prev, base * 100.0 if base else 0.0)
            pause = min(prev, max(deadline.remaining(), 0.0))
            if pause > 0:
                sleep(pause)
            continue
        if health is not None:
            health.record_ok(op)
        return out


class CircuitBreaker:
    """Consecutive-failure breaker with a cooldown + half-open probe.

    ``allow()`` answers "should this tier be PREFERRED right now" —
    callers deprioritize an open tier (try the others first), they never
    hard-skip it, so a store whose every tier is sick still serves the
    last-resort read. After `cooldown_s` the breaker half-opens: traffic
    is allowed again, one success closes it, one failure re-arms the
    cooldown."""

    def __init__(self, threshold: int = 8, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        self.threshold = max(int(threshold), 1)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._consecutive = 0
        self._opened_at: float | None = None
        self._trips = 0

    def record_ok(self):
        with self._lock:
            self._consecutive = 0
            self._opened_at = None

    def record_error(self):
        with self._lock:
            self._consecutive += 1
            if self._consecutive >= self.threshold:
                if self._opened_at is None:
                    self._trips += 1
                # an error while open (or half-open) re-arms the cooldown
                self._opened_at = self._clock()

    def allow(self) -> bool:
        return self.state != "open"

    @property
    def state(self) -> str:
        with self._lock:
            if self._opened_at is None:
                return "closed"
            if self._clock() - self._opened_at >= self.cooldown_s:
                return "half-open"
            return "open"

    @property
    def trips(self) -> int:
        with self._lock:
            return self._trips


class TierHealth:
    """Per-tier error accounting: op-keyed ok/error/retry counters plus
    the circuit breaker. One instance per tier, owned by the
    ``TieredStore`` (``health_for``); snapshots feed ``_CAS/health.json``
    and ``inspect_ckpt --health``."""

    def __init__(self, name: str, breaker: CircuitBreaker | None = None):
        self.name = name
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self._lock = threading.Lock()
        self._counters: dict = {}

    def _bump(self, key: str):
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + 1

    def record_ok(self, op: str):
        self._bump(f"{op}_ok")
        self.breaker.record_ok()

    def record_error(self, op: str):
        self._bump(f"{op}_errors")
        self.breaker.record_error()

    def note_retry(self, op: str):
        self._bump(f"{op}_retries")

    def note(self, key: str):
        """Free-form event counter (e.g. degraded failover writes)."""
        self._bump(key)

    def allow(self) -> bool:
        return self.breaker.allow()

    @property
    def counters(self) -> dict:
        with self._lock:
            return dict(self._counters)

    def snapshot(self) -> dict:
        return {"counters": self.counters,
                "breaker": {"state": self.breaker.state,
                            "trips": self.breaker.trips,
                            "threshold": self.breaker.threshold,
                            "cooldown_s": self.breaker.cooldown_s}}
