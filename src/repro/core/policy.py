"""Composable checkpoint policy objects — the production configuration
surface of the C/R system.

The paper's production-hardening lesson (and the MANA restart-agnosticism
follow-on) is that a restarted job must not depend on the caller
reconstructing the writer's environment by hand. Two consequences shape
this module:

  * the public API is a handful of small, frozen, composable policy
    dataclasses instead of a flat kwarg namespace — ``ChunkingPolicy``
    (scheme, sizes, candidate-scan backend), ``PipelinePolicy`` (chunk-IO
    width, the bounded multi-round persist queue, host snapshot byte
    budget, read-cache budget, drain mode), ``DurabilityPolicy``
    (replicas, retention, coordinator timeouts/retries) and
    ``CodecPolicy``, composed into one validated ``CheckpointPolicy``;
  * the policy travels WITH the data: manifest v6 embeds the writer's
    effective policy (``to_dict``/``from_dict`` round-trip), so restore
    and the inspector adopt the writer's chunking/scan/codec settings
    with zero caller configuration — a caller whose config drifted from
    the history it restores cannot silently mis-deduplicate against it.

Every legacy flat ``CheckpointManager`` kwarg maps onto exactly one
policy field (``from_legacy_kwargs``, one ``DeprecationWarning`` per
construction); ``with_overrides`` merges flat CLI-style overrides and
``from_env`` merges ``REPRO_CKPT_*`` environment overrides on top of any
base policy.
"""
from __future__ import annotations

import warnings
from dataclasses import asdict, dataclass, field, fields

from . import cdc_scan
from . import codec as codec_mod
from .cas import DEFAULT_CHUNK_SIZE
from .chunk_exec import DEFAULT_IO_THREADS
from .errors import CodecUnavailableError
from .storage import DEFAULT_REMOTE_PART_BYTES

MODES = ("full", "incremental")
CHUNKINGS = ("fixed", "cdc")

DEFAULT_READ_CACHE_BYTES = 1 << 30
ENV_PREFIX = "REPRO_CKPT_"


@dataclass(frozen=True)
class ChunkingPolicy:
    """How encoded shard payloads become CAS chunks.

    ``chunk_size`` is the fixed size for ``scheme="fixed"`` and the
    content-defined AVERAGE for ``scheme="cdc"`` (min/avg/max default to
    size/4, size, size*4 — FastCDC normalization — unless ``min_size`` /
    ``max_size`` pin them). ``scan_backend`` picks the CDC candidate-scan
    engine (``core.cdc_scan``); the serial engine is always pinned to the
    numpy oracle regardless."""
    scheme: str = "fixed"
    chunk_size: int = DEFAULT_CHUNK_SIZE
    min_size: int | None = None
    max_size: int | None = None
    scan_backend: str = "auto"

    def __post_init__(self):
        if self.scheme not in CHUNKINGS:
            raise ValueError(f"chunking must be one of {CHUNKINGS}, "
                             f"got {self.scheme!r}")
        if int(self.chunk_size) <= 0:
            raise ValueError("chunk_size must be positive")
        if self.scan_backend not in cdc_scan.BACKENDS:
            raise ValueError(
                f"scan_backend must be one of {cdc_scan.BACKENDS}, "
                f"got {self.scan_backend!r}")


@dataclass(frozen=True)
class PipelinePolicy:
    """Concurrency shape of the save/restore engines.

    ``io_threads=1`` is the serial PR-1 reference engine (it also forces
    ``persist_queue_depth`` to 1 and the numpy CDC scan — the baseline
    stays byte-for-byte). ``persist_queue_depth`` bounds how many
    overlapped rounds may be in flight at once (snapshot round N+1 while
    round N persists); ``host_bytes_budget`` caps the aggregate host
    snapshot bytes those rounds may pin (admission blocks the next
    snapshot rather than OOMing the host). ``async_drain=None`` leaves
    the store's drain mode as constructed."""
    io_threads: int = DEFAULT_IO_THREADS
    persist_queue_depth: int = 1
    host_bytes_budget: int | None = None
    read_cache_bytes: int = DEFAULT_READ_CACHE_BYTES
    async_drain: bool | None = None

    def __post_init__(self):
        if int(self.persist_queue_depth) < 1:
            raise ValueError("persist_queue_depth must be >= 1")
        if self.host_bytes_budget is not None \
                and int(self.host_bytes_budget) <= 0:
            raise ValueError("host_bytes_budget must be positive or None")
        if int(self.read_cache_bytes) <= 0:
            raise ValueError("read_cache_bytes must be positive")

    @property
    def serial(self) -> bool:
        return int(self.io_threads) <= 1

    @property
    def effective_queue_depth(self) -> int:
        """The serial engine is pinned to depth 1 (PR-1 baseline purity)."""
        return 1 if self.serial else int(self.persist_queue_depth)


@dataclass(frozen=True)
class DurabilityPolicy:
    """Redundancy, retention and the coordinator's failure clocks.

    The ``io_*`` trio is the typed retry budget (``resilience``): up to
    `io_retries` same-tier re-attempts per transient ``OSError``, with
    decorrelated jitter starting at `io_backoff_ms`, and every retry
    sleep of one round drawing from a single shared `io_deadline_s`
    budget so a sick tier bounds the aggregate stall, not
    retries × fault sites. Consumed only by the pipelined engine — the
    serial (``io_threads=1``) engine stays fail-fast (PR-1 purity)."""
    replicas: int = 1                   # 2 = buddy redundancy
    retain: int = 3
    keepalive_s: float = 10.0
    save_timeout_s: float = 600.0
    max_retries: int = 1
    io_retries: int = 2
    io_backoff_ms: float = 5.0
    io_deadline_s: float = 30.0


@dataclass(frozen=True)
class CodecPolicy:
    """Shard payload encodings. ``None`` resolves to the best codec the
    environment supports (zstd with the optional ``zstandard`` package,
    raw otherwise); ``params_codec`` defaults to ``codec`` (int8 opt-in).

    ``device_precondition`` controls whether a byteplane codec's forward
    transform runs ON DEVICE, fused into the CDC scan dispatch (the
    tentpole fusion): ``None`` (auto) enables it on the pipelined engine
    and never on the serial engine (host numpy purity); ``False`` forces
    the host oracle encoder everywhere. A MACHINE-LOCAL performance knob:
    the stored bytes are identical either way, so manifest adoption keeps
    the reader's own setting.

    ``device_entropy`` is the same knob for the chunk-encoded codecs'
    plane entropy stage (byteplane-rle / byteplane-rans): ``None``
    (auto) fuses RLE/rANS coding into the same device dispatch so chunks
    reach the host pre-compressed; ``False`` keeps the scan/transform
    fusion but runs the entropy stage through the host oracle. Equally
    machine-local — every backend is byte-identical."""
    codec: str | None = None
    params_codec: str | None = None
    device_precondition: bool | None = None
    device_entropy: bool | None = None

    def __post_init__(self):
        for c in (self.codec, self.params_codec):
            if c is not None and c not in codec_mod.CODECS:
                raise ValueError(f"unknown codec {c!r}")

    def precondition_enabled(self, serial: bool) -> bool:
        """Effective device_precondition for an engine: the serial engine
        is always pinned to the host path (PR-1 baseline purity)."""
        if serial:
            return False
        return True if self.device_precondition is None \
            else bool(self.device_precondition)

    def entropy_enabled(self, serial: bool) -> bool:
        """Effective device_entropy for an engine — same pinning rules as
        ``precondition_enabled``: the serial engine always takes the host
        oracle path."""
        if serial:
            return False
        return True if self.device_entropy is None \
            else bool(self.device_entropy)

    def resolved(self) -> tuple:
        """(codec, params_codec) with defaults resolved against THIS
        environment; raises ``CodecUnavailableError`` when a requested
        codec needs a package the environment lacks."""
        codec = self.codec or codec_mod.default_codec()
        params = self.params_codec or codec
        for c in {codec, params}:
            if not codec_mod.available(c):
                # fail fast with the real cause — otherwise every writer
                # rank dies on encode and the save aborts with an opaque
                # "no surviving writer ranks"
                raise CodecUnavailableError(
                    "codec requires the optional `zstandard` package "
                    "(pip install 'repro[compress]')", codec=c)
        return codec, params


@dataclass(frozen=True)
class RestorePolicy:
    """Read-side behaviour — reader-LOCAL, like pipeline/durability: the
    manifest adoption path never takes these from a writer's embedded
    policy, because the writer's streaming choice must not change a
    reader's restore semantics.

    ``streaming=True`` makes the trainer restore through
    ``CheckpointManager.restore_streaming``: leaves release to device
    placement as they land (first-use order) and step 0 begins once the
    frontier — the first ``frontier_classes`` distinct first-use classes,
    embedding + block 0 by default — is resident, with every later touch
    of an un-landed leaf blocking on its future (bit-exact by
    construction). ``remote_part_bytes`` sizes the remote tier's
    multipart ranged GETs."""
    streaming: bool = False
    frontier_classes: int = 2
    remote_part_bytes: int = DEFAULT_REMOTE_PART_BYTES

    def __post_init__(self):
        if int(self.frontier_classes) < 1:
            raise ValueError("frontier_classes must be >= 1")
        if int(self.remote_part_bytes) <= 0:
            raise ValueError("remote_part_bytes must be positive")


_SECTIONS = {"chunking": ChunkingPolicy, "pipeline": PipelinePolicy,
             "durability": DurabilityPolicy, "codec": CodecPolicy,
             "restore": RestorePolicy}

# flat-name → policy-field map: the legacy CheckpointManager kwargs plus
# the newer pipeline knobs, shared by the legacy shim, CLI merging and
# environment overrides
FLAT_FIELDS = {
    "mode": ("mode",),
    "n_writers": ("n_writers",),
    "chunking": ("chunking", "scheme"),
    "chunk_size": ("chunking", "chunk_size"),
    "min_chunk_size": ("chunking", "min_size"),
    "max_chunk_size": ("chunking", "max_size"),
    "scan_backend": ("chunking", "scan_backend"),
    "io_threads": ("pipeline", "io_threads"),
    "persist_queue_depth": ("pipeline", "persist_queue_depth"),
    "host_bytes_budget": ("pipeline", "host_bytes_budget"),
    "read_cache_bytes": ("pipeline", "read_cache_bytes"),
    "async_drain_to_slow": ("pipeline", "async_drain"),
    "replicas": ("durability", "replicas"),
    "retain": ("durability", "retain"),
    "keepalive_s": ("durability", "keepalive_s"),
    "save_timeout_s": ("durability", "save_timeout_s"),
    "max_retries": ("durability", "max_retries"),
    "io_retries": ("durability", "io_retries"),
    "io_backoff_ms": ("durability", "io_backoff_ms"),
    "io_deadline_s": ("durability", "io_deadline_s"),
    "codec": ("codec", "codec"),
    "params_codec": ("codec", "params_codec"),
    "device_precondition": ("codec", "device_precondition"),
    "device_entropy": ("codec", "device_entropy"),
    "streaming_restore": ("restore", "streaming"),
    "restore_frontier_classes": ("restore", "frontier_classes"),
    "remote_part_bytes": ("restore", "remote_part_bytes"),
}

# exactly the pre-policy CheckpointManager.__init__ kwargs, in their
# historical signature order — the deprecation shim accepts these and
# nothing else
LEGACY_KWARGS = (
    "n_writers", "codec", "params_codec", "replicas", "retain",
    "keepalive_s", "save_timeout_s", "max_retries", "async_drain_to_slow",
    "mode", "chunk_size", "chunking", "scan_backend", "io_threads",
)

_ENV_INT = {"n_writers", "chunk_size", "min_chunk_size", "max_chunk_size",
            "io_threads", "persist_queue_depth", "host_bytes_budget",
            "read_cache_bytes", "replicas", "retain", "max_retries",
            "io_retries", "restore_frontier_classes", "remote_part_bytes"}
_ENV_FLOAT = {"keepalive_s", "save_timeout_s", "io_backoff_ms",
              "io_deadline_s"}
_ENV_BOOL = {"async_drain_to_slow", "streaming_restore",
             "device_precondition", "device_entropy"}


@dataclass(frozen=True)
class CheckpointPolicy:
    """The validated, composed checkpoint configuration —
    ``CheckpointManager(store, policy=CheckpointPolicy(...))`` is the
    canonical constructor. Section fields accept the dataclass or a plain
    dict (``from_dict`` convenience)."""
    mode: str = "full"
    n_writers: int = 4
    chunking: ChunkingPolicy = field(default_factory=ChunkingPolicy)
    pipeline: PipelinePolicy = field(default_factory=PipelinePolicy)
    durability: DurabilityPolicy = field(default_factory=DurabilityPolicy)
    codec: CodecPolicy = field(default_factory=CodecPolicy)
    restore: RestorePolicy = field(default_factory=RestorePolicy)

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, "
                             f"got {self.mode!r}")
        for name, cls in _SECTIONS.items():
            v = getattr(self, name)
            if isinstance(v, dict):
                object.__setattr__(self, name, cls(**v))
            elif not isinstance(v, cls):
                raise TypeError(f"{name} must be a {cls.__name__} or a "
                                f"dict, got {type(v).__name__}")

    # ------------------------------------------------------------------
    # serialization (manifest v6 embeds the writer's policy)
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CheckpointPolicy":
        """Lenient inverse of ``to_dict``: unknown keys are ignored
        (manifests written by NEWER code stay readable), missing keys
        take their defaults. Values are still validated — garbage raises,
        and callers reading untrusted manifests catch + warn."""
        if not isinstance(d, dict):
            raise TypeError("policy must be a mapping, "
                            f"got {type(d).__name__}")
        kw: dict = {}
        if "mode" in d:
            kw["mode"] = d["mode"]
        if "n_writers" in d:
            kw["n_writers"] = int(d["n_writers"])
        for name, klass in _SECTIONS.items():
            sub = d.get(name)
            if sub is None:
                continue
            if not isinstance(sub, dict):
                raise TypeError(f"policy section {name!r} must be a "
                                f"mapping, got {type(sub).__name__}")
            known = {f.name for f in fields(klass)}
            kw[name] = klass(**{k: v for k, v in sub.items() if k in known})
        return cls(**kw)

    # ------------------------------------------------------------------
    # flat-override merging (legacy kwargs, CLI flags, env vars)
    # ------------------------------------------------------------------
    def with_overrides(self, **flat) -> "CheckpointPolicy":
        """Merge flat overrides (the legacy kwarg names plus the newer
        pipeline knobs, see ``FLAT_FIELDS``) onto this policy. ``None``
        values are skipped — an unset CLI flag never clobbers the base."""
        top = {"mode": self.mode, "n_writers": self.n_writers}
        secs = {name: dict(vars(getattr(self, name)).items())
                for name in _SECTIONS}
        for k, v in flat.items():
            path = FLAT_FIELDS.get(k)
            if path is None:
                raise TypeError(f"unknown checkpoint policy override {k!r}")
            if v is None:
                continue
            if len(path) == 1:
                top[path[0]] = v
            else:
                secs[path[0]][path[1]] = v
        return CheckpointPolicy(
            mode=top["mode"], n_writers=top["n_writers"],
            **{name: cls(**secs[name]) for name, cls in _SECTIONS.items()})

    @classmethod
    def from_legacy_kwargs(cls, **kwargs) -> "CheckpointPolicy":
        """The deprecation shim behind ``CheckpointManager(store, mode=...,
        chunking=..., ...)``: every historical flat kwarg maps onto its
        policy field with identical validation and defaults. Emits exactly
        ONE ``DeprecationWarning`` per call, however many kwargs ride it."""
        unknown = sorted(set(kwargs) - set(LEGACY_KWARGS))
        if unknown:
            raise TypeError(
                f"unexpected keyword argument(s) {unknown}; pass a "
                f"CheckpointPolicy (policy=) for non-legacy configuration")
        warnings.warn(
            "flat CheckpointManager kwargs are deprecated; pass "
            "CheckpointManager(store, policy=CheckpointPolicy(...)) "
            f"instead (got legacy: {sorted(kwargs)})",
            DeprecationWarning, stacklevel=3)
        return cls().with_overrides(**kwargs)

    @classmethod
    def from_env(cls, env=None, *, base: "CheckpointPolicy | None" = None,
                 prefix: str = ENV_PREFIX) -> "CheckpointPolicy":
        """Merge ``REPRO_CKPT_<FLAT_NAME>`` environment overrides onto
        ``base`` (default policy when None) — e.g. ``REPRO_CKPT_IO_THREADS=8``,
        ``REPRO_CKPT_PERSIST_QUEUE_DEPTH=2``. Empty values are ignored."""
        if env is None:
            import os
            env = os.environ
        flat: dict = {}
        for name in FLAT_FIELDS:
            raw = env.get(prefix + name.upper())
            if raw is None or raw == "":
                continue
            if name in _ENV_INT:
                flat[name] = int(raw)
            elif name in _ENV_FLOAT:
                flat[name] = float(raw)
            elif name in _ENV_BOOL:
                flat[name] = raw.strip().lower() in ("1", "true", "yes", "on")
            else:
                flat[name] = raw
        return (base or cls()).with_overrides(**flat)


def policy_from_manifest(manifest: dict) -> CheckpointPolicy | None:
    """The policy a v6 manifest embeds: ``None`` when absent (v≤5
    manifests), the parsed ``CheckpointPolicy`` otherwise. A corrupted
    block RAISES — callers (restore adoption, the inspector) degrade it
    to a warning; the shard records stay self-describing either way."""
    block = manifest.get("policy")
    if block is None:
        return None
    return CheckpointPolicy.from_dict(block)
