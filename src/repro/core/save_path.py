"""Save-path pipeline stages: planning, the rank-wide chunk submission
queue, the phase-1 write engine, and the background persist stage.

``CheckpointManager`` used to interleave all of this inside one ~900-line
module; the stages now live here so each can evolve independently:

  SavePlan      pure planning — round-robin shard→rank assignment, buddy
                replica placement, and the manifest-record skeletons;
  SaveSession   a RANK-WIDE submission queue over the shared
                ``ChunkIOExecutor``: chunks from payload k+1 enter the pool
                while payload k's tail is still in flight, eliminating the
                per-shard ``put_payload`` drain bubble (the ROADMAP's
                writer-rank cross-payload pipelining item). Digest order,
                per-payload crc folding, heartbeats, dedup accounting and
                the error-joins-all guarantee are all preserved;
  write_shards  the retrying two-phase-commit phase 1: writer threads per
                surviving rank, coordinator-supervised, redistributing a
                dead rank's shards to survivors;
  PersistStage  the background persist thread for ``save(blocking=False)``:
                the training thread returns after the device→host snapshot
                while chunk/hash/write/COMMIT run here, with a
                preemption-aware fast-flush hook (SIGTERM → skip
                non-essential maintenance, drain, exit).

``io_threads=1`` stays byte-for-byte the serial PR-1 engine: SaveSession
degrades to the original chunk-at-a-time ``put_payload`` calls.
"""
from __future__ import annotations

import threading
import time
import zlib
from collections import Counter, deque
from concurrent.futures import wait as futures_wait

import msgpack
import numpy as np

from . import codec as codec_mod
from . import resilience
from .atomic import NO_CRASH, CrashInjector
from .cas import ChunkStore, chunk_digest, split_payload
from .cas import run_chunker as cas_run_chunker
from .elastic import ShardRange, normalize_index
from .errors import warn
from .namespace import REPLICA_SUFFIX, UPPER_DIR, leaf_to_fname


def pack_shard(leaf: str, rng: ShardRange, arr, codec: str):
    """Full-mode (v2) inline shard file: length-prefixed msgpack header +
    encoded payload."""
    payload, meta = codec_mod.encode(arr, codec)
    header = {
        "leaf": leaf,
        "global_dtype": str(arr.dtype),
        "start": list(rng.start),
        "stop": list(rng.stop),
        "codec": codec,
        "meta": meta,
        "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
        "payload_bytes": len(payload),
    }
    hb = msgpack.packb(header)
    return len(hb).to_bytes(4, "little") + hb + payload, header


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

class SavePlan:
    """Pure planning for one write attempt: which rank writes which shard
    (round-robin over survivors), where buddy replicas go (the next alive
    rank), and the full-mode manifest shard records. No IO."""

    def __init__(self, per_rank: dict, manifest_shards: dict,
                 shard_order: dict):
        self.per_rank = per_rank            # rank → [(i, name, rng, arr, fname, is_replica)]
        self.manifest_shards = manifest_shards  # full mode: leaf → [records]
        self.shard_order = shard_order      # leaf → [item indices]

    @classmethod
    def build(cls, items, alive: list, *, incremental: bool, replicas: int,
              leaf_codec) -> "SavePlan":
        per_rank = {r: [] for r in alive}
        shards: dict = {}
        order: dict = {}
        for i, (name, rng, arr) in enumerate(items):
            r = alive[i % len(alive)]
            fname = f"{UPPER_DIR}/{leaf_to_fname(name)}/shard-{i:05d}.bin"
            per_rank[r].append((i, name, rng, arr, fname, False))
            order.setdefault(name, []).append(i)
            if incremental:
                # chunk objects carry their own replica copies
                continue
            replica_files = [fname]
            if replicas > 1 and len(alive) > 1:
                buddy = alive[(i + 1) % len(alive)]
                rf = fname + REPLICA_SUFFIX
                per_rank[buddy].append((i, name, rng, arr, rf, True))
                replica_files.append(rf)
            shards.setdefault(name, []).append({
                "file": fname, "replicas": replica_files,
                "start": list(rng.start), "stop": list(rng.stop),
                "dtype": str(arr.dtype),
                "codec": leaf_codec(name),
            })
        return cls(per_rank, shards, order)

    def manifest_leaves(self, leaf_specs, shard_records: dict | None) -> dict:
        """Manifest ``leaves`` table. ``leaf_specs``: [(name, shape, dtype)]
        for every leaf of the state. ``shard_records`` (incremental mode):
        item index → chunked record; None selects the full-mode records."""
        if shard_records is not None:
            return {
                name: {"shape": list(shape), "dtype": dtype,
                       "shards": [shard_records[i]
                                  for i in self.shard_order.get(name, [])]}
                for name, shape, dtype in leaf_specs
            }
        return {
            name: {"shape": list(shape), "dtype": dtype,
                   "shards": self.manifest_shards.get(name, [])}
            for name, shape, dtype in leaf_specs
        }


# ---------------------------------------------------------------------------
# rank-wide chunk submission queue
# ---------------------------------------------------------------------------

def _slice_encoded(stream, block_lens, cuts):
    """Slice per-chunk encodings out of a whole-payload framed block
    stream: every cut is ENTROPY_BLOCK-aligned (except the final one), so
    chunk ends map to block indices and encoded offsets are prefix sums
    of the per-block lengths. Returns (encoded chunk views, raw_lens)."""
    eoffs = np.concatenate(
        [[0], np.cumsum(np.asarray(block_lens, np.int64))])
    chunks, raw_lens = [], []
    prev_raw = prev_blk = 0
    for c in cuts:
        b1 = -(-int(c) // codec_mod.ENTROPY_BLOCK)
        chunks.append(stream[eoffs[prev_blk]:eoffs[b1]])
        raw_lens.append(int(c) - prev_raw)
        prev_raw, prev_blk = int(c), b1
    return chunks, raw_lens


class PayloadTicket:
    """Accumulator for one submitted payload: digests in chunk order,
    per-chunk byte lengths (manifest v5 offset lists), bytes physically
    written, running crc32, and a completion count. Resolved by the
    session's consumption loop; read it only after ``flush()`` (or
    ``result()``, which drains just far enough).

    A ticket whose payload sits in the scan-ahead queue (its candidate
    scan still in flight on the device) has ``submitted=False`` until the
    session chunks it and feeds the pool.

    For chunk-encoded codecs ``raw_lens`` carries the pre-entropy
    (transformed-stream) chunk lengths; ``lens``/``crc``/``new_bytes``
    then describe the ENCODED chunks that were physically stored, and
    ``payload_bytes`` stays the transformed length."""

    __slots__ = ("digests", "lens", "new_bytes", "crc", "remaining",
                 "n_chunks", "payload_bytes", "submitted", "raw_lens")

    def __init__(self, n_chunks: int, payload_bytes: int,
                 submitted: bool = True):
        self.digests: list = []
        self.lens: list = []
        self.new_bytes = 0
        self.crc = 0
        self.remaining = n_chunks
        self.n_chunks = n_chunks
        self.payload_bytes = payload_bytes
        self.submitted = submitted
        self.raw_lens: list | None = None

    @property
    def done(self) -> bool:
        return self.submitted and self.remaining == 0


class SaveSession:
    """Rank-wide submission queue feeding the chunk pool continuously
    ACROSS shard boundaries.

    ``put_payload`` drains its in-flight window at every payload end, so a
    writer rank with many small shards stalls the pool once per shard.
    Here the writer submits each payload and immediately moves on; chunk
    completions are consumed (in global submission order) only to keep the
    window bounded, to fold each payload's crc, and to run the coordinator
    heartbeat. ``flush()`` drains everything before the rank's durability
    barrier.

    Error semantics match ``ChunkIOExecutor.map_ordered``: the first
    failure (including injected ``CrashPoint``s) cancels queued chunks,
    joins every in-flight call, and re-raises — when a SaveSession method
    exits with an error, no submitted work is still running.

    The serial engine (``io_threads=1``) bypasses the queue entirely:
    ``submit_payload`` runs the original chunk-at-a-time ``put_payload``
    inline, so the PR-1 baseline stays byte-for-byte intact.
    """

    def __init__(self, chunks: ChunkStore, *, crash: CrashInjector = NO_CRASH,
                 on_chunk=None, chunker=None, dirs: set | None = None,
                 window: int | None = None):
        self._chunks = chunks
        self._crash = crash
        self._on_chunk = on_chunk
        self._chunker = chunker
        # a chunker OBJECT (cdc.GearChunker) exposes the async candidate
        # scanner — that unlocks the scan-ahead queue below; a plain
        # callable still works and chunks inline
        self._chunker_obj = chunker if hasattr(chunker, "scanner") else None
        self._exec = chunks.executor
        self.serial = self._exec.serial
        # fan-out dirs pending the rank's batched fsync barrier
        self.dirs: set = dirs if dirs is not None else set()
        self._dirs_lock = threading.Lock()
        self._window = max(int(window or 2 * self._exec.threads), 1)
        self._pending: deque = deque()      # (future, ticket, chunk)
        self._scan_queue: deque = deque()   # (resolve fn, ticket)

    # -- submission ----------------------------------------------------
    def submit_payload(self, payload) -> PayloadTicket:
        """Chunk `payload` and feed the pool; returns the payload's ticket.
        Serial engine: runs to completion inline (PR-1 path).

        Pipelined engine with an accelerated CDC scanner: the payload's
        candidate scan is DISPATCHED here (async, on the device) and its
        chunks are only fed to the pool when the next payload arrives (or
        at flush/result) — so the scan of payload k+1 overlaps the chunk
        hash/write of payload k instead of serializing in front of it."""
        if self.serial:
            lens: list = []
            digests, new = self._chunks.put_payload(
                payload, self._crash, on_chunk=self._on_chunk,
                chunker=self._chunker, lens_out=lens)
            ticket = PayloadTicket(0, len(payload))
            ticket.digests = digests
            ticket.lens = lens
            ticket.new_bytes = new
            ticket.crc = zlib.crc32(payload) & 0xFFFFFFFF
            return ticket
        if self._chunker_obj is not None and \
                self._chunker_obj.scanner.resolve(len(payload)) != "numpy":
            ticket = PayloadTicket(-1, len(payload), submitted=False)
            try:
                handle = self._chunker_obj.scanner.scan_async(payload)

                def resolve(payload=payload, handle=handle):
                    return payload, self._chunker_obj.chunk(
                        payload, candidates=handle.result())

                self._enqueue_scan(resolve, ticket)
            except BaseException:
                self.abort()
                raise
            return ticket
        chunks = (cas_run_chunker(self._chunker, payload)
                  if self._chunker is not None
                  else split_payload(payload, self._chunks.chunk_size))
        ticket = PayloadTicket(len(chunks), len(payload))
        try:
            self._feed(chunks, ticket)
        except BaseException:
            self.abort()
            raise
        return ticket

    def submit_preconditioned(self, payload, itemsize: int,
                              codec_name: str, *,
                              device_entropy: bool = True) -> PayloadTicket:
        """Byteplane-codec payload submission (pipelined engine only —
        the serial engine encodes on the host, PR-1 purity). The forward
        transform runs ON DEVICE: fused with the candidate scan when the
        chunk grid is content-defined over the transformed stream
        (``codec="byteplane"`` + CDC chunker) — ONE device round-trip per
        payload, gear bitmap and transformed bytes back together — and as
        a standalone async transform otherwise (fixed chunking, or a
        zstd stage between transform and chunking). Either way the device
        works on payload k+1 while the pool hashes/writes payload k, and
        the stored stream is byte-identical to the host
        ``codec_mod.encode`` path.

        Chunk-encoded codecs (byteplane-rle/-rans) add the plane entropy
        stage to the SAME dispatch when ``device_entropy`` and a CDC
        chunker are active: boundaries are cut on the transformed stream
        (rounded up to plane-block alignment) and each chunk's encoding
        is sliced out of the whole-payload encoded stream the device
        returned — byte-identical to per-chunk host encoding, but D2H and
        hashing pay only the compressed size."""
        ticket = PayloadTicket(-1, len(payload), submitted=False)
        n = len(payload)
        accel = (self._chunker_obj is not None
                 and self._chunker_obj.scanner.resolve(n) != "numpy")
        try:
            if codec_name in codec_mod.CHUNK_ENCODED \
                    and self._chunker_obj is not None:
                ck = self._chunker_obj
                if device_entropy or not accel:
                    # fused 3-stage dispatch (or the inline host oracle
                    # below the acceleration threshold — same bytes)
                    handle = ck.scanner.scan_transform_encode_async(
                        payload, itemsize, codec_name)

                    def resolve(handle=handle, ck=ck, ticket=ticket, n=n):
                        cands, stream, block_lens = handle.result()
                        cuts = ck.align_cuts(ck.cut_points_n(n, cands), n,
                                             codec_mod.ENTROPY_BLOCK)
                        chunks, ticket.raw_lens = \
                            _slice_encoded(stream, block_lens, cuts)
                        return n, chunks
                else:
                    # device transform + scan, host entropy stage
                    handle = ck.scanner.scan_transform_async(
                        payload, itemsize)

                    def resolve(handle=handle, ck=ck, ticket=ticket,
                                codec_name=codec_name):
                        cands, t = handle.result()
                        cuts = ck.align_cuts(
                            ck.cut_points_n(len(t), cands), len(t),
                            codec_mod.ENTROPY_BLOCK)
                        chunks, raw_lens, pos = [], [], 0
                        for c in cuts:
                            chunks.append(codec_mod.plane_encode_chunk(
                                t[pos:c], codec_name))
                            raw_lens.append(c - pos)
                            pos = c
                        ticket.raw_lens = raw_lens
                        return len(t), chunks
            elif codec_name in codec_mod.CHUNK_ENCODED:
                # fixed chunk grid: boundaries are not plane-aligned, so
                # each fixed-size raw chunk is entropy-coded on the host
                # (chunk-relative blocks — still a pure function of the
                # chunk bytes)
                from . import cdc_scan
                handle = cdc_scan.transform_async(payload, itemsize)

                def resolve(handle=handle, ticket=ticket,
                            codec_name=codec_name):
                    t = handle.result()
                    raw_chunks = split_payload(t, self._chunks.chunk_size)
                    ticket.raw_lens = [len(c) for c in raw_chunks]
                    return len(t), [
                        codec_mod.plane_encode_chunk(c, codec_name)
                        for c in raw_chunks]
            elif codec_name == "byteplane" and accel:
                handle = self._chunker_obj.scanner.scan_transform_async(
                    payload, itemsize)

                def resolve(handle=handle):
                    cands, t = handle.result()
                    return t, self._chunker_obj.chunk(t, candidates=cands)
            else:
                from . import cdc_scan
                handle = cdc_scan.transform_async(payload, itemsize)

                def resolve(handle=handle, codec_name=codec_name):
                    enc = codec_mod.encode_preconditioned(handle.result(),
                                                          codec_name)
                    if self._chunker_obj is not None:
                        chunks = self._chunker_obj.chunk(enc)
                    elif self._chunker is not None:
                        chunks = cas_run_chunker(self._chunker, enc)
                    else:
                        chunks = split_payload(enc,
                                               self._chunks.chunk_size)
                    return enc, chunks

            self._enqueue_scan(resolve, ticket)
        except BaseException:
            self.abort()
            raise
        return ticket

    def submit_chunk_encoded(self, payload, itemsize: int,
                             codec_name: str) -> PayloadTicket:
        """Host-oracle path for chunk-encoded codecs: the serial engine
        (PR-1 purity — pure numpy, inline) and the pipelined engine with
        device pre-conditioning disabled. Transformed stream, aligned
        cuts and per-chunk encodings are all oracle-computed, so the
        stored objects and the manifest are byte-identical to the device
        path's."""
        u8 = payload if isinstance(payload, np.ndarray) \
            else np.frombuffer(payload, np.uint8)
        t = codec_mod.byteplane_forward(u8, itemsize)
        if self._chunker_obj is not None:
            ck = self._chunker_obj
            cuts = ck.align_cuts(ck.cut_points(t), len(t),
                                 codec_mod.ENTROPY_BLOCK)
        else:
            cs = self._chunks.chunk_size
            cuts = list(range(cs, len(t), cs)) + ([len(t)] if len(t) else [])
        raw_lens, chunks, pos = [], [], 0
        for c in cuts:
            chunks.append(codec_mod.plane_encode_chunk(t[pos:c], codec_name))
            raw_lens.append(c - pos)
            pos = c
        if self.serial:
            enc_stream = b"".join(chunks)
            lens: list = []
            digests, new = self._chunks.put_payload(
                enc_stream, self._crash, on_chunk=self._on_chunk,
                chunker=lambda _p: chunks, lens_out=lens)
            ticket = PayloadTicket(0, len(t))
            ticket.digests = digests
            ticket.lens = lens
            ticket.new_bytes = new
            ticket.crc = zlib.crc32(enc_stream) & 0xFFFFFFFF
            ticket.raw_lens = raw_lens
            return ticket
        ticket = PayloadTicket(len(chunks), len(t))
        ticket.raw_lens = raw_lens
        try:
            self._feed(chunks, ticket)
        except BaseException:
            self.abort()
            raise
        return ticket

    def _enqueue_scan(self, resolve, ticket: PayloadTicket):
        self._scan_queue.append((resolve, ticket))
        # depth-1 scan-ahead: feed the pool with every OLDER payload's
        # chunks (their device work had the whole previous hash/write
        # phase to finish) while the device transforms/scans this one
        while len(self._scan_queue) > 1:
            self._submit_scanned()

    def _feed(self, chunks, ticket: PayloadTicket):
        for chunk in chunks:
            while len(self._pending) >= self._window:
                self._consume_one()
            fut = self._exec.submit(self._store, chunk)
            self._pending.append((fut, ticket, chunk))

    def _submit_scanned(self):
        """Resolve the oldest queued device dispatch and feed its chunks
        to the pool (tickets always submit — and therefore resolve — in
        order). ``resolve`` returns (final payload, chunks): for a
        pre-conditioned codec the final payload is the transformed (and
        possibly compressed) stream, so the ticket's payload length is
        only known here."""
        resolve, ticket = self._scan_queue.popleft()
        try:
            payload, chunks = resolve()
            # chunk-encoded resolves return the transformed LENGTH (the
            # fused entropy dispatch never materializes the stream on
            # host) — everything else returns the payload itself
            ticket.payload_bytes = payload if isinstance(payload, int) \
                else len(payload)
            ticket.n_chunks = ticket.remaining = len(chunks)
            ticket.submitted = True
            self._feed(chunks, ticket)
        except BaseException:
            self.abort()
            raise

    def _store(self, chunk):
        d = chunk_digest(chunk)
        return d, self._chunks.store_chunk(d, chunk, self._crash,
                                           self.dirs, self._dirs_lock)

    # -- consumption ---------------------------------------------------
    def _consume_one(self):
        fut, ticket, chunk = self._pending.popleft()
        try:
            d, new = fut.result()
        except BaseException:
            self.abort()
            raise
        ticket.digests.append(d)
        ticket.lens.append(len(chunk))
        ticket.new_bytes += new
        ticket.crc = zlib.crc32(chunk, ticket.crc)
        ticket.remaining -= 1
        try:
            if ticket.n_chunks > 1 and \
                    len(ticket.digests) == 1:
                # first chunk of a multi-chunk payload durably renamed
                # while its siblings are still in flight — the mid-batch
                # crash point
                self._crash.maybe("cas_mid_batch")
            if self._on_chunk is not None:
                self._on_chunk()
        except BaseException:
            self.abort()
            raise

    def abort(self):
        """Cancel what hasn't started, join what has (no stray worker may
        still be writing objects while the caller's abort path runs).
        Queued scans are dropped (device scan results are side-effect
        free). Session methods call this on their own failures; a CALLER
        whose error occurs between session calls (codec failure, injected
        crash) must call it too before unwinding, or pool workers would
        still be renaming objects while the abort/GC path runs."""
        self._scan_queue.clear()
        futs = [f for f, _, _ in self._pending]
        for f in futs:
            f.cancel()
        futures_wait(futs)
        self._pending.clear()

    def result(self, ticket: PayloadTicket) -> tuple:
        """Drain until `ticket` resolves; returns (digests, new_bytes, crc)
        (per-chunk lengths ride on ``ticket.lens``). Chunks of LATER
        payloads may remain in flight."""
        while not ticket.submitted:
            self._submit_scanned()
        while not ticket.done:
            self._consume_one()
        return ticket.digests, ticket.new_bytes, ticket.crc & 0xFFFFFFFF

    def flush(self):
        """Drain every queued scan and in-flight chunk (all tickets
        resolve)."""
        while self._scan_queue:
            self._submit_scanned()
        while self._pending:
            self._consume_one()

    def barrier(self, crash: CrashInjector | None = None):
        """flush + the rank's ONE batched durability fsync over every
        fan-out dir this session touched."""
        self.flush()
        if self.dirs:
            self._chunks.fsync_dirs(self.dirs, crash or self._crash)
            self.dirs.clear()


# ---------------------------------------------------------------------------
# phase-1 write engine (retrying, coordinator-supervised)
# ---------------------------------------------------------------------------

class WriteOutcome:
    """Result of the phase-1 barrier: per-attempt stats, chunked records,
    the plan that produced them, and abort blame."""

    def __init__(self):
        self.ok = False
        self.reason = ""
        self.plan: SavePlan | None = None
        self.stats = {"files": 0, "payload_bytes": 0, "written_bytes": 0,
                      "new_object_bytes": 0, "chunks": 0}
        self.shard_records: dict = {}       # item index → chunked record
        self.dead: set = set()


def write_shards(*, items, alive_hint: int, coordinator, chunks: ChunkStore,
                 store, rel_stage: str, step: int, incremental: bool,
                 chunking: str, chunker, replicas: int, leaf_codec,
                 max_retries: int, save_timeout_s: float,
                 crash: CrashInjector, overlapped: bool = False,
                 device_precondition: bool = False,
                 device_entropy: bool = True) \
        -> WriteOutcome:
    """Run the retrying 2PC phase 1: plan an attempt over surviving ranks,
    start one writer thread per rank, wait for the all-PREPARED barrier,
    and on a rank death redistribute its shards to survivors (up to
    ``max_retries`` times). Pure write-side — commit/abort stays with the
    caller."""
    out = WriteOutcome()
    stats_lock = threading.Lock()

    def writer(rank: int, work: list):
        session = None
        try:
            coordinator.rank_begin(rank)
            nbytes = 0
            files: list = []
            rank_chunks: Counter = Counter()
            session = SaveSession(chunks, crash=crash,
                                  on_chunk=lambda: coordinator.heartbeat(rank),
                                  chunker=chunker)
            deferred: list = []             # (item index, ticket, record)
            for i, name, rng, arr, fname, is_replica in work:
                codec_name = leaf_codec(name)
                if incremental:
                    if not session.serial and device_precondition \
                            and codec_name in codec_mod.PRECONDITIONED:
                        # device pre-conditioning: the byteplane forward
                        # transform runs on device, fused into the CDC
                        # scan dispatch when the chunk grid follows the
                        # transformed stream — chunking, dedup and the
                        # manifest crc all operate on exactly the bytes
                        # the host encoder would have produced
                        u8 = np.ascontiguousarray(arr) \
                            .reshape(-1).view(np.uint8)
                        meta = codec_mod.byteplane_meta(arr)
                        crash.maybe(f"rank{rank}_before_write")
                        ticket = session.submit_preconditioned(
                            u8, arr.dtype.itemsize, codec_name,
                            device_entropy=device_entropy)
                        # the device dispatch is in flight but this
                        # payload's chunks have NOT been fed to the pool
                        # yet (scan-ahead queue) — the crash matrix kills
                        # the writer exactly here
                        crash.maybe(f"rank{rank}_after_fused_dispatch")
                    elif codec_name in codec_mod.CHUNK_ENCODED:
                        # host-oracle entropy path (serial engine, or
                        # device pre-conditioning disabled): same aligned
                        # cuts, same per-chunk encodings, same manifest
                        u8 = np.ascontiguousarray(arr) \
                            .reshape(-1).view(np.uint8)
                        meta = codec_mod.byteplane_meta(arr)
                        crash.maybe(f"rank{rank}_before_write")
                        ticket = session.submit_chunk_encoded(
                            u8, arr.dtype.itemsize, codec_name)
                    else:
                        if not session.serial and codec_name == "raw":
                            # zero-copy feed: the chunk pipeline consumes
                            # a uint8 VIEW of the host array — no
                            # tobytes() copy, and chunk slices stay views
                            # all the way into hash/crc/write
                            payload = np.ascontiguousarray(arr) \
                                .reshape(-1).view(np.uint8)
                            meta = {}
                        else:
                            payload, meta = codec_mod.encode(arr,
                                                             codec_name)
                        crash.maybe(f"rank{rank}_before_write")
                        ticket = session.submit_payload(payload)
                    rec = {
                        "chunks": None,     # filled after the flush below
                        "chunk_size": chunks.chunk_size,
                        "chunking": chunking,
                        "start": list(rng.start), "stop": list(rng.stop),
                        "dtype": str(arr.dtype), "codec": codec_name,
                        "meta": meta,
                        "crc32": None,
                        # pre-conditioned payloads learn their final
                        # length at resolve time; refined below
                        "payload_bytes": ticket.payload_bytes,
                    }
                    deferred.append((i, ticket, rec))
                else:
                    data, header = pack_shard(name, rng, arr, codec_name)
                    crash.maybe(f"rank{rank}_before_write")
                    # full-mode shard files get the bounded retry but NOT
                    # the degraded failover: the commit path renames the
                    # staging dir within the fast root, so a shard landed
                    # on another tier could never be committed
                    if chunks.retry is not None:
                        resilience.retry_io(
                            lambda d=data, f=fname: store.fast.write_file(
                                f"{rel_stage}/{f}", d),
                            chunks.retry, deadline=chunks._deadline,
                            health=store.health_for(store.fast),
                            op="shard_write")
                    else:
                        store.fast.write_file(f"{rel_stage}/{fname}", data)
                    nbytes += len(data)
                    files.append(fname)
                    with stats_lock:
                        out.stats["written_bytes"] += len(data)
                        if not is_replica:
                            out.stats["files"] += 1
                            out.stats["payload_bytes"] += \
                                header["payload_bytes"]
                coordinator.heartbeat(rank)
            # one durability barrier per rank, fanned over the chunk pool —
            # PREPARED may only be acked once every object this rank wrote
            # is findable after a crash
            session.barrier(crash)
            coordinator.heartbeat(rank)
            for i, ticket, rec in deferred:
                digests, new_bytes, crc = session.result(ticket)
                # the matrix's "writer dies with orphan chunks on disk"
                # point: this payload's objects are renamed AND covered by
                # the barrier above, so the injected death deterministically
                # leaves durable orphans for the recovery sweep
                crash.maybe(f"rank{rank}_after_chunk_write")
                rec["chunks"] = digests
                rec["crc32"] = crc
                rec["payload_bytes"] = ticket.payload_bytes
                if ticket.raw_lens is not None:
                    # manifest v7: chunk-encoded codec — chunk_lens keep
                    # their physical meaning (encoded bytes: offsets,
                    # direct placement and the crc all describe what is
                    # actually read), raw lens drive the per-chunk
                    # entropy decode after placement
                    rec["payload_bytes"] = int(sum(ticket.lens))
                    rec["raw_payload_bytes"] = int(ticket.payload_bytes)
                    rec["chunk_lens"] = [int(n) for n in ticket.lens]
                    rec["chunk_raw_lens"] = [int(n)
                                             for n in ticket.raw_lens]
                elif chunking == "cdc":
                    # manifest v5: content-defined chunk lengths — restore
                    # prefix-sums them into offsets and places reads
                    # directly (fixed chunking derives offsets instead)
                    rec["chunk_lens"] = [int(n) for n in ticket.lens]
                rank_chunks.update(digests)
                nbytes += new_bytes
                with stats_lock:
                    out.shard_records[i] = rec
                    out.stats["files"] += 1
                    out.stats["payload_bytes"] += rec["payload_bytes"]
                    out.stats["written_bytes"] += new_bytes
                    out.stats["new_object_bytes"] += new_bytes
                    out.stats["chunks"] += len(digests)
            coordinator.rank_prepared(rank, nbytes=nbytes, files=files,
                                      chunks=rank_chunks)
        except Exception as e:  # noqa
            if session is not None:
                # an error raised BETWEEN session calls (codec failure,
                # injected crash) leaves chunk futures in flight — join
                # them before reporting failure, or pool workers would
                # still be renaming objects while the round's abort /
                # retry / GC path runs
                try:
                    session.abort()
                except Exception:  # noqa — the original error wins
                    pass
            coordinator.rank_failed(rank, f"{type(e).__name__}: {e}")

    for attempt in range(max_retries + 1):
        alive = [r for r in range(alive_hint) if r not in out.dead]
        if not alive:
            out.reason = "no surviving writer ranks"
            break
        # one shared IO-retry deadline per attempt: every transient-error
        # retry across all ranks draws from the same io_deadline_s budget
        chunks.begin_io_window()
        for k in out.stats:
            out.stats[k] = 0
        out.shard_records.clear()
        out.plan = SavePlan.build(items, alive, incremental=incremental,
                                  replicas=replicas, leaf_codec=leaf_codec)
        coordinator.begin_round(step, participants=alive,
                                overlapped=overlapped)
        threads = [threading.Thread(target=writer,
                                    args=(r, out.plan.per_rank[r]),
                                    daemon=True) for r in alive]
        for t in threads:
            t.start()
        out.ok = coordinator.wait_all_prepared(timeout=save_timeout_s)
        out.reason = coordinator.abort_reason()
        newly_dead = set(coordinator.round.failed) if coordinator.round \
            else set()
        for t in threads:
            t.join()
        if out.ok:
            break
        coordinator.finish_round(False)
        out.dead |= newly_dead or set(alive)  # timeout w/o blame: give up
        if attempt < max_retries and newly_dead:
            warn("CKPT_W_RETRY",
                 "writer rank(s) failed; redistributing their shards "
                 "to survivors and retrying",
                 dead=sorted(out.dead), step=step, reason=out.reason)
    return out


# ---------------------------------------------------------------------------
# snapshot stage (stage 0 — the only blocking part of an overlapped save)
# ---------------------------------------------------------------------------

def iter_snapshot_shards(state):
    """One (name, range, device_data) entry per unique logical shard range
    of `state` (replicated copies save once) — THE enumeration both the
    snapshot copy and the byte-budget estimate consume: admission must
    account exactly the bytes the snapshot will pin, so there is one
    dedup rule, not two that can drift."""
    from .split_state import leaf_paths
    for name, leaf in leaf_paths(state):
        if hasattr(leaf, "addressable_shards"):
            seen = set()
            gshape = leaf.shape
            for sh in leaf.addressable_shards:
                rng = normalize_index(sh.index, gshape)
                key = (rng.start, rng.stop)
                if key in seen:
                    continue               # replicated copy — save once
                seen.add(key)
                yield name, rng, sh.data
        else:
            arr = np.asarray(leaf)
            yield name, ShardRange((0,) * arr.ndim, arr.shape), arr


def estimate_snapshot_bytes(state) -> int:
    """Host bytes ONE snapshot of `state` will pin. The persist queue's
    byte-budget admission must run BEFORE the host copy exists, so it
    gates on this metadata-only walk of ``iter_snapshot_shards`` (exact
    for the snapshot: same entries, same nbytes)."""
    return sum(int(data.nbytes)
               for _, _, data in iter_snapshot_shards(state))


def snapshot_items(state, pool) -> list:
    """Device → host copy of every ``iter_snapshot_shards`` entry. The
    pipelined engine fans the per-shard host copies out over `pool` (the
    save-time idle restore pool); the serial engine keeps the original
    inline copies."""
    pending = list(iter_snapshot_shards(state))
    hosts = pool.map_ordered(np.asarray, [d for _, _, d in pending])
    return [(name, rng, arr)
            for (name, rng, _), arr in zip(pending, hosts)]


# ---------------------------------------------------------------------------
# maintenance stage (stage 3: retention + CAS mark-and-sweep)
# ---------------------------------------------------------------------------

def collect_live_refs(store, memo: dict, tiers=None,
                      errors: list | None = None) -> Counter:
    """Mark phase: chunk refcounts implied by every committed manifest on
    the given tiers (default: all — old steps may survive on the slow tier
    after fast-tier retirement and their chunks stay live). Committed
    manifests are immutable, so per-(tier, step) ref counters are memoized
    in `memo`: each save only parses the manifest it just wrote instead of
    re-reading the whole run history.

    An unreadable manifest does NOT silently contribute zero refs: the
    same step's copy on another tier is still consulted (a step only
    counts as seen once successfully parsed), and any step that stays
    unreadable everywhere is appended to `errors` so a destructive caller
    can fail safe instead of sweeping that step's chunks."""
    import json

    from . import atomic, cas
    full_scan = tiers is None
    tiers = store.tiers() if full_scan else tiers
    live: Counter = Counter()
    seen_steps: set = set()
    failed_steps: dict = {}
    valid_keys: set = set()
    for tier in tiers:
        for s in atomic.list_committed_steps(tier.root):
            key = (tier.name, s)
            valid_keys.add(key)
            if s in seen_steps:
                continue
            refs = memo.get(key)
            if refs is None:
                mpath = atomic.committed_dir(tier.root, s) / atomic.MANIFEST
                try:
                    refs = cas.live_chunk_refs(
                        [json.loads(mpath.read_text())])
                except (OSError, ValueError):
                    failed_steps[s] = tier.name
                    continue
                memo[key] = refs
            seen_steps.add(s)
            live.update(refs)
    if errors is not None:
        errors.extend((t, s) for s, t in failed_steps.items()
                      if s not in seen_steps)
    if full_scan:                      # drop memo entries of retired steps
        for key in list(memo):
            if key not in valid_keys:
                del memo[key]
    return live


def run_maintenance(store, chunks: ChunkStore, retain: int, collect,
                    crash: CrashInjector = NO_CRASH,
                    force_sweep: bool = False, scrub: bool = False,
                    scrub_sample: int | None = None, scrub_seed: int = 0,
                    should_stop=None) -> dict:
    """Stage 3 body: retire fast-tier steps beyond `retain`, clear staging
    litter, then mark-and-sweep the content-addressed store. `collect` is
    the manager's memoizing mark-phase callable (tiers=, errors=).

    The destructive mark-and-sweep is O(total objects + history), so the
    per-save path only runs it when retention actually dropped a step
    (that's when objects become garbage in bulk); an explicit gc() always
    sweeps, which is how aborted-round orphans are reclaimed on demand.

    ``scrub=True`` additionally re-hashes the live object set (or a
    seeded `scrub_sample`) and heals/quarantines per ``ChunkStore.scrub``;
    `should_stop` defers the remainder between objects (preemption). The
    maintenance pass also persists ``_CAS/health.json`` (tier health
    snapshot) and, after a scrub, ``_CAS/last_scrub.json`` — the offline
    inspector reads state from files, not from this process."""
    import json
    import shutil

    from . import atomic, cas

    def _finish(result: dict) -> dict:
        try:
            atomic.atomic_write_bytes(
                store.fast.root / cas.HEALTH_FILE,
                json.dumps(store.health_report(),
                           separators=(",", ":")).encode())
        except OSError:
            pass                    # telemetry must never fail maintenance
        return result

    # a step being drained to the slow tier MUST land before retirement
    # and marking — otherwise retiring its fast copy mid-copy would leave
    # its manifest on no tier and sweep would reap its chunks
    store.wait_drained()
    steps = atomic.list_committed_steps(store.root)
    dropped = steps[:-retain] if retain else []
    for s in dropped:
        shutil.rmtree(atomic.committed_dir(store.root, s),
                      ignore_errors=True)
    atomic.gc_staging(store.root)
    # a crash inside an atomic fast-tier write (committed step dirs,
    # LATEST, _CAS/refs.json) leaves .tmp-* FILES that neither gc_staging
    # (whole staging dirs) nor the drain purge (slow-tier step dirs)
    # revisits — sweep them every round, post-drain so none can be live
    fast_tmp_removed = store.fast.sweep_tmp_litter()
    no_sweep = {"swept": 0, "swept_bytes": 0, "kept": 0, "kept_bytes": 0,
                "tmp_removed": 0, "evicted": 0, "evicted_bytes": 0}
    if not (dropped or force_sweep or scrub):
        return _finish({"steps_dropped": [],
                        "fast_tmp_removed": fast_tmp_removed,
                        "cas": dict(no_sweep, skipped=True)})
    errors: list = []
    live = collect(errors=errors)
    scrub_report = None
    if scrub and not errors:
        # scrub BEFORE the sweep: healing rewrites live slots, and the
        # sweep must see the healed tree (quarantine/ lives outside
        # objects/, so quarantined copies are never re-marked or swept)
        scrub_report = chunks.scrub(live, sample=scrub_sample,
                                    seed=scrub_seed,
                                    should_stop=should_stop, crash=crash)
        try:
            atomic.atomic_write_bytes(
                store.fast.root / cas.SCRUB_FILE,
                json.dumps(scrub_report, separators=(",", ":")).encode())
        except OSError:
            pass
    if not (dropped or force_sweep):
        return _finish({"steps_dropped": [],
                        "fast_tmp_removed": fast_tmp_removed,
                        "cas": dict(no_sweep, skipped=True),
                        "scrub": scrub_report})
    fast_errors: list = []
    fast_live = (collect(tiers=[store.fast], errors=fast_errors)
                 if store.slow is not None else None)
    if fast_errors:
        # eviction's mark set is incomplete (a fast-tier manifest is
        # unreadable even though the slow copy may be fine) — evicting on
        # it would silently demote a retained step to slow-tier bandwidth,
        # so skip eviction this round
        warn("CKPT_W_GC", "unreadable fast-tier manifest(s); skipping "
             "burst-buffer eviction this round", steps=fast_errors[:8])
        fast_live = None
    crash.maybe("after_gc_mark")
    if errors:
        # fail safe: with any committed manifest unreadable the mark set
        # is incomplete, and sweeping would permanently delete chunks a
        # committed checkpoint still needs
        warn("CKPT_W_GC", "unreadable committed manifest(s); skipping "
             "the CAS sweep (fail-safe) — repair or remove the damaged "
             "step(s) and rerun gc()", steps=errors[:8])
        return _finish({"steps_dropped": dropped,
                        "fast_tmp_removed": fast_tmp_removed,
                        "cas": dict(no_sweep, skipped=True,
                                    unreadable_manifests=errors),
                        "scrub": scrub_report})
    return _finish({"steps_dropped": dropped,
                    "fast_tmp_removed": fast_tmp_removed,
                    "cas": chunks.sweep(live, crash, fast_live=fast_live),
                    "scrub": scrub_report})


# ---------------------------------------------------------------------------
# background persist stage
# ---------------------------------------------------------------------------

class PersistStage:
    """Owns the overlapped persist: ``save(blocking=False)`` hands the
    snapshotted round here and returns; chunk/hash/write/2PC-COMMIT run on
    ONE worker thread, in submission order, while training continues.

    ``depth`` bounds how many rounds may be admitted at once (the
    multi-round persist queue: snapshot round N+1 while round N persists
    — checkpoint cadence decoupled from persist latency). ``depth=1`` is
    the PR-3 behaviour, and the serial engine is always pinned there.
    ``host_bytes_budget`` caps the aggregate host snapshot bytes admitted
    rounds may pin: ``admit()`` blocks the NEXT snapshot (before its
    device→host copy exists) rather than letting two full snapshots OOM
    the host; a lone over-budget round still admits (never deadlocks).

    ``request_fast_flush()`` is the preemption hook: a SIGTERM handler (via
    ``PreemptionGuard.add_callback``) flips a flag the in-flight round
    consults to skip non-essential maintenance (the per-save GC sweep) so
    the round commits and the process can exit promptly — the commit
    itself, refcount publication and the slow-tier drain are never
    skipped (durability is the point of the final checkpoint). The flag
    covers every round queued at request time and clears when the queue
    drains (per-request, not a latch). A request with NO round in flight
    deliberately applies to the next overlapped round (the signal may land
    during the snapshot, before the persist worker runs); if the process
    then survives the preemption, the cost is one skipped maintenance
    round — self-healing, since the following round (or an explicit gc())
    retires everything that accumulated."""

    def __init__(self, depth: int = 1, host_bytes_budget: int | None = None):
        self.depth = max(int(depth or 1), 1)
        self.host_bytes_budget = (int(host_bytes_budget)
                                  if host_bytes_budget else None)
        self._cv = threading.Condition()
        self._q: deque = deque()            # (fn, on_error, nbytes)
        self._thread: threading.Thread | None = None
        self._err: BaseException | None = None
        self._inflight = 0                  # admitted rounds not yet done
        self._inflight_bytes = 0
        self._fast_flush = threading.Event()

    @property
    def active(self) -> bool:
        with self._cv:
            return self._inflight > 0 or bool(self._q)

    @property
    def inflight(self) -> int:
        """Rounds currently admitted (reserved + queued + running)."""
        with self._cv:
            return self._inflight

    @property
    def inflight_bytes(self) -> int:
        with self._cv:
            return self._inflight_bytes

    @property
    def fast_flush_requested(self) -> bool:
        return self._fast_flush.is_set()

    def request_fast_flush(self):
        self._fast_flush.set()

    def raise_pending(self):
        """Surface (and clear) a failed round's error NOW. The queued
        save path calls this before admitting the next round — at depth 1
        the drain-before-snapshot wait() surfaces persist failures on the
        very next save, and a deeper queue must not turn that into
        checkpoints silently failing for the rest of the run."""
        if self._err is not None:
            e, self._err = self._err, None
            raise e

    # -- admission -----------------------------------------------------
    def admit(self, nbytes: int = 0) -> float:
        """Block until a queue slot AND the host byte budget admit a round
        of `nbytes`, then RESERVE both — the caller's snapshot counts
        against the budget from this moment. Hand the reservation to the
        queue with ``submit(..., reserved=True)`` or cancel it with
        ``release()`` if the snapshot fails. An empty stage always admits
        (a single round larger than the whole budget must run, not
        deadlock). Returns seconds spent blocked."""
        nbytes = max(int(nbytes), 0)
        t0 = time.monotonic()
        with self._cv:
            while self._inflight >= self.depth or (
                    self.host_bytes_budget is not None
                    and self._inflight > 0
                    and self._inflight_bytes + nbytes
                    > self.host_bytes_budget):
                self._cv.wait()
            self._inflight += 1
            self._inflight_bytes += nbytes
        return time.monotonic() - t0

    def release(self, nbytes: int = 0):
        """Return an admitted round's slot + bytes (round done, or its
        snapshot failed before submission)."""
        with self._cv:
            self._inflight -= 1
            self._inflight_bytes -= max(int(nbytes), 0)
            self._cv.notify_all()

    # -- execution -----------------------------------------------------
    def submit(self, fn, on_error, nbytes: int = 0, reserved: bool = False):
        """Queue ``fn`` for the persist worker (FIFO — rounds always
        commit in submission order); ``on_error(exc)`` runs on the worker
        on failure (the manager uses it to keep the drain counters moving —
        a stuck counter would deadlock the trainer). ``reserved=True``
        consumes an ``admit()`` reservation instead of taking a new
        slot."""
        with self._cv:
            if not reserved:
                self._inflight += 1
                self._inflight_bytes += max(int(nbytes), 0)
            self._q.append((fn, on_error, max(int(nbytes), 0)))
            if self._thread is None:
                self._thread = threading.Thread(target=self._run,
                                                daemon=True)
                self._thread.start()
            self._cv.notify_all()

    def _run(self):
        while True:
            with self._cv:
                if not self._q:
                    # worker retires under the lock — a concurrent submit
                    # either sees the queue non-empty (we loop) or
                    # _thread=None (it starts a fresh worker): no round
                    # can be stranded between the two
                    self._thread = None
                    # fast-flush is per-request, not a latch: once every
                    # flushed round has landed (or died) the next round
                    # must run full maintenance again, or a survived
                    # preemption request would disable GC for the rest of
                    # the process lifetime
                    self._fast_flush.clear()
                    self._cv.notify_all()
                    return
                fn, on_error, nbytes = self._q.popleft()
            try:
                fn()
            except BaseException as e:  # noqa — propagated via wait()
                if self._err is None:   # first failure wins
                    self._err = e
                on_error(e)
            finally:
                self.release(nbytes)

    def wait(self):
        """Drain every admitted round, then surface the first error."""
        with self._cv:
            while self._inflight > 0 or self._q:
                self._cv.wait()
            t = self._thread
        if t is not None:
            t.join()
        if self._err is not None:
            e, self._err = self._err, None
            raise e
