"""Reserved-namespace registry — the file-descriptor-conflict analogue.

In MANA, the upper half could open an fd before checkpoint that the lower
half later claimed on restart; the fix was tagging and reserving descriptor
ranges per half. Here, checkpoint-internal artifacts (manifests, staging
dirs, pointers, replica suffixes) live under reserved prefixes, and
upper-half leaf names are validated against them — a collision is a hard
error before any byte is written, not a corrupt restore later.
"""
from __future__ import annotations

import re

from .errors import NamespaceError

# lower-half reserved names (checkpoint machinery)
RESERVED_PREFIXES = ("_META", ".tmp-", "LATEST", "_AOT_CACHE", "_DRAIN",
                     "_CAS")
REPLICA_SUFFIX = ".r1"
UPPER_DIR = "upper"

_SAFE = re.compile(r"[^A-Za-z0-9_.\-]")


def leaf_to_fname(leaf_path: str) -> str:
    """Map a pytree leaf path ('params/stage_0/b1/wg') to a flat, safe file
    stem. '/' → '__' keeps paths shallow (srun-arg-limit lesson: workers read
    the manifest, never a file list)."""
    check_leaf_name(leaf_path)
    return _SAFE.sub("_", leaf_path.replace("/", "__"))


def check_leaf_name(leaf_path: str):
    head = leaf_path.split("/", 1)[0]
    for pfx in RESERVED_PREFIXES:
        if head.startswith(pfx):
            raise NamespaceError(
                "upper-half leaf name collides with reserved lower-half "
                "namespace", leaf=leaf_path, reserved=pfx)
    if leaf_path.endswith(REPLICA_SUFFIX):
        raise NamespaceError("leaf name ends with replica suffix",
                             leaf=leaf_path)
    return True
