"""Preemption handling — the paper's scheduling-flexibility use case:

  "making space for high-priority, real-time workloads by preempting
   low-priority jobs" — i.e. SIGTERM arrives, the job checkpoints at the
   next step boundary and exits cleanly; the scheduler later restarts it
   and it resumes bit-exactly.
"""
from __future__ import annotations

import signal
import threading
import time


class PreemptionGuard:
    """Installs handlers for `signals`; the training loop polls
    ``should_preempt`` at step boundaries (checkpointing mid-step is exactly
    the in-transit-message hazard the drain protocol exists to avoid)."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGUSR1)):
        self.signals = signals
        self._flag = threading.Event()
        self._old = {}
        self.received_at: float | None = None
        self.signum: int | None = None

    def _handler(self, signum, frame):
        self.signum = signum
        self.received_at = time.time()
        self._flag.set()

    def __enter__(self):
        for s in self.signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        self._old.clear()
        return False

    @property
    def should_preempt(self) -> bool:
        return self._flag.is_set()

    def request(self):
        """Programmatic preemption (tests / preempt-queue simulation)."""
        self._handler(signal.SIGUSR1, None)


class PreemptQueue:
    """Tiny priority-scheduler simulation for examples: high-priority
    arrivals preempt the running low-priority job via its guard."""

    def __init__(self):
        self.events = []

    def submit_high_priority(self, guard: PreemptionGuard, job: str):
        self.events.append(("preempt", job, time.time()))
        guard.request()
