"""Preemption handling — the paper's scheduling-flexibility use case:

  "making space for high-priority, real-time workloads by preempting
   low-priority jobs" — i.e. SIGTERM arrives, the job checkpoints at the
   next step boundary and exits cleanly; the scheduler later restarts it
   and it resumes bit-exactly.
"""
from __future__ import annotations

import signal
import threading
import time

from .errors import warn


class PreemptionGuard:
    """Installs handlers for `signals`; the training loop polls
    ``should_preempt`` at step boundaries (checkpointing mid-step is exactly
    the in-transit-message hazard the drain protocol exists to avoid).

    Every received signal is recorded (``signums``), not just the last.
    OS-delivered signals are DEFERRED, not swallowed: on ``__exit__`` each
    one is re-delivered to the restored handler, so an outer SIGTERM
    handler (or the default action — process exit, which is what a
    preempted job owes its scheduler) still observes the signal once the
    guarded region has checkpointed. ``request()`` (programmatic
    preemption) sets the flag without scheduling any re-delivery.

    ``add_callback`` registers signal-handler-safe hooks that run on every
    preemption signal — the checkpoint manager uses one to fast-flush an
    in-flight overlapped persist.
    """

    def __init__(self, signals=(signal.SIGTERM, signal.SIGUSR1)):
        self.signals = signals
        self._flag = threading.Event()
        self._old = {}
        self.received_at: float | None = None
        self.signum: int | None = None          # most recent
        self.signums: list = []                 # every one, in order
        self._deferred: list = []               # OS-delivered only
        self._callbacks: list = []

    def add_callback(self, fn):
        """Run `fn()` on every preemption signal. Must be signal-safe
        (set an event, flip a flag); exceptions are logged, not raised —
        a broken hook must not lose the signal itself. Re-registering an
        equal callable is a no-op (a trainer re-entering fit() with the
        same guard must not stack duplicates)."""
        if fn not in self._callbacks:
            self._callbacks.append(fn)

    def _record(self, signum):
        self.signum = signum
        self.signums.append(signum)
        self.received_at = time.time()
        self._flag.set()
        for fn in self._callbacks:
            try:
                fn()
            except Exception as e:  # noqa — see add_callback
                warn("CKPT_W_PREEMPT_HOOK", "preemption callback failed",
                     error=f"{type(e).__name__}: {e}")

    def _handler(self, signum, frame):
        self._deferred.append(signum)
        self._record(signum)

    def __enter__(self):
        for s in self.signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        self._old.clear()
        # re-deliver what the guard intercepted: the outer handler (or the
        # default action) must still see the preemption — before this, a
        # SIGTERM caught inside the guard simply vanished and the process
        # out-lived its eviction notice
        deferred, self._deferred = self._deferred, []
        for s in dict.fromkeys(deferred):
            signal.raise_signal(s)
        return False

    @property
    def should_preempt(self) -> bool:
        return self._flag.is_set()

    def request(self):
        """Programmatic preemption (tests / preempt-queue simulation) —
        sets the flag and runs callbacks, but schedules no re-delivery
        (there is no real OS signal to hand back)."""
        self._record(signal.SIGUSR1)


class PreemptQueue:
    """Tiny priority-scheduler simulation for examples: high-priority
    arrivals preempt the running low-priority job via its guard."""

    def __init__(self):
        self.events = []

    def submit_high_priority(self, guard: PreemptionGuard, job: str):
        self.events.append(("preempt", job, time.time()))
        guard.request()
