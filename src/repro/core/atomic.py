"""Atomic commit primitives (paper Lesson 3: CHANGES_PENDING fields acting as
locks, even for single-threaded code) and crash-consistent directory commit.

Protocol:
  * all writes land in ``<root>/step_<N>.tmp-<nonce>/`` (staging);
  * a ``_META/PENDING`` marker exists while any mutation is in flight;
  * commit = write manifest → fsync → remove PENDING → rename staging dir to
    ``<root>/step_<N>`` (atomic on POSIX) → rewrite LATEST pointer atomically.

A crash at ANY point leaves either the previous committed checkpoint intact
(staging dirs are ignored/garbage-collected) or the new one fully committed.
Property-tested with injected crashes at every protocol step.
"""
from __future__ import annotations

import json
import os
import secrets
from pathlib import Path

from .errors import StaleStateError

PENDING = "_META/PENDING"
MANIFEST = "_META/manifest.json"
LATEST = "LATEST"


class CrashPoint(Exception):
    """Raised by tests to simulate a crash at a protocol step."""


class CrashInjector:
    def __init__(self, crash_at: str | None = None):
        self.crash_at = crash_at

    def maybe(self, point: str):
        if self.crash_at == point:
            raise CrashPoint(point)


NO_CRASH = CrashInjector()


def fsync_file(path: Path):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path: Path):
    fd = os.open(path, os.O_RDONLY | os.O_DIRECTORY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: Path, data: bytes, crash: CrashInjector = NO_CRASH):
    tmp = path.with_name(path.name + f".tmp-{secrets.token_hex(4)}")
    tmp.parent.mkdir(parents=True, exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    crash.maybe("after_tmp_write")
    os.rename(tmp, path)
    crash.maybe("after_rename")
    fsync_dir(path.parent)


def staging_dir(root: Path, step: int) -> Path:
    return root / f"step_{step:08d}.tmp-{secrets.token_hex(4)}"


def committed_dir(root: Path, step: int) -> Path:
    return root / f"step_{step:08d}"


def mark_pending(stage: Path, payload: dict):
    p = stage / PENDING
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload))
    fsync_file(p)


def clear_pending(stage: Path):
    p = stage / PENDING
    if p.exists():
        p.unlink()
        fsync_dir(p.parent)


def assert_not_pending(d: Path):
    if (d / PENDING).exists():
        raise StaleStateError("checkpoint directory has a PENDING marker",
                              path=str(d))


def commit_dir(stage: Path, final: Path, crash: CrashInjector = NO_CRASH):
    """Atomic promotion of a fully-written staging dir."""
    assert (stage / MANIFEST).exists(), "commit without manifest"
    assert_not_pending(stage)
    crash.maybe("before_commit_rename")
    if final.exists():
        raise FileExistsError(final)
    os.rename(stage, final)
    crash.maybe("after_commit_rename")
    fsync_dir(final.parent)


def write_latest(root: Path, step: int, crash: CrashInjector = NO_CRASH):
    atomic_write_bytes(root / LATEST, str(step).encode(), crash)


def read_latest(root: Path):
    p = root / LATEST
    if not p.exists():
        return None
    try:
        return int(p.read_text().strip())
    except ValueError:
        return None


def list_committed_steps(root: Path) -> list:
    out = []
    if not root.exists():
        return out
    for d in root.iterdir():
        if d.is_dir() and d.name.startswith("step_") and ".tmp-" not in d.name \
                and (d / MANIFEST).exists() and not (d / PENDING).exists():
            try:
                out.append(int(d.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
    return sorted(out)


def gc_staging(root: Path):
    """Remove orphaned staging dirs (crash leftovers)."""
    import shutil
    n = 0
    if not root.exists():
        return 0
    for d in root.iterdir():
        if d.is_dir() and ".tmp-" in d.name:
            shutil.rmtree(d, ignore_errors=True)
            n += 1
    return n
