"""Bounded chunk-IO executor — the pipelining engine under the CAS hot path.

The PR-1 data path was strictly serial: each writer rank hashed and wrote
its chunks one at a time with a directory fsync per object, and restore
reassembled payloads chunk by chunk. On the storage hierarchies this system
targets (burst buffer + parallel filesystem) every one of those stages —
blake2b hashing, file writes, fsync, reads — releases the GIL or waits on
the kernel, so a small thread pool pipelines them almost for free.

``ChunkIOExecutor`` is deliberately tiny and deliberately *not* a bare
``ThreadPoolExecutor``:

  * ``map_ordered`` keeps a bounded in-flight window, so reassembling a
    multi-GiB payload never materialises every chunk's future (or buffer)
    at once — it is a prefetch pipeline, not a scatter-gather;
  * results are delivered **in item order** with an optional per-result
    callback, which is how writer ranks keep their coordinator keepalive
    heartbeat alive through a long batch;
  * an error (including an injected ``CrashPoint``) cancels the queue and
    **joins every in-flight call before re-raising** — no stray worker may
    still be writing objects while the caller's abort/GC path runs, or the
    crash matrix's post-crash fsck would race its own litter;
  * ``threads <= 1`` is a true serial mode that runs inline on the caller's
    thread — byte-for-byte the PR-1 behaviour, used as the benchmark
    baseline and available for debugging.

The pool is created lazily (a restore-only process that never touches a
chunked checkpoint spawns no threads) and torn down via ``shutdown()``.
"""
from __future__ import annotations

import os
import threading
from collections import deque
from concurrent.futures import (Future, ThreadPoolExecutor,
                                wait as futures_wait)

DEFAULT_IO_THREADS = 4


def cpu_cap() -> int:
    """Parallelism cap for CPU/bandwidth-bound stages (hash, crc, memcpy,
    cached reads): more threads than cores only adds contention there.
    Latency-bound stages (fsync, cold reads) are the ones that want the
    full io_threads width."""
    return max(os.cpu_count() or 2, 2)


class ChunkIOExecutor:
    def __init__(self, threads: int = DEFAULT_IO_THREADS):
        self.threads = max(int(threads), 1)
        self._pool: ThreadPoolExecutor | None = None
        self._lock = threading.Lock()

    @property
    def serial(self) -> bool:
        return self.threads <= 1

    # ------------------------------------------------------------------
    def _get_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.threads,
                    thread_name_prefix="chunk-io")
            return self._pool

    def map_ordered(self, fn, items, *, window: int | None = None,
                    on_result=None) -> list:
        """Apply ``fn`` to every item, returning results in item order.

        At most ``window`` calls are in flight (default ``2 × threads``).
        ``on_result`` is invoked on the caller's thread after each result is
        consumed, in order. On any exception — from ``fn`` or from
        ``on_result`` — pending calls are cancelled, in-flight calls are
        joined, and the first error re-raises: when this method exits, no
        submitted work is still running.
        """
        items = list(items)
        if self.serial or len(items) <= 1:
            out = []
            for it in items:
                out.append(fn(it))
                if on_result is not None:
                    on_result(out[-1])
            return out
        window = max(int(window or 2 * self.threads), 1)
        pool = self._get_pool()
        pending: deque = deque()
        out: list = []
        i = 0
        try:
            while i < len(items) or pending:
                while i < len(items) and len(pending) < window:
                    pending.append(pool.submit(fn, items[i]))
                    i += 1
                f = pending.popleft()
                out.append(f.result())
                if on_result is not None:
                    on_result(out[-1])
        except BaseException:
            for f in pending:
                f.cancel()
            futures_wait(list(pending))
            raise
        return out

    def submit(self, fn, *args) -> Future:
        """Raw pool submission for streaming callers (``save_path.
        SaveSession``) that manage their own in-flight window and
        consumption order. A serial executor runs the call inline and
        returns an already-resolved future, so callers need no branch."""
        if self.serial:
            f: Future = Future()
            try:
                f.set_result(fn(*args))
            except BaseException as e:  # noqa — future carries it
                f.set_exception(e)
            return f
        return self._get_pool().submit(fn, *args)

    def shutdown(self, wait: bool = True):
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=wait)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False
